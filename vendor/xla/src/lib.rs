//! Offline **stub** of the vendored `xla` PJRT bindings.
//!
//! The real build vendors a patched `xla-rs` (PJRT C-API client with
//! untupled executable outputs — see `rust/src/runtime/mod.rs`). Build
//! containers without a PJRT plugin use this stub instead: it provides
//! the exact API surface the crate consumes and fails loudly (an `Err`,
//! never UB or a panic) the moment anything touches PJRT, starting at
//! [`PjRtClient::cpu`].
//!
//! Everything that does not touch PJRT — the compiled serving router
//! (`router::plan` / `router::engine`), the dispatch simulator, the
//! metrics, the data pipeline — builds and runs against this stub, and
//! the PJRT-backed tests and benches self-skip when artifacts are
//! absent. Swap this directory for the patched xla-rs checkout to run
//! the training/repro paths.

use std::fmt;

/// Stub error type; call sites format it with `{:?}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable — this build uses the stub `xla` \
         crate (vendor/xla); vendor the patched xla-rs to enable the \
         runtime paths"
    )))
}

/// Element types transferable to/from device buffers.
pub trait NativeType: sealed::Sealed {}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct PjRtClient {
    _priv: (),
}

pub struct PjRtBuffer {
    _priv: (),
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

pub struct HloModuleProto {
    _priv: (),
}

pub struct XlaComputation {
    _priv: (),
}

pub struct Literal {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(
        _path: P,
    ) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

impl PjRtLoadedExecutable {
    /// Execute with device-resident args; replica-major untupled
    /// outputs (`[replica][output]`) in the patched crate.
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(format!("{err:?}").contains("PJRT unavailable"));
        assert!(err.to_string().contains("stub"));
    }
}
