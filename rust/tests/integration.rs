//! End-to-end integration over the real AOT artifacts (quickstart
//! preset): init determinism, device-resident training, eval
//! determinism, loss-weight patching, checkpoint round-trip, router
//! artifact execution, and the full execute_run path.
//!
//! PJRT handles are not `Send`, so everything runs as ONE sequential
//! test sharing a single client + compiled artifact set (compiles once).
//! Self-skips when artifacts are absent; `make test` builds them first.

use std::path::PathBuf;

use lpr::config::{execute_run, RunSpec};
use lpr::coordinator::{checkpoint, Trainer};
use lpr::data::{Batcher, ZipfMarkovCorpus};
use lpr::runtime::{CompiledArtifacts, Runtime};

struct Ctx {
    rt: Runtime,
    arts: CompiledArtifacts,
    art_dir: PathBuf,
}

fn batch(arts: &CompiledArtifacts, seed: u64) -> lpr::data::LmBatch {
    let (b, t) = arts.meta.batch_shape;
    let mut corpus = ZipfMarkovCorpus::standard(arts.meta.config.vocab, seed);
    Batcher::new(b, t).next_synthetic(&mut corpus)
}

#[test]
fn integration_suite() {
    let art_dir = lpr::default_art_dir();
    if !art_dir.join("quickstart.meta.json").exists() {
        eprintln!(
            "SKIP integration: no quickstart artifact in {} \
             (run `make artifacts`)",
            art_dir.display()
        );
        return;
    }
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            // artifacts exist but the PJRT plugin is absent/broken in
            // this environment (e.g. the offline stub build) — skip
            // with the reason rather than failing a tier the suite
            // cannot exercise here
            eprintln!("SKIP integration: no PJRT cpu client: {e}");
            return;
        }
    };
    let arts = CompiledArtifacts::load(&rt, &art_dir, "quickstart")
        .expect("compile quickstart artifacts");
    let c = Ctx { rt, arts, art_dir };

    init_is_deterministic_and_seed_sensitive(&c);
    train_step_learns_and_conserves_load(&c);
    eval_is_deterministic(&c);
    loss_weight_patches_change_training(&c);
    checkpoint_roundtrip_preserves_eval(&c);
    router_artifact_runs_and_confidence_in_range(&c);
    execute_run_produces_full_summary(&c);
}

fn init_is_deterministic_and_seed_sensitive(c: &Ctx) {
    let t1 = Trainer::new(&c.rt, &c.arts, 7, None).unwrap();
    let t2 = Trainer::new(&c.rt, &c.arts, 7, None).unwrap();
    let t3 = Trainer::new(&c.rt, &c.arts, 8, None).unwrap();
    let a = t1.params_to_host().unwrap();
    let b = t2.params_to_host().unwrap();
    let d = t3.params_to_host().unwrap();
    assert_eq!(a, b, "same seed must give identical params");
    assert_ne!(a, d, "different seed must give different params");
    // embed table std ~ 0.02 sanity (embed is the first leaf)
    let embed = &a[0];
    let m: f32 = embed.iter().sum::<f32>() / embed.len() as f32;
    let std = (embed.iter().map(|x| (x - m) * (x - m)).sum::<f32>()
        / embed.len() as f32)
        .sqrt();
    assert!((std - 0.02).abs() < 0.005, "embed std {std}");
    eprintln!("ok: init determinism");
}

fn train_step_learns_and_conserves_load(c: &Ctx) {
    let mut trainer = Trainer::new(&c.rt, &c.arts, 0, None).unwrap();
    let meta = &c.arts.meta;
    let b = batch(&c.arts, 11);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..10 {
        let m = trainer.train_step(&b).unwrap(); // same batch: memorize
        let loss = m.get(meta, "loss").unwrap();
        assert!(loss.is_finite());
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first - 0.2,
        "loss must drop on repeated batch: {first} -> {last}"
    );
    let (l, _e) = meta.load_shape;
    let expect = (l * meta.config.tokens_per_batch() * meta.config.top_k)
        as f64
        * trainer.step as f64;
    let total: f64 = trainer.load.counts.iter().sum();
    assert!((total - expect).abs() < 1e-3, "load {total} != {expect}");
    eprintln!("ok: train learns + load conserved");
}

fn eval_is_deterministic(c: &Ctx) {
    let trainer = Trainer::new(&c.rt, &c.arts, 3, None).unwrap();
    let mut c1 = ZipfMarkovCorpus::standard(c.arts.meta.config.vocab, 99);
    let mut c2 = ZipfMarkovCorpus::standard(c.arts.meta.config.vocab, 99);
    let e1 = trainer.evaluate(&mut c1, 2).unwrap();
    let e2 = trainer.evaluate(&mut c2, 2).unwrap();
    assert_eq!(e1.loss, e2.loss);
    assert_eq!(e1.load.counts, e2.load.counts);
    let lnv = (c.arts.meta.config.vocab as f64).ln();
    assert!((e1.loss - lnv).abs() < 1.0, "loss {} vs ln(V) {lnv}", e1.loss);
    eprintln!("ok: eval deterministic");
}

fn loss_weight_patches_change_training(c: &Ctx) {
    let b = batch(&c.arts, 5);
    let mut t_on = Trainer::new(&c.rt, &c.arts, 0, None).unwrap();
    let mut lw = c.arts.meta.default_loss_weights.clone();
    lw[0] = 0.0; // beta_rs = 0 kills the LPR regularizers
    let mut t_off = Trainer::new(&c.rt, &c.arts, 0, Some(lw)).unwrap();
    let m_on = t_on.train_step(&b).unwrap();
    let m_off = t_off.train_step(&b).unwrap();
    let meta = &c.arts.meta;
    assert_eq!(
        m_on.get(meta, "loss").unwrap(),
        m_off.get(meta, "loss").unwrap()
    );
    assert!(
        m_on.get(meta, "total_loss").unwrap()
            > m_off.get(meta, "total_loss").unwrap(),
        "regularizers must add mass"
    );
    eprintln!("ok: loss-weight patches");
}

fn checkpoint_roundtrip_preserves_eval(c: &Ctx) {
    let mut trainer = Trainer::new(&c.rt, &c.arts, 1, None).unwrap();
    let b = batch(&c.arts, 21);
    for _ in 0..3 {
        trainer.train_step(&b).unwrap();
    }
    let mut ec = ZipfMarkovCorpus::standard(c.arts.meta.config.vocab, 77);
    let before = trainer.evaluate(&mut ec, 2).unwrap();

    let dir = std::env::temp_dir().join("lpr-integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.ckpt");
    let state = trainer.state_to_host().unwrap();
    checkpoint::save(&path, "quickstart", trainer.step, &state).unwrap();

    let ck = checkpoint::load(&path).unwrap();
    assert_eq!(ck.step, 3);
    let mut restored = Trainer::new(&c.rt, &c.arts, 999, None).unwrap();
    restored.state_from_host(&ck.buffers).unwrap();
    let mut ec2 = ZipfMarkovCorpus::standard(c.arts.meta.config.vocab, 77);
    let after = restored.evaluate(&mut ec2, 2).unwrap();
    assert_eq!(before.loss, after.loss, "checkpoint must restore exactly");
    assert_eq!(before.load.counts, after.load.counts);
    eprintln!("ok: checkpoint roundtrip");
}

fn router_artifact_runs_and_confidence_in_range(c: &Ctx) {
    let trainer = Trainer::new(&c.rt, &c.arts, 0, None).unwrap();
    let conf = lpr::config::router_top1_confidence(&c.rt, &c.arts, &trainer)
        .unwrap();
    let k = c.arts.meta.config.top_k as f64;
    assert!(
        conf >= 1.0 / k - 1e-6 && conf <= 1.0 + 1e-6,
        "top-1 confidence {conf} outside [1/k, 1]"
    );
    eprintln!("ok: router artifact");
}

fn execute_run_produces_full_summary(c: &Ctx) {
    let spec = RunSpec::new("itest", "quickstart").steps(4);
    let s = execute_run(&c.rt, &c.art_dir, &spec, false).unwrap();
    assert_eq!(s.steps, 4);
    assert_eq!(s.loss_curve.len(), 4);
    assert!(s.test_loss.is_finite());
    assert!(s.gini >= 0.0 && s.gini <= 1.0);
    assert!(s.min_max >= 0.0 && s.min_max <= 1.0 + 1e-9);
    assert!(s.steps_per_s > 0.0);
    eprintln!("ok: execute_run summary");
}
