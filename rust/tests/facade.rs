//! Integration tests for the PR 5 public surface: the engine facade
//! (`Engine::builder()` as the one construction path) and the
//! wall-clock `serve::Server` front-end. Pure Rust — no artifacts, no
//! PJRT, so unlike `integration.rs` these never self-skip.

use std::time::Duration;

use lpr::dispatch::OverflowPolicy;
use lpr::engine::{Backend, Engine, MoeEngine};
use lpr::model::synthetic_stacked_model;
use lpr::serve::{Server, ServeConfig, ServeRuntime, SubmitError};
use lpr::util::rng::Rng;

const D: usize = 16;

fn model(layers: usize) -> lpr::model::StackedModel {
    synthetic_stacked_model("cosine", &Rng::new(3), layers, D, 8, 6, 2, 10)
}

/// The facade is one interface over both backends: identical outputs,
/// from the same builder calls, through the boxed trait object the
/// runtime consumes.
#[test]
fn one_builder_both_backends_bit_identical() {
    let mut rng = Rng::new(9);
    let h: Vec<f32> =
        (0..37 * D).map(|_| rng.normal() as f32).collect();
    let mut outs: Vec<Vec<f32>> = Vec::new();
    for backend in
        [Backend::Scoped { threads: 3 }, Backend::Pool { workers: 2 }]
    {
        let mut engine: Box<dyn MoeEngine> = Engine::builder()
            .model(model(3))
            .backend(backend)
            .policy(OverflowPolicy::NextChoice)
            .capacity_factor(1.0)
            .build()
            .expect("valid config")
            .into_inner();
        assert_eq!(engine.layers(), 3);
        assert_eq!(engine.d_model(), D);
        outs.push(engine.forward(&h, 37).hidden.to_vec());
    }
    assert_eq!(outs[0], outs[1]);
}

/// Acceptance: `serve::Server` round-trips a real-time request batch
/// end-to-end — wall-clock arrivals, background flushing, blocking
/// await — with a fixed service-time override keeping the service
/// accounting deterministic.
#[test]
fn server_round_trips_a_real_time_request_batch() {
    let engine = Engine::builder()
        .model(model(2))
        .backend(Backend::Pool { workers: 2 })
        .policy(OverflowPolicy::Drop)
        .capacity_factor(1.25)
        .build()
        .expect("valid config");
    let cfg = ServeConfig {
        max_batch: 32,
        max_wait: 2_000, // age-flush a partial batch after 2ms
        queue_tokens: 256,
        service_ticks: Some(25),
        ..ServeConfig::default()
    };
    let server = Server::with_poll_interval(
        ServeRuntime::with_engine(engine.into_inner(), cfg),
        Duration::from_micros(200),
    );
    // an oversized request is refused with the typed error up front
    assert_eq!(
        server.enqueue(&vec![0.0f32; 33 * D]),
        Err(SubmitError::TooLarge)
    );
    let mut rng = Rng::new(4);
    let mut ids = Vec::new();
    for _ in 0..6 {
        let h: Vec<f32> =
            (0..4 * D).map(|_| rng.normal() as f32).collect();
        ids.push(server.enqueue(&h).expect("queue has room"));
    }
    for &id in &ids {
        let c = server.await_completion(id);
        assert_eq!(c.n_tokens, 4);
        // latency includes at least the fixed service override
        assert!(c.latency >= 25, "latency {} < service 25", c.latency);
    }
    let report = server.shutdown();
    assert_eq!(report.requests, 6);
    assert_eq!(report.tokens, 24);
    assert_eq!(report.rejected, 0);
    assert!(report.batches >= 1);
    assert!(report.latency_p99_us >= report.latency_p50_us);
}
