//! Admission under overload, and the TCP framing layer over real
//! loopback sockets.
//!
//! The overload test drives the virtual-clock [`AdmittedRuntime`] at
//! 2x its (deterministic, `service_ticks`-pinned) capacity with a
//! best-effort-heavy mix and pins the contract the admission layer
//! sells: the best-effort lane absorbs >= 90% of the shedding, the
//! priority lane keeps a bounded p99, and `admitted + rejected`
//! conserves submissions exactly.
//!
//! The framing tests run a wall-clock [`Server`] behind a
//! [`NetServer`] on `127.0.0.1:0` and exercise the wire the way real
//! peers do: byte-split writes, two frames coalesced into one write,
//! malformed-but-framed requests (connection survives), an oversized
//! frame (typed refusal, then close), a half-written frame cut by the
//! client, and the HTTP-shaped wire's 503 mapping.

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use lpr::data::MixtureStream;
use lpr::dispatch::OverflowPolicy;
use lpr::engine::{Backend, Engine, MoeEngine};
use lpr::experts::ExpertBank;
use lpr::router::synthetic_lpr_router;
use lpr::serve::{
    run_admitted_open_loop, AdmissionConfig, AdmittedRuntime, HttpWire,
    LengthPrefixed, NetServer, RequestMeta, Server, ServeConfig,
    ServeRuntime, Status,
};
use lpr::util::rng::Rng;

/// Build the small single-layer pool engine the socket tests serve.
fn small_engine(
    d: usize,
    dz: usize,
    e: usize,
    k: usize,
    d_ff: usize,
) -> Box<dyn MoeEngine> {
    let mut rng = Rng::new(23);
    let router = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
    let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
    Engine::builder()
        .layer(router.plan().clone(), bank)
        .backend(Backend::Scoped { threads: 1 })
        .policy(OverflowPolicy::Drop)
        .capacity_factor(1.25)
        .build()
        .expect("valid engine config")
        .into_inner()
}

/// 2x overload, 3:1 best-effort-heavy traffic: best-effort sheds,
/// priority holds. Deterministic — the virtual clock and the pinned
/// `service_ticks` make capacity exact, not measured.
#[test]
fn two_x_overload_sheds_best_effort_and_bounds_priority_p99() {
    let (d, dz, e, k, d_ff) = (32usize, 16, 32, 4, 64);
    let (max_batch, req_tokens, n_requests) = (64usize, 8usize, 600usize);
    let mut rng = Rng::new(23);
    let router = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
    let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
    let mix = MixtureStream::skewed(&mut rng, d, 1.6);
    let engine = Engine::builder()
        .layer(router.plan().clone(), bank)
        .backend(Backend::Pool { workers: 2 })
        .policy(OverflowPolicy::Drop)
        .capacity_factor(1.25)
        .build()
        .expect("valid engine config");
    let cfg = ServeConfig {
        max_batch,
        max_wait: 200,
        queue_tokens: 8 * max_batch,
        service_ticks: Some(500),
        ..ServeConfig::default()
    };
    let config = AdmissionConfig::parse(
        "lane priority\n  path_prefix /priority\n  quota 512\n\
         \x20 weight 8\n  max_wait 200\nlane best-effort\n\
         \x20 quota 128\n  max_wait 200\n",
    )
    .expect("two-lane overload config parses");
    let adm = config
        .compile(d, max_batch)
        .expect("two-lane overload config compiles");
    let metas = {
        let prio = config.lanes[0].example_meta();
        let best = config.lanes[1].example_meta();
        [prio, best.clone(), best.clone(), best]
    };
    let mut rt = AdmittedRuntime::new(engine.into_inner(), cfg, adm);
    // every batch takes exactly 500 ticks (1 tick = 1 us), so capacity
    // is max_batch / 500 us = 128k tok/s; offer twice that
    let cap_tok_s = max_batch as f64 / 500e-6;
    run_admitted_open_loop(
        &mut rt,
        &mix,
        &mut rng,
        &metas,
        n_requests,
        req_tokens,
        2.0 * cap_tok_s,
    );
    let rep = rt.report();
    assert_eq!(rep.lanes.len(), 2);
    let (pri, best) = (&rep.lanes[0], &rep.lanes[1]);
    assert_eq!(pri.name, "priority");
    assert_eq!(best.name, "best-effort");
    // conservation: every submission is admitted or rejected, exactly
    let admitted = pri.admitted + best.admitted;
    let rejected = pri.rejected + best.rejected;
    assert_eq!(
        admitted + rejected,
        n_requests,
        "admitted {admitted} + rejected {rejected} must conserve \
         submissions"
    );
    // the drain at the end of the open loop completes every admission
    assert_eq!(pri.completed, pri.admitted);
    assert_eq!(best.completed, best.admitted);
    assert_eq!(pri.queue_depth_tokens, 0);
    assert_eq!(best.queue_depth_tokens, 0);
    // 2x offered load must actually shed, and best-effort absorbs it:
    // >= 90% of all rejections land on the best-effort lane
    assert!(rejected > 0, "2x overload produced no shedding at all");
    assert!(
        best.rejected * 10 >= rejected * 9,
        "best-effort absorbed {} of {} rejections (< 90%)",
        best.rejected,
        rejected
    );
    // the priority lane keeps completing (it sheds at most 10% of its
    // own traffic) and its p99 stays bounded by its own quota backlog
    // (8 batches) plus the best-effort quota in flight — far below
    // the unbounded queueing a shared queue shows
    assert!(
        pri.rejected * 10 <= pri.admitted,
        "priority shed {} of {} admitted",
        pri.rejected,
        pri.admitted
    );
    assert!(
        pri.latency_p99_us <= 8_000.0,
        "priority p99 {} us exceeds the 8000 us bound",
        pri.latency_p99_us
    );
}

/// A wall-clock `Server` + `NetServer` over loopback, plus the bound
/// address. `max_wait` 2 ms so sub-batch requests age-flush quickly.
fn start_net<W: lpr::serve::Wire>(
    d: usize,
    wire: W,
) -> (NetServer, Arc<Server>) {
    let engine = small_engine(d, 4, 8, 2, 16);
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: 2_000,
        queue_tokens: 64,
        service_ticks: Some(1),
        ..ServeConfig::default()
    };
    let rt = ServeRuntime::with_engine(engine, cfg);
    let server = Arc::new(Server::start(rt));
    let net = NetServer::start(server.clone(), "127.0.0.1:0", wire)
        .expect("bind loopback");
    (net, server)
}

fn stop_net(net: NetServer, server: Arc<Server>) {
    net.shutdown();
    Arc::try_unwrap(server)
        .ok()
        .expect("net server released its handle")
        .shutdown();
}

const D: usize = 8;

/// Byte-split and coalesced writes both frame correctly, a malformed
/// (but well-framed) request answers 400 and keeps the connection,
/// and the stream resyncs onto the next request.
#[test]
fn length_prefixed_survives_split_and_coalesced_writes() {
    let (net, server) = start_net(D, LengthPrefixed::default());
    let mut s =
        TcpStream::connect(net.addr()).expect("connect loopback");
    s.set_nodelay(true).ok();

    // one request, written three bytes at a time
    let frame = LengthPrefixed::encode_request(
        &RequestMeta::default(),
        &vec![0.25f32; 2 * D],
    );
    for chunk in frame.chunks(3) {
        s.write_all(chunk).expect("split write");
        s.flush().expect("flush");
    }
    let r = LengthPrefixed::read_response(&mut s).expect("response");
    assert_eq!(r.status, Status::Ok);
    assert_eq!(r.n_tokens, 2);

    // two requests coalesced into a single write
    let mut two = LengthPrefixed::encode_request(
        &RequestMeta::default(),
        &vec![0.5f32; D],
    );
    two.extend_from_slice(&LengthPrefixed::encode_request(
        &RequestMeta::default(),
        &vec![-0.5f32; D],
    ));
    s.write_all(&two).expect("coalesced write");
    let r1 = LengthPrefixed::read_response(&mut s).expect("first");
    let r2 = LengthPrefixed::read_response(&mut s).expect("second");
    assert_eq!(r1.status, Status::Ok);
    assert_eq!(r2.status, Status::Ok);
    assert_ne!(r1.id, r2.id, "each request gets its own id");

    // a well-framed request whose activations are not a whole number
    // of d_model rows: 400, but the connection keeps serving
    let bad = LengthPrefixed::encode_request(
        &RequestMeta::default(),
        &vec![1.0f32; 3],
    );
    s.write_all(&bad).expect("bad-shape write");
    let r = LengthPrefixed::read_response(&mut s).expect("reject");
    assert_eq!(r.status, Status::BadFrame);
    let again = LengthPrefixed::encode_request(
        &RequestMeta::default(),
        &vec![0.125f32; D],
    );
    s.write_all(&again).expect("recovery write");
    let r = LengthPrefixed::read_response(&mut s).expect("recovery");
    assert_eq!(r.status, Status::Ok);

    drop(s);
    stop_net(net, server);
}

/// Keep-alive request cap: a connection serves exactly N responses —
/// each fully flushed — then closes gracefully; a fresh connection is
/// unaffected (the cap is per-connection, not per-server).
#[test]
fn keep_alive_cap_closes_after_n_requests() {
    let engine = small_engine(D, 4, 8, 2, 16);
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: 2_000,
        queue_tokens: 64,
        service_ticks: Some(1),
        ..ServeConfig::default()
    };
    let rt = ServeRuntime::with_engine(engine, cfg);
    let server = Arc::new(Server::start(rt));
    let net = NetServer::start_with_limit(
        server.clone(),
        "127.0.0.1:0",
        LengthPrefixed::default(),
        Some(2),
    )
    .expect("bind loopback");

    let mut s =
        TcpStream::connect(net.addr()).expect("connect loopback");
    s.set_nodelay(true).ok();
    let frame = LengthPrefixed::encode_request(
        &RequestMeta::default(),
        &vec![0.25f32; D],
    );
    // two requests coalesced into one write: both are answered
    let mut two = frame.clone();
    two.extend_from_slice(&frame);
    s.write_all(&two).expect("write first two");
    let r1 = LengthPrefixed::read_response(&mut s).expect("first");
    let r2 = LengthPrefixed::read_response(&mut s).expect("second");
    assert_eq!(r1.status, Status::Ok);
    assert_eq!(r2.status, Status::Ok);
    // the capped connection is now closed: a third request never gets
    // a response
    let _ = s.write_all(&frame);
    let _ = s.flush();
    assert!(
        LengthPrefixed::read_response(&mut s).is_err(),
        "connection must close after its 2-request cap"
    );
    drop(s);

    // a new connection gets its own budget
    let mut s2 =
        TcpStream::connect(net.addr()).expect("reconnect loopback");
    s2.write_all(&frame).expect("write on fresh connection");
    let r = LengthPrefixed::read_response(&mut s2).expect("fresh");
    assert_eq!(r.status, Status::Ok);
    drop(s2);
    stop_net(net, server);
}

/// An oversized declared frame gets a typed 413-style refusal and the
/// connection closes (the stream cannot be resynced past it).
#[test]
fn oversized_frame_is_refused_then_closed() {
    let (net, server) =
        start_net(D, LengthPrefixed { max_frame: 256 });
    let mut s =
        TcpStream::connect(net.addr()).expect("connect loopback");
    s.write_all(&100_000u32.to_le_bytes()).expect("prefix write");
    let r = LengthPrefixed::read_response(&mut s).expect("refusal");
    assert_eq!(r.status, Status::TooLarge);
    assert!(
        LengthPrefixed::read_response(&mut s).is_err(),
        "server must close after an oversized frame"
    );
    drop(s);
    stop_net(net, server);
}

/// A client that dies mid-frame gets a best-effort 400 and a clean
/// close — no hang, no partial request reaching the engine.
#[test]
fn half_written_frame_then_close_is_answered_and_dropped() {
    let (net, server) = start_net(D, LengthPrefixed::default());
    let mut s =
        TcpStream::connect(net.addr()).expect("connect loopback");
    // declare 64 payload bytes, deliver 10, hang up
    s.write_all(&64u32.to_le_bytes()).expect("prefix write");
    s.write_all(&[0u8; 10]).expect("partial payload");
    s.shutdown(Shutdown::Write).expect("half-close");
    let r = LengthPrefixed::read_response(&mut s).expect("refusal");
    assert_eq!(r.status, Status::BadFrame);
    assert_eq!(
        server.report().requests,
        0,
        "no partial request may be admitted"
    );
    drop(s);
    stop_net(net, server);
}

/// The HTTP-shaped wire round-trips, maps admission refusals to 503
/// with the typed `x-status` header, and keeps the connection across
/// refusals.
#[test]
fn http_wire_round_trips_and_maps_refusals_to_503() {
    let engine = small_engine(D, 4, 8, 2, 16);
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: 2_000,
        queue_tokens: 64,
        service_ticks: Some(1),
        ..ServeConfig::default()
    };
    // one /hi lane, no catch-all: everything else is a typed 503
    let config =
        AdmissionConfig::parse("lane hi\n  path_prefix /hi\n  quota 8\n")
            .expect("single-lane config parses");
    let adm = config.compile(D, 8).expect("single-lane config compiles");
    let rt = ServeRuntime::with_engine(engine, cfg);
    let server = Arc::new(Server::with_admission(
        rt,
        adm,
        Duration::from_micros(200),
    ));
    let net = NetServer::start(
        server.clone(),
        "127.0.0.1:0",
        HttpWire::default(),
    )
    .expect("bind loopback");
    let mut s =
        TcpStream::connect(net.addr()).expect("connect loopback");

    let body: Vec<u8> = vec![0.5f32; D]
        .iter()
        .flat_map(|x| x.to_le_bytes())
        .collect();
    let mut req = format!(
        "POST /hi/generate HTTP/1.1\r\nx-tenant: acme\r\n\
         x-priority: 7\r\ncontent-length: {}\r\n\r\n",
        body.len()
    )
    .into_bytes();
    req.extend_from_slice(&body);
    s.write_all(&req).expect("http request");
    let r = HttpWire::read_response(&mut s).expect("http response");
    assert_eq!(r.status, Status::Ok);
    assert_eq!(r.n_tokens, 1);

    // no lane matches /nowhere: explicit 503, connection survives
    let miss = format!(
        "POST /nowhere HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
        body.len()
    );
    s.write_all(miss.as_bytes()).expect("miss head");
    s.write_all(&body).expect("miss body");
    let r = HttpWire::read_response(&mut s).expect("miss response");
    assert_eq!(r.status, Status::NoRoute);
    assert_eq!(r.status.http_code().0, 503);

    // and the connection still serves after the refusal
    s.write_all(&req).expect("http request after 503");
    let r = HttpWire::read_response(&mut s).expect("post-503 response");
    assert_eq!(r.status, Status::Ok);

    drop(s);
    net.shutdown();
    let rep = Arc::try_unwrap(server)
        .ok()
        .expect("net server released its handle")
        .shutdown();
    assert_eq!(rep.requests, 2);
    assert_eq!(rep.rejected, 1);
    assert_eq!(rep.lanes.len(), 1);
    assert_eq!(rep.lanes[0].admitted, 2);
    assert_eq!(rep.lanes[0].rejected, 1);
}
