//! Decode-path acceptance: cached token-at-a-time decode is **bitwise
//! identical** to full-sequence prefill, across thread counts, both
//! backends, and arbitrary ragged splits — the determinism contract of
//! the autoregressive subsystem (`model::attention`, `model::cache`,
//! `engine::decode`).
//!
//! Every engine here is built with `capacity_factor = n_experts`, the
//! no-drop configuration: dispatch bins scale with batch size, so only
//! a configuration that admits every token is batch-invariant. With
//! drops possible, a token's routing could depend on which other rows
//! share its forward — and prefill-vs-decode parity would be off the
//! table by construction, not by bug.
//!
//! The generation golden is self-contained: an independent no-cache
//! reference (re-prefill the whole prefix every step, no `KvCache`, no
//! `DecodeSession`) pins what greedy generation must produce, and the
//! continuous-batching session must match it bitwise on every backend.

use lpr::engine::{Backend, DecodeSession, Engine, GenRequest, MoeEngine};
use lpr::model::cache::{KvCache, SeqSpan};
use lpr::model::{synthetic_decoder_model, DecoderModel};
use lpr::util::rng::Rng;

const L: usize = 2;
const D: usize = 16;
const DZ: usize = 8;
const E: usize = 6;
const K: usize = 2;
const FF: usize = 10;
const H: usize = 4;
const V: usize = 32;

fn decoder(seed: u64) -> DecoderModel {
    synthetic_decoder_model(
        "cosine",
        &Rng::new(seed),
        L,
        D,
        DZ,
        E,
        K,
        FF,
        H,
        V,
    )
}

/// A fresh engine over the seed's model on the given backend, built
/// with the no-drop capacity factor.
fn engine(seed: u64, backend: Backend) -> Engine {
    let (model, _head) = decoder(seed).into_parts();
    Engine::builder()
        .model(model)
        .backend(backend)
        .capacity_factor(E as f64)
        .build()
        .expect("engine builds")
}

/// Run `h` through the engine in ragged `chunks` via the cached
/// sequence path, concatenating the output rows.
fn decode_chunked(eng: &mut Engine, h: &[f32], chunks: &[usize]) -> Vec<f32> {
    assert_eq!(chunks.iter().sum::<usize>(), h.len() / D);
    let mut cache = KvCache::new(1, eng.layers(), D, h.len() / D);
    let slot = cache.alloc().expect("slot");
    let mut got = Vec::new();
    let mut off = 0;
    for &c in chunks {
        let rows = &h[off * D..(off + c) * D];
        let out =
            eng.forward_seqs(rows, &[SeqSpan { slot, n_tokens: c }], &mut cache);
        got.extend_from_slice(out.hidden);
        off += c;
    }
    assert_eq!(cache.len(slot), h.len() / D);
    got
}

/// Property: for random stacks and activations, every split of the
/// sequence — full prefill, token-at-a-time, ragged — produces the
/// prefill's hidden states bit-for-bit, on scoped and pool backends
/// across thread counts {1, 2, 3, 8}.
#[test]
fn decode_is_bitwise_prefill_across_backends_and_threads() {
    let t = 9usize;
    for seed in [5u64, 29] {
        let h: Vec<f32> = {
            let mut rng = Rng::new(seed ^ 0xfeed);
            (0..t * D).map(|_| rng.normal() as f32 * 0.5).collect()
        };
        let want = {
            let mut oracle = engine(seed, Backend::Scoped { threads: 1 });
            oracle.forward(&h, t).hidden.to_vec()
        };
        let ones = vec![1usize; t];
        let ragged = vec![4usize, 1, 1, 3];
        let mixed = vec![2usize, 5, 2];
        for threads in [1usize, 2, 3, 8] {
            for backend in [
                Backend::Scoped { threads },
                Backend::Pool { workers: threads },
            ] {
                let mut eng = engine(seed, backend);
                // full-sequence prefill through the cache path
                let full = decode_chunked(&mut eng, &h, &[t]);
                assert_eq!(full, want, "prefill seed={seed} {backend:?}");
                for chunks in [&ones, &ragged, &mixed] {
                    let mut eng = engine(seed, backend);
                    let got = decode_chunked(&mut eng, &h, chunks);
                    assert_eq!(
                        got, want,
                        "seed={seed} {backend:?} chunks={chunks:?}"
                    );
                }
            }
        }
    }
}

/// The independent no-cache greedy reference: every step re-prefills
/// the whole token prefix through a **fresh** engine and takes the
/// argmax of the last row — no `KvCache`, no session, no shared state
/// with the code under test.
fn greedy_reference(
    seed: u64,
    prompt: &[usize],
    max_new: usize,
) -> Vec<usize> {
    let head = decoder(seed).into_parts().1;
    let mut toks = prompt.to_vec();
    let mut out = Vec::new();
    let mut scratch = Vec::new();
    let mut h = Vec::new();
    for _ in 0..max_new {
        let mut eng = engine(seed, Backend::Scoped { threads: 1 });
        head.embed_tokens(&toks, &mut h);
        let fwd = eng.forward(&h, toks.len());
        let last = fwd.token_row(toks.len() - 1);
        let tok = head.greedy_next(last, &mut scratch);
        out.push(tok);
        toks.push(tok);
    }
    out
}

/// Golden generation: the session's cached continuous-batching decode
/// reproduces the no-cache reference bitwise (same argmax at every
/// step), identically on every backend and thread count — and twice in
/// a row on the same session (slot reuse does not leak state).
#[test]
fn generation_matches_no_cache_reference_on_all_backends() {
    let seed = 11u64;
    let prompt = vec![3usize, 1, 4, 1, 5];
    let max_new = 8usize;
    let golden = greedy_reference(seed, &prompt, max_new);
    assert_eq!(golden.len(), max_new);
    assert!(golden.iter().all(|&t| t < V));

    for backend in [
        Backend::Scoped { threads: 1 },
        Backend::Scoped { threads: 3 },
        Backend::Scoped { threads: 8 },
        Backend::Pool { workers: 2 },
        Backend::Pool { workers: 8 },
    ] {
        let (model, head) = decoder(seed).into_parts();
        let eng = Engine::builder()
            .model(model)
            .backend(backend)
            .capacity_factor(E as f64)
            .build()
            .expect("engine builds");
        let mut sess = DecodeSession::new(eng, head, 2, 32);
        sess.submit(GenRequest { prompt: prompt.clone(), max_new })
            .expect("submit");
        let stats = sess.run_to_idle();
        assert!(
            stats.iter().all(|s| s.n_dropped == 0),
            "no-drop config must never drop"
        );
        let fin = sess.take_finished();
        assert_eq!(fin[0].tokens, golden, "{backend:?}");

        // second pass on the same session: freed slot, same output
        sess.submit(GenRequest { prompt: prompt.clone(), max_new })
            .expect("resubmit");
        sess.run_to_idle();
        assert_eq!(
            sess.take_finished()[0].tokens,
            golden,
            "slot reuse {backend:?}"
        );
        assert_eq!(sess.cache().n_live(), 0);
    }
}

/// Join-timing invariance: whether a second request is submitted
/// up-front or only after the first has generated half its budget, both
/// sequences produce their solo outputs — batching composition never
/// leaks between sequences.
#[test]
fn join_timing_does_not_change_any_sequence() {
    let seed = 47u64;
    let pa = vec![7usize, 7, 2, 9];
    let pb = vec![1usize, 30];
    let ga = greedy_reference(seed, &pa, 5);
    let gb = greedy_reference(seed, &pb, 5);

    let session = |sub_b_at: Option<usize>| {
        let (model, head) = decoder(seed).into_parts();
        let eng = Engine::builder()
            .model(model)
            .backend(Backend::Pool { workers: 3 })
            .capacity_factor(E as f64)
            .build()
            .expect("engine builds");
        let mut sess = DecodeSession::new(eng, head, 2, 32);
        let ida = sess
            .submit(GenRequest { prompt: pa.clone(), max_new: 5 })
            .unwrap();
        let mut idb = None;
        match sub_b_at {
            None => {
                idb = Some(
                    sess.submit(GenRequest { prompt: pb.clone(), max_new: 5 })
                        .unwrap(),
                );
            }
            Some(steps) => {
                for _ in 0..steps {
                    let _ = sess.step();
                }
                idb = Some(
                    sess.submit(GenRequest { prompt: pb.clone(), max_new: 5 })
                        .unwrap(),
                );
            }
        }
        sess.run_to_idle();
        let fin = sess.take_finished();
        let a = fin.iter().find(|f| f.id == ida).unwrap().tokens.clone();
        let b = fin
            .iter()
            .find(|f| Some(f.id) == idb)
            .unwrap()
            .tokens
            .clone();
        (a, b)
    };

    for timing in [None, Some(1), Some(3)] {
        let (a, b) = session(timing);
        assert_eq!(a, ga, "sequence A, join timing {timing:?}");
        assert_eq!(b, gb, "sequence B, join timing {timing:?}");
    }
}

/// Slot lifecycle under more requests than slots: three requests on a
/// two-slot cache all finish, FIFO admission holds, and every slot is
/// back in the free pool at idle.
#[test]
fn oversubscribed_slots_drain_fifo() {
    let (model, head) = decoder(3).into_parts();
    let eng = Engine::builder()
        .model(model)
        .backend(Backend::Scoped { threads: 2 })
        .capacity_factor(E as f64)
        .build()
        .expect("engine builds");
    let mut sess = DecodeSession::new(eng, head, 2, 16);
    let ids: Vec<u64> = [(vec![1usize, 2], 4), (vec![3usize], 2), (vec![4usize, 5, 6], 3)]
        .into_iter()
        .map(|(prompt, max_new)| {
            sess.submit(GenRequest { prompt, max_new }).unwrap()
        })
        .collect();
    let stats = sess.run_to_idle();
    assert!(stats.iter().any(|s| s.n_seqs == 2), "work must overlap");
    let fin = sess.take_finished();
    assert_eq!(fin.len(), 3);
    // ids come back exactly once each; the two-slot cache forces the
    // third request to wait for a freed slot, so completion order is
    // admission order for same-budget work
    let mut seen: Vec<u64> = fin.iter().map(|f| f.id).collect();
    seen.sort_unstable();
    assert_eq!(seen, ids);
    assert_eq!(sess.cache().n_live(), 0);
    assert!(sess.is_idle());
}
