//! Fixture-driven conformance suite for the admission front-end.
//!
//! Every `rust/tests/fixtures/admission/*.txt` file declares a lane
//! config plus either a `[cases]` table of `(path, headers) ->
//! expected lane | reject` rows or an `[error]` section naming the
//! typed validation error the config must die with. Each case is
//! driven through BOTH the compiled matcher
//! (`Admission::classify`) and the naive first-match reference
//! (`Admission::classify_reference`), so adding a fixture file is
//! adding a test — no Rust edits needed.
//!
//! Failures print one `FIXTURE FAIL <file>: ...` line per defect (CI
//! greps these into the job summary) and the test asserts at the end,
//! so a broken fixture reports every bad case at once.
//!
//! Fixture format:
//!
//! ```text
//! # comments anywhere
//! [lanes]
//! lane api
//!   path /v1/generate
//!   quota 64
//! lane rest
//!   quota 64
//!
//! [cases]
//! /v1/generate => api
//! /other tenant=acme priority=9 => rest
//! /nothing/matches => reject        # only without a catch-all lane
//!
//! [error]          # instead of [cases], for malformed configs
//! duplicate-lane   # AdmissionError::code() string
//! ```

use std::path::PathBuf;

use lpr::serve::{AdmissionConfig, RequestMeta};

/// The geometry every fixture compiles against. Quotas in valid
/// fixtures must be >= MAX_BATCH or validation refuses them.
const D_MODEL: usize = 4;
const MAX_BATCH: usize = 32;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/admission")
}

fn fixture_files() -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(fixture_dir())
        .expect("fixture directory exists")
        .map(|e| e.expect("readable dir entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "txt"))
        .collect();
    files.sort();
    files
}

/// One parsed fixture: the lane config text, the case table, and the
/// expected error code for malformed-config fixtures.
struct Fixture {
    lanes: String,
    cases: Vec<(String, RequestMeta, Option<String>)>,
    error: Option<String>,
}

fn parse_case(
    line: &str,
) -> Result<(RequestMeta, Option<String>), String> {
    let (lhs, rhs) = line
        .split_once("=>")
        .ok_or_else(|| "case line missing `=>`".to_string())?;
    let expect = rhs.trim();
    let expect = if expect == "reject" {
        None
    } else {
        Some(expect.to_string())
    };
    let mut toks = lhs.split_whitespace();
    let mut meta = RequestMeta {
        path: toks
            .next()
            .ok_or_else(|| "case line missing path".to_string())?
            .to_string(),
        ..RequestMeta::default()
    };
    for t in toks {
        if let Some(v) = t.strip_prefix("tenant=") {
            meta.tenant = Some(v.to_string());
        } else if let Some(v) = t.strip_prefix("priority=") {
            meta.priority = v
                .parse()
                .map_err(|_| format!("bad priority `{v}`"))?;
        } else {
            return Err(format!("unknown case token `{t}`"));
        }
    }
    Ok((meta, expect))
}

fn parse_fixture(text: &str) -> Result<Fixture, String> {
    let mut section = "";
    let mut fx = Fixture {
        lanes: String::new(),
        cases: Vec::new(),
        error: None,
    };
    for raw in text.lines() {
        let line = raw.trim();
        match line {
            "[lanes]" | "[cases]" | "[error]" => {
                section = line;
                continue;
            }
            _ => {}
        }
        match section {
            "[lanes]" => {
                // keep raw so lane-config comments stay line-accurate
                fx.lanes.push_str(raw);
                fx.lanes.push('\n');
            }
            "[cases]" => {
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                let (meta, expect) = parse_case(line)?;
                fx.cases.push((line.to_string(), meta, expect));
            }
            "[error]" => {
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                if fx.error.is_some() {
                    return Err(
                        "multiple [error] codes".to_string()
                    );
                }
                fx.error = Some(line.to_string());
            }
            _ => {
                if !(line.is_empty() || line.starts_with('#')) {
                    return Err(format!(
                        "content before any section: `{line}`"
                    ));
                }
            }
        }
    }
    if fx.error.is_some() == !fx.cases.is_empty() {
        return Err(
            "fixture needs exactly one of [cases] or [error]"
                .to_string(),
        );
    }
    Ok(fx)
}

/// Run one fixture; returns one message per defect (empty = pass).
fn run_fixture(fx: &Fixture) -> Vec<String> {
    let mut fails = Vec::new();
    let parsed = AdmissionConfig::parse(&fx.lanes);
    if let Some(want) = &fx.error {
        // malformed-config fixture: parse or validation must die with
        // the declared typed error, and compile must agree
        let got = match parsed {
            Err(e) => Some(e),
            Ok(config) => config.validate(MAX_BATCH).err(),
        };
        match got {
            None => fails.push(format!(
                "expected error `{want}` but config was accepted"
            )),
            Some(e) if e.code() != want => fails.push(format!(
                "expected error `{want}`, got `{}` ({e})",
                e.code()
            )),
            Some(_) => {}
        }
        return fails;
    }
    let config = match parsed {
        Ok(c) => c,
        Err(e) => {
            fails.push(format!("config failed to parse: {e}"));
            return fails;
        }
    };
    let adm = match config.compile(D_MODEL, MAX_BATCH) {
        Ok(a) => a,
        Err(e) => {
            fails.push(format!("config failed to compile: {e}"));
            return fails;
        }
    };
    for (line, meta, expect) in &fx.cases {
        let want = match expect {
            None => None,
            Some(name) => {
                let Some(i) = config
                    .lanes
                    .iter()
                    .position(|l| l.name == *name)
                else {
                    fails.push(format!(
                        "case `{line}` names unknown lane `{name}`"
                    ));
                    continue;
                };
                Some(i)
            }
        };
        let compiled = adm.classify(meta);
        let reference = adm.classify_reference(meta);
        if compiled != want {
            fails.push(format!(
                "case `{line}`: compiled matcher chose {:?}, \
                 expected {:?}",
                compiled.map(|i| &config.lanes[i].name),
                expect.as_ref()
            ));
        }
        if reference != want {
            fails.push(format!(
                "case `{line}`: reference matcher chose {:?}, \
                 expected {:?}",
                reference.map(|i| &config.lanes[i].name),
                expect.as_ref()
            ));
        }
    }
    fails
}

/// Every fixture passes the parser, validator, compiled matcher, and
/// naive reference matcher; all defects across all fixtures are
/// reported in one run.
#[test]
fn every_fixture_passes_both_matchers() {
    let mut fails = Vec::new();
    for path in fixture_files() {
        let file = path
            .file_name()
            .expect("fixture has a file name")
            .to_string_lossy()
            .into_owned();
        let text = std::fs::read_to_string(&path)
            .expect("fixture file is readable");
        match parse_fixture(&text) {
            Err(e) => fails.push(format!("FIXTURE FAIL {file}: {e}")),
            Ok(fx) => {
                for msg in run_fixture(&fx) {
                    fails.push(format!("FIXTURE FAIL {file}: {msg}"));
                }
            }
        }
    }
    for f in &fails {
        println!("{f}");
    }
    assert!(
        fails.is_empty(),
        "{} fixture defect(s); see FIXTURE FAIL lines above",
        fails.len()
    );
}

/// Guard against the suite silently testing nothing: the fixture set
/// must exercise lane cases, explicit rejects, and malformed configs.
#[test]
fn fixture_set_is_populated() {
    let mut n_valid = 0usize;
    let mut n_error = 0usize;
    let mut n_reject_cases = 0usize;
    for path in fixture_files() {
        let text = std::fs::read_to_string(&path)
            .expect("fixture file is readable");
        let fx = parse_fixture(&text).expect("fixture parses");
        if fx.error.is_some() {
            n_error += 1;
        } else {
            n_valid += 1;
            assert!(
                !fx.cases.is_empty(),
                "valid fixture {} has no cases",
                path.display()
            );
            n_reject_cases +=
                fx.cases.iter().filter(|c| c.2.is_none()).count();
        }
    }
    assert!(n_valid >= 5, "want >= 5 valid fixtures, have {n_valid}");
    assert!(
        n_error >= 4,
        "want >= 4 malformed-config fixtures, have {n_error}"
    );
    assert!(
        n_reject_cases >= 1,
        "no fixture case exercises an explicit reject"
    );
}
