//! Load-balance metrics — paper §3.1 eq.25 (Gini) and eq.26 (min-max),
//! plus normalized entropy and coefficient of variation.
//!
//! Mirrors `python/compile/metrics.py`; the two implementations are
//! cross-checked against `artifacts/goldens/metrics.json` in the
//! integration tests (`rust/tests/goldens.rs`).

pub const EPS: f64 = 1e-9;

/// Gini coefficient of an expert-load vector. 0 = perfectly balanced,
/// (n-1)/n = all load on one expert.
pub fn gini(load: &[f32]) -> f64 {
    let n = load.len();
    if n == 0 {
        return 0.0;
    }
    let mut x: Vec<f64> = load.iter().map(|&v| v as f64).collect();
    x.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total: f64 = x.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for (i, v) in x.iter().enumerate() {
        // paper eq.25 with i as 1-based rank
        acc += (2.0 * (i as f64 + 1.0) - n as f64 - 1.0) * v;
    }
    acc / (n as f64 * total)
}

/// Min-max ratio (paper eq.26): min load / (max load + eps).
pub fn min_max_ratio(load: &[f32]) -> f64 {
    if load.is_empty() {
        return 0.0;
    }
    let min = load.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let max = load.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    min / (max + EPS)
}

/// Normalized entropy of the load distribution: 1 = uniform.
pub fn entropy_frac(load: &[f32]) -> f64 {
    let total: f64 = load.iter().map(|&v| v as f64).sum();
    if total <= 0.0 || load.len() < 2 {
        return 0.0;
    }
    let h: f64 = load
        .iter()
        .map(|&v| {
            let p = (v as f64 / total).max(EPS);
            -p * p.ln()
        })
        .sum();
    h / (load.len() as f64).ln()
}

/// Coefficient of variation (std / mean) of expert loads.
pub fn cv(load: &[f32]) -> f64 {
    if load.is_empty() {
        return 0.0;
    }
    let n = load.len() as f64;
    let mean: f64 = load.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var: f64 = load
        .iter()
        .map(|&v| (v as f64 - mean) * (v as f64 - mean))
        .sum::<f64>()
        / n;
    var.sqrt() / mean.max(EPS)
}

/// Per-layer load accounting accumulated over a training/eval run.
#[derive(Debug, Clone)]
pub struct LoadMatrix {
    pub n_layers: usize,
    pub n_experts: usize,
    /// Row-major [n_layers * n_experts] cumulative counts.
    pub counts: Vec<f64>,
}

impl LoadMatrix {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        LoadMatrix {
            n_layers,
            n_experts,
            counts: vec![0.0; n_layers * n_experts],
        }
    }

    /// Accumulate one step's [L, E] load histogram (f32, row-major).
    pub fn accumulate(&mut self, step_load: &[f32]) {
        assert_eq!(step_load.len(), self.counts.len());
        for (c, &v) in self.counts.iter_mut().zip(step_load) {
            *c += v as f64;
        }
    }

    pub fn layer(&self, l: usize) -> Vec<f32> {
        let e = self.n_experts;
        self.counts[l * e..(l + 1) * e]
            .iter()
            .map(|&v| v as f32)
            .collect()
    }

    /// Load summed over layers.
    pub fn total(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_experts];
        for l in 0..self.n_layers {
            for (o, &v) in out.iter_mut().zip(&self.counts[l * self.n_experts..])
            {
                *o += v as f32;
            }
        }
        out
    }

    /// Mean per-layer metric values (how the paper reports model-level
    /// Gini / min-max: averaged over MoE layers).
    pub fn mean_gini(&self) -> f64 {
        (0..self.n_layers).map(|l| gini(&self.layer(l))).sum::<f64>()
            / self.n_layers.max(1) as f64
    }

    pub fn mean_min_max(&self) -> f64 {
        (0..self.n_layers)
            .map(|l| min_max_ratio(&self.layer(l)))
            .sum::<f64>()
            / self.n_layers.max(1) as f64
    }

    /// Normalized per-layer loads (each layer sums to 1) — the exact
    /// quantity figure 1 visualizes.
    pub fn normalized(&self) -> Vec<Vec<f64>> {
        (0..self.n_layers)
            .map(|l| {
                let row = self.layer(l);
                let total: f64 = row.iter().map(|&v| v as f64).sum();
                row.iter()
                    .map(|&v| v as f64 / total.max(EPS))
                    .collect()
            })
            .collect()
    }
}

/// Render a Fig.1-style ASCII heatmap of normalized per-layer loads.
pub fn ascii_heatmap(lm: &LoadMatrix) -> String {
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let norm = lm.normalized();
    let uniform = 1.0 / lm.n_experts as f64;
    let mut s = String::new();
    s.push_str(&format!(
        "normalized expert load ({} layers x {} experts); \
         '@' >= 3x uniform, ' ' = starved\n",
        lm.n_layers, lm.n_experts
    ));
    for (l, row) in norm.iter().enumerate() {
        s.push_str(&format!("L{l:<2} |"));
        for &v in row {
            let rel = (v / uniform / 3.0).min(1.0);
            let idx = (rel * (shades.len() - 1) as f64).round() as usize;
            s.push(shades[idx]);
        }
        s.push_str(&format!(
            "| gini={:.3} minmax={:.3}\n",
            gini(&lm.layer(l)),
            min_max_ratio(&lm.layer(l))
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn gini_uniform_zero() {
        assert!(gini(&[5.0; 16]).abs() < 1e-12);
    }

    #[test]
    fn gini_one_expert_takes_all() {
        let mut load = vec![0.0f32; 8];
        load[3] = 10.0;
        assert!((gini(&load) - 7.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn gini_known_value() {
        assert!((gini(&[1.0, 2.0, 3.0, 4.0]) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn gini_props() {
        forall(
            200,
            42,
            |r| gen::vec_f32(r, 64, 0.0, 1e4),
            |v| {
                let g = gini(v);
                if !(-1e-9..=1.0).contains(&g) {
                    return Err(format!("gini out of bounds: {g}"));
                }
                // scale invariance
                let scaled: Vec<f32> = v.iter().map(|x| x * 3.7).collect();
                if (gini(&scaled) - g).abs() > 1e-6 {
                    return Err("not scale invariant".into());
                }
                // permutation invariance
                let mut rev = v.clone();
                rev.reverse();
                if (gini(&rev) - g).abs() > 1e-9 {
                    return Err("not permutation invariant".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn min_max_props() {
        forall(
            200,
            43,
            |r| gen::vec_f32(r, 64, 0.001, 1e3),
            |v| {
                let r = min_max_ratio(v);
                if !(0.0..=1.0 + 1e-9).contains(&r) {
                    return Err(format!("minmax out of bounds: {r}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn minmax_uniform_is_one() {
        assert!((min_max_ratio(&[2.0; 4]) - 1.0).abs() < 1e-6);
        assert!(min_max_ratio(&[0.0, 5.0]) < 1e-9);
    }

    #[test]
    fn entropy_and_cv() {
        assert!((entropy_frac(&[3.0; 32]) - 1.0).abs() < 1e-9);
        assert!(cv(&[3.0; 32]).abs() < 1e-9);
        let skew = [0.0, 0.0, 0.0, 12.0];
        assert!(entropy_frac(&skew) < 0.2);
        assert!(cv(&skew) > 1.0);
    }

    #[test]
    fn balanced_always_beats_skewed() {
        forall(
            100,
            44,
            |r| {
                let n = 2 + r.below(32);
                let mut skew = vec![0.1f32; n];
                skew[0] = 100.0;
                (vec![1.0f32; n], skew)
            },
            |(bal, skew)| {
                if gini(bal) < gini(skew)
                    && min_max_ratio(bal) > min_max_ratio(skew)
                    && entropy_frac(bal) > entropy_frac(skew)
                    && cv(bal) < cv(skew)
                {
                    Ok(())
                } else {
                    Err("metric ordering violated".into())
                }
            },
        );
    }

    #[test]
    fn load_matrix_accumulates() {
        let mut lm = LoadMatrix::new(2, 4);
        lm.accumulate(&[1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        lm.accumulate(&[1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        assert_eq!(lm.layer(0), vec![2.0, 0.0, 0.0, 0.0]);
        assert!((lm.mean_gini() - (0.75 + 0.0) / 2.0).abs() < 1e-9);
        assert_eq!(lm.total(), vec![4.0, 2.0, 2.0, 2.0]);
        let norm = lm.normalized();
        assert!((norm[1].iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn heatmap_renders() {
        let mut lm = LoadMatrix::new(1, 8);
        lm.accumulate(&[8.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let s = ascii_heatmap(&lm);
        assert!(s.contains("L0"));
        assert!(s.contains("gini="));
    }
}
