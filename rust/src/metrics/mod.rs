//! Load-balance metrics — paper §3.1 eq.25 (Gini) and eq.26 (min-max),
//! plus normalized entropy, coefficient of variation, and a streaming
//! windowed [`LoadTracker`] (rolling Gini / min-max / CV over the last
//! W serving steps) shared by the dispatch simulator, the serving
//! engine, and the reporter.
//!
//! Mirrors `python/compile/metrics.py`; the two implementations are
//! cross-checked against `artifacts/goldens/metrics.json` in the
//! integration tests (`rust/tests/goldens.rs`).

pub const EPS: f64 = 1e-9;

/// Default [`LoadTracker`] window (serving steps) shared by the
/// dispatch simulator and the serving engine.
pub const DEFAULT_LOAD_WINDOW: usize = 64;

/// Nearest-rank percentile over an **ascending-sorted** slice: the
/// value at 1-based rank `ceil(p · len)`, clamped to `1..=len`; `0.0`
/// on empty input. This is the single percentile convention shared by
/// [`crate::dispatch::DispatchSim`]'s latency report and the serving
/// runtime's per-request latency stats (`crate::serve::ServeReport`) —
/// the two must never disagree on what "p99" means.
///
/// ```
/// use lpr::metrics::percentile_nearest_rank;
/// let lat: Vec<f64> = (1..=10).map(f64::from).collect();
/// assert_eq!(percentile_nearest_rank(&lat, 0.50), 5.0);
/// assert_eq!(percentile_nearest_rank(&lat, 0.99), 10.0);
/// ```
pub fn percentile_nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p * sorted.len() as f64).ceil().max(1.0) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

/// Gini coefficient of an expert-load vector. 0 = perfectly balanced,
/// (n-1)/n = all load on one expert.
pub fn gini(load: &[f32]) -> f64 {
    let n = load.len();
    if n == 0 {
        return 0.0;
    }
    let mut x: Vec<f64> = load.iter().map(|&v| v as f64).collect();
    // total order (same approach as router::rank_cmp): NaN entries must
    // not panic the sort — they sort last and propagate NaN through the
    // sum, so a poisoned load vector yields gini = NaN, never a panic.
    x.sort_by(f64::total_cmp);
    let total: f64 = x.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for (i, v) in x.iter().enumerate() {
        // paper eq.25 with i as 1-based rank
        acc += (2.0 * (i as f64 + 1.0) - n as f64 - 1.0) * v;
    }
    acc / (n as f64 * total)
}

/// Min-max ratio (paper eq.26): min load / (max load + eps).
pub fn min_max_ratio(load: &[f32]) -> f64 {
    if load.is_empty() {
        return 0.0;
    }
    let min = load.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let max = load.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    min / (max + EPS)
}

/// Normalized entropy of the load distribution: 1 = uniform.
pub fn entropy_frac(load: &[f32]) -> f64 {
    let total: f64 = load.iter().map(|&v| v as f64).sum();
    if total <= 0.0 || load.len() < 2 {
        return 0.0;
    }
    let h: f64 = load
        .iter()
        .map(|&v| {
            let p = (v as f64 / total).max(EPS);
            -p * p.ln()
        })
        .sum();
    h / (load.len() as f64).ln()
}

/// Coefficient of variation (std / mean) of expert loads.
pub fn cv(load: &[f32]) -> f64 {
    if load.is_empty() {
        return 0.0;
    }
    let n = load.len() as f64;
    let mean: f64 = load.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var: f64 = load
        .iter()
        .map(|&v| (v as f64 - mean) * (v as f64 - mean))
        .sum::<f64>()
        / n;
    var.sqrt() / mean.max(EPS)
}

/// Per-layer load accounting accumulated over a training/eval run.
#[derive(Debug, Clone)]
pub struct LoadMatrix {
    pub n_layers: usize,
    pub n_experts: usize,
    /// Row-major [n_layers * n_experts] cumulative counts.
    pub counts: Vec<f64>,
}

impl LoadMatrix {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        LoadMatrix {
            n_layers,
            n_experts,
            counts: vec![0.0; n_layers * n_experts],
        }
    }

    /// Accumulate one step's [L, E] load histogram (f32, row-major).
    pub fn accumulate(&mut self, step_load: &[f32]) {
        assert_eq!(step_load.len(), self.counts.len());
        for (c, &v) in self.counts.iter_mut().zip(step_load) {
            *c += v as f64;
        }
    }

    pub fn layer(&self, l: usize) -> Vec<f32> {
        let e = self.n_experts;
        self.counts[l * e..(l + 1) * e]
            .iter()
            .map(|&v| v as f32)
            .collect()
    }

    /// Load summed over layers.
    pub fn total(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_experts];
        for l in 0..self.n_layers {
            for (o, &v) in out.iter_mut().zip(&self.counts[l * self.n_experts..])
            {
                *o += v as f32;
            }
        }
        out
    }

    /// Mean per-layer metric values (how the paper reports model-level
    /// Gini / min-max: averaged over MoE layers).
    pub fn mean_gini(&self) -> f64 {
        (0..self.n_layers).map(|l| gini(&self.layer(l))).sum::<f64>()
            / self.n_layers.max(1) as f64
    }

    pub fn mean_min_max(&self) -> f64 {
        (0..self.n_layers)
            .map(|l| min_max_ratio(&self.layer(l)))
            .sum::<f64>()
            / self.n_layers.max(1) as f64
    }

    /// Normalized per-layer loads (each layer sums to 1) — the exact
    /// quantity figure 1 visualizes.
    pub fn normalized(&self) -> Vec<Vec<f64>> {
        (0..self.n_layers)
            .map(|l| {
                let row = self.layer(l);
                let total: f64 = row.iter().map(|&v| v as f64).sum();
                row.iter()
                    .map(|&v| v as f64 / total.max(EPS))
                    .collect()
            })
            .collect()
    }
}

/// Streaming windowed load statistics: rolling Gini / min-max / CV over
/// the last `window` serving steps. One tracker is shared by the
/// dispatch simulator, the serving engine, and the reporter so "recent
/// balance" means the same thing everywhere (cumulative metrics like
/// [`LoadMatrix`] hide drift: a router that was balanced for the first
/// million tokens and collapsed afterwards still looks fine on the
/// cumulative Gini).
///
/// `push` is O(E) (ring-buffer overwrite plus an incremental update of
/// the per-expert column sums: subtract the evicted ring row, add the
/// new one), so windowed Gini / min-max / CV reads are O(E) instead of
/// an O(window·E) recompute. The sums accumulate in f64 — every f32
/// load value is exactly representable there, so add/subtract cancels
/// exactly for realistic token counts — and every windowed read
/// debug-asserts the incremental sums against the exact from-the-ring
/// recompute (`incremental_window_sums_never_drift` pins the parity
/// across thousands of mixed pushes in release mode too).
#[derive(Debug, Clone)]
pub struct LoadTracker {
    window: usize,
    n_experts: usize,
    /// [window * n_experts] ring of per-step load rows.
    ring: Vec<f32>,
    /// [n_experts] incremental column sums over the live ring rows.
    sums: Vec<f64>,
    /// Next write slot in [0, window).
    head: usize,
    /// Filled rows (saturates at `window`).
    len: usize,
    total_steps: usize,
}

impl LoadTracker {
    pub fn new(window: usize, n_experts: usize) -> LoadTracker {
        assert!(window >= 1, "window must be >= 1");
        assert!(n_experts >= 1, "n_experts must be >= 1");
        LoadTracker {
            window,
            n_experts,
            ring: vec![0.0; window * n_experts],
            sums: vec![0.0; n_experts],
            head: 0,
            len: 0,
            total_steps: 0,
        }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Steps currently inside the window.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Steps observed over the tracker's lifetime.
    pub fn total_steps(&self) -> usize {
        self.total_steps
    }

    /// Record one step's `[E]` load row, evicting the oldest step once
    /// the window is full.
    pub fn push(&mut self, step_load: &[f32]) {
        assert_eq!(step_load.len(), self.n_experts, "load row shape");
        let e = self.n_experts;
        let row = &mut self.ring[self.head * e..(self.head + 1) * e];
        if self.len == self.window {
            // evicting: subtract the overwritten row from the sums
            for (s, &old) in self.sums.iter_mut().zip(row.iter()) {
                *s -= old as f64;
            }
        }
        row.copy_from_slice(step_load);
        for (s, &v) in self.sums.iter_mut().zip(step_load) {
            *s += v as f64;
        }
        self.head = (self.head + 1) % self.window;
        self.len = (self.len + 1).min(self.window);
        self.total_steps += 1;
    }

    /// `push` for integer assignment counts (the dispatch-plan layout).
    pub fn push_counts(&mut self, counts: &[u32]) {
        assert_eq!(counts.len(), self.n_experts, "load row shape");
        let e = self.n_experts;
        let row = &mut self.ring[self.head * e..(self.head + 1) * e];
        if self.len == self.window {
            for (s, &old) in self.sums.iter_mut().zip(row.iter()) {
                *s -= old as f64;
            }
        }
        for ((slot, s), &c) in
            row.iter_mut().zip(&mut self.sums).zip(counts)
        {
            *slot = c as f32;
            *s += c as f64;
        }
        self.head = (self.head + 1) % self.window;
        self.len = (self.len + 1).min(self.window);
        self.total_steps += 1;
    }

    /// Per-expert load summed over the window, into a reusable buffer.
    /// O(E): reads the incrementally-maintained column sums.
    pub fn windowed_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.sums.iter().map(|&s| s as f32));
        debug_assert!(
            {
                let exact = self.windowed_exact();
                self.sums.iter().zip(&exact).all(|(&s, &x)| {
                    (s - x).abs() <= 1e-6 * x.abs().max(1.0)
                })
            },
            "incremental window sums drifted from the exact recompute"
        );
    }

    /// Exact per-expert window sums recomputed from the ring — the
    /// O(window·E) reference the incremental `sums` are checked against
    /// (debug assertion in [`Self::windowed_into`] plus the
    /// `incremental_window_sums_never_drift` regression test).
    fn windowed_exact(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n_experts];
        for row in self.ring.chunks(self.n_experts).take(self.len) {
            for (acc, &v) in out.iter_mut().zip(row) {
                *acc += v as f64;
            }
        }
        out
    }

    /// Per-expert load summed over the window.
    pub fn windowed(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.windowed_into(&mut out);
        out
    }

    /// Rolling Gini over the window (0.0 when no steps recorded).
    pub fn gini(&self) -> f64 {
        gini(&self.windowed())
    }

    /// Rolling min-max ratio over the window.
    pub fn min_max(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        min_max_ratio(&self.windowed())
    }

    /// Rolling coefficient of variation over the window.
    pub fn cv(&self) -> f64 {
        cv(&self.windowed())
    }

    /// The most recently pushed `[E]` load row, or `None` before the
    /// first push. This is the single-step (n=1 decode) view behind
    /// [`LayerLoadTracker::last_step`]; the windowed accessors above
    /// smooth over up to `window` steps.
    pub fn last_row(&self) -> Option<&[f32]> {
        if self.len == 0 {
            return None;
        }
        let e = self.n_experts;
        let idx = (self.head + self.window - 1) % self.window;
        Some(&self.ring[idx * e..(idx + 1) * e])
    }
}

/// One layer's rolling balance snapshot, as reported by
/// [`LayerLoadTracker::per_layer`] — the row format of the layer-resolved
/// Gini/min-max tables (`repro model-serve`, `lpr serve`, `model-sim`).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerBalance {
    pub layer: usize,
    pub gini: f64,
    pub min_max: f64,
    pub cv: f64,
}

/// Per-layer generalization of [`LoadTracker`]: `L` independent rolling
/// windows over `[L, E]` load rows, one per MoE layer of a served model
/// stack. The paper measures balance *per layer* (its Gini 0.70 → 0.035
/// numbers are per-layer values over whole models), so serving-side
/// telemetry must resolve layers too — a stack whose mean Gini looks
/// healthy can still hide one collapsed layer.
///
/// Layer `l`'s window is exactly a [`LoadTracker`]; `mean_gini` /
/// `mean_min_max` aggregate the way the paper reports model-level
/// numbers (mean over MoE layers, like [`LoadMatrix::mean_gini`]).
#[derive(Debug, Clone)]
pub struct LayerLoadTracker {
    layers: Vec<LoadTracker>,
}

impl LayerLoadTracker {
    pub fn new(n_layers: usize, window: usize, n_experts: usize) -> Self {
        Self::with_experts(window, &vec![n_experts; n_layers])
    }

    /// Constructor for stacks whose layers hold different expert
    /// counts: one window per entry of `n_experts_per_layer`.
    pub fn with_experts(window: usize, n_experts_per_layer: &[usize]) -> Self {
        assert!(!n_experts_per_layer.is_empty(), "n_layers must be >= 1");
        LayerLoadTracker {
            layers: n_experts_per_layer
                .iter()
                .map(|&e| LoadTracker::new(window, e))
                .collect(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Layer `l`'s rolling window.
    pub fn layer(&self, l: usize) -> &LoadTracker {
        &self.layers[l]
    }

    /// Record one step's `[E]` load row for layer `l`.
    pub fn push(&mut self, l: usize, step_load: &[f32]) {
        self.layers[l].push(step_load);
    }

    /// [`Self::push`] for integer assignment counts.
    pub fn push_counts(&mut self, l: usize, counts: &[u32]) {
        self.layers[l].push_counts(counts);
    }

    /// Rolling balance of every layer, in layer order.
    pub fn per_layer(&self) -> Vec<LayerBalance> {
        self.layers
            .iter()
            .enumerate()
            .map(|(layer, t)| LayerBalance {
                layer,
                gini: t.gini(),
                min_max: t.min_max(),
                cv: t.cv(),
            })
            .collect()
    }

    /// Balance of every layer computed over the **last pushed step
    /// only** — the per-decode-step view `lpr generate` / `repro
    /// decode` print for the paper's n=1 serving regime, where
    /// [`Self::per_layer`]'s rolling window would smear consecutive
    /// single-token steps together. Layers that have not recorded a
    /// step yet report the empty-load conventions (gini 0, min-max 0).
    pub fn last_step(&self) -> Vec<LayerBalance> {
        self.layers
            .iter()
            .enumerate()
            .map(|(layer, t)| {
                let row = t.last_row().unwrap_or(&[]);
                LayerBalance {
                    layer,
                    gini: gini(row),
                    min_max: min_max_ratio(row),
                    cv: cv(row),
                }
            })
            .collect()
    }

    /// Mean per-layer rolling Gini (the paper's model-level convention).
    pub fn mean_gini(&self) -> f64 {
        self.layers.iter().map(|t| t.gini()).sum::<f64>()
            / self.layers.len() as f64
    }

    pub fn mean_min_max(&self) -> f64 {
        self.layers.iter().map(|t| t.min_max()).sum::<f64>()
            / self.layers.len() as f64
    }

    pub fn mean_cv(&self) -> f64 {
        self.layers.iter().map(|t| t.cv()).sum::<f64>()
            / self.layers.len() as f64
    }
}

/// Render a Fig.1-style ASCII heatmap of normalized per-layer loads.
pub fn ascii_heatmap(lm: &LoadMatrix) -> String {
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let norm = lm.normalized();
    let uniform = 1.0 / lm.n_experts as f64;
    let mut s = String::new();
    s.push_str(&format!(
        "normalized expert load ({} layers x {} experts); \
         '@' >= 3x uniform, ' ' = starved\n",
        lm.n_layers, lm.n_experts
    ));
    for (l, row) in norm.iter().enumerate() {
        s.push_str(&format!("L{l:<2} |"));
        for &v in row {
            let rel = (v / uniform / 3.0).min(1.0);
            let idx = (rel * (shades.len() - 1) as f64).round() as usize;
            s.push(shades[idx]);
        }
        s.push_str(&format!(
            "| gini={:.3} minmax={:.3}\n",
            gini(&lm.layer(l)),
            min_max_ratio(&lm.layer(l))
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    /// Satellite: the shared latency-percentile helper pinned on a
    /// known vector (the classic nearest-rank worked example), matching
    /// `DispatchSim`'s convention exactly.
    #[test]
    fn percentile_nearest_rank_pinned() {
        let v = [15.0, 20.0, 35.0, 40.0, 50.0];
        assert_eq!(percentile_nearest_rank(&v, 0.05), 15.0);
        assert_eq!(percentile_nearest_rank(&v, 0.30), 20.0);
        assert_eq!(percentile_nearest_rank(&v, 0.40), 20.0);
        assert_eq!(percentile_nearest_rank(&v, 0.50), 35.0);
        assert_eq!(percentile_nearest_rank(&v, 0.99), 50.0);
        assert_eq!(percentile_nearest_rank(&v, 1.00), 50.0);
        // clamped at both ends; empty input is defined
        assert_eq!(percentile_nearest_rank(&v, 0.0), 15.0);
        assert_eq!(percentile_nearest_rank(&v, 2.0), 50.0);
        assert_eq!(percentile_nearest_rank(&[], 0.5), 0.0);
    }

    #[test]
    fn gini_uniform_zero() {
        assert!(gini(&[5.0; 16]).abs() < 1e-12);
    }

    #[test]
    fn gini_one_expert_takes_all() {
        let mut load = vec![0.0f32; 8];
        load[3] = 10.0;
        assert!((gini(&load) - 7.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn gini_known_value() {
        assert!((gini(&[1.0, 2.0, 3.0, 4.0]) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn gini_props() {
        forall(
            200,
            42,
            |r| gen::vec_f32(r, 64, 0.0, 1e4),
            |v| {
                let g = gini(v);
                if !(-1e-9..=1.0).contains(&g) {
                    return Err(format!("gini out of bounds: {g}"));
                }
                // scale invariance
                let scaled: Vec<f32> = v.iter().map(|x| x * 3.7).collect();
                if (gini(&scaled) - g).abs() > 1e-6 {
                    return Err("not scale invariant".into());
                }
                // permutation invariance
                let mut rev = v.clone();
                rev.reverse();
                if (gini(&rev) - g).abs() > 1e-9 {
                    return Err("not permutation invariant".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn min_max_props() {
        forall(
            200,
            43,
            |r| gen::vec_f32(r, 64, 0.001, 1e3),
            |v| {
                let r = min_max_ratio(v);
                if !(0.0..=1.0 + 1e-9).contains(&r) {
                    return Err(format!("minmax out of bounds: {r}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn minmax_uniform_is_one() {
        assert!((min_max_ratio(&[2.0; 4]) - 1.0).abs() < 1e-6);
        assert!(min_max_ratio(&[0.0, 5.0]) < 1e-9);
    }

    #[test]
    fn entropy_and_cv() {
        assert!((entropy_frac(&[3.0; 32]) - 1.0).abs() < 1e-9);
        assert!(cv(&[3.0; 32]).abs() < 1e-9);
        let skew = [0.0, 0.0, 0.0, 12.0];
        assert!(entropy_frac(&skew) < 0.2);
        assert!(cv(&skew) > 1.0);
    }

    #[test]
    fn balanced_always_beats_skewed() {
        forall(
            100,
            44,
            |r| {
                let n = 2 + r.below(32);
                let mut skew = vec![0.1f32; n];
                skew[0] = 100.0;
                (vec![1.0f32; n], skew)
            },
            |(bal, skew)| {
                if gini(bal) < gini(skew)
                    && min_max_ratio(bal) > min_max_ratio(skew)
                    && entropy_frac(bal) > entropy_frac(skew)
                    && cv(bal) < cv(skew)
                {
                    Ok(())
                } else {
                    Err("metric ordering violated".into())
                }
            },
        );
    }

    #[test]
    fn load_matrix_accumulates() {
        let mut lm = LoadMatrix::new(2, 4);
        lm.accumulate(&[1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        lm.accumulate(&[1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        assert_eq!(lm.layer(0), vec![2.0, 0.0, 0.0, 0.0]);
        assert!((lm.mean_gini() - (0.75 + 0.0) / 2.0).abs() < 1e-9);
        assert_eq!(lm.total(), vec![4.0, 2.0, 2.0, 2.0]);
        let norm = lm.normalized();
        assert!((norm[1].iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gini_nan_entry_does_not_panic() {
        // regression: the old partial_cmp().unwrap() comparator panicked
        // on NaN load entries mid-sort; NaN must now propagate instead.
        let g = gini(&[1.0, f32::NAN, 2.0]);
        assert!(g.is_nan(), "NaN load should yield NaN gini, got {g}");
        // and the NaN-free path is untouched
        assert!((gini(&[1.0, 2.0, 3.0, 4.0]) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn load_tracker_windows_roll() {
        let mut t = LoadTracker::new(2, 3);
        assert!(t.is_empty());
        assert!(t.gini().abs() < 1e-12); // empty window: defined, zero
        t.push(&[4.0, 0.0, 0.0]);
        assert_eq!(t.len(), 1);
        assert_eq!(t.windowed(), vec![4.0, 0.0, 0.0]);
        t.push_counts(&[0, 4, 0]);
        assert_eq!(t.windowed(), vec![4.0, 4.0, 0.0]);
        // third push evicts the first step: window is [step2, step3]
        t.push(&[0.0, 0.0, 4.0]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.total_steps(), 3);
        assert_eq!(t.windowed(), vec![0.0, 4.0, 4.0]);
    }

    #[test]
    fn load_tracker_metrics_match_free_functions() {
        let mut t = LoadTracker::new(8, 4);
        t.push(&[1.0, 2.0, 3.0, 4.0]);
        t.push(&[4.0, 3.0, 2.0, 1.0]);
        let w = t.windowed();
        assert_eq!(w, vec![5.0; 4]);
        assert!((t.gini() - gini(&w)).abs() < 1e-12);
        assert!((t.min_max() - min_max_ratio(&w)).abs() < 1e-12);
        assert!((t.cv() - cv(&w)).abs() < 1e-12);
        // uniform window: perfectly balanced
        assert!(t.gini().abs() < 1e-12);
        assert!((t.min_max() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn load_tracker_sees_recent_collapse_cumulative_misses() {
        // 100 balanced steps then 16 collapsed steps: the cumulative
        // load still looks healthy, the windowed tracker does not.
        let mut cumulative = vec![0.0f32; 4];
        let mut t = LoadTracker::new(16, 4);
        for _ in 0..100 {
            let row = [1.0f32; 4];
            for (c, v) in cumulative.iter_mut().zip(row) {
                *c += v;
            }
            t.push(&row);
        }
        for _ in 0..16 {
            let row = [4.0f32, 0.0, 0.0, 0.0];
            for (c, v) in cumulative.iter_mut().zip(row) {
                *c += v;
            }
            t.push(&row);
        }
        assert!(gini(&cumulative) < 0.2, "cumulative hides the collapse");
        assert!(t.gini() > 0.7, "window must expose it: {}", t.gini());
    }

    /// Satellite regression: the incremental column sums (add new row,
    /// subtract evicted row) must track the exact from-the-ring
    /// recompute across thousands of mixed `push`/`push_counts` calls
    /// The per-step view reads exactly the last pushed row — across
    /// ring wrap-around — and never mixes steps the way the windowed
    /// accessors do.
    #[test]
    fn last_row_tracks_the_most_recent_step() {
        let mut t = LoadTracker::new(3, 2);
        assert_eq!(t.last_row(), None);
        for step in 0..7u32 {
            let row = [step as f32, 10.0 + step as f32];
            t.push(&row);
            assert_eq!(t.last_row(), Some(&row[..]));
        }
        // the layer-resolved view: layer 0 pushed, layer 1 untouched
        let mut lt = LayerLoadTracker::new(2, 4, 2);
        lt.push(0, &[3.0, 1.0]);
        let snap = lt.last_step();
        assert_eq!(snap.len(), 2);
        assert!((snap[0].gini - gini(&[3.0, 1.0])).abs() < 1e-12);
        assert!(
            (snap[0].min_max - min_max_ratio(&[3.0, 1.0])).abs() < 1e-12
        );
        assert_eq!(snap[1].gini, 0.0);
        assert_eq!(snap[1].min_max, 0.0);
    }

    /// with many evictions — in release builds too, where the
    /// per-read debug assertion is compiled out.
    #[test]
    fn incremental_window_sums_never_drift() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        let (window, e) = (17usize, 5);
        let mut t = LoadTracker::new(window, e);
        for step in 0..10_000usize {
            if step % 3 == 0 {
                let counts: Vec<u32> =
                    (0..e).map(|_| rng.below(5000) as u32).collect();
                t.push_counts(&counts);
            } else {
                let row: Vec<f32> = (0..e)
                    .map(|_| rng.range_f64(0.0, 1.0e4) as f32)
                    .collect();
                t.push(&row);
            }
            if step % 997 == 0 || step + 1 == 10_000 {
                let got = t.windowed();
                let exact = t.windowed_exact();
                for (i, (&g, &x)) in got.iter().zip(&exact).enumerate() {
                    assert!(
                        (g as f64 - x).abs() <= 1e-6 * x.abs().max(1.0),
                        "expert {i} drifted at step {step}: \
                         incremental {g} vs exact {x}"
                    );
                }
            }
        }
        assert_eq!(t.len(), window);
        assert_eq!(t.total_steps(), 10_000);
    }

    #[test]
    fn layer_tracker_resolves_per_layer_balance() {
        let mut t = LayerLoadTracker::new(2, 8, 4);
        // layer 0 balanced, layer 1 collapsed onto expert 0
        t.push(0, &[1.0, 1.0, 1.0, 1.0]);
        t.push_counts(1, &[4, 0, 0, 0]);
        let per = t.per_layer();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].layer, 0);
        assert!(per[0].gini.abs() < 1e-12);
        assert!((per[0].min_max - 1.0).abs() < 1e-6);
        assert!((per[1].gini - 0.75).abs() < 1e-9);
        assert!(per[1].min_max < 1e-6);
        // mean aggregates match the free functions per layer
        assert!((t.mean_gini() - (0.0 + 0.75) / 2.0).abs() < 1e-9);
        assert!((t.mean_min_max() - (1.0 + 0.0) / 2.0).abs() < 1e-5);
        assert!(t.mean_cv() > 0.0);
        // and layer windows are the plain LoadTracker semantics
        assert_eq!(t.layer(1).windowed(), vec![4.0, 0.0, 0.0, 0.0]);
        assert_eq!(t.n_layers(), 2);
    }

    #[test]
    fn heatmap_renders() {
        let mut lm = LoadMatrix::new(1, 8);
        lm.accumulate(&[8.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let s = ascii_heatmap(&lm);
        assert!(s.contains("L0"));
        assert!(s.contains("gini="));
    }
}
