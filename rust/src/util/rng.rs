//! Deterministic PRNG (splitmix64 + xoshiro256**) for the data pipeline,
//! the dispatch simulator and the property-test harness.
//!
//! Offline build: no `rand` crate, so this is self-contained. Streams are
//! seeded explicitly everywhere so every experiment is reproducible from
//! its config.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Independent child stream (for per-layer / per-worker seeding).
    pub fn fold(&self, n: u64) -> Rng {
        let mut sm = self.s[0] ^ n.wrapping_mul(0xa0761d6478bd642f);
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // 128-bit multiply rejection-free mapping (Lemire); bias is
        // negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn fold_streams_independent() {
        let base = Rng::new(7);
        assert_ne!(base.fold(0).next_u64(), base.fold(1).next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let n = 20000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "{ratio}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
