//! Markdown table rendering for the experiment reports
//! (`lpr repro tN` output mirrors the paper's tables).

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut s = String::new();
        if !self.title.is_empty() {
            s.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.headers, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        s.push_str(&sep);
        for row in &self.rows {
            s.push_str(&fmt_row(row, &width));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            s.push('\n');
        }
        s
    }
}

/// Compact scientific formatting matched to how the paper prints values
/// (e.g. `1.27e-16` for min-max ratios, 3 decimals for losses).
pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() < 1e-3 {
        format!("{x:.2e}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a | long-header |"));
        assert!(md.contains("| 1 | 2           |"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn sci_format() {
        assert_eq!(fmt_sci(0.0), "0");
        assert_eq!(fmt_sci(3.6661), "3.666");
        assert!(fmt_sci(1.27e-16).contains("e-16"));
    }
}
