//! Micro-benchmark harness (offline build: no `criterion`).
//!
//! `cargo bench` binaries use `Bench` to time closures with warmup and
//! report min/median/mean like criterion's summary line. Results are
//! also appended to CSV/JSON artifacts so the ROADMAP perf-trajectory
//! tables (see `docs/ARCHITECTURE.md` §Benchmarks) can track deltas
//! across optimization iterations.

use std::time::Instant;

pub struct Bench {
    pub name: String,
    /// Target wall-time per measurement batch, seconds.
    pub target_s: f64,
    pub warmup_iters: usize,
    results: Vec<BenchResult>,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn per_item_ns(&self) -> f64 {
        self.median_ns / self.items_per_iter.max(1.0)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        println!("== bench suite: {suite}");
        Bench {
            name: suite.to_string(),
            target_s: 1.0,
            warmup_iters: 3,
            results: Vec::new(),
        }
    }

    /// Time `f`, auto-scaling iteration count to ~target_s of wall time.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> BenchResult {
        self.run_items(name, 1.0, &mut f)
    }

    /// Like `run`, but reports per-item throughput too.
    pub fn run_items(
        &mut self,
        name: &str,
        items_per_iter: f64,
        f: &mut dyn FnMut(),
    ) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        // estimate single-iter cost
        let t0 = Instant::now();
        f();
        let est = t0.elapsed().as_secs_f64().max(1e-9);
        let batch = ((self.target_s / 10.0 / est).ceil() as usize).clamp(1, 1_000_000);
        let n_batches = 10usize;
        let mut samples = Vec::with_capacity(n_batches);
        for _ in 0..n_batches {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() * 1e9 / batch as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: format!("{}/{}", self.name, name),
            iters: batch * n_batches,
            min_ns: samples[0],
            median_ns: samples[n_batches / 2],
            mean_ns: samples.iter().sum::<f64>() / n_batches as f64,
            items_per_iter,
        };
        let thr = if items_per_iter > 1.0 {
            format!(
                "  ({:.2} Melem/s)",
                items_per_iter / res.median_ns * 1e3
            )
        } else {
            String::new()
        };
        println!(
            "{:<44} median {:>10}  min {:>10}  n={}{}",
            res.name,
            fmt_ns(res.median_ns),
            fmt_ns(res.min_ns),
            res.iters,
            thr
        );
        self.results.push(res.clone());
        res
    }

    /// Append all results to a CSV (created with header if absent).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let new = !path.exists();
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        if new {
            writeln!(f, "name,iters,min_ns,median_ns,mean_ns,items_per_iter")?;
        }
        for r in &self.results {
            writeln!(
                f,
                "{},{},{},{},{},{}",
                r.name, r.iters, r.min_ns, r.median_ns, r.mean_ns,
                r.items_per_iter
            )?;
        }
        Ok(())
    }
}

/// Write pre-formatted JSON objects as a pretty-printed array — the
/// shared emitter behind every `BENCH_*.json` perf artifact
/// (`benches/micro.rs` and the `serve-bench` CLI).
pub fn write_json_rows(path: &str, rows: &[String]) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 == rows.len() { "" } else { "," };
        s.push_str(&format!("  {r}{sep}\n"));
    }
    s.push_str("]\n");
    std::fs::write(path, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_rows_render_as_array() {
        let p = std::env::temp_dir().join("lpr-bench-rows.json");
        let path = p.to_str().unwrap();
        write_json_rows(
            path,
            &["{\"a\": 1}".to_string(), "{\"b\": 2}".to_string()],
        )
        .unwrap();
        let s = std::fs::read_to_string(path).unwrap();
        assert_eq!(s, "[\n  {\"a\": 1},\n  {\"b\": 2}\n]\n");
        // parses back with the in-tree JSON parser
        assert!(crate::util::json::Json::parse(&s).is_ok());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn times_a_closure() {
        let mut b = Bench::new("test");
        b.target_s = 0.05;
        b.warmup_iters = 1;
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
    }

    #[test]
    fn csv_written() {
        let mut b = Bench::new("test2");
        b.target_s = 0.02;
        b.warmup_iters = 0;
        b.run("x", || {
            std::hint::black_box(3u64.pow(7));
        });
        let p = std::env::temp_dir().join("lpr-bench-test.csv");
        let _ = std::fs::remove_file(&p);
        b.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("name,"));
        assert!(s.contains("test2/x"));
    }
}
