//! Micro property-testing harness (offline build: no `proptest`).
//!
//! `forall(cases, seed, gen, check)` draws `cases` random inputs from
//! `gen` and asserts `check`; on failure it panics with the case index
//! and a debug dump of the failing input so the run is reproducible from
//! the fixed seed.

use super::rng::Rng;

pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed}): \
                 {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Common generators.
pub mod gen {
    use super::Rng;

    pub fn vec_f64(rng: &mut Rng, len_max: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = 1 + rng.below(len_max.max(1));
        (0..n).map(|_| rng.range_f64(lo, hi)).collect()
    }

    pub fn vec_f32(rng: &mut Rng, len_max: usize, lo: f64, hi: f64) -> Vec<f32> {
        vec_f64(rng, len_max, lo, hi)
            .into_iter()
            .map(|x| x as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(
            50,
            1,
            |r| gen::vec_f64(r, 16, 0.0, 1.0),
            |v| {
                if v.iter().all(|x| (0.0..1.0).contains(x)) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(
            10,
            2,
            |r| r.below(10),
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) },
        );
    }
}
