//! Minimal JSON parser/serializer.
//!
//! The offline build has no `serde`/`serde_json`, so the L2⇄L3 contract
//! files (`meta.json`, `manifest.json`, goldens) are read with this
//! self-contained implementation. It supports the full JSON grammar the
//! AOT pipeline emits: objects, arrays, strings (with escapes), numbers,
//! booleans and null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style access; panics with a useful message if the
    /// path is absent (contract files are trusted build outputs).
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key '{key}' in {self:.80?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Flatten a (possibly nested) numeric array into f32s.
    pub fn as_f32_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        fn rec(j: &Json, out: &mut Vec<f32>) {
            match j {
                Json::Num(x) => out.push(*x as f32),
                Json::Arr(v) => v.iter().for_each(|x| rec(x, out)),
                _ => panic!("non-numeric array element: {j:?}"),
            }
        }
        rec(self, &mut out);
        out
    }

    pub fn as_usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .expect("expected array")
            .iter()
            .map(|x| x.as_usize().expect("expected number"))
            .collect()
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(true) => s.push_str("true"),
            Json::Bool(false) => s.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    s.push_str(&format!("{}", *x as i64));
                } else {
                    s.push_str(&format!("{x}"));
                }
            }
            Json::Str(v) => {
                s.push('"');
                for c in v.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\t' => s.push_str("\\t"),
                        '\r' => s.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            s.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(v) => {
                s.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    x.write(s);
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    Json::Str(k.clone()).write(s);
                    s.push(':');
                    x.write(s);
                }
                s.push('}');
            }
        }
    }
}

/// Convenience constructors for report/manifest writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only; surrogate pairs are not emitted by
                            // our python writer.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at("a").as_arr().unwrap()[2].at("b").as_str(),
            Some("x")
        );
        assert_eq!(j.at("c"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s\"q",true,null,{"n":-3}]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn flat_f32() {
        let j = Json::parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(j.as_f32_flat(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("[1, ").unwrap_err();
        assert!(e.pos >= 3);
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1] x").is_err());
    }
}
