//! Self-contained substrate utilities (the offline build has no serde /
//! clap / rand / proptest — these modules replace them).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
