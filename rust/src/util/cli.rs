//! Tiny argv parser (offline build: no `clap`).
//!
//! Grammar: `lpr <subcommand> [positional...] [--flag] [--key value]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            a.cmd = cmd.clone();
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value` or `--key value` or bare `--flag`
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    a.options
                        .insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(name.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        // NOTE: a bare `--flag` followed by a non-dash token is parsed as
        // `--key value`; flags must therefore come last or use `--k=v`.
        let a = Args::parse(&argv(
            "train ab-base extra --steps 100 --out=/tmp/x --quiet",
        ));
        assert_eq!(a.cmd, "train");
        assert_eq!(a.positional, vec!["ab-base", "extra"]);
        assert_eq!(a.opt("steps"), Some("100"));
        assert_eq!(a.opt("out"), Some("/tmp/x"));
        assert!(a.has_flag("quiet"));
        assert_eq!(a.opt_usize("steps", 0), 100);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&argv("eval --fast"));
        assert!(a.has_flag("fast"));
        assert!(a.opt("fast").is_none());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("x"));
        assert_eq!(a.opt_or("missing", "d"), "d");
        assert_eq!(a.opt_usize("n", 7), 7);
        assert_eq!(a.opt_f64("f", 1.5), 1.5);
    }
}
