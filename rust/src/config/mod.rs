//! Experiment run specifications and the single-run executor.
//!
//! A `RunSpec` names an AOT artifact (preset from
//! `python/compile/configs.py`), optional loss-weight patches (how the
//! Table 2/4 ablations reuse one compiled artifact) and run length;
//! `execute_run` trains it on the synthetic corpus, evaluates on a
//! held-out stream and returns the paper's headline numbers
//! (test loss, Gini, min-max).

use anyhow::{Context, Result};
use std::path::Path;
use std::time::Instant;

use crate::coordinator::Trainer;
use crate::data::ZipfMarkovCorpus;
use crate::metrics::LoadMatrix;
use crate::runtime::{CompiledArtifacts, Runtime};

#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Row label in the report (mirrors the paper's table rows).
    pub label: String,
    /// Artifact preset name under `artifacts/`.
    pub artifact: String,
    /// Override the config's total_steps (None = use config).
    pub steps: Option<usize>,
    /// Data / init seed.
    pub seed: i32,
    /// (index, value) patches over the meta's default loss weights.
    pub lw_patch: Vec<(usize, f32)>,
    /// Held-out batches for the final evaluation.
    pub eval_batches: usize,
}

impl RunSpec {
    pub fn new(label: &str, artifact: &str) -> Self {
        RunSpec {
            label: label.to_string(),
            artifact: artifact.to_string(),
            steps: None,
            seed: 0,
            lw_patch: Vec::new(),
            eval_batches: 8,
        }
    }

    pub fn steps(mut self, n: usize) -> Self {
        self.steps = Some(n);
        self
    }

    pub fn patch(mut self, idx: usize, value: f32) -> Self {
        self.lw_patch.push((idx, value));
        self
    }
}

/// Everything a table row needs, plus curves for the figures.
#[derive(Debug)]
pub struct RunSummary {
    pub label: String,
    pub artifact: String,
    pub steps: usize,
    pub train_loss_final: f64,
    pub test_loss: f64,
    /// Mean per-layer Gini / min-max of the *held-out* load distribution
    /// (the paper evaluates balance on the validation set).
    pub gini: f64,
    pub min_max: f64,
    pub drop_frac: f64,
    pub eval_load: LoadMatrix,
    pub train_load: LoadMatrix,
    /// Per-step training loss (figure 3 input).
    pub loss_curve: Vec<f32>,
    /// Mean top-1 combine weight on held-out tokens (specialization
    /// proxy for figure 4; see `docs/ARCHITECTURE.md` §Telemetry).
    pub top1_confidence: f64,
    pub wall_s: f64,
    pub steps_per_s: f64,
}

/// Train + evaluate one spec. Separate corpora seeds keep eval held out.
pub fn execute_run(
    rt: &Runtime,
    art_dir: &Path,
    spec: &RunSpec,
    verbose: bool,
) -> Result<RunSummary> {
    let arts = CompiledArtifacts::load(rt, art_dir, &spec.artifact)
        .with_context(|| format!("artifact '{}'", spec.artifact))?;
    execute_run_arts(rt, &arts, spec, verbose)
}

/// Like [`execute_run`] but reuses an already-compiled artifact set
/// (the Reporter caches compiles: tables 2/4 and fig.4 re-run `ab-base`
/// nine times with different runtime loss weights).
pub fn execute_run_arts(
    rt: &Runtime,
    arts: &CompiledArtifacts,
    spec: &RunSpec,
    verbose: bool,
) -> Result<RunSummary> {
    let meta = arts.meta.clone();
    let steps = spec.steps.unwrap_or(meta.config.total_steps);

    let mut lw = meta.default_loss_weights.clone();
    for &(i, v) in &spec.lw_patch {
        lw[i] = v;
    }

    let mut trainer = Trainer::new(rt, arts, spec.seed, Some(lw))?;
    let mut corpus = ZipfMarkovCorpus::standard(
        meta.config.vocab,
        1000 + spec.seed as u64,
    );

    let t0 = Instant::now();
    let loss_idx = meta.metric_idx("loss")?;
    let mut loss_curve = Vec::with_capacity(steps);
    trainer.train_synthetic(&mut corpus, steps, |m| {
        loss_curve.push(m.values[loss_idx]);
        if verbose && (m.step % 50 == 0 || m.step + 1 == steps) {
            eprintln!(
                "  [{}] step {:>4}/{steps} loss {:.4}",
                spec.label, m.step, m.values[loss_idx]
            );
        }
    })?;
    let wall_s = t0.elapsed().as_secs_f64();

    // Held-out evaluation: same corpus law, disjoint sample stream.
    let mut eval_corpus = ZipfMarkovCorpus::held_out(
        meta.config.vocab,
        1000 + spec.seed as u64,
        900_000 + spec.seed as u64,
    );
    let eval = trainer.evaluate(&mut eval_corpus, spec.eval_batches)?;

    // Specialization proxy: run the standalone router artifact on the
    // trained router params with cluster-structured inputs.
    let top1 = router_top1_confidence(rt, arts, &trainer)
        .unwrap_or(f64::NAN);

    Ok(RunSummary {
        label: spec.label.clone(),
        artifact: spec.artifact.clone(),
        steps,
        train_loss_final: *loss_curve.last().unwrap_or(&f32::NAN) as f64,
        test_loss: eval.loss,
        gini: eval.load.mean_gini(),
        min_max: eval.load.mean_min_max(),
        drop_frac: eval.drop_frac,
        eval_load: eval.load,
        train_load: trainer.load.clone(),
        loss_curve,
        top1_confidence: top1,
        wall_s,
        steps_per_s: steps as f64 / wall_s.max(1e-9),
    })
}

/// Extract layer-0 router params from the trained state and run the
/// router-only executable on synthetic clusterable activations; returns
/// the mean top-1 combine weight (1/k = undecided, 1.0 = fully
/// specialized routing).
pub fn router_top1_confidence(
    rt: &Runtime,
    arts: &CompiledArtifacts,
    trainer: &Trainer,
) -> Result<f64> {
    let meta = &arts.meta;
    let host = trainer.params_to_host()?;
    let prefix = "['layers'][0]['moe']['router']";

    let mut router_bufs = Vec::new();
    for rp in &meta.router_params {
        let full = format!("{prefix}{}", rp.path);
        let idx = meta
            .params
            .iter()
            .position(|p| p.path == full)
            .with_context(|| format!("router leaf {full} not in params"))?;
        router_bufs.push(rt.buf_f32(&host[idx], &meta.params[idx].shape)?);
    }

    // Cluster-structured inputs: a Gaussian mixture with E/4 centers —
    // the clusterability assumption of §2.2.1.
    let n = meta.config.tokens_per_batch();
    let d = meta.config.d_model;
    let mut rng = crate::util::rng::Rng::new(4242);
    let n_centers = (meta.config.n_experts / 4).max(2);
    let centers: Vec<f32> = (0..n_centers * d)
        .map(|_| rng.normal() as f32)
        .collect();
    let mut h = vec![0.0f32; n * d];
    for t in 0..n {
        let c = rng.below(n_centers);
        for j in 0..d {
            h[t * d + j] =
                centers[c * d + j] + 0.3 * rng.normal() as f32;
        }
    }
    let h_buf = rt.buf_f32(&h, &[n, d])?;
    let mut args: Vec<&xla::PjRtBuffer> = router_bufs.iter().collect();
    args.push(&h_buf);
    let outs = crate::runtime::execute_buffers(&arts.router, &args)?;
    // outputs: topk_idx [N,k] i32, weights [N,k] f32, load [E] f32
    let weights = rt.to_f32(&outs[1])?;
    let k = meta.config.top_k;
    let mut sum = 0.0f64;
    for t in 0..n {
        let row = &weights[t * k..(t + 1) * k];
        sum += row.iter().cloned().fold(f32::MIN, f32::max) as f64;
    }
    Ok(sum / n as f64)
}
