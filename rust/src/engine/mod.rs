//! The engine facade: **one** public forward API over every serving
//! backend.
//!
//! Four PRs of growth left the forward path with six overlapping entry
//! points (`Router::forward`, `RouterPlan::forward_into`,
//! `ServingEngine::forward_full`, `PoolEngine::{forward_full,
//! forward_model}`, `ModelEngine::forward`), so every new scenario
//! re-wired the stack by hand — choosing a backend type, threading the
//! capacity factor and overflow policy through each call, and
//! remembering which object owns `set_renormalize`. This module
//! replaces that with a single trait and a single construction path:
//!
//! - [`MoeEngine`] — the one forward interface: `forward(h, n)` runs
//!   the full route → dispatch-plan → expert FFN → combine → residual
//!   pipeline over the whole layer stack and returns a borrowed
//!   [`EngineOutput`] view (zero copies, zero steady-state allocation);
//!   `route_into` serves routing-only studies; `balance()`, `layers()`,
//!   `d_model()` expose the telemetry and shape every scenario needs.
//! - [`EngineBuilder`] (via [`Engine::builder`]) — owns **all**
//!   configuration that used to be scattered across constructors and
//!   setters (model, backend, overflow policy, capacity factor,
//!   renormalization, GEMM kernel, weight dtype) and validates it into
//!   typed [`EngineBuildError`]s instead of panics. `.kernel(..)`
//!   selects the FFN micro-kernel (naive / register-blocked / AVX2 /
//!   NEON; auto-picked from the weight dtype when omitted),
//!   `.gemm_tiles(..)` sets the MC×KC×NC cache tiles, and
//!   `.weight_dtype(..)` quantizes the expert banks (bf16 / int8) once
//!   at build time — see [`crate::kernels`] for the determinism and
//!   error-bound contracts.
//! - [`Backend`] — `Scoped { threads }` (per-batch `thread::scope`,
//!   via `model::ModelEngine`) or `Pool { workers }` (persistent
//!   channel-fed workers, via `serve::PoolEngine`). Both are
//!   bit-identical to each other and to the legacy entry points for
//!   every thread/worker count — pinned by the parity property tests
//!   below across backends × layers × workers {1, 2, 3, 8}.
//!
//! The legacy entry points remain as thin `#[deprecated]` shims (see
//! the deprecation table in `docs/ARCHITECTURE.md`); the engines they
//! name are now *backend internals* constructed only here. The
//! trait-object indirection costs ≈0 ns/token at serving batch sizes —
//! `BENCH_engine.json` (facade vs direct-call rows, emitted by
//! `benches/micro.rs`) tracks that claim in CI.
//!
//! ```
//! use lpr::engine::{Backend, Engine, MoeEngine};
//! use lpr::model::synthetic_stacked_model;
//! use lpr::util::rng::Rng;
//!
//! let model =
//!     synthetic_stacked_model("cosine", &Rng::new(1), 3, 8, 4, 4, 2, 6);
//! // the same model behind both backends, built the same way
//! let mut scoped = Engine::builder()
//!     .model(model.clone())
//!     .backend(Backend::Scoped { threads: 2 })
//!     .build()?;
//! let mut pool = Engine::builder()
//!     .model(model)
//!     .backend(Backend::Pool { workers: 3 })
//!     .build()?;
//! let h = vec![0.25f32; 5 * 8];
//! let a = scoped.forward(&h, 5).hidden.to_vec();
//! let b = pool.forward(&h, 5).hidden.to_vec();
//! assert_eq!(a, b); // bit-identical across backends
//! # Ok::<(), lpr::engine::EngineBuildError>(())
//! ```

pub mod builder;
pub mod decode;

pub use builder::{Backend, EngineBuildError, EngineBuilder};
pub use decode::{
    DecodeError, DecodeSession, FinishedSeq, GenRequest, StepStat,
};

use crate::dispatch::placement::PlacementConfig;
use crate::dispatch::plan::OverflowPolicy;
use crate::kernels::{GemmTiles, Kernel};
use crate::metrics::LayerLoadTracker;
use crate::model::cache::{KvCache, SeqSpan};
use crate::model::{ModelEngine, ModelForward, StackedModel};
use crate::router::{FullForward, RouterBatch};
use crate::serve::PoolEngine;

/// Borrowed view of one stacked forward — what [`MoeEngine::forward`]
/// returns. The referenced buffers live inside the engine and are
/// overwritten by the next `forward` call (clone what must outlive it).
#[derive(Debug)]
pub struct EngineOutput<'a> {
    /// Tokens in this batch.
    pub n_tokens: usize,
    /// `[n_tokens, d]` residual stream after the last layer.
    pub hidden: &'a [f32],
    /// Per-layer pipeline state (routed batch, dispatch plan, combined
    /// MoE output), layer order.
    pub layers: &'a [FullForward],
}

impl<'a> EngineOutput<'a> {
    /// Final residual-stream row of token `r`.
    pub fn token_row(&self, r: usize) -> &'a [f32] {
        let hidden: &'a [f32] = self.hidden;
        let d = hidden.len() / self.n_tokens.max(1);
        &hidden[r * d..(r + 1) * d]
    }
}

/// The one forward interface every serving backend implements. All
/// run-time configuration (capacity factor, overflow policy,
/// renormalization) is owned by the engine — fixed at
/// [`Engine::builder`] time — so call sites pass activations and
/// nothing else.
///
/// Implementations are `Send` (a boxed engine can move behind
/// [`crate::serve::Server`]'s background thread) and deterministic:
/// `forward` is bit-identical for every backend and thread/worker
/// count (the thread-determinism contract in `docs/ARCHITECTURE.md`).
pub trait MoeEngine: Send {
    /// Run the full stack over `h` (`[n, d]` row-major, `n` tokens):
    /// per layer route → compile a dispatch plan → expert FFNs →
    /// gate-weighted combine, composed through the residual add.
    fn forward(&mut self, h: &[f32], n: usize) -> EngineOutput<'_>;

    /// Route `h` through **layer 0**'s router only (no dispatch/FFN) —
    /// the routing-study entry point (`route synthetic`,
    /// `dispatch-sim --routed`, the router benches).
    fn route_into(&mut self, h: &[f32], out: &mut RouterBatch);

    /// Rolling per-layer `[L, E]` routed-load balance over this
    /// engine's batches.
    fn balance(&self) -> &LayerLoadTracker;

    /// The capacity factor every batch is planned with (builder-owned).
    /// Exposed so drivers that also feed a `DispatchSim` can *assert*
    /// the two agree on bin sizes instead of trusting a comment.
    fn capacity_factor(&self) -> f64;

    /// The overflow policy every batch is planned with (builder-owned).
    fn policy(&self) -> OverflowPolicy;

    /// MoE layers in the served stack.
    fn layers(&self) -> usize;

    /// Residual-stream width.
    fn d_model(&self) -> usize;

    /// The last `forward`'s full pipeline state (valid — empty — before
    /// the first call). `serve::ServeRuntime` uses this to map batch
    /// members onto combined rows.
    fn last(&self) -> &ModelForward;

    /// Run the stack over a **ragged step batch**: `h` is `[N, d]`
    /// whose rows concatenate `spans` in span order, each span
    /// extending one cached sequence by its new positions (1 for a
    /// decode step, the prompt length for a prefill — see
    /// [`crate::model::cache`]). Attention sublayers read each span's
    /// past keys/values from (and append the new ones to) its cache
    /// slot; on attention-less stacks the cache only tracks lengths.
    /// Bit-identical however a sequence's rows are split across calls
    /// and across thread counts/backends, provided the engine's
    /// capacity factor admits every token — dispatch bins scale with
    /// batch size, so a dropping configuration is not batch-invariant
    /// (see [`decode`]). Callers pre-check slot capacity with
    /// [`KvCache::check_capacity`]; violations panic.
    fn forward_seqs(
        &mut self,
        h: &[f32],
        spans: &[SeqSpan],
        cache: &mut KvCache,
    ) -> EngineOutput<'_>;
}

impl MoeEngine for Box<dyn MoeEngine> {
    fn forward(&mut self, h: &[f32], n: usize) -> EngineOutput<'_> {
        (**self).forward(h, n)
    }
    fn forward_seqs(
        &mut self,
        h: &[f32],
        spans: &[SeqSpan],
        cache: &mut KvCache,
    ) -> EngineOutput<'_> {
        (**self).forward_seqs(h, spans, cache)
    }
    fn route_into(&mut self, h: &[f32], out: &mut RouterBatch) {
        (**self).route_into(h, out)
    }
    fn balance(&self) -> &LayerLoadTracker {
        (**self).balance()
    }
    fn capacity_factor(&self) -> f64 {
        (**self).capacity_factor()
    }
    fn policy(&self) -> OverflowPolicy {
        (**self).policy()
    }
    fn layers(&self) -> usize {
        (**self).layers()
    }
    fn d_model(&self) -> usize {
        (**self).d_model()
    }
    fn last(&self) -> &ModelForward {
        (**self).last()
    }
}

/// Scoped-thread backend: `model::ModelEngine` (one
/// `router::ServingEngine` per layer, threads spawned per batch) plus
/// the builder-owned run configuration. Constructed only by
/// [`EngineBuilder::build`].
pub(crate) struct ScopedBackend {
    eng: ModelEngine,
    capacity_factor: f64,
    policy: OverflowPolicy,
    out: ModelForward,
}

impl ScopedBackend {
    pub(crate) fn new(
        model: StackedModel,
        threads: usize,
        capacity_factor: f64,
        policy: OverflowPolicy,
        renormalize: bool,
        kernel: Kernel,
        tiles: GemmTiles,
    ) -> ScopedBackend {
        let mut eng = ModelEngine::new(model, threads);
        eng.set_renormalize(renormalize);
        eng.set_kernel(kernel);
        eng.set_gemm_tiles(tiles);
        let mut out = ModelForward::new();
        out.ensure_layers(eng.n_layers());
        ScopedBackend { eng, capacity_factor, policy, out }
    }
}

impl MoeEngine for ScopedBackend {
    fn forward(&mut self, h: &[f32], n: usize) -> EngineOutput<'_> {
        assert_eq!(h.len(), n * self.eng.d_model(), "h must be [n, d]");
        self.eng.forward(h, self.capacity_factor, self.policy, &mut self.out);
        EngineOutput {
            n_tokens: n,
            hidden: &self.out.hidden,
            layers: &self.out.layers,
        }
    }
    fn forward_seqs(
        &mut self,
        h: &[f32],
        spans: &[SeqSpan],
        cache: &mut KvCache,
    ) -> EngineOutput<'_> {
        let n = h.len() / self.eng.d_model().max(1);
        self.eng.forward_seqs(
            h,
            spans,
            self.capacity_factor,
            self.policy,
            cache,
            &mut self.out,
        );
        EngineOutput {
            n_tokens: n,
            hidden: &self.out.hidden,
            layers: &self.out.layers,
        }
    }
    fn route_into(&mut self, h: &[f32], out: &mut RouterBatch) {
        self.eng.route_into(h, out);
    }
    fn balance(&self) -> &LayerLoadTracker {
        self.eng.tracker()
    }
    fn capacity_factor(&self) -> f64 {
        self.capacity_factor
    }
    fn policy(&self) -> OverflowPolicy {
        self.policy
    }
    fn layers(&self) -> usize {
        self.eng.n_layers()
    }
    fn d_model(&self) -> usize {
        self.eng.d_model()
    }
    fn last(&self) -> &ModelForward {
        &self.out
    }
}

/// Persistent-pool backend: `serve::PoolEngine` (long-lived channel-fed
/// workers serving the whole stack) plus the builder-owned run
/// configuration. Constructed only by [`EngineBuilder::build`].
pub(crate) struct PoolBackend {
    pool: PoolEngine,
    capacity_factor: f64,
    policy: OverflowPolicy,
    out: ModelForward,
}

impl PoolBackend {
    pub(crate) fn new(
        model: StackedModel,
        workers: usize,
        capacity_factor: f64,
        policy: OverflowPolicy,
        renormalize: bool,
        kernel: Kernel,
        tiles: GemmTiles,
    ) -> PoolBackend {
        let mut pool = PoolEngine::from_model(model, workers);
        pool.set_renormalize(renormalize);
        pool.set_kernel(kernel);
        pool.set_gemm_tiles(tiles);
        let mut out = ModelForward::new();
        out.ensure_layers(pool.n_layers());
        PoolBackend { pool, capacity_factor, policy, out }
    }

    /// Forward the builder's `.placement(..)` knob to the pool's
    /// expert-stage partitioner (see
    /// [`PoolEngine::set_placement`](crate::serve::PoolEngine::set_placement)).
    pub(crate) fn set_placement(&mut self, cfg: PlacementConfig) {
        self.pool.set_placement(cfg);
    }
}

impl MoeEngine for PoolBackend {
    fn forward(&mut self, h: &[f32], n: usize) -> EngineOutput<'_> {
        assert_eq!(h.len(), n * self.pool.d_model(), "h must be [n, d]");
        self.pool.forward_model(
            h,
            self.capacity_factor,
            self.policy,
            &mut self.out,
        );
        EngineOutput {
            n_tokens: n,
            hidden: &self.out.hidden,
            layers: &self.out.layers,
        }
    }
    fn forward_seqs(
        &mut self,
        h: &[f32],
        spans: &[SeqSpan],
        cache: &mut KvCache,
    ) -> EngineOutput<'_> {
        let n = h.len() / self.pool.d_model().max(1);
        self.pool.forward_model_seqs(
            h,
            spans,
            self.capacity_factor,
            self.policy,
            cache,
            &mut self.out,
        );
        EngineOutput {
            n_tokens: n,
            hidden: &self.out.hidden,
            layers: &self.out.layers,
        }
    }
    fn route_into(&mut self, h: &[f32], out: &mut RouterBatch) {
        self.pool.route_into(h, out);
    }
    fn balance(&self) -> &LayerLoadTracker {
        self.pool.layer_tracker()
    }
    fn capacity_factor(&self) -> f64 {
        self.capacity_factor
    }
    fn policy(&self) -> OverflowPolicy {
        self.policy
    }
    fn layers(&self) -> usize {
        self.pool.n_layers()
    }
    fn d_model(&self) -> usize {
        self.pool.d_model()
    }
    fn last(&self) -> &ModelForward {
        &self.out
    }
}

/// A built engine: the boxed backend plus the resolved configuration,
/// for introspection. `Engine` itself implements [`MoeEngine`]
/// (delegating), so scenario code can hold either an `Engine` or a
/// `Box<dyn MoeEngine>` ([`Engine::into_inner`]) interchangeably.
pub struct Engine {
    inner: Box<dyn MoeEngine>,
    backend: Backend,
    capacity_factor: f64,
    policy: OverflowPolicy,
    kernel: Kernel,
    gemm_tiles: GemmTiles,
}

impl Engine {
    /// The one construction path: `Engine::builder().model(m)…build()`.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    pub(crate) fn from_parts(
        inner: Box<dyn MoeEngine>,
        backend: Backend,
        capacity_factor: f64,
        policy: OverflowPolicy,
        kernel: Kernel,
        gemm_tiles: GemmTiles,
    ) -> Engine {
        Engine { inner, backend, capacity_factor, policy, kernel, gemm_tiles }
    }

    /// The backend this engine was built with. (Capacity factor and
    /// policy are exposed through the [`MoeEngine`] trait.)
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The GEMM kernel the build resolved to — the explicit
    /// [`EngineBuilder::kernel`] choice, or the auto-pick (Blocked for
    /// quantized weights, Naive for f32).
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The GEMM cache tiles the build resolved to (explicit knob >
    /// `LPR_GEMM_TILES` > defaults).
    pub fn gemm_tiles(&self) -> GemmTiles {
        self.gemm_tiles
    }

    /// Unwrap into the boxed trait object (e.g. for
    /// `serve::ServeRuntime::with_engine`, whose default engine type is
    /// `Box<dyn MoeEngine>`).
    pub fn into_inner(self) -> Box<dyn MoeEngine> {
        self.inner
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("backend", &self.backend)
            .field("layers", &self.inner.layers())
            .field("d_model", &self.inner.d_model())
            .field("capacity_factor", &self.capacity_factor)
            .field("policy", &self.policy.name())
            .field("kernel", &self.kernel.name())
            .field("gemm_tiles", &self.gemm_tiles)
            .finish()
    }
}

impl MoeEngine for Engine {
    fn forward(&mut self, h: &[f32], n: usize) -> EngineOutput<'_> {
        self.inner.forward(h, n)
    }
    fn forward_seqs(
        &mut self,
        h: &[f32],
        spans: &[SeqSpan],
        cache: &mut KvCache,
    ) -> EngineOutput<'_> {
        self.inner.forward_seqs(h, spans, cache)
    }
    fn route_into(&mut self, h: &[f32], out: &mut RouterBatch) {
        self.inner.route_into(h, out)
    }
    fn balance(&self) -> &LayerLoadTracker {
        self.inner.balance()
    }
    fn capacity_factor(&self) -> f64 {
        self.capacity_factor
    }
    fn policy(&self) -> OverflowPolicy {
        self.policy
    }
    fn layers(&self) -> usize {
        self.inner.layers()
    }
    fn d_model(&self) -> usize {
        self.inner.d_model()
    }
    fn last(&self) -> &ModelForward {
        self.inner.last()
    }
}

#[cfg(test)]
#[allow(deprecated)] // the parity oracles ARE the deprecated paths
mod tests {
    use super::*;
    use crate::experts::ExpertBank;
    use crate::model::{synthetic_stacked_model, StackedModel};
    use crate::router::{synthetic_lpr_router, ServingEngine};
    use crate::util::rng::Rng;

    const D: usize = 16;
    const DZ: usize = 8;
    const E: usize = 6;
    const K: usize = 2;
    const FF: usize = 10;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn tiny_model(n_layers: usize) -> StackedModel {
        synthetic_stacked_model(
            "cosine",
            &Rng::new(5),
            n_layers,
            D,
            DZ,
            E,
            K,
            FF,
        )
    }

    fn build(
        model: StackedModel,
        backend: Backend,
        policy: OverflowPolicy,
        cf: f64,
    ) -> Engine {
        Engine::builder()
            .model(model)
            .backend(backend)
            .policy(policy)
            .capacity_factor(cf)
            .build()
            .unwrap()
    }

    /// Acceptance (tentpole parity): the facade is bit-identical to
    /// every legacy path it replaces, for both backends × layers
    /// {1, 3} × workers {1, 2, 3, 8} × every overflow policy — final
    /// residual stream, every layer's combined output, routed batches,
    /// and dispatch plans.
    #[test]
    fn facade_is_bit_identical_to_legacy_paths() {
        let mut rng = Rng::new(71);
        for n_layers in [1usize, 3] {
            let model = tiny_model(n_layers);
            for n in [5usize, 61] {
                let h = rand_vec(&mut rng, n * D);
                for policy in OverflowPolicy::ALL {
                    // legacy oracle: scoped ModelEngine, single thread
                    let mut legacy =
                        crate::model::ModelEngine::new(model.clone(), 1);
                    let mut want = ModelForward::new();
                    legacy.forward(&h, 1.0, policy, &mut want);
                    for par in [1usize, 2, 3, 8] {
                        for backend in [
                            Backend::Scoped { threads: par },
                            Backend::Pool { workers: par },
                        ] {
                            let mut eng = build(
                                model.clone(),
                                backend,
                                policy,
                                1.0,
                            );
                            let out = eng.forward(&h, n);
                            assert_eq!(out.n_tokens, n);
                            assert_eq!(
                                out.hidden, &want.hidden[..],
                                "L={n_layers} n={n} par={par} \
                                 {backend:?} {} hidden diverged",
                                policy.name()
                            );
                            for l in 0..n_layers {
                                assert_eq!(
                                    out.layers[l].combined,
                                    want.layers[l].combined,
                                    "layer {l}"
                                );
                                assert_eq!(
                                    out.layers[l].batch,
                                    want.layers[l].batch
                                );
                                assert_eq!(
                                    out.layers[l].plan,
                                    want.layers[l].plan
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// The L=1 facade also pins against the oldest legacy path:
    /// `ServingEngine::forward_full` with an explicit bank.
    #[test]
    fn single_layer_facade_matches_serving_engine_forward_full() {
        let mut rng = Rng::new(81);
        let r = synthetic_lpr_router("kl", &mut rng, D, DZ, E, K);
        let bank = ExpertBank::new(&Rng::new(3), E, D, FF);
        let h = rand_vec(&mut rng, 33 * D);
        let mut legacy = ServingEngine::new(r.plan().clone(), 2);
        let mut want = FullForward::new();
        legacy.forward_full(
            &h,
            &bank,
            1.25,
            OverflowPolicy::NextChoice,
            &mut want,
        );
        let mut eng = Engine::builder()
            .layer(r.plan().clone(), bank)
            .backend(Backend::Pool { workers: 2 })
            .policy(OverflowPolicy::NextChoice)
            .capacity_factor(1.25)
            .build()
            .unwrap();
        let out = eng.forward(&h, 33);
        assert_eq!(out.layers[0].combined, want.combined);
        assert_eq!(out.layers[0].batch, want.batch);
        assert_eq!(out.layers[0].plan, want.plan);
        // L=1 hidden = h + combined
        let mut hidden = Vec::new();
        crate::model::residual_add(&h, &want.combined, &mut hidden);
        assert_eq!(out.hidden, &hidden[..]);
    }

    /// `route_into` through the facade equals the legacy routing
    /// engine, for both backends.
    #[test]
    fn facade_route_matches_serving_engine() {
        let mut rng = Rng::new(91);
        let model = tiny_model(2);
        let h = rand_vec(&mut rng, 40 * D);
        let mut legacy =
            ServingEngine::new(model.layer(0).plan.clone(), 1);
        let want = legacy.route(&h);
        for backend in
            [Backend::Scoped { threads: 3 }, Backend::Pool { workers: 3 }]
        {
            let mut eng = build(
                model.clone(),
                backend,
                OverflowPolicy::Drop,
                1.25,
            );
            let mut got = RouterBatch::new();
            eng.route_into(&h, &mut got);
            assert_eq!(got, want, "{backend:?}");
            // routing-only batches land in the layer-0 balance window
            assert_eq!(eng.balance().layer(0).total_steps(), 1);
            assert_eq!(eng.balance().layer(0).windowed(), got.load);
        }
    }

    /// Satellite: with a capacity that never drops, `renormalize(true)`
    /// is a bit-exact no-op through the facade.
    #[test]
    fn renormalize_without_drops_is_a_no_op() {
        let mut rng = Rng::new(13);
        let model = tiny_model(2);
        let h = rand_vec(&mut rng, 24 * D);
        // capacity factor E = one bin per (token, slot): cannot overflow
        let cf = E as f64;
        let mut plain = build(
            model.clone(),
            Backend::Scoped { threads: 2 },
            OverflowPolicy::Drop,
            cf,
        );
        let a = plain.forward(&h, 24).hidden.to_vec();
        let mut renorm = Engine::builder()
            .model(model)
            .backend(Backend::Scoped { threads: 2 })
            .capacity_factor(cf)
            .renormalize(true)
            .build()
            .unwrap();
        let out = renorm.forward(&h, 24);
        assert_eq!(out.layers[0].plan.n_dropped, 0);
        assert_eq!(out.hidden, &a[..]);
    }

    /// Satellite: the builder validation matrix — every misconfiguration
    /// returns its typed error, not a panic.
    #[test]
    fn builder_rejects_bad_configs_with_typed_errors() {
        let mut rng = Rng::new(2);
        let r = synthetic_lpr_router("cosine", &mut rng, D, DZ, E, K);
        let plan = r.plan().clone();
        let bank = ExpertBank::new(&Rng::new(1), E, D, FF);

        // no model at all
        assert_eq!(
            Engine::builder().build().unwrap_err(),
            EngineBuildError::MissingModel
        );
        // both .model() and .layer()
        assert_eq!(
            Engine::builder()
                .model(tiny_model(1))
                .layer(plan.clone(), bank.clone())
                .build()
                .unwrap_err(),
            EngineBuildError::ModelAndLayers
        );
        // bad d_model: bank width disagrees with the plan
        let wide_bank = ExpertBank::new(&Rng::new(1), E, 2 * D, FF);
        assert_eq!(
            Engine::builder()
                .layer(plan.clone(), wide_bank)
                .build()
                .unwrap_err(),
            EngineBuildError::LayerMismatch {
                layer: 0,
                what: "d_model",
                plan: D,
                bank: 2 * D,
            }
        );
        // bad d_model: mixed widths across layers
        let r2 = synthetic_lpr_router("cosine", &mut rng, 2 * D, DZ, E, K);
        let bank2 = ExpertBank::new(&Rng::new(1), E, 2 * D, FF);
        assert_eq!(
            Engine::builder()
                .layer(plan.clone(), bank.clone())
                .layer(r2.plan().clone(), bank2)
                .build()
                .unwrap_err(),
            EngineBuildError::WidthMismatch {
                layer: 1,
                d_model: 2 * D,
                expected: D,
            }
        );
        // expert-count mismatch between plan and bank
        let small_bank = ExpertBank::new(&Rng::new(1), E - 1, D, FF);
        assert_eq!(
            Engine::builder()
                .layer(plan.clone(), small_bank)
                .build()
                .unwrap_err(),
            EngineBuildError::LayerMismatch {
                layer: 0,
                what: "expert count",
                plan: E,
                bank: E - 1,
            }
        );
        // top_k > E (plan construction asserts this, so force the state
        // the builder must defend against via the pub config)
        let mut bad_plan = plan.clone();
        bad_plan.cfg.top_k = E + 1;
        assert_eq!(
            Engine::builder()
                .layer(bad_plan, bank.clone())
                .build()
                .unwrap_err(),
            EngineBuildError::TopKExceedsExperts {
                layer: 0,
                top_k: E + 1,
                n_experts: E,
            }
        );
        // zero workers / threads
        assert_eq!(
            Engine::builder()
                .model(tiny_model(1))
                .backend(Backend::Pool { workers: 0 })
                .build()
                .unwrap_err(),
            EngineBuildError::ZeroParallelism { backend: "pool" }
        );
        assert_eq!(
            Engine::builder()
                .model(tiny_model(1))
                .backend(Backend::Scoped { threads: 0 })
                .build()
                .unwrap_err(),
            EngineBuildError::ZeroParallelism { backend: "scoped" }
        );
        // zero / negative / NaN capacity factor
        for cf in [0.0f64, -1.0] {
            assert_eq!(
                Engine::builder()
                    .model(tiny_model(1))
                    .capacity_factor(cf)
                    .build()
                    .unwrap_err(),
                EngineBuildError::BadCapacityFactor(cf)
            );
        }
        assert!(matches!(
            Engine::builder()
                .model(tiny_model(1))
                .capacity_factor(f64::NAN)
                .build()
                .unwrap_err(),
            EngineBuildError::BadCapacityFactor(_)
        ));
        // every error renders through Display and the shared
        // crate-level conversion
        let e = Engine::builder().build().unwrap_err();
        assert!(!e.to_string().is_empty());
        let shared: crate::Error = e.into();
        assert!(shared.to_string().contains("model"));
    }

    /// The facade's accessors describe the stack; `.layer()` pairs
    /// assemble in call order.
    #[test]
    fn accessors_and_layer_assembly() {
        let model = tiny_model(3);
        let eng = build(
            model,
            Backend::Pool { workers: 2 },
            OverflowPolicy::LeastLoaded,
            1.5,
        );
        assert_eq!(eng.layers(), 3);
        assert_eq!(eng.d_model(), D);
        assert_eq!(eng.backend(), Backend::Pool { workers: 2 });
        assert_eq!(eng.policy(), OverflowPolicy::LeastLoaded);
        assert!((eng.capacity_factor() - 1.5).abs() < 1e-12);
        assert_eq!(eng.balance().n_layers(), 3);
        // pre-first-forward: last() is valid and empty (the PR 3
        // contract ServeRuntime relies on)
        assert!(eng.last().hidden.is_empty());
        assert_eq!(eng.last().layers.len(), 3);
        assert!(eng.last().layers[0].combined.is_empty());
        // the boxed view keeps the same answers
        let mut boxed = eng.into_inner();
        assert_eq!(boxed.layers(), 3);
        assert_eq!(boxed.d_model(), D);
        let h = vec![0.1f32; 4 * D];
        assert_eq!(boxed.forward(&h, 4).hidden.len(), 4 * D);
    }

    /// Satellite: the `.placement(..)` knob — more devices than experts
    /// under a non-trivial placement is a typed builder error (through
    /// the crate-level `Error` too), the round-robin default never
    /// triggers it, and with placement engaged the facade stays
    /// bit-identical across backends and worker counts.
    #[test]
    fn placement_knob_validates_and_stays_bit_identical() {
        use crate::dispatch::{PlacementConfig, PlacementPolicy};
        let err = Engine::builder()
            .model(tiny_model(1))
            .backend(Backend::Pool { workers: E + 2 })
            .placement(PlacementConfig::with_policy(
                PlacementPolicy::LoadAware,
            ))
            .build()
            .unwrap_err();
        assert_eq!(
            err,
            EngineBuildError::DevicesExceedExperts {
                n_experts: E,
                n_devices: E + 2,
            }
        );
        assert!(err.to_string().contains("devices exceed"), "{err}");
        let shared: crate::Error = err.into();
        assert!(shared.to_string().contains("engine configuration"));
        // the round-robin default builds fine at the same worker count
        assert!(Engine::builder()
            .model(tiny_model(1))
            .backend(Backend::Pool { workers: E + 2 })
            .build()
            .is_ok());
        // placement moves wall time, never bytes: every policy ×
        // backend × parallelism equals the no-knob oracle
        let mut rng = Rng::new(41);
        let model = tiny_model(2);
        let h = rand_vec(&mut rng, 37 * D);
        let want = build(
            model.clone(),
            Backend::Scoped { threads: 2 },
            OverflowPolicy::Drop,
            1.25,
        )
        .forward(&h, 37)
        .hidden
        .to_vec();
        for policy in
            [PlacementPolicy::LoadAware, PlacementPolicy::Replicated]
        {
            for backend in [
                Backend::Scoped { threads: 3 },
                Backend::Pool { workers: 2 },
                Backend::Pool { workers: 3 },
            ] {
                let mut eng = Engine::builder()
                    .model(model.clone())
                    .backend(backend)
                    .capacity_factor(1.25)
                    .placement(PlacementConfig::with_policy(policy))
                    .build()
                    .unwrap();
                assert_eq!(
                    eng.forward(&h, 37).hidden.to_vec(),
                    want,
                    "{backend:?} {} diverged with placement engaged",
                    policy.name()
                );
            }
        }
    }

    /// Tentpole: the builder's `.kernel(..)` knob. The default (Naive)
    /// is bit-identical to an engine that never touched the knob — the
    /// goldens cannot move — and every kernel is bit-identical across
    /// backends through the facade.
    #[test]
    fn kernel_knob_keeps_backends_bit_identical() {
        let mut rng = Rng::new(29);
        let model = tiny_model(2);
        let h = rand_vec(&mut rng, 31 * D);
        let default_hidden = build(
            model.clone(),
            Backend::Scoped { threads: 2 },
            OverflowPolicy::Drop,
            1.25,
        )
        .forward(&h, 31)
        .hidden
        .to_vec();
        for kernel in Kernel::ALL {
            let mut per_backend = Vec::new();
            for backend in [
                Backend::Scoped { threads: 2 },
                Backend::Pool { workers: 3 },
            ] {
                let mut eng = Engine::builder()
                    .model(model.clone())
                    .backend(backend)
                    .kernel(kernel)
                    .build()
                    .unwrap();
                per_backend.push(eng.forward(&h, 31).hidden.to_vec());
            }
            assert_eq!(
                per_backend[0],
                per_backend[1],
                "{} diverged across backends",
                kernel.name()
            );
            if kernel == Kernel::Naive {
                assert_eq!(
                    per_backend[0], default_hidden,
                    "explicit Naive must equal the builder default"
                );
            }
            // Blocked shares Naive's f32 accumulation order exactly
            // (see kernels::blocked_gemm), so it cannot move either.
            if kernel == Kernel::Blocked {
                assert_eq!(per_backend[0], default_hidden);
            }
        }
    }

    /// Tentpole: `.weight_dtype(..)` quantizes the banks at build time.
    /// The quantized forward stays within the documented round-trip
    /// bounds of the f32 reference and remains bit-identical across
    /// backends per dtype.
    #[test]
    fn weight_dtype_knob_quantizes_within_tolerance() {
        use crate::kernels::WeightDtype;
        let mut rng = Rng::new(37);
        let model = tiny_model(2);
        let h = rand_vec(&mut rng, 19 * D);
        let want = build(
            model.clone(),
            Backend::Scoped { threads: 1 },
            OverflowPolicy::Drop,
            1.25,
        )
        .forward(&h, 19)
        .hidden
        .to_vec();
        for dtype in [WeightDtype::Bf16, WeightDtype::Int8] {
            let mut per_backend = Vec::new();
            for backend in [
                Backend::Scoped { threads: 2 },
                Backend::Pool { workers: 2 },
            ] {
                let mut eng = Engine::builder()
                    .model(model.clone())
                    .backend(backend)
                    .weight_dtype(dtype)
                    .build()
                    .unwrap();
                per_backend.push(eng.forward(&h, 19).hidden.to_vec());
            }
            assert_eq!(
                per_backend[0],
                per_backend[1],
                "{} diverged across backends",
                dtype.name()
            );
            // Loose end-to-end envelope: two quantized GEMMs per layer
            // compose, so allow a generous multiple of the per-GEMM
            // bound; the tight bounds are pinned in kernels::tests.
            let mut max_rel = 0.0f32;
            for (a, b) in per_backend[0].iter().zip(&want) {
                let rel = (a - b).abs() / b.abs().max(1.0);
                max_rel = max_rel.max(rel);
            }
            assert!(
                max_rel < 0.25,
                "{} drifted {max_rel} from f32",
                dtype.name()
            );
            assert!(
                max_rel > 0.0,
                "{} produced bit-identical output — quantization \
                 apparently never happened",
                dtype.name()
            );
        }
    }

    /// Satellite: the kernel auto-pick selection matrix. With no
    /// explicit `.kernel(..)`, f32 weights keep the Naive golden
    /// default and quantized weights get Blocked (panel-at-a-time
    /// dequantization); an explicit call always wins, for every
    /// kernel × dtype combination.
    #[test]
    fn builder_auto_picks_blocked_for_quantized_weights() {
        use crate::kernels::WeightDtype;
        let pick = |kernel: Option<Kernel>, dtype: WeightDtype| {
            let mut b = Engine::builder()
                .model(tiny_model(1))
                .weight_dtype(dtype);
            if let Some(k) = kernel {
                b = b.kernel(k);
            }
            b.build().unwrap().kernel()
        };
        // auto-pick row: f32 -> Naive, quantized -> Blocked
        assert_eq!(pick(None, WeightDtype::F32), Kernel::Naive);
        assert_eq!(pick(None, WeightDtype::Bf16), Kernel::Blocked);
        assert_eq!(pick(None, WeightDtype::Int8), Kernel::Blocked);
        // explicit rows: the caller's choice survives every dtype
        for kernel in Kernel::ALL {
            for dtype in
                [WeightDtype::F32, WeightDtype::Bf16, WeightDtype::Int8]
            {
                assert_eq!(
                    pick(Some(kernel), dtype),
                    kernel,
                    "explicit {} lost to auto-pick under {}",
                    kernel.name(),
                    dtype.name()
                );
            }
        }
        // and the auto-pick never changes bits: Blocked ≡ Naive
        let mut rng = Rng::new(47);
        let h = rand_vec(&mut rng, 17 * D);
        use crate::kernels::WeightDtype::Bf16;
        let mut auto_eng = Engine::builder()
            .model(tiny_model(1))
            .weight_dtype(Bf16)
            .build()
            .unwrap();
        let mut naive_eng = Engine::builder()
            .model(tiny_model(1))
            .weight_dtype(Bf16)
            .kernel(Kernel::Naive)
            .build()
            .unwrap();
        assert_eq!(
            auto_eng.forward(&h, 17).hidden,
            naive_eng.forward(&h, 17).hidden
        );
    }

    /// Satellite (regression): handing the builder an
    /// already-quantized bank and asking for a different dtype is the
    /// typed [`EngineBuildError::RequantizeDtype`] — it used to be a
    /// panic inside `ExpertBank::quantized`.
    #[test]
    fn requantize_error_surfaces_through_builder() {
        use crate::kernels::WeightDtype;
        let mut rng = Rng::new(2);
        let r = synthetic_lpr_router("cosine", &mut rng, D, DZ, E, K);
        let bank = ExpertBank::new(&Rng::new(1), E, D, FF);
        let int8 = bank.quantized(WeightDtype::Int8).unwrap();
        let err = Engine::builder()
            .layer(r.plan().clone(), int8.clone())
            .weight_dtype(WeightDtype::Bf16)
            .build()
            .unwrap_err();
        assert_eq!(
            err,
            EngineBuildError::RequantizeDtype {
                from: WeightDtype::Int8,
                to: WeightDtype::Bf16,
            }
        );
        assert!(err.to_string().contains("requantize"), "{err}");
        // same dtype is the no-op clone, so it still builds
        assert!(Engine::builder()
            .layer(r.plan().clone(), int8)
            .weight_dtype(WeightDtype::Int8)
            .build()
            .is_ok());
    }

    /// Tentpole: `.gemm_tiles(..)` moves cache behaviour, never bits —
    /// the forward is bitwise tile-invariant per kernel across both
    /// backends — and a zero dimension is the typed
    /// [`EngineBuildError::BadGemmTiles`].
    #[test]
    fn gemm_tiles_knob_keeps_results_bit_identical() {
        use crate::kernels::GemmTiles;
        let mut rng = Rng::new(53);
        let model = tiny_model(2);
        let h = rand_vec(&mut rng, 23 * D);
        for kernel in [Kernel::Naive, Kernel::Blocked, Kernel::Simd] {
            let mut oracle = Engine::builder()
                .model(model.clone())
                .kernel(kernel)
                .build()
                .unwrap();
            let want = oracle.forward(&h, 23).hidden.to_vec();
            for tiles in [
                GemmTiles::new(1, 1, 1),
                GemmTiles::new(8, 16, 8),
                GemmTiles::new(512, 512, 512),
            ] {
                for backend in [
                    Backend::Scoped { threads: 2 },
                    Backend::Pool { workers: 3 },
                ] {
                    let mut eng = Engine::builder()
                        .model(model.clone())
                        .backend(backend)
                        .kernel(kernel)
                        .gemm_tiles(tiles)
                        .build()
                        .unwrap();
                    assert_eq!(eng.gemm_tiles(), tiles);
                    assert_eq!(
                        eng.forward(&h, 23).hidden,
                        &want[..],
                        "{} {backend:?} tiles {tiles} moved bits",
                        kernel.name()
                    );
                }
            }
        }
        let err = Engine::builder()
            .model(model)
            .gemm_tiles(GemmTiles::new(0, 4, 4))
            .build()
            .unwrap_err();
        assert!(
            matches!(err, EngineBuildError::BadGemmTiles { .. }),
            "{err}"
        );
        assert!(err.to_string().contains("tiles"), "{err}");
    }

    /// Tentpole: a gated (SwiGLU) bank serves bit-identically across
    /// backends and parallelism for every kernel, and its output
    /// actually differs from the ungated bank built from the same
    /// `w1`/`w2` — the gate is live, not decorative.
    #[test]
    fn gated_banks_stay_bit_identical_across_backends() {
        let mut rng = Rng::new(59);
        let r = synthetic_lpr_router("cosine", &mut rng, D, DZ, E, K);
        let w1 = rand_vec(&mut rng, E * D * FF);
        let w3 = rand_vec(&mut rng, E * D * FF);
        let w2 = rand_vec(&mut rng, E * FF * D);
        let gated = ExpertBank::from_weights_gated(
            E,
            D,
            FF,
            w1.clone(),
            w3,
            w2.clone(),
        );
        let ungated = ExpertBank::from_weights(E, D, FF, w1, w2);
        let h = rand_vec(&mut rng, 21 * D);
        let mut oracle = Engine::builder()
            .layer(r.plan().clone(), gated.clone())
            .backend(Backend::Scoped { threads: 1 })
            .build()
            .unwrap();
        let want = oracle.forward(&h, 21).hidden.to_vec();
        let mut plain = Engine::builder()
            .layer(r.plan().clone(), ungated)
            .backend(Backend::Scoped { threads: 1 })
            .build()
            .unwrap();
        assert_ne!(
            plain.forward(&h, 21).hidden,
            &want[..],
            "the gate projection changed nothing"
        );
        for kernel in Kernel::ALL {
            let mut per_config = Vec::new();
            for backend in [
                Backend::Scoped { threads: 3 },
                Backend::Pool { workers: 2 },
                Backend::Pool { workers: 8 },
            ] {
                let mut eng = Engine::builder()
                    .layer(r.plan().clone(), gated.clone())
                    .backend(backend)
                    .kernel(kernel)
                    .build()
                    .unwrap();
                per_config.push(eng.forward(&h, 21).hidden.to_vec());
            }
            assert!(
                per_config.windows(2).all(|w| w[0] == w[1]),
                "{} diverged across gated backends",
                kernel.name()
            );
            if matches!(kernel, Kernel::Naive | Kernel::Blocked) {
                assert_eq!(
                    per_config[0], want,
                    "{} diverged from the gated oracle",
                    kernel.name()
                );
            }
        }
    }
}
