//! [`EngineBuilder`]: the one place engine configuration lives.
//!
//! Before this module, every scenario (CLI subcommand, repro table,
//! bench, example) hand-wired the serving stack — pick a
//! `ServingEngine` / `PoolEngine` / `ModelEngine`, thread the capacity
//! factor and overflow policy through each call, remember to
//! `set_renormalize` on the right object — and misconfigurations
//! surfaced as panics deep inside the pipeline (or not at all). The
//! builder owns all of that configuration up front and validates it
//! into **typed** [`EngineBuildError`]s (`Display` +
//! `std::error::Error`, convertible into [`crate::Error`]) before any
//! worker spawns or buffer allocates.
//!
//! ```
//! use lpr::engine::{Backend, Engine, MoeEngine};
//! use lpr::dispatch::OverflowPolicy;
//! use lpr::model::synthetic_stacked_model;
//! use lpr::util::rng::Rng;
//!
//! let model =
//!     synthetic_stacked_model("cosine", &Rng::new(7), 2, 8, 4, 4, 2, 6);
//! let mut engine = Engine::builder()
//!     .model(model)
//!     .backend(Backend::Scoped { threads: 2 })
//!     .policy(OverflowPolicy::LeastLoaded)
//!     .capacity_factor(1.25)
//!     .renormalize(true)
//!     .build()?;
//! let h = vec![0.5f32; 4 * 8];
//! let out = engine.forward(&h, 4);
//! assert_eq!(out.hidden.len(), 4 * 8);
//! assert_eq!(engine.layers(), 2);
//! # Ok::<(), lpr::engine::EngineBuildError>(())
//! ```

use crate::dispatch::placement::{PlacementConfig, PlacementPolicy};
use crate::dispatch::plan::OverflowPolicy;
use crate::experts::ExpertBank;
use crate::kernels::{GemmTiles, Kernel, WeightDtype};
use crate::model::{MoeLayer, StackedModel};
use crate::router::RouterPlan;

use super::{Engine, PoolBackend, ScopedBackend};

/// Which execution backend serves the model. Both run the identical
/// route → plan → FFN → combine → residual pipeline and are
/// bit-identical to each other for every thread/worker count (the
/// thread-determinism contract; pinned by the parity tests in
/// `engine::tests`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Scoped worker threads spawned per batch
    /// (`model::ModelEngine` over `router::ServingEngine`): no
    /// long-lived threads, best for one-shot or bursty work.
    Scoped { threads: usize },
    /// Persistent channel-fed worker pool (`serve::PoolEngine`): the
    /// workers outlive every batch, best for sustained serving traffic.
    Pool { workers: usize },
}

impl Backend {
    /// The configured parallelism (threads or workers).
    pub fn parallelism(self) -> usize {
        match self {
            Backend::Scoped { threads } => threads,
            Backend::Pool { workers } => workers,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Scoped { .. } => "scoped",
            Backend::Pool { .. } => "pool",
        }
    }
}

/// A rejected engine configuration. Every variant names the offending
/// layer/value so `main.rs` can print it verbatim instead of
/// re-deriving context by hand.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineBuildError {
    /// Neither [`EngineBuilder::model`] nor [`EngineBuilder::layer`]
    /// was called.
    MissingModel,
    /// Both [`EngineBuilder::model`] and [`EngineBuilder::layer`] were
    /// called — ambiguous; pick one.
    ModelAndLayers,
    /// A layer's router plan and expert bank disagree on a dimension.
    LayerMismatch {
        layer: usize,
        what: &'static str,
        plan: usize,
        bank: usize,
    },
    /// A layer's `d_model` differs from layer 0's — the residual
    /// stream needs one width.
    WidthMismatch { layer: usize, d_model: usize, expected: usize },
    /// A layer's `d_model` is zero.
    ZeroWidth { layer: usize },
    /// A layer routes top-0: no expert is ever selected.
    ZeroTopK { layer: usize },
    /// A layer's `top_k` exceeds its expert count — the flat `[N·k]`
    /// routed layout cannot hold `k` distinct experts.
    TopKExceedsExperts { layer: usize, top_k: usize, n_experts: usize },
    /// `Backend::Scoped { threads: 0 }` / `Backend::Pool { workers: 0 }`.
    /// (The legacy constructors silently clamped this to 1; the builder
    /// rejects it instead.)
    ZeroParallelism { backend: &'static str },
    /// Capacity factor must be finite and `> 0` (0 would squeeze every
    /// expert bin to the minimum regardless of batch size — always a
    /// misconfiguration, never an intent).
    BadCapacityFactor(f64),
    /// More devices (or pool workers, with a placement planner
    /// engaged) than experts — expert-parallel placement needs at
    /// least one expert per device. Also raised by
    /// [`crate::dispatch::DispatchSim::new`], which used to panic on
    /// this instead.
    DevicesExceedExperts { n_experts: usize, n_devices: usize },
    /// An already-quantized expert bank was asked to re-quantize into a
    /// *different* storage dtype — that would compound round-trip
    /// error, so [`crate::experts::ExpertBank::quantized`] rejects it
    /// (it used to panic).
    RequantizeDtype { from: WeightDtype, to: WeightDtype },
    /// The GEMM cache tiles — from [`EngineBuilder::gemm_tiles`] or the
    /// `LPR_GEMM_TILES` environment override — failed to parse or
    /// validate; `detail` carries the parser's message.
    BadGemmTiles { detail: String },
}

impl std::fmt::Display for EngineBuildError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineBuildError::MissingModel => write!(
                f,
                "engine builder needs a model: call .model(..) or \
                 .layer(..) before .build()"
            ),
            EngineBuildError::ModelAndLayers => write!(
                f,
                "engine builder got both .model(..) and .layer(..) — \
                 supply the stack one way or the other"
            ),
            EngineBuildError::LayerMismatch { layer, what, plan, bank } => {
                write!(
                    f,
                    "layer {layer}: router plan and expert bank disagree \
                     on {what} (plan {plan}, bank {bank})"
                )
            }
            EngineBuildError::WidthMismatch { layer, d_model, expected } => {
                write!(
                    f,
                    "layer {layer}: d_model {d_model} differs from layer \
                     0's {expected} — the residual stream needs one width"
                )
            }
            EngineBuildError::ZeroWidth { layer } => {
                write!(f, "layer {layer}: d_model must be >= 1")
            }
            EngineBuildError::ZeroTopK { layer } => {
                write!(f, "layer {layer}: top_k must be >= 1")
            }
            EngineBuildError::TopKExceedsExperts {
                layer,
                top_k,
                n_experts,
            } => write!(
                f,
                "layer {layer}: top_k ({top_k}) exceeds the expert count \
                 ({n_experts})"
            ),
            EngineBuildError::ZeroParallelism { backend } => write!(
                f,
                "{backend} backend needs at least 1 worker thread"
            ),
            EngineBuildError::BadCapacityFactor(cf) => write!(
                f,
                "capacity factor must be finite and > 0, got {cf}"
            ),
            EngineBuildError::DevicesExceedExperts {
                n_experts,
                n_devices,
            } => write!(
                f,
                "{n_devices} devices exceed {n_experts} experts — \
                 expert-parallel placement needs at least one expert \
                 per device"
            ),
            EngineBuildError::RequantizeDtype { from, to } => write!(
                f,
                "cannot requantize {} weights to {} — quantization \
                 must start from f32 (rebuild the bank in full \
                 precision first)",
                from.name(),
                to.name()
            ),
            EngineBuildError::BadGemmTiles { detail } => {
                write!(f, "bad GEMM tiles: {detail}")
            }
        }
    }
}

impl std::error::Error for EngineBuildError {}

/// Builder for [`Engine`] — see the module docs for a worked example.
/// Defaults: `Backend::Scoped { threads: 1 }`, `OverflowPolicy::Drop`,
/// capacity factor 1.25, renormalization off, auto-picked GEMM kernel
/// ([`Kernel::Naive`] for f32 weights, [`Kernel::Blocked`] once
/// [`EngineBuilder::weight_dtype`] quantizes — see
/// [`EngineBuilder::kernel`]), default [`GemmTiles`], f32 weights.
#[derive(Debug, Clone, Default)]
pub struct EngineBuilder {
    model: Option<StackedModel>,
    raw_layers: Vec<(RouterPlan, ExpertBank)>,
    backend: Option<Backend>,
    policy: OverflowPolicy,
    capacity_factor: Option<f64>,
    renormalize: bool,
    kernel: Option<Kernel>,
    gemm_tiles: Option<GemmTiles>,
    weight_dtype: WeightDtype,
    placement: PlacementConfig,
}

impl EngineBuilder {
    pub fn new() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Serve a pre-assembled [`StackedModel`] (e.g. from
    /// `model::bridge` or `model::synthetic_stacked_model`).
    pub fn model(mut self, model: StackedModel) -> EngineBuilder {
        self.model = Some(model);
        self
    }

    /// Push one layer as a raw (plan, bank) pair; layers stack in call
    /// order. Unlike `MoeLayer::new`, mismatched pairs surface as typed
    /// [`EngineBuildError`]s at [`Self::build`], not panics.
    pub fn layer(
        mut self,
        plan: RouterPlan,
        bank: ExpertBank,
    ) -> EngineBuilder {
        self.raw_layers.push((plan, bank));
        self
    }

    /// Execution backend (default `Scoped { threads: 1 }`).
    pub fn backend(mut self, backend: Backend) -> EngineBuilder {
        self.backend = Some(backend);
        self
    }

    /// Overflow policy applied at every layer's dispatch-plan build
    /// (default [`OverflowPolicy::Drop`]).
    pub fn policy(mut self, policy: OverflowPolicy) -> EngineBuilder {
        self.policy = policy;
        self
    }

    /// Expert capacity factor per batch (default 1.25; shared
    /// `dispatch::capacity_for` rule).
    pub fn capacity_factor(mut self, cf: f64) -> EngineBuilder {
        self.capacity_factor = Some(cf);
        self
    }

    /// Rescale a partially-dropped token's surviving gate weights to
    /// its pre-drop mass in every layer's combine (default off). With
    /// no drops this is a bit-exact no-op (pinned by
    /// `renormalize_without_drops_is_a_no_op`).
    pub fn renormalize(mut self, on: bool) -> EngineBuilder {
        self.renormalize = on;
        self
    }

    /// GEMM micro-kernel for every layer's expert FFN stage. When not
    /// called, the builder auto-picks: [`Kernel::Naive`] (bit-identical
    /// to the historic goldens) for f32 weights, [`Kernel::Blocked`]
    /// once [`EngineBuilder::weight_dtype`] quantizes the banks —
    /// quantized stores pay a per-element dequantize in the naive inner
    /// loop but amortize it panel-at-a-time in the blocked path, and
    /// Blocked stays bitwise equal to Naive, so the switch never
    /// changes results. An explicit call always wins. All four kernels
    /// ([`Kernel::Simd`] / [`Kernel::Neon`] included) keep the
    /// bit-identical-across-threads/backends contract per kernel; see
    /// [`crate::kernels`] for the tiling scheme and the cross-kernel
    /// equality guarantees.
    pub fn kernel(mut self, kernel: Kernel) -> EngineBuilder {
        self.kernel = Some(kernel);
        self
    }

    /// Cache-blocking tile sizes (MC×KC×NC) for the blocked/SIMD GEMM
    /// paths. Precedence: this call, else a well-formed
    /// `LPR_GEMM_TILES=MCxKCxNC` environment override, else the
    /// [`GemmTiles::default`] constants. Tiles move cache behaviour,
    /// never results — every kernel is bitwise tile-invariant (pinned
    /// in `kernels::tests`) — so this knob is safe to sweep in benches.
    /// Malformed values (zero dims, unparseable env strings) surface as
    /// [`EngineBuildError::BadGemmTiles`] at [`Self::build`].
    pub fn gemm_tiles(mut self, tiles: GemmTiles) -> EngineBuilder {
        self.gemm_tiles = Some(tiles);
        self
    }

    /// Storage dtype for every layer's FFN weights (default
    /// [`WeightDtype::F32`]). Non-f32 dtypes quantize the banks once at
    /// build time — halving (bf16) or quartering (int8) the weight
    /// bytes the FFN streams per token, at the round-trip error bounds
    /// documented in [`crate::kernels`]. Biases and accumulation stay
    /// f32.
    pub fn weight_dtype(mut self, dtype: WeightDtype) -> EngineBuilder {
        self.weight_dtype = dtype;
        self
    }

    /// Expert→worker placement policy for the pool backend (default
    /// round-robin — the historical layout, bit-identical to every
    /// pre-placement pin). `LoadAware` re-partitions each batch's
    /// expert buckets onto workers by LPT over the measured load
    /// window; [`PlacementPolicy::Replicated`] additionally
    /// splits the hottest experts' rows across workers with the
    /// deterministic `(token_slot, expert, step)` replica hash. Either
    /// way the combined outputs stay bit-identical to round-robin —
    /// every grouped row's FFN output depends only on its own input
    /// row — so this knob moves wall time, never results. The scoped
    /// backend (fresh threads per batch, no persistent worker↔expert
    /// affinity) accepts the knob and keeps its per-batch contiguous
    /// split: it is the bit-identity oracle the pool is checked
    /// against.
    pub fn placement(mut self, placement: PlacementConfig) -> EngineBuilder {
        self.placement = placement;
        self
    }

    /// Validate the configuration and construct the backend. The only
    /// place in the crate where backends are built for scenario code.
    pub fn build(self) -> Result<Engine, EngineBuildError> {
        let model = match (self.model, self.raw_layers.is_empty()) {
            (Some(_), false) => {
                return Err(EngineBuildError::ModelAndLayers)
            }
            (None, true) => return Err(EngineBuildError::MissingModel),
            (Some(m), true) => {
                validate_layers(m.layers().iter().map(|l| (&l.plan, &l.bank)))?;
                m
            }
            (None, false) => {
                validate_layers(
                    self.raw_layers.iter().map(|(p, b)| (p, b)),
                )?;
                // validation passed, so the MoeLayer/StackedModel
                // construction asserts cannot fire
                StackedModel::new(
                    self.raw_layers
                        .into_iter()
                        .map(|(p, b)| MoeLayer::new(p, b))
                        .collect(),
                )
            }
        };
        let backend = self.backend.unwrap_or(Backend::Scoped { threads: 1 });
        if backend.parallelism() == 0 {
            return Err(EngineBuildError::ZeroParallelism {
                backend: backend.name(),
            });
        }
        let cf = self.capacity_factor.unwrap_or(1.25);
        if !cf.is_finite() || cf <= 0.0 {
            return Err(EngineBuildError::BadCapacityFactor(cf));
        }
        if self.placement.policy != PlacementPolicy::RoundRobin {
            // a placement planner needs at least one expert per worker
            // "device" on every layer it packs
            let workers = backend.parallelism();
            if let Some(min_e) =
                model.layers().iter().map(|l| l.plan.cfg.n_experts).min()
            {
                if min_e < workers {
                    return Err(EngineBuildError::DevicesExceedExperts {
                        n_experts: min_e,
                        n_devices: workers,
                    });
                }
            }
        }
        // Kernel auto-pick: an explicit .kernel(..) always wins;
        // otherwise quantized weights get Blocked (panel-at-a-time
        // dequantization instead of a per-element dequant in the naive
        // inner loop — same bits, since Blocked ≡ Naive bitwise) and
        // f32 keeps the Naive golden default.
        let kernel = self.kernel.unwrap_or(
            if self.weight_dtype != WeightDtype::F32 {
                Kernel::Blocked
            } else {
                Kernel::Naive
            },
        );
        // Tiles: explicit > LPR_GEMM_TILES env > defaults; malformed
        // values are typed errors, never silent fallbacks.
        let tiles = match self.gemm_tiles {
            Some(t) => t,
            None => GemmTiles::from_env()
                .map_err(|detail| EngineBuildError::BadGemmTiles {
                    detail,
                })?
                .unwrap_or_default(),
        };
        tiles
            .validate()
            .map_err(|detail| EngineBuildError::BadGemmTiles { detail })?;
        // Quantize once at build time so the serving hot loop only ever
        // sees a bank in its final storage dtype. `quantized` is a
        // no-op clone for matching dtypes, so f32 stays zero-cost.
        let model = if self.weight_dtype == WeightDtype::F32 {
            model
        } else {
            let mut layers = Vec::new();
            for l in model.into_layers() {
                let bank = l.bank.quantized(self.weight_dtype)?;
                layers.push(MoeLayer::with_attn(l.plan, bank, l.attn));
            }
            StackedModel::new(layers)
        };
        let inner: Box<dyn super::MoeEngine> = match backend {
            Backend::Scoped { threads } => Box::new(ScopedBackend::new(
                model,
                threads,
                cf,
                self.policy,
                self.renormalize,
                kernel,
                tiles,
            )),
            Backend::Pool { workers } => {
                let mut pool = PoolBackend::new(
                    model,
                    workers,
                    cf,
                    self.policy,
                    self.renormalize,
                    kernel,
                    tiles,
                );
                pool.set_placement(self.placement.clone());
                Box::new(pool)
            }
        };
        Ok(Engine::from_parts(inner, backend, cf, self.policy, kernel, tiles))
    }
}

/// The shared layer validation behind both builder input forms.
fn validate_layers<'a>(
    layers: impl Iterator<Item = (&'a RouterPlan, &'a ExpertBank)>,
) -> Result<(), EngineBuildError> {
    let mut expected_d = None;
    let mut any = false;
    for (layer, (plan, bank)) in layers.enumerate() {
        any = true;
        let cfg = &plan.cfg;
        if cfg.d_model == 0 {
            return Err(EngineBuildError::ZeroWidth { layer });
        }
        if cfg.d_model != bank.d_model {
            return Err(EngineBuildError::LayerMismatch {
                layer,
                what: "d_model",
                plan: cfg.d_model,
                bank: bank.d_model,
            });
        }
        if cfg.n_experts != bank.n_experts {
            return Err(EngineBuildError::LayerMismatch {
                layer,
                what: "expert count",
                plan: cfg.n_experts,
                bank: bank.n_experts,
            });
        }
        if cfg.top_k == 0 {
            return Err(EngineBuildError::ZeroTopK { layer });
        }
        if cfg.top_k > cfg.n_experts {
            return Err(EngineBuildError::TopKExceedsExperts {
                layer,
                top_k: cfg.top_k,
                n_experts: cfg.n_experts,
            });
        }
        let expected = *expected_d.get_or_insert(cfg.d_model);
        if cfg.d_model != expected {
            return Err(EngineBuildError::WidthMismatch {
                layer,
                d_model: cfg.d_model,
                expected,
            });
        }
    }
    debug_assert!(any, "builder forms guarantee at least one layer");
    Ok(())
}
