//! Continuous-batching autoregressive decode loop over the
//! [`MoeEngine`] facade.
//!
//! A [`DecodeSession`] owns an engine, a [`DecodeHead`] (tied
//! embedding + final norm → greedy argmax), a slot-pooled
//! [`KvCache`], and a [`BatchQueue`] admission lane. Requests enter
//! through [`DecodeSession::submit`] as token prompts; every
//! [`DecodeSession::step`] coalesces all in-flight work into **one
//! ragged step batch** — prompt prefills for sequences admitted this
//! step, a single row for every sequence already generating — and runs
//! it through [`MoeEngine::forward_seqs`] in one forward. New requests
//! join mid-generation as cache slots free up (continuous batching);
//! finished sequences release their slot the step they complete.
//!
//! # Determinism and the no-drop precondition
//!
//! Greedy decode here is bit-deterministic: every pipeline stage is
//! row-independent with a fixed reduction order, so a sequence's
//! hidden states — and therefore its argmax tokens — do not depend on
//! which other sequences share its step batches, on thread count, or
//! on backend. That holds **provided no token is ever dropped**:
//! dispatch bins scale with the step-batch size, so a capacity factor
//! that drops under load would make routing depend on who else is in
//! the batch. Build the engine with `capacity_factor >= n_experts`
//! (bins of `n·k` slots can never overflow) when batch-invariant
//! output matters; [`StepStat::n_dropped`] reports violations.
//!
//! # Telemetry
//!
//! Each step records a [`StepStat`]: batch shape, latency, and the
//! **per-step** per-layer balance view
//! ([`LayerLoadTracker::last_step`](crate::metrics::LayerLoadTracker::last_step))
//! — the paper's Gini / min-max numbers for the n=1 serving regime,
//! where the engine's rolling window would smear consecutive
//! single-token steps together.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::time::Instant;

use super::MoeEngine;
use crate::metrics::LayerBalance;
use crate::model::cache::{KvCache, SeqSpan};
use crate::model::DecodeHead;
use crate::serve::queue::{BatchQueue, SubmitError};

/// One generation request: a token prompt plus a generation budget.
/// Generation is greedy (argmax, ties to the lowest token id) and runs
/// for exactly `max_new` tokens — the synthetic vocabulary has no
/// end-of-sequence convention.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenRequest {
    /// Prompt token ids, each `< vocab`.
    pub prompt: Vec<usize>,
    /// Tokens to generate after the prompt (>= 1).
    pub max_new: usize,
}

/// Typed submission failures. Everything here is caught at
/// [`DecodeSession::submit`] time — a request that enters the session
/// always runs to completion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// The admission queue refused the prompt (full / over-sized).
    Queue(SubmitError),
    /// `prompt + max_new` positions would exceed the cache's per-slot
    /// `max_seq` bound.
    TooLong { prompt: usize, max_new: usize, max_seq: usize },
    /// The prompt carries no tokens.
    EmptyPrompt,
    /// `max_new` is zero.
    NoNewTokens,
    /// A prompt token is outside the head's vocabulary.
    BadToken { tok: usize, vocab: usize },
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            DecodeError::Queue(ref e) => write!(f, "admission queue: {e}"),
            DecodeError::TooLong { prompt, max_new, max_seq } => write!(
                f,
                "prompt of {prompt} tokens + {max_new} generated exceeds \
                 the kv cache max_seq bound of {max_seq}"
            ),
            DecodeError::EmptyPrompt => {
                write!(f, "prompt must carry at least one token")
            }
            DecodeError::NoNewTokens => {
                write!(f, "max_new must be >= 1")
            }
            DecodeError::BadToken { tok, vocab } => write!(
                f,
                "prompt token {tok} is outside the vocabulary of {vocab}"
            ),
        }
    }
}

impl std::error::Error for DecodeError {}

impl From<SubmitError> for DecodeError {
    fn from(e: SubmitError) -> DecodeError {
        DecodeError::Queue(e)
    }
}

/// A completed generation, as drained by
/// [`DecodeSession::take_finished`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FinishedSeq {
    /// Request id handed out by [`DecodeSession::submit`].
    pub id: u64,
    /// Prompt length the request was admitted with.
    pub prompt_len: usize,
    /// The `max_new` greedily generated token ids, in order.
    pub tokens: Vec<usize>,
}

/// One decode step's telemetry: the ragged batch shape, wall-clock
/// latency, and the per-step per-layer balance table (module docs).
#[derive(Debug, Clone)]
pub struct StepStat {
    /// 0-based index of this productive step.
    pub step: usize,
    /// Sequences in the step batch (after admissions).
    pub n_seqs: usize,
    /// Sequences admitted (prefilled) this step.
    pub n_joined: usize,
    /// Total batch rows (prompt rows + one per generating sequence).
    pub n_tokens: usize,
    /// Routed slots dropped across all layers this step — non-zero
    /// only when the engine's capacity factor violates the no-drop
    /// precondition (module docs).
    pub n_dropped: usize,
    /// Forward wall-clock for this step.
    pub latency_ns: u128,
    /// Per-layer Gini / min-max / CV of **this step's** routed load.
    pub layers: Vec<LayerBalance>,
}

/// A sequence holding a cache slot and generating.
#[derive(Debug)]
struct ActiveSeq {
    id: u64,
    slot: usize,
    prompt_len: usize,
    max_new: usize,
    /// Tokens generated so far.
    tokens: Vec<usize>,
    /// Rows to feed the next step: the embedded prompt right after
    /// admission, then the last generated token's embedding.
    pending: Vec<f32>,
}

/// A request popped from the admission queue, waiting for a slot.
#[derive(Debug)]
struct Waiting {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    h: Vec<f32>,
}

/// Per-request metadata kept while the prompt sits in the queue.
#[derive(Debug, Clone, Copy)]
struct PendingMeta {
    max_new: usize,
}

/// Continuous-batching greedy decode driver (module docs).
///
/// ```
/// use lpr::engine::{Backend, DecodeSession, Engine, GenRequest};
/// use lpr::model::synthetic_decoder_model;
/// use lpr::util::rng::Rng;
///
/// let (e, k) = (4usize, 2usize);
/// let dec = synthetic_decoder_model(
///     "cosine", &Rng::new(7), 2, 8, 4, e, k, 6, 2, 16,
/// );
/// let (model, head) = dec.into_parts();
/// let engine = Engine::builder()
///     .model(model)
///     .backend(Backend::Scoped { threads: 2 })
///     .capacity_factor(e as f64) // no-drop: decode is batch-invariant
///     .build()?;
/// let mut sess = DecodeSession::new(engine, head, 2, 32);
/// let id = sess.submit(GenRequest { prompt: vec![1, 2, 3], max_new: 4 })?;
/// let stats = sess.run_to_idle();
/// let fin = sess.take_finished();
/// assert_eq!((fin[0].id, fin[0].tokens.len()), (id, 4));
/// assert!(stats.iter().all(|s| s.n_dropped == 0));
/// # Ok::<(), Box<dyn std::error::Error>>(())
/// ```
pub struct DecodeSession<E: MoeEngine> {
    engine: E,
    head: DecodeHead,
    cache: KvCache,
    queue: BatchQueue,
    meta: HashMap<u64, PendingMeta>,
    waiting: VecDeque<Waiting>,
    active: Vec<ActiveSeq>,
    finished: Vec<FinishedSeq>,
    /// Virtual clock driving the admission queue, one tick per step.
    now: u64,
    steps: usize,
    // reusable per-step scratch
    step_h: Vec<f32>,
    spans: Vec<SeqSpan>,
    batch_h: Vec<f32>,
    members: Vec<crate::serve::queue::BatchMember>,
    next_toks: Vec<usize>,
    embed_buf: Vec<f32>,
    norm_scratch: Vec<f32>,
}

impl<E: MoeEngine> DecodeSession<E> {
    /// A session over `engine`/`head` with `n_slots` concurrent
    /// sequences, each bounded to `max_seq` cached positions. The head
    /// width must match the engine's residual stream.
    pub fn new(
        engine: E,
        head: DecodeHead,
        n_slots: usize,
        max_seq: usize,
    ) -> DecodeSession<E> {
        let d = engine.d_model();
        assert_eq!(
            d,
            head.d_model(),
            "decode head width must match the engine"
        );
        let cache = KvCache::new(n_slots, engine.layers().max(1), d, max_seq);
        // The queue admits whole prompts only; its token bound is the
        // most the cache could ever hold, so it never splits a join
        // wave smaller than the slot pool allows.
        let max_batch = n_slots.saturating_mul(max_seq).max(1);
        let queue =
            BatchQueue::new(d, max_batch, 0, max_batch.saturating_mul(2));
        DecodeSession {
            engine,
            head,
            cache,
            queue,
            meta: HashMap::new(),
            waiting: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            now: 0,
            steps: 0,
            step_h: Vec::new(),
            spans: Vec::new(),
            batch_h: Vec::new(),
            members: Vec::new(),
            next_toks: Vec::new(),
            embed_buf: Vec::new(),
            norm_scratch: Vec::new(),
        }
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    pub fn head(&self) -> &DecodeHead {
        &self.head
    }

    /// The slot-pooled cache (inspectable for slot accounting).
    pub fn cache(&self) -> &KvCache {
        &self.cache
    }

    /// Sequences currently holding a slot and generating.
    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Requests admitted but not yet finished, plus queued prompts.
    pub fn is_idle(&self) -> bool {
        self.active.is_empty()
            && self.waiting.is_empty()
            && self.queue.is_empty()
    }

    /// Productive steps run so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Validate and enqueue a request; returns its id. The request
    /// joins generation at the next [`Self::step`] with a free slot.
    pub fn submit(&mut self, req: GenRequest) -> Result<u64, DecodeError> {
        if req.prompt.is_empty() {
            return Err(DecodeError::EmptyPrompt);
        }
        if req.max_new == 0 {
            return Err(DecodeError::NoNewTokens);
        }
        let vocab = self.head.vocab();
        if let Some(&tok) = req.prompt.iter().find(|&&t| t >= vocab) {
            return Err(DecodeError::BadToken { tok, vocab });
        }
        // Conservative by one: the final generated token is never fed
        // back, so at most prompt + max_new - 1 positions are cached.
        if req.prompt.len() + req.max_new > self.cache.max_seq() {
            return Err(DecodeError::TooLong {
                prompt: req.prompt.len(),
                max_new: req.max_new,
                max_seq: self.cache.max_seq(),
            });
        }
        self.head.embed_tokens(&req.prompt, &mut self.embed_buf);
        let id = self.queue.submit(&self.embed_buf, self.now)?;
        self.meta.insert(id, PendingMeta { max_new: req.max_new });
        Ok(id)
    }

    /// Move queued prompts into free cache slots, FIFO. Returns the
    /// number of sequences admitted.
    fn admit(&mut self) -> usize {
        let d = self.head.d_model();
        let mut joined = 0;
        while self.cache.n_live() < self.cache.n_slots() {
            if let Some(w) = self.waiting.pop_front() {
                let slot =
                    self.cache.alloc().expect("a free slot was just checked");
                self.active.push(ActiveSeq {
                    id: w.id,
                    slot,
                    prompt_len: w.prompt_len,
                    max_new: w.max_new,
                    tokens: Vec::new(),
                    pending: w.h,
                });
                joined += 1;
            } else if !self.queue.is_empty() && self.queue.ready(self.now) {
                self.queue.pop_batch(&mut self.batch_h, &mut self.members);
                for m in &self.members {
                    let meta = self
                        .meta
                        .remove(&m.id)
                        .expect("submitted request has metadata");
                    let rows = &self.batch_h
                        [m.start * d..(m.start + m.n_tokens) * d];
                    self.waiting.push_back(Waiting {
                        id: m.id,
                        prompt_len: m.n_tokens,
                        max_new: meta.max_new,
                        h: rows.to_vec(),
                    });
                }
            } else {
                break;
            }
        }
        joined
    }

    /// One decode step: admit what fits, coalesce every in-flight
    /// sequence into one ragged batch, forward, extend each sequence
    /// by its greedy next token, and retire finished sequences.
    /// Returns `None` when there is nothing to run.
    pub fn step(&mut self) -> Option<StepStat> {
        self.now += 1;
        let n_joined = self.admit();
        if self.active.is_empty() {
            return None;
        }
        let d = self.head.d_model();
        self.step_h.clear();
        self.spans.clear();
        for seq in &self.active {
            let n = seq.pending.len() / d;
            debug_assert!(n >= 1, "an active sequence always has rows");
            self.spans.push(SeqSpan { slot: seq.slot, n_tokens: n });
            self.step_h.extend_from_slice(&seq.pending);
        }
        let n_tokens = self.step_h.len() / d;
        let t0 = Instant::now();
        let out = self.engine.forward_seqs(
            &self.step_h,
            &self.spans,
            &mut self.cache,
        );
        let n_dropped: usize =
            out.layers.iter().map(|l| l.plan.n_dropped).sum();
        self.next_toks.clear();
        let mut off = 0;
        for span in &self.spans {
            let h_last = out.token_row(off + span.n_tokens - 1);
            self.next_toks
                .push(self.head.greedy_next(h_last, &mut self.norm_scratch));
            off += span.n_tokens;
        }
        let latency_ns = t0.elapsed().as_nanos();
        let layers = self.engine.balance().last_step();

        let DecodeSession { active, cache, finished, head, next_toks, .. } =
            self;
        let mut i = 0;
        active.retain_mut(|seq| {
            let tok = next_toks[i];
            i += 1;
            seq.tokens.push(tok);
            if seq.tokens.len() >= seq.max_new {
                cache.free(seq.slot);
                finished.push(FinishedSeq {
                    id: seq.id,
                    prompt_len: seq.prompt_len,
                    tokens: std::mem::take(&mut seq.tokens),
                });
                false
            } else {
                seq.pending.clear();
                seq.pending.extend_from_slice(head.embedding(tok));
                true
            }
        });

        let stat = StepStat {
            step: self.steps,
            n_seqs: self.spans.len(),
            n_joined,
            n_tokens,
            n_dropped,
            latency_ns,
            layers,
        };
        self.steps += 1;
        Some(stat)
    }

    /// Drive [`Self::step`] until every submitted request has
    /// finished; returns the per-step telemetry.
    pub fn run_to_idle(&mut self) -> Vec<StepStat> {
        let mut stats = Vec::new();
        while !self.is_idle() {
            match self.step() {
                Some(s) => stats.push(s),
                // Defensive: unreachable with this queue configuration
                // (max_wait 0 ⇒ pending work is always admissible).
                None => break,
            }
        }
        stats
    }

    /// Drain completed generations, in completion order.
    pub fn take_finished(&mut self) -> Vec<FinishedSeq> {
        std::mem::take(&mut self.finished)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Backend, Engine};
    use crate::model::synthetic_decoder_model;
    use crate::util::rng::Rng;

    const L: usize = 2;
    const D: usize = 16;
    const DZ: usize = 8;
    const E: usize = 6;
    const K: usize = 2;
    const FF: usize = 10;
    const H: usize = 4;
    const V: usize = 32;

    fn session(
        backend: Backend,
        n_slots: usize,
        max_seq: usize,
    ) -> DecodeSession<Engine> {
        let dec = synthetic_decoder_model(
            "cosine",
            &Rng::new(11),
            L,
            D,
            DZ,
            E,
            K,
            FF,
            H,
            V,
        );
        let (model, head) = dec.into_parts();
        let engine = Engine::builder()
            .model(model)
            .backend(backend)
            .capacity_factor(E as f64) // no-drop: batch-invariant decode
            .build()
            .expect("engine builds");
        DecodeSession::new(engine, head, n_slots, max_seq)
    }

    /// Greedy output is a pure function of the prompt: the same
    /// request, run solo or sharing its batches with another sequence
    /// that joins mid-generation, generates the same tokens on both
    /// backends — the continuous-batching invariance the module
    /// promises.
    #[test]
    fn joins_do_not_change_generated_tokens() {
        let prompt_a = vec![3usize, 1, 4, 1, 5];
        let prompt_b = vec![9usize, 2, 6];

        // solo references
        let mut solo = session(Backend::Scoped { threads: 1 }, 1, 32);
        let ida = solo.submit(GenRequest {
            prompt: prompt_a.clone(),
            max_new: 6,
        });
        solo.run_to_idle();
        let ref_a = solo.take_finished().remove(0);
        assert_eq!(Some(ref_a.id), ida.ok());
        let mut solo_b = session(Backend::Scoped { threads: 1 }, 1, 32);
        solo_b
            .submit(GenRequest { prompt: prompt_b.clone(), max_new: 4 })
            .unwrap();
        solo_b.run_to_idle();
        let ref_b = solo_b.take_finished().remove(0);

        for backend in [
            Backend::Scoped { threads: 3 },
            Backend::Pool { workers: 2 },
        ] {
            let mut sess = session(backend, 2, 32);
            let ida = sess
                .submit(GenRequest { prompt: prompt_a.clone(), max_new: 6 })
                .unwrap();
            // let A prefill + generate two tokens before B joins
            let s0 = sess.step().unwrap();
            assert_eq!((s0.n_joined, s0.n_tokens), (1, prompt_a.len()));
            sess.step().unwrap();
            let idb = sess
                .submit(GenRequest { prompt: prompt_b.clone(), max_new: 4 })
                .unwrap();
            let s2 = sess.step().unwrap();
            // B's prefill shares the batch with A's decode row
            assert_eq!(s2.n_joined, 1);
            assert_eq!(s2.n_tokens, prompt_b.len() + 1);
            let stats = sess.run_to_idle();
            assert!(stats.iter().all(|s| s.n_dropped == 0));
            let fin = sess.take_finished();
            let a = fin.iter().find(|f| f.id == ida).unwrap();
            let b = fin.iter().find(|f| f.id == idb).unwrap();
            assert_eq!(a.tokens, ref_a.tokens, "{backend:?}");
            assert_eq!(b.tokens, ref_b.tokens, "{backend:?}");
            assert_eq!(a.prompt_len, prompt_a.len());
            assert!(sess.is_idle());
            assert_eq!(sess.cache().n_live(), 0);
        }
    }

    /// With one slot, the second request waits in the queue, joins
    /// when the first finishes, and reuses the freed slot.
    #[test]
    fn one_slot_serializes_and_recycles() {
        let mut sess = session(Backend::Scoped { threads: 2 }, 1, 16);
        let ida = sess
            .submit(GenRequest { prompt: vec![1, 2], max_new: 3 })
            .unwrap();
        let idb = sess
            .submit(GenRequest { prompt: vec![3], max_new: 2 })
            .unwrap();
        assert_ne!(ida, idb);
        let stats = sess.run_to_idle();
        // every step batches exactly one sequence
        assert!(stats.iter().all(|s| s.n_seqs == 1));
        assert_eq!(stats.len(), 3 + 2);
        let fin = sess.take_finished();
        assert_eq!(fin.len(), 2);
        assert_eq!(fin[0].id, ida, "FIFO admission");
        assert_eq!(fin[1].id, idb);
        assert_eq!(sess.cache().n_live(), 0);

        // the same session keeps serving after going idle
        sess.submit(GenRequest { prompt: vec![5, 6, 7], max_new: 1 })
            .unwrap();
        sess.run_to_idle();
        assert_eq!(sess.take_finished().len(), 1);
    }

    /// Submission-time validation is typed and total.
    #[test]
    fn submit_rejects_bad_requests() {
        let mut sess = session(Backend::Scoped { threads: 1 }, 1, 8);
        assert_eq!(
            sess.submit(GenRequest { prompt: vec![], max_new: 1 }),
            Err(DecodeError::EmptyPrompt)
        );
        assert_eq!(
            sess.submit(GenRequest { prompt: vec![1], max_new: 0 }),
            Err(DecodeError::NoNewTokens)
        );
        assert_eq!(
            sess.submit(GenRequest { prompt: vec![V], max_new: 1 }),
            Err(DecodeError::BadToken { tok: V, vocab: V })
        );
        let err = sess
            .submit(GenRequest { prompt: vec![1; 6], max_new: 3 })
            .unwrap_err();
        assert_eq!(
            err,
            DecodeError::TooLong { prompt: 6, max_new: 3, max_seq: 8 }
        );
        assert!(err.to_string().contains("max_seq"), "{err}");
        // the boundary itself is accepted
        assert!(sess
            .submit(GenRequest { prompt: vec![1; 5], max_new: 3 })
            .is_ok());
    }

    /// Per-step telemetry carries one balance row per layer and a
    /// non-trivial load snapshot once routing has run.
    #[test]
    fn step_stats_resolve_layers() {
        let mut sess = session(Backend::Scoped { threads: 2 }, 2, 16);
        sess.submit(GenRequest { prompt: vec![2, 4, 8], max_new: 2 })
            .unwrap();
        let stat = sess.step().unwrap();
        assert_eq!(stat.step, 0);
        assert_eq!(stat.layers.len(), L);
        assert!(stat.layers.iter().enumerate().all(|(l, b)| b.layer == l));
        // a 3-token, k=2 step routes 6 slots over 6 experts: min-max is
        // defined (not the empty-load 0/0 convention) and gini < 1
        assert!(stat.layers.iter().all(|b| b.gini < 1.0));
        assert!(stat.n_dropped == 0);
        assert!(stat.latency_ns > 0);
    }
}
