//! L3 coordinator: the training orchestrator.
//!
//! Owns the training loop around the AOT `train_step` executable. Model
//! parameters and Adam state live as PJRT device buffers for the whole
//! run (`execute_b` feeds the previous step's output buffers straight
//! back in); per step the host only uploads the token batch + step index
//! and downloads the small metrics vector and the [L, E] load histogram.
//!
//! Also provides deterministic evaluation over held-out batches,
//! checkpointing (custom binary format — no external deps), and CSV
//! metric logs for the experiment reports.

pub mod checkpoint;

use anyhow::{bail, Context, Result};

use crate::data::{Batcher, LmBatch, ZipfMarkovCorpus};
use crate::metrics::LoadMatrix;
use crate::runtime::{execute_buffers, CompiledArtifacts, Runtime};

/// Scalar metrics of one training step (layout = meta.metric_names).
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: usize,
    pub values: Vec<f32>,
}

impl StepMetrics {
    pub fn get(&self, meta: &crate::runtime::ArtifactMeta, name: &str) -> f32 {
        self.values[meta.metric_idx(name)]
    }
}

/// Device-resident trainer for one artifact set.
pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub arts: &'a CompiledArtifacts,
    state: Vec<xla::PjRtBuffer>,
    lw: Vec<f32>,
    lw_buf: xla::PjRtBuffer,
    pub step: usize,
    /// Cumulative per-layer expert loads over all training steps.
    pub load: LoadMatrix,
    pub history: Vec<StepMetrics>,
}

impl<'a> Trainer<'a> {
    /// Initialize model + optimizer state on device via the init
    /// executable. `loss_weights = None` uses the config defaults.
    pub fn new(
        rt: &'a Runtime,
        arts: &'a CompiledArtifacts,
        seed: i32,
        loss_weights: Option<Vec<f32>>,
    ) -> Result<Self> {
        let meta = &arts.meta;
        let lw = loss_weights.unwrap_or_else(|| meta.default_loss_weights.clone());
        if lw.len() != meta.default_loss_weights.len() {
            bail!(
                "loss weight vector must have {} entries",
                meta.default_loss_weights.len()
            );
        }
        let seed_buf = rt.buf_scalar_i32(seed)?;
        let state = execute_buffers(&arts.init, &[&seed_buf])
            .context("init executable")?;
        if state.len() != meta.n_state {
            bail!(
                "init returned {} buffers, meta says {}",
                state.len(),
                meta.n_state
            );
        }
        let lw_buf = rt.buf_f32(&lw, &[lw.len()])?;
        let (l, e) = meta.load_shape;
        Ok(Trainer {
            rt,
            arts,
            state,
            lw,
            lw_buf,
            step: 0,
            load: LoadMatrix::new(l, e),
            history: Vec::new(),
        })
    }

    /// Change loss weights mid-run (used by ablation schedules).
    pub fn set_loss_weights(&mut self, lw: Vec<f32>) -> Result<()> {
        self.lw_buf = self.rt.buf_f32(&lw, &[lw.len()])?;
        self.lw = lw;
        Ok(())
    }

    pub fn loss_weights(&self) -> &[f32] {
        &self.lw
    }

    /// One optimization step on `batch`. State stays on device.
    pub fn train_step(&mut self, batch: &LmBatch) -> Result<StepMetrics> {
        let meta = &self.arts.meta;
        let (b, t) = meta.batch_shape;
        debug_assert_eq!(batch.tokens.len(), b * t);

        let step_buf = self.rt.buf_scalar_i32(self.step as i32)?;
        let tok_buf = self.rt.buf_i32(&batch.tokens, &[b, t])?;
        let tgt_buf = self.rt.buf_i32(&batch.targets, &[b, t])?;

        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(meta.n_state + 4);
        args.extend(self.state.iter());
        args.push(&step_buf);
        args.push(&self.lw_buf);
        args.push(&tok_buf);
        args.push(&tgt_buf);

        let mut outs = execute_buffers(&self.arts.train, &args)
            .with_context(|| format!("train step {}", self.step))?;
        if outs.len() != meta.n_state + 2 {
            bail!(
                "train returned {} outputs, expected {}",
                outs.len(),
                meta.n_state + 2
            );
        }
        let load_buf = outs.pop().unwrap();
        let metrics_buf = outs.pop().unwrap();
        self.state = outs;

        let values = self.rt.to_f32(&metrics_buf)?;
        let load = self.rt.to_f32(&load_buf)?;
        self.load.accumulate(&load);

        let m = StepMetrics { step: self.step, values };
        self.history.push(m.clone());
        self.step += 1;
        Ok(m)
    }

    /// Run `n` steps drawing batches from a synthetic corpus.
    pub fn train_synthetic(
        &mut self,
        corpus: &mut ZipfMarkovCorpus,
        n: usize,
        mut on_step: impl FnMut(&StepMetrics),
    ) -> Result<()> {
        let (b, t) = self.arts.meta.batch_shape;
        let batcher = Batcher::new(b, t);
        for _ in 0..n {
            let batch = batcher.next_synthetic(corpus);
            let m = self.train_step(&batch)?;
            on_step(&m);
        }
        Ok(())
    }

    /// Deterministic evaluation over `n_batches` held-out batches.
    /// Returns (mean loss, mean drop_frac, eval LoadMatrix).
    pub fn evaluate(
        &self,
        corpus: &mut ZipfMarkovCorpus,
        n_batches: usize,
    ) -> Result<EvalResult> {
        let meta = &self.arts.meta;
        let (b, t) = meta.batch_shape;
        let (l, e) = meta.load_shape;
        let batcher = Batcher::new(b, t);
        let mut loss_sum = 0.0f64;
        let mut drop_sum = 0.0f64;
        let mut load = LoadMatrix::new(l, e);
        for _ in 0..n_batches {
            let batch = batcher.next_synthetic(corpus);
            let tok_buf = self.rt.buf_i32(&batch.tokens, &[b, t])?;
            let tgt_buf = self.rt.buf_i32(&batch.targets, &[b, t])?;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(meta.n_params + 2);
            args.extend(self.state.iter().take(meta.n_params));
            args.push(&tok_buf);
            args.push(&tgt_buf);
            let outs = execute_buffers(&self.arts.eval, &args)
                .context("eval step")?;
            if outs.len() != 2 {
                bail!("eval returned {} outputs, expected 2", outs.len());
            }
            let m = self.rt.to_f32(&outs[0])?;
            loss_sum += m[0] as f64;
            drop_sum += m[1] as f64;
            load.accumulate(&self.rt.to_f32(&outs[1])?);
        }
        let n = n_batches.max(1) as f64;
        Ok(EvalResult {
            loss: loss_sum / n,
            drop_frac: drop_sum / n,
            load,
        })
    }

    /// Download the model parameters (first P state buffers) to host.
    pub fn params_to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.state
            .iter()
            .take(self.arts.meta.n_params)
            .map(|b| self.rt.to_f32(b))
            .collect()
    }

    /// Download full state (params + Adam moments) for checkpointing.
    pub fn state_to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.state.iter().map(|b| self.rt.to_f32(b)).collect()
    }

    /// Restore full state from host vectors (checkpoint resume).
    pub fn state_from_host(&mut self, host: &[Vec<f32>]) -> Result<()> {
        let meta = &self.arts.meta;
        if host.len() != meta.n_state {
            bail!("checkpoint has {} buffers, want {}", host.len(), meta.n_state);
        }
        let mut bufs = Vec::with_capacity(host.len());
        for (i, data) in host.iter().enumerate() {
            let spec = &meta.params[i % meta.n_params];
            if data.len() != spec.numel() {
                bail!(
                    "buffer {i} ({}) has {} elems, want {}",
                    spec.path,
                    data.len(),
                    spec.numel()
                );
            }
            bufs.push(self.rt.buf_f32(data, &spec.shape)?);
        }
        self.state = bufs;
        Ok(())
    }

    /// Write a CSV of the full metric history.
    pub fn history_csv(&self) -> String {
        let meta = &self.arts.meta;
        let mut s = String::from("step,");
        s.push_str(&meta.metric_names.join(","));
        s.push('\n');
        for m in &self.history {
            s.push_str(&format!("{}", m.step));
            for v in &m.values {
                s.push_str(&format!(",{v}"));
            }
            s.push('\n');
        }
        s
    }
}

#[derive(Debug)]
pub struct EvalResult {
    pub loss: f64,
    pub drop_frac: f64,
    pub load: LoadMatrix,
}
