//! L3 coordinator: the training orchestrator.
//!
//! Owns the training loop around the AOT `train_step` executable. Model
//! parameters and Adam state live as PJRT device buffers for the whole
//! run (`execute_b` feeds the previous step's output buffers straight
//! back in); per step the host only uploads the token batch + step index
//! and downloads the small metrics vector and the [L, E] load histogram.
//!
//! Also provides deterministic evaluation over held-out batches,
//! checkpointing (custom binary format — no external deps), and CSV
//! metric logs for the experiment reports.

pub mod checkpoint;

use anyhow::{bail, Context, Result};

use crate::data::{Batcher, LmBatch, ZipfMarkovCorpus};
use crate::metrics::LoadMatrix;
use crate::runtime::{execute_buffers, CompiledArtifacts, Runtime};

/// Scalar metrics of one training step (layout = meta.metric_names).
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: usize,
    pub values: Vec<f32>,
}

impl StepMetrics {
    pub fn get(
        &self,
        meta: &crate::runtime::ArtifactMeta,
        name: &str,
    ) -> Result<f32> {
        Ok(self.values[meta.metric_idx(name)?])
    }
}

/// Which Adam-state segment a flat state-buffer index belongs to.
fn state_segment(i: usize, n_params: usize) -> &'static str {
    match i / n_params {
        0 => "param",
        1 => "adam-m",
        _ => "adam-v",
    }
}

/// Validate a full host state (params + Adam moments) against the
/// artifact's leaf specs — every buffer must match its leaf's element
/// count. Errors name the leaf *and* the state segment: buffer
/// `i >= n_params` is an Adam moment of `params[i % n_params]`, and the
/// old message labeled it as the parameter itself, pointing debugging
/// at the wrong buffer. Pure host-side, so it is testable (and usable)
/// without a PJRT runtime.
pub fn validate_state_shapes(
    meta: &crate::runtime::ArtifactMeta,
    host: &[Vec<f32>],
) -> Result<()> {
    if host.len() != meta.n_state {
        bail!(
            "checkpoint has {} buffers, want {}",
            host.len(),
            meta.n_state
        );
    }
    for (i, data) in host.iter().enumerate() {
        let spec = &meta.params[i % meta.n_params];
        if data.len() != spec.numel() {
            bail!(
                "state buffer {i} ({} of {}) has {} elems, want {}",
                state_segment(i, meta.n_params),
                spec.path,
                data.len(),
                spec.numel()
            );
        }
    }
    Ok(())
}

/// Device-resident trainer for one artifact set.
pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub arts: &'a CompiledArtifacts,
    state: Vec<xla::PjRtBuffer>,
    lw: Vec<f32>,
    lw_buf: xla::PjRtBuffer,
    pub step: usize,
    /// Cumulative per-layer expert loads over all training steps.
    pub load: LoadMatrix,
    pub history: Vec<StepMetrics>,
}

impl<'a> Trainer<'a> {
    /// Initialize model + optimizer state on device via the init
    /// executable. `loss_weights = None` uses the config defaults.
    pub fn new(
        rt: &'a Runtime,
        arts: &'a CompiledArtifacts,
        seed: i32,
        loss_weights: Option<Vec<f32>>,
    ) -> Result<Self> {
        let meta = &arts.meta;
        let lw = loss_weights.unwrap_or_else(|| meta.default_loss_weights.clone());
        if lw.len() != meta.default_loss_weights.len() {
            bail!(
                "loss weight vector must have {} entries",
                meta.default_loss_weights.len()
            );
        }
        let seed_buf = rt.buf_scalar_i32(seed)?;
        let state = execute_buffers(&arts.init, &[&seed_buf])
            .context("init executable")?;
        if state.len() != meta.n_state {
            bail!(
                "init returned {} buffers, meta says {}",
                state.len(),
                meta.n_state
            );
        }
        let lw_buf = rt.buf_f32(&lw, &[lw.len()])?;
        let (l, e) = meta.load_shape;
        Ok(Trainer {
            rt,
            arts,
            state,
            lw,
            lw_buf,
            step: 0,
            load: LoadMatrix::new(l, e),
            history: Vec::new(),
        })
    }

    /// Change loss weights mid-run (used by ablation schedules).
    pub fn set_loss_weights(&mut self, lw: Vec<f32>) -> Result<()> {
        self.lw_buf = self.rt.buf_f32(&lw, &[lw.len()])?;
        self.lw = lw;
        Ok(())
    }

    pub fn loss_weights(&self) -> &[f32] {
        &self.lw
    }

    /// One optimization step on `batch`. State stays on device.
    pub fn train_step(&mut self, batch: &LmBatch) -> Result<StepMetrics> {
        let meta = &self.arts.meta;
        let (b, t) = meta.batch_shape;
        debug_assert_eq!(batch.tokens.len(), b * t);

        let step_buf = self.rt.buf_scalar_i32(self.step as i32)?;
        let tok_buf = self.rt.buf_i32(&batch.tokens, &[b, t])?;
        let tgt_buf = self.rt.buf_i32(&batch.targets, &[b, t])?;

        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(meta.n_state + 4);
        args.extend(self.state.iter());
        args.push(&step_buf);
        args.push(&self.lw_buf);
        args.push(&tok_buf);
        args.push(&tgt_buf);

        let mut outs = execute_buffers(&self.arts.train, &args)
            .with_context(|| format!("train step {}", self.step))?;
        if outs.len() != meta.n_state + 2 {
            bail!(
                "train returned {} outputs, expected {}",
                outs.len(),
                meta.n_state + 2
            );
        }
        let load_buf = outs.pop().unwrap();
        let metrics_buf = outs.pop().unwrap();
        self.state = outs;

        let values = self.rt.to_f32(&metrics_buf)?;
        let load = self.rt.to_f32(&load_buf)?;
        self.load.accumulate(&load);

        let m = StepMetrics { step: self.step, values };
        self.history.push(m.clone());
        self.step += 1;
        Ok(m)
    }

    /// Run `n` steps drawing batches from a synthetic corpus.
    pub fn train_synthetic(
        &mut self,
        corpus: &mut ZipfMarkovCorpus,
        n: usize,
        mut on_step: impl FnMut(&StepMetrics),
    ) -> Result<()> {
        let (b, t) = self.arts.meta.batch_shape;
        let batcher = Batcher::new(b, t);
        for _ in 0..n {
            let batch = batcher.next_synthetic(corpus);
            let m = self.train_step(&batch)?;
            on_step(&m);
        }
        Ok(())
    }

    /// Deterministic evaluation over `n_batches` held-out batches.
    /// Returns (mean loss, mean drop_frac, eval LoadMatrix).
    pub fn evaluate(
        &self,
        corpus: &mut ZipfMarkovCorpus,
        n_batches: usize,
    ) -> Result<EvalResult> {
        let meta = &self.arts.meta;
        let (b, t) = meta.batch_shape;
        let (l, e) = meta.load_shape;
        let batcher = Batcher::new(b, t);
        let mut loss_sum = 0.0f64;
        let mut drop_sum = 0.0f64;
        let mut load = LoadMatrix::new(l, e);
        for _ in 0..n_batches {
            let batch = batcher.next_synthetic(corpus);
            let tok_buf = self.rt.buf_i32(&batch.tokens, &[b, t])?;
            let tgt_buf = self.rt.buf_i32(&batch.targets, &[b, t])?;
            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(meta.n_params + 2);
            args.extend(self.state.iter().take(meta.n_params));
            args.push(&tok_buf);
            args.push(&tgt_buf);
            let outs = execute_buffers(&self.arts.eval, &args)
                .context("eval step")?;
            if outs.len() != 2 {
                bail!("eval returned {} outputs, expected 2", outs.len());
            }
            let m = self.rt.to_f32(&outs[0])?;
            loss_sum += m[0] as f64;
            drop_sum += m[1] as f64;
            load.accumulate(&self.rt.to_f32(&outs[1])?);
        }
        let n = n_batches.max(1) as f64;
        Ok(EvalResult {
            loss: loss_sum / n,
            drop_frac: drop_sum / n,
            load,
        })
    }

    /// Download the model parameters (first P state buffers) to host.
    pub fn params_to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.state
            .iter()
            .take(self.arts.meta.n_params)
            .map(|b| self.rt.to_f32(b))
            .collect()
    }

    /// Download full state (params + Adam moments) for checkpointing.
    pub fn state_to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.state.iter().map(|b| self.rt.to_f32(b)).collect()
    }

    /// Restore full state from host vectors (checkpoint resume).
    /// Shape validation (with Adam-moment-aware error labels) runs
    /// before any device upload — see [`validate_state_shapes`].
    pub fn state_from_host(&mut self, host: &[Vec<f32>]) -> Result<()> {
        let meta = &self.arts.meta;
        validate_state_shapes(meta, host)?;
        let mut bufs = Vec::with_capacity(host.len());
        for (i, data) in host.iter().enumerate() {
            let spec = &meta.params[i % meta.n_params];
            bufs.push(self.rt.buf_f32(data, &spec.shape)?);
        }
        self.state = bufs;
        Ok(())
    }

    /// Write a CSV of the full metric history.
    pub fn history_csv(&self) -> String {
        let meta = &self.arts.meta;
        let mut s = String::from("step,");
        s.push_str(&meta.metric_names.join(","));
        s.push('\n');
        for m in &self.history {
            s.push_str(&format!("{}", m.step));
            for v in &m.values {
                s.push_str(&format!(",{v}"));
            }
            s.push('\n');
        }
        s
    }
}

#[derive(Debug)]
pub struct EvalResult {
    pub loss: f64,
    pub drop_frac: f64,
    pub load: LoadMatrix,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::bridge::synth_checkpoint_artifact;

    /// Satellite regression: shape errors label Adam-moment buffers as
    /// such. The old message reported the *param* path alone for
    /// moment buffers (`meta.params[i % n_params]`), pointing debugging
    /// at the wrong buffer when a moment was truncated.
    #[test]
    fn state_validation_labels_adam_moments() {
        let (meta, mut state) =
            synth_checkpoint_artifact("t", "cosine", 2, 8, 4, 4, 2, 6, 3)
                .unwrap();
        assert!(validate_state_shapes(&meta, &state).is_ok());

        // corrupt the first adam-m buffer (index n_params)
        let i = meta.n_params;
        state[i] = vec![0.0; 1];
        let err = validate_state_shapes(&meta, &state).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("adam-m"), "{msg}");
        assert!(msg.contains(&meta.params[0].path), "{msg}");
        assert!(msg.contains(&format!("buffer {i}")), "{msg}");

        // corrupt an adam-v buffer too
        state[i] = vec![0.0; meta.params[0].numel()];
        let j = 2 * meta.n_params + 1;
        state[j] = vec![0.0; 1];
        let err = validate_state_shapes(&meta, &state).unwrap_err();
        assert!(format!("{err:#}").contains("adam-v"));

        // wrong buffer count still rejected
        state.truncate(meta.n_params);
        assert!(validate_state_shapes(&meta, &state).is_err());
    }

    #[test]
    fn state_segments_partition_the_flat_index() {
        assert_eq!(state_segment(0, 4), "param");
        assert_eq!(state_segment(3, 4), "param");
        assert_eq!(state_segment(4, 4), "adam-m");
        assert_eq!(state_segment(7, 4), "adam-m");
        assert_eq!(state_segment(8, 4), "adam-v");
        assert_eq!(state_segment(11, 4), "adam-v");
    }
}
