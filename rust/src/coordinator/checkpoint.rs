//! Checkpoint format: `LPRCKPT1` magic + json header + raw little-endian
//! f32 payload. Self-contained (no npy/serde); resumable across runs of
//! the same artifact (the header pins the artifact name and step).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::{obj, Json};

const MAGIC: &[u8; 8] = b"LPRCKPT1";

pub struct Checkpoint {
    pub artifact: String,
    pub step: usize,
    pub buffers: Vec<Vec<f32>>,
}

pub fn save(path: &Path, artifact: &str, step: usize, buffers: &[Vec<f32>]) -> Result<()> {
    let header = obj(vec![
        ("artifact", Json::Str(artifact.to_string())),
        ("step", Json::Num(step as f64)),
        (
            "lens",
            Json::Arr(buffers.iter().map(|b| Json::Num(b.len() as f64)).collect()),
        ),
    ])
    .to_string();
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for buf in buffers {
        // SAFETY-free: explicit LE encoding, portable.
        let mut bytes = Vec::with_capacity(buf.len() * 4);
        for v in buf {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an LPR checkpoint: bad magic");
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .context("checkpoint header")?;
    let artifact = header.at("artifact").as_str().unwrap().to_string();
    let step = header.at("step").as_usize().unwrap();
    let lens = header.at("lens").as_usize_vec();
    let mut buffers = Vec::with_capacity(lens.len());
    for len in lens {
        let mut bytes = vec![0u8; len * 4];
        f.read_exact(&mut bytes)
            .context("checkpoint payload truncated")?;
        let buf: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        buffers.push(buf);
    }
    Ok(Checkpoint { artifact, step, buffers })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let bufs = vec![vec![1.0f32, -2.5, 3.25], vec![0.0; 7]];
        save(&path, "quickstart", 42, &bufs).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.artifact, "quickstart");
        assert_eq!(ck.step, 42);
        assert_eq!(ck.buffers, bufs);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn empty_buffers_ok() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.ckpt");
        save(&path, "x", 0, &[]).unwrap();
        let ck = load(&path).unwrap();
        assert!(ck.buffers.is_empty());
    }
}
