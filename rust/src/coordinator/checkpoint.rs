//! Checkpoint format: `LPRCKPT1` magic + json header + raw little-endian
//! f32 payload. Self-contained (no npy/serde); resumable across runs of
//! the same artifact (the header pins the artifact name and step).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::{obj, Json};

const MAGIC: &[u8; 8] = b"LPRCKPT1";

pub struct Checkpoint {
    pub artifact: String,
    pub step: usize,
    pub buffers: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Reject a checkpoint saved for a different artifact — the shared
    /// guard behind `lpr eval/route/serve --ckpt` and the
    /// `model::bridge` checkpoint path.
    pub fn expect_artifact(&self, name: &str) -> Result<()> {
        if self.artifact != name {
            bail!(
                "checkpoint is for artifact '{}', not '{name}'",
                self.artifact
            );
        }
        Ok(())
    }
}

pub fn save(path: &Path, artifact: &str, step: usize, buffers: &[Vec<f32>]) -> Result<()> {
    let header = obj(vec![
        ("artifact", Json::Str(artifact.to_string())),
        ("step", Json::Num(step as f64)),
        (
            "lens",
            Json::Arr(buffers.iter().map(|b| Json::Num(b.len() as f64)).collect()),
        ),
    ])
    .to_string();
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for buf in buffers {
        // SAFETY-free: explicit LE encoding, portable.
        let mut bytes = Vec::with_capacity(buf.len() * 4);
        for v in buf {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an LPR checkpoint: bad magic");
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)
        .context("checkpoint header length truncated")?;
    let hlen = u64::from_le_bytes(len8) as usize;
    // a corrupt length would otherwise drive a multi-GB allocation
    if hlen > 1 << 20 {
        bail!("implausible checkpoint header length ({hlen} bytes)");
    }
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes).context("checkpoint header truncated")?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .context("checkpoint header")?;
    // header fields parse to Results (a truncated/garbage header is an
    // IO-shaped failure, not a programmer error)
    let artifact = header
        .get("artifact")
        .and_then(Json::as_str)
        .context("checkpoint header: missing artifact name")?
        .to_string();
    let step = header
        .get("step")
        .and_then(Json::as_usize)
        .context("checkpoint header: missing step")?;
    let lens: Vec<usize> = header
        .get("lens")
        .and_then(Json::as_arr)
        .context("checkpoint header: missing buffer lengths")?
        .iter()
        .map(|x| x.as_usize().context("checkpoint header: bad length"))
        .collect::<Result<_>>()?;
    // every buffer length must fit the file that claims it — a corrupt
    // `lens` entry must not drive a huge allocation (or a silent
    // `len * 4` overflow) any more than a corrupt header length may
    let payload_bytes = f
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len()
        .saturating_sub(16 + hlen as u64);
    let mut claimed = 0u64;
    for &len in &lens {
        claimed = claimed.saturating_add(
            u64::try_from(len).unwrap_or(u64::MAX).saturating_mul(4),
        );
    }
    if claimed > payload_bytes {
        bail!(
            "checkpoint payload truncated: header claims {claimed} \
             bytes, file holds {payload_bytes}"
        );
    }
    let mut buffers = Vec::with_capacity(lens.len());
    for len in lens {
        let mut bytes = vec![0u8; len * 4];
        f.read_exact(&mut bytes)
            .context("checkpoint payload truncated")?;
        let buf: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        buffers.push(buf);
    }
    Ok(Checkpoint { artifact, step, buffers })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let bufs = vec![vec![1.0f32, -2.5, 3.25], vec![0.0; 7]];
        save(&path, "quickstart", 42, &bufs).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.artifact, "quickstart");
        assert_eq!(ck.step, 42);
        assert_eq!(ck.buffers, bufs);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
    }

    /// Satellite: golden round-trip — extreme/bit-exact f32 values
    /// (denormals, infinities, NaN payloads, signed zero) survive the
    /// explicit little-endian encoding bit-for-bit.
    #[test]
    fn golden_roundtrip_is_bit_exact() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("golden.ckpt");
        let golden: Vec<f32> = vec![
            0.0,
            -0.0,
            1.0,
            -1.5,
            f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 2.0, // subnormal
            f32::MAX,
            f32::MIN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::from_bits(0x7fc0_dead), // NaN with payload
            std::f32::consts::PI,
        ];
        save(&path, "golden-art", 123, &[golden.clone(), vec![2.5; 3]])
            .unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.artifact, "golden-art");
        assert_eq!(ck.step, 123);
        assert_eq!(ck.buffers.len(), 2);
        // bit-for-bit, not float-compare (NaN != NaN under PartialEq)
        let got: Vec<u32> =
            ck.buffers[0].iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = golden.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
        assert_eq!(ck.buffers[1], vec![2.5; 3]);
    }

    /// Satellite: a checkpoint truncated mid-payload (or mid-header) is
    /// rejected with a truncation error, never a short silent read.
    #[test]
    fn truncated_checkpoint_is_rejected() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.ckpt");
        save(&path, "t", 7, &[vec![1.0f32; 64], vec![2.0f32; 64]]).unwrap();
        let full = std::fs::read(&path).unwrap();
        // drop the tail of the payload
        let cut = dir.join("cut.ckpt");
        std::fs::write(&cut, &full[..full.len() - 17]).unwrap();
        let err = load(&cut).unwrap_err();
        assert!(
            format!("{err:#}").contains("truncated"),
            "payload cut: {err:#}"
        );
        // cut inside the JSON header
        std::fs::write(&cut, &full[..20]).unwrap();
        let err = load(&cut).unwrap_err();
        assert!(
            format!("{err:#}").contains("header"),
            "header cut: {err:#}"
        );
        // a corrupt header length must not drive a huge allocation
        let mut bad = full.clone();
        bad[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&cut, &bad).unwrap();
        let err = load(&cut).unwrap_err();
        assert!(format!("{err:#}").contains("implausible"), "{err:#}");
        // ... and neither must a corrupt per-buffer length: a valid
        // small header claiming a multi-TB buffer is rejected up front
        // (checked against the file size), never allocated
        let huge = dir.join("huge-lens.ckpt");
        let header = r#"{"artifact":"t","lens":[1099511627776],"step":1}"#;
        let mut buf = Vec::new();
        buf.extend_from_slice(b"LPRCKPT1");
        buf.extend_from_slice(&(header.len() as u64).to_le_bytes());
        buf.extend_from_slice(header.as_bytes());
        buf.extend_from_slice(&[0u8; 64]);
        std::fs::write(&huge, &buf).unwrap();
        let err = load(&huge).unwrap_err();
        assert!(
            format!("{err:#}").contains("truncated"),
            "huge lens: {err:#}"
        );
    }

    /// Satellite: wrong-artifact-name rejection via the shared guard.
    #[test]
    fn wrong_artifact_name_is_rejected() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("named.ckpt");
        save(&path, "preset-a", 1, &[vec![1.0f32]]).unwrap();
        let ck = load(&path).unwrap();
        assert!(ck.expect_artifact("preset-a").is_ok());
        let err = ck.expect_artifact("preset-b").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("preset-a") && msg.contains("preset-b"), "{msg}");
    }

    #[test]
    fn empty_buffers_ok() {
        let dir = std::env::temp_dir().join("lpr-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.ckpt");
        save(&path, "x", 0, &[]).unwrap();
        let ck = load(&path).unwrap();
        assert!(ck.buffers.is_empty());
    }
}
