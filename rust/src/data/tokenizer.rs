//! Byte-level tokenizer with an optional learned merge table (mini-BPE).
//!
//! Lets the pipeline consume real text files: bytes are the base vocab
//! (0..256) and `train_merges` learns the most frequent pair merges,
//! producing ids in [256, 256+n_merges). For the synthetic experiments
//! the plain byte path suffices; mini-BPE exists so the e2e driver can
//! run on any user-provided corpus with a vocab that matches the
//! artifact's embedding table.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    /// Learned merges in application order: (left, right) -> new id.
    pub merges: Vec<(i32, i32)>,
    merge_lookup: HashMap<(i32, i32), i32>,
}

impl Default for ByteTokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer { merges: Vec::new(), merge_lookup: HashMap::new() }
    }

    pub fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }

    /// Learn `n_merges` byte-pair merges from `text` (greedy BPE).
    pub fn train_merges(&mut self, text: &[u8], n_merges: usize) {
        let mut ids: Vec<i32> = text.iter().map(|&b| b as i32).collect();
        for step in 0..n_merges {
            let mut counts: HashMap<(i32, i32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            let Some((&pair, &cnt)) =
                counts.iter().max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = 256 + step as i32;
            self.merges.push(pair);
            self.merge_lookup.insert(pair, new_id);
            ids = Self::apply_merge(&ids, pair, new_id);
        }
    }

    fn apply_merge(ids: &[i32], pair: (i32, i32), new_id: i32) -> Vec<i32> {
        let mut out = Vec::with_capacity(ids.len());
        let mut i = 0;
        while i < ids.len() {
            if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                out.push(new_id);
                i += 2;
            } else {
                out.push(ids[i]);
                i += 1;
            }
        }
        out
    }

    pub fn encode(&self, text: &[u8]) -> Vec<i32> {
        let mut ids: Vec<i32> = text.iter().map(|&b| b as i32).collect();
        for (k, pair) in self.merges.iter().enumerate() {
            ids = Self::apply_merge(&ids, *pair, 256 + k as i32);
        }
        ids
    }

    pub fn decode(&self, ids: &[i32]) -> Vec<u8> {
        // Expand merges recursively (merge ids may reference merge ids).
        fn expand(tok: &ByteTokenizer, id: i32, out: &mut Vec<u8>) {
            if id < 256 {
                out.push(id as u8);
            } else {
                let (l, r) = tok.merges[(id - 256) as usize];
                expand(tok, l, out);
                expand(tok, r, out);
            }
        }
        let mut out = Vec::with_capacity(ids.len());
        for &id in ids {
            expand(self, id, &mut out);
        }
        out
    }

    /// Clamp token ids into a model vocab (ids >= vocab map to bytes via
    /// modulo — only relevant when a text has merges beyond the model's
    /// embedding size).
    pub fn clamp_to_vocab(ids: &[i32], vocab: usize) -> Vec<i32> {
        ids.iter().map(|&t| t % vocab as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip_without_merges() {
        let t = ByteTokenizer::new();
        let text = b"hello, world! \xf0\x9f\x99\x82";
        assert_eq!(t.decode(&t.encode(text)), text.to_vec());
    }

    #[test]
    fn merges_compress_and_roundtrip() {
        let mut t = ByteTokenizer::new();
        let text = b"abababab ababab abab".repeat(8);
        t.train_merges(&text, 16);
        assert!(!t.merges.is_empty());
        let enc = t.encode(&text);
        assert!(enc.len() < text.len(), "{} !< {}", enc.len(), text.len());
        assert_eq!(t.decode(&enc), text);
    }

    #[test]
    fn merge_ids_sequential() {
        let mut t = ByteTokenizer::new();
        t.train_merges(&b"xyxyxyxy".repeat(4), 4);
        let max_id = *t.encode(&b"xyxyxyxy".repeat(4)).iter().max().unwrap();
        assert!(max_id >= 256);
        assert!((max_id as usize) < t.vocab_size());
    }

    #[test]
    fn clamp_stays_in_vocab() {
        let ids = vec![0, 100, 255, 256, 300];
        let c = ByteTokenizer::clamp_to_vocab(&ids, 128);
        assert!(c.iter().all(|&t| (t as usize) < 128));
    }

    #[test]
    fn train_on_empty_is_noop() {
        let mut t = ByteTokenizer::new();
        t.train_merges(b"", 8);
        assert!(t.merges.is_empty());
    }
}
