//! L3 data pipeline — the fineweb substitute (DESIGN.md §Substitutions).
//!
//! The paper's routing phenomena rest on two token-distribution facts
//! (§2.2.1): *clusterability* (tokens form semantically coherent
//! clusters) and *imbalanced frequencies* (cluster sizes are Zipf-
//! skewed). `ZipfMarkovCorpus` reproduces both: a seeded first-order
//! Markov chain whose stationary distribution is Zipf(s) and whose
//! transition rows are sparse (each token has a small out-neighborhood),
//! giving learnable sequential structure for the LM task.
//!
//! A byte-level tokenizer is included for feeding real text files
//! through the same batcher.

pub mod tokenizer;

use crate::util::rng::Rng;

/// Gaussian-mixture *activation* stream with Zipf-skewed cluster sizes:
/// the continuous-space analogue of `ZipfMarkovCorpus`, mirroring the
/// same §2.2.1 assumptions (clusterability + imbalanced frequencies)
/// for code that feeds token activations straight into the serving
/// router (`route synthetic`, `dispatch-sim --routed`, the
/// `dispatch-routed` report, `examples/serving_sim.rs`).
pub struct MixtureStream {
    pub d: usize,
    /// [n_clusters, d] cluster centers.
    centers: Vec<f32>,
    /// Zipf cluster-selection weights.
    weights: Vec<f64>,
    /// Per-dim Gaussian noise scale around the chosen center.
    noise: f32,
}

impl MixtureStream {
    pub fn new(
        rng: &mut Rng,
        d: usize,
        n_clusters: usize,
        zipf_s: f64,
        noise: f32,
    ) -> MixtureStream {
        let centers =
            (0..n_clusters * d).map(|_| rng.normal() as f32).collect();
        let weights = (1..=n_clusters)
            .map(|r| 1.0 / (r as f64).powf(zipf_s))
            .collect();
        MixtureStream { d, centers, weights, noise }
    }

    /// The configuration shared by every synthetic serving driver:
    /// 8 clusters, Zipf(1.1) sizes, noise 0.4.
    pub fn standard(rng: &mut Rng, d: usize) -> MixtureStream {
        MixtureStream::new(rng, d, 8, 1.1, 0.4)
    }

    /// `standard` with an explicit cluster-size skew — the overflow-
    /// policy studies sweep this to stress the capacity bins (larger
    /// `zipf_s` concentrates tokens on few clusters, hence few experts).
    pub fn skewed(rng: &mut Rng, d: usize, zipf_s: f64) -> MixtureStream {
        MixtureStream::new(rng, d, 8, zipf_s, 0.4)
    }

    /// Sample `n_tokens` activations into `h` ([n_tokens, d]; cleared
    /// and resized, so a reused buffer does not allocate steady-state).
    pub fn fill(&self, rng: &mut Rng, n_tokens: usize, h: &mut Vec<f32>) {
        h.clear();
        h.resize(n_tokens * self.d, 0.0);
        for t in 0..n_tokens {
            let c = rng.categorical(&self.weights);
            for j in 0..self.d {
                h[t * self.d + j] = self.centers[c * self.d + j]
                    + self.noise * rng.normal() as f32;
            }
        }
    }
}

/// Streaming synthetic corpus with Zipf marginals + Markov structure.
pub struct ZipfMarkovCorpus {
    pub vocab: usize,
    rng: Rng,
    state: usize,
    /// Per-token sparse transition table: (next_token, weight).
    transitions: Vec<Vec<(usize, f64)>>,
    /// Zipf weights, used for restarts and for building transitions.
    zipf: Vec<f64>,
}

impl ZipfMarkovCorpus {
    /// `s` is the Zipf exponent (paper-scale natural text is s ~= 1.0-1.2);
    /// `branching` is the out-degree of the Markov chain (structure
    /// strength: smaller = more predictable).
    pub fn new(vocab: usize, seed: u64, s: f64, branching: usize) -> Self {
        Self::with_law(vocab, seed, seed, s, branching)
    }

    /// Build the transition table ("the language") from `law_seed` and
    /// the sampling stream from `stream_seed`. Train and held-out
    /// corpora MUST share the law and differ only in the stream —
    /// otherwise evaluation measures loss on a different language and
    /// sits at ln(V) regardless of training.
    pub fn with_law(vocab: usize, law_seed: u64, stream_seed: u64,
                    s: f64, branching: usize) -> Self {
        assert!(vocab >= 4 && branching >= 2);
        let zipf: Vec<f64> =
            (1..=vocab).map(|r| 1.0 / (r as f64).powf(s)).collect();
        let mut build_rng = Rng::new(law_seed ^ 0x5eed_c0de);
        let mut transitions = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            // Sparse successor set *drawn from* the Zipf marginal (so
            // frequent tokens are frequent successors and the stationary
            // distribution stays skewed) but with near-uniform weights
            // within the set (so the conditional next-token entropy is
            // ~ln(branching), far below the unigram entropy — i.e. the
            // chain is learnable).
            let mut succ: Vec<(usize, f64)> = Vec::with_capacity(branching);
            while succ.len() < branching {
                let t = build_rng.categorical(&zipf);
                if !succ.iter().any(|&(s, _)| s == t) {
                    succ.push((t, build_rng.range_f64(0.5, 1.5)));
                }
            }
            transitions.push(succ);
        }
        let mut rng = Rng::new(stream_seed);
        let state = rng.categorical(&zipf);
        ZipfMarkovCorpus { vocab, rng, state, transitions, zipf }
    }

    /// Default corpus parameters used by all experiments.
    /// NOTE: law and stream both derive from `seed`; for a held-out
    /// stream of the SAME language use [`ZipfMarkovCorpus::held_out`].
    pub fn standard(vocab: usize, seed: u64) -> Self {
        Self::new(vocab, seed, 1.1, 12)
    }

    /// Held-out stream: same language (transition law) as
    /// `standard(vocab, seed)` but a disjoint sample path.
    pub fn held_out(vocab: usize, law_seed: u64, stream_seed: u64) -> Self {
        Self::with_law(vocab, law_seed, stream_seed, 1.1, 12)
    }

    pub fn next_token(&mut self) -> usize {
        // 2% restart probability keeps the chain ergodic over the full
        // vocabulary (otherwise rare tokens would never re-appear).
        if self.rng.f64() < 0.02 {
            self.state = self.rng.categorical(&self.zipf);
            return self.state;
        }
        let row = &self.transitions[self.state];
        let weights: Vec<f64> = row.iter().map(|&(_, w)| w).collect();
        let k = self.rng.categorical(&weights);
        self.state = row[k].0;
        self.state
    }

    pub fn fill(&mut self, out: &mut [i32]) {
        for slot in out.iter_mut() {
            *slot = self.next_token() as i32;
        }
    }
}

/// Produces fixed-shape `[B, T]` next-token batches from any token stream.
pub struct Batcher {
    pub batch: usize,
    pub seq: usize,
}

#[derive(Debug, Clone)]
pub struct LmBatch {
    /// [B*T] row-major input ids.
    pub tokens: Vec<i32>,
    /// [B*T] row-major next-token targets.
    pub targets: Vec<i32>,
}

impl Batcher {
    pub fn new(batch: usize, seq: usize) -> Self {
        Batcher { batch, seq }
    }

    /// Draw one batch from a synthetic corpus. Each row consumes T+1
    /// tokens so targets are true next tokens (no wraparound hack).
    pub fn next_synthetic(&self, corpus: &mut ZipfMarkovCorpus) -> LmBatch {
        let (b, t) = (self.batch, self.seq);
        let mut tokens = vec![0i32; b * t];
        let mut targets = vec![0i32; b * t];
        let mut row = vec![0i32; t + 1];
        for i in 0..b {
            corpus.fill(&mut row);
            tokens[i * t..(i + 1) * t].copy_from_slice(&row[..t]);
            targets[i * t..(i + 1) * t].copy_from_slice(&row[1..]);
        }
        LmBatch { tokens, targets }
    }

    /// Slice sequential batches out of a pre-tokenized document stream.
    /// `cursor` advances; wraps around at the end of the stream.
    pub fn next_from_stream(&self, stream: &[i32], cursor: &mut usize) -> LmBatch {
        let (b, t) = (self.batch, self.seq);
        assert!(
            stream.len() > t + 1,
            "stream too short: {} <= {}",
            stream.len(),
            t + 1
        );
        let mut tokens = vec![0i32; b * t];
        let mut targets = vec![0i32; b * t];
        for i in 0..b {
            if *cursor + t + 1 > stream.len() {
                *cursor = 0;
            }
            let chunk = &stream[*cursor..*cursor + t + 1];
            tokens[i * t..(i + 1) * t].copy_from_slice(&chunk[..t]);
            targets[i * t..(i + 1) * t].copy_from_slice(&chunk[1..]);
            *cursor += t;
        }
        LmBatch { tokens, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::gini;

    #[test]
    fn corpus_is_deterministic() {
        let mut a = ZipfMarkovCorpus::standard(256, 9);
        let mut b = ZipfMarkovCorpus::standard(256, 9);
        let sa: Vec<usize> = (0..256).map(|_| a.next_token()).collect();
        let sb: Vec<usize> = (0..256).map(|_| b.next_token()).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn tokens_in_vocab() {
        let mut c = ZipfMarkovCorpus::standard(128, 1);
        for _ in 0..5000 {
            assert!(c.next_token() < 128);
        }
    }

    #[test]
    fn frequencies_are_zipf_skewed() {
        // The paper's premise: token frequencies are highly imbalanced.
        let vocab = 256;
        let mut c = ZipfMarkovCorpus::standard(vocab, 2);
        let mut counts = vec![0f32; vocab];
        for _ in 0..200_000 {
            counts[c.next_token()] += 1.0;
        }
        let g = gini(&counts);
        assert!(g > 0.45, "corpus should be skewed, gini={g}");
        // ... and ergodic: a large majority of the vocab appears.
        let seen = counts.iter().filter(|&&c| c > 0.0).count();
        assert!(seen > vocab * 2 / 3, "only {seen}/{vocab} tokens seen");
    }

    #[test]
    fn markov_structure_is_learnable() {
        // Bigram conditional entropy must be far below unigram entropy —
        // otherwise the LM task has nothing to learn.
        let vocab = 64;
        let mut c = ZipfMarkovCorpus::standard(vocab, 3);
        let n = 300_000;
        let mut uni = vec![0f64; vocab];
        let mut bi = vec![0f64; vocab * vocab];
        let mut prev = c.next_token();
        for _ in 0..n {
            let t = c.next_token();
            uni[t] += 1.0;
            bi[prev * vocab + t] += 1.0;
            prev = t;
        }
        let h_uni: f64 = uni
            .iter()
            .filter(|&&x| x > 0.0)
            .map(|&x| {
                let p = x / n as f64;
                -p * p.ln()
            })
            .sum();
        let mut h_bi = 0.0;
        for p_row in 0..vocab {
            let row = &bi[p_row * vocab..(p_row + 1) * vocab];
            let tot: f64 = row.iter().sum();
            if tot == 0.0 {
                continue;
            }
            for &x in row {
                if x > 0.0 {
                    let p = x / tot;
                    h_bi -= (x / n as f64) * p.ln();
                }
            }
        }
        assert!(
            h_bi < 0.8 * h_uni,
            "bigram entropy {h_bi:.3} not « unigram {h_uni:.3}"
        );
    }

    #[test]
    fn batcher_targets_are_next_tokens() {
        let stream: Vec<i32> = (0..100).collect();
        let b = Batcher::new(2, 8);
        let mut cursor = 0;
        let batch = b.next_from_stream(&stream, &mut cursor);
        assert_eq!(batch.tokens[..8], (0..8).collect::<Vec<i32>>()[..]);
        assert_eq!(batch.targets[..8], (1..9).collect::<Vec<i32>>()[..]);
        assert_eq!(batch.tokens[8..16], (8..16).collect::<Vec<i32>>()[..]);
        assert_eq!(cursor, 16);
    }

    #[test]
    fn batcher_wraps_stream() {
        let stream: Vec<i32> = (0..20).collect();
        let b = Batcher::new(1, 8);
        let mut cursor = 16; // forces wrap
        let batch = b.next_from_stream(&stream, &mut cursor);
        assert_eq!(batch.tokens[0], 0);
    }

    #[test]
    fn synthetic_batch_shapes() {
        let mut c = ZipfMarkovCorpus::standard(64, 5);
        let b = Batcher::new(3, 16);
        let batch = b.next_synthetic(&mut c);
        assert_eq!(batch.tokens.len(), 48);
        assert_eq!(batch.targets.len(), 48);
        assert!(batch.tokens.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn mixture_stream_shapes_and_determinism() {
        let mut rng = Rng::new(12);
        let mix = MixtureStream::standard(&mut rng, 8);
        let mut h1 = Vec::new();
        mix.fill(&mut Rng::new(99), 17, &mut h1);
        assert_eq!(h1.len(), 17 * 8);
        // same sampling seed -> identical stream; reused buffer resizes
        let mut h2 = vec![0.0f32; 3];
        mix.fill(&mut Rng::new(99), 17, &mut h2);
        assert_eq!(h1, h2);
        assert!(h1.iter().any(|&x| x != 0.0));
    }
}
