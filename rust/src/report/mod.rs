//! Experiment reports: one function per paper table/figure.
//!
//! Each `repro_*` builds the RunSpecs for that experiment, executes
//! them, and renders a markdown table next to the paper's published
//! values (so the *shape* comparison — who wins, by what factor — is
//! visible in one place). Results are also written to `results/` as
//! markdown + CSV, and the raw loss curves / load matrices as CSV for
//! the figures.

use anyhow::{Context, Result};
use std::path::Path;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::config::{execute_run_arts, RunSpec, RunSummary};
use crate::data::MixtureStream;
use crate::dispatch::{
    assignments_from_load, run_routed_steps, synthetic_assignments,
    DispatchSim, OverflowPolicy, PlacementConfig, PlacementPolicy,
    SimConfig,
};
use crate::engine::{Backend, DecodeSession, Engine, GenRequest};
use crate::experts::ExpertBank;
use crate::metrics::ascii_heatmap;
use crate::model::{bridge, run_model_steps, StackedModel};
use crate::router::{synthetic_lpr_router, RouterPlan, METRICS};
use crate::runtime::Runtime;
use crate::serve::{
    measure_engine_rate, run_admitted_open_loop, run_open_loop,
    AdmissionConfig, AdmittedRuntime, RequestMeta, ServeConfig,
    ServeRuntime,
};
use crate::util::rng::Rng;
use crate::util::table::{fmt_sci, Table};

/// The report cells' single engine construction point: a pool- or
/// scoped-backend facade over one `(plan, bank)` layer. Routing-only
/// reports pass a 1-wide placeholder bank (the FFN stage never runs).
fn build_layer_engine(
    plan: RouterPlan,
    bank: ExpertBank,
    backend: Backend,
    policy: OverflowPolicy,
    cf: f64,
) -> Result<Engine> {
    Ok(Engine::builder()
        .layer(plan, bank)
        .backend(backend)
        .policy(policy)
        .capacity_factor(cf)
        .build()?)
}

// Loss-weight vector indices (configs.LOSS_WEIGHTS layout).
pub const LW_BETA_RS: usize = 0;
pub const LW_BETA_DIV: usize = 1;
pub const LW_BETA_ALIGN: usize = 2;
pub const LW_BETA_KL: usize = 3;

pub struct Reporter<'a> {
    /// PJRT runtime, present only when the artifacts/training paths are
    /// available (the pure-Rust serving reports — `dispatch*`, `serve`
    /// — run without it, so they work against the offline `vendor/xla`
    /// stub).
    pub rt: Option<&'a Runtime>,
    pub art_dir: &'a Path,
    pub out_dir: &'a Path,
    pub steps_override: Option<usize>,
    pub verbose: bool,
    /// PJRT compiles are seconds each; cache per artifact name (tables
    /// 2/4 and fig.4 reuse `ab-base` nine times).
    compiled: RefCell<HashMap<String, Rc<crate::runtime::CompiledArtifacts>>>,
}

/// Paper reference values for one row: (loss, gini, minmax).
type PaperRow = (&'static str, f64, f64, f64);

impl<'a> Reporter<'a> {
    pub fn new(
        rt: Option<&'a Runtime>,
        art_dir: &'a Path,
        out_dir: &'a Path,
    ) -> Self {
        std::fs::create_dir_all(out_dir).ok();
        Reporter {
            rt,
            art_dir,
            out_dir,
            steps_override: None,
            verbose: true,
            compiled: RefCell::new(HashMap::new()),
        }
    }

    /// The PJRT runtime, or a useful error for experiments that need
    /// artifacts when only the offline stub is present.
    fn runtime(&self) -> Result<&'a Runtime> {
        self.rt.context(
            "this experiment needs the PJRT runtime (AOT artifacts + a \
             patched vendor/xla); the pure-Rust reports are: dispatch, \
             dispatch-routed, dispatch-policies, serve, admission",
        )
    }

    fn artifacts(
        &self,
        name: &str,
    ) -> Result<Rc<crate::runtime::CompiledArtifacts>> {
        if let Some(a) = self.compiled.borrow().get(name) {
            return Ok(a.clone());
        }
        let a = Rc::new(crate::runtime::CompiledArtifacts::load(
            self.runtime()?,
            self.art_dir,
            name,
        )?);
        self.compiled
            .borrow_mut()
            .insert(name.to_string(), a.clone());
        Ok(a)
    }

    fn run(&self, spec: RunSpec) -> Result<RunSummary> {
        let spec = match self.steps_override {
            Some(s) => spec.steps(s),
            None => spec,
        };
        if self.verbose {
            eprintln!("== running {} ({})", spec.label, spec.artifact);
        }
        let arts = self.artifacts(&spec.artifact)?;
        execute_run_arts(self.runtime()?, &arts, &spec, self.verbose)
    }

    fn emit(&self, name: &str, table: &Table, extra: &str) -> Result<String> {
        let md = format!("{}\n{}", table.to_markdown(), extra);
        std::fs::write(self.out_dir.join(format!("{name}.md")), &md)
            .context("write report md")?;
        std::fs::write(
            self.out_dir.join(format!("{name}.csv")),
            table.to_csv(),
        )?;
        println!("{md}");
        Ok(md)
    }

    fn standard_table(
        &self,
        name: &str,
        title: &str,
        specs: Vec<RunSpec>,
        paper: &[PaperRow],
    ) -> Result<Vec<RunSummary>> {
        let mut t = Table::new(
            title,
            &[
                "Method", "Test Loss", "GINI", "Min-Max",
                "paper:Loss", "paper:GINI", "paper:Min-Max",
            ],
        );
        let mut runs = Vec::new();
        let mut curves = String::from("label,step,loss\n");
        for (i, spec) in specs.into_iter().enumerate() {
            let s = self.run(spec)?;
            let p = paper.get(i).copied().unwrap_or(("-", f64::NAN, f64::NAN, f64::NAN));
            t.row(vec![
                s.label.clone(),
                fmt_sci(s.test_loss),
                fmt_sci(s.gini),
                fmt_sci(s.min_max),
                if p.1.is_nan() { "-".into() } else { fmt_sci(p.1) },
                if p.2.is_nan() { "-".into() } else { fmt_sci(p.2) },
                if p.3.is_nan() { "-".into() } else { fmt_sci(p.3) },
            ]);
            for (step, l) in s.loss_curve.iter().enumerate() {
                curves.push_str(&format!("{},{},{}\n", s.label, step, l));
            }
            runs.push(s);
        }
        std::fs::write(
            self.out_dir.join(format!("{name}.curves.csv")),
            curves,
        )?;
        self.emit(name, &t, "")?;
        Ok(runs)
    }

    // ------------------------------------------------------------------
    // Table 1: routing method comparison across architectures
    // ------------------------------------------------------------------
    pub fn table1(&self) -> Result<Vec<RunSummary>> {
        let specs = vec![
            RunSpec::new("Mixtral (64-8)", "t1-mixtral"),
            RunSpec::new("Mixtral-LPR (w/o init)", "t1-mixtral-lpr"),
            RunSpec::new("DeepSeekV3 (64-8)", "t1-deepseek"),
            RunSpec::new("DeepSeekMoe-LPR (w/o init)", "t1-deepseek-lpr"),
            RunSpec::new("Qwen3Moe (64-8)", "t1-qwen3"),
            RunSpec::new("Qwen3Moe-LPR (w/ init)", "t1-qwen3-lpr"),
            RunSpec::new("Qwen3Moe-LPR (w/o init)", "t1-qwen3-lpr-noinit"),
        ];
        let paper: &[PaperRow] = &[
            ("mixtral", 3.683, 0.635, 3.33e-6),
            ("mixtral-lpr", 3.747, 0.047, 0.649),
            ("deepseek", 3.673, 0.790, 6.41e-9),
            ("deepseek-lpr", 3.720, 0.036, 0.724),
            ("qwen3", 3.666, 0.707, 1.27e-16),
            ("qwen3-lpr-init", 3.685, 0.057, 0.597),
            ("qwen3-lpr", 3.697, 0.039, 0.696),
        ];
        self.standard_table(
            "table1",
            "Table 1: routing method comparison (tiny-scale mirror; \
             paper = 0.6B/C4)",
            specs,
            paper,
        )
    }

    // ------------------------------------------------------------------
    // Table 2: component ablation (same artifact, loss-weight patches)
    // ------------------------------------------------------------------
    pub fn table2(&self) -> Result<Vec<RunSummary>> {
        let specs = vec![
            RunSpec::new("Full LPR", "ab-base"),
            RunSpec::new("w/o KL (b=0)", "ab-base").patch(LW_BETA_KL, 0.0),
            RunSpec::new("w/o Align Loss", "ab-base")
                .patch(LW_BETA_ALIGN, 0.0),
            RunSpec::new("w/o Diversity Loss", "ab-base")
                .patch(LW_BETA_DIV, 0.0),
        ];
        let paper: &[PaperRow] = &[
            ("full", 4.86, 0.06, 0.595),
            ("no-kl", 4.82, 0.115, 0.304),
            ("no-align", 4.83, 0.115, 0.286),
            ("no-div", 5.01, 0.716, 0.002),
        ];
        self.standard_table(
            "table2",
            "Table 2: LPR component ablation",
            specs,
            paper,
        )
    }

    // ------------------------------------------------------------------
    // Table 3: latent dimension sweep
    // ------------------------------------------------------------------
    pub fn table3(&self) -> Result<Vec<RunSummary>> {
        let dims = [4usize, 8, 16, 32, 64, 128, 256];
        let paper_vals = [
            (5.085, 0.122, 0.385),
            (4.927, 0.085, 0.480),
            (4.869, 0.060, 0.595),
            (4.828, 0.070, 0.5247),
            (4.874, 0.063, 0.525),
            (4.891, 0.074, 0.507),
            (4.902, 0.093, 0.395),
        ];
        let specs = dims
            .iter()
            .map(|d| RunSpec::new(&format!("dim={d}"), &format!("t3-dim{d}")))
            .collect();
        let paper: Vec<PaperRow> = paper_vals
            .iter()
            .map(|&(l, g, m)| ("", l, g, m))
            .collect();
        self.standard_table(
            "table3",
            "Table 3: effect of encoder latent dimension",
            specs,
            &paper,
        )
    }

    // ------------------------------------------------------------------
    // Table 4: regularization strength sweep (runtime weight patches)
    // ------------------------------------------------------------------
    pub fn table4(&self) -> Result<Vec<RunSummary>> {
        let strengths = [0.0f32, 0.01, 0.04, 0.1, 0.5];
        let paper_vals = [
            (4.995, 0.72, 0.0009),
            (4.870, 0.060, 0.595),
            (5.060, 0.043, 0.668),
            (5.234, 0.044, 0.662),
            (5.752, 0.05, 0.628),
        ];
        let specs = strengths
            .iter()
            .map(|&b| {
                RunSpec::new(&format!("beta_rs={b}"), "ab-base")
                    .patch(LW_BETA_RS, b)
            })
            .collect();
        let paper: Vec<PaperRow> = paper_vals
            .iter()
            .map(|&(l, g, m)| ("", l, g, m))
            .collect();
        self.standard_table(
            "table4",
            "Table 4: effect of regularization strength",
            specs,
            &paper,
        )
    }

    // ------------------------------------------------------------------
    // Table 5: expert count sweep (+ a no-reg collapse row)
    // ------------------------------------------------------------------
    pub fn table5(&self) -> Result<Vec<RunSummary>> {
        // Tiny-scale mirror: paper sweeps 128..512 experts at 0.6B; we
        // sweep 32..128 at the same N/k ratios.
        let specs = vec![
            RunSpec::new("32-8", "t5-32-8"),
            RunSpec::new("64-8", "t5-64-8"),
            RunSpec::new("128-8", "t5-128-8"),
            RunSpec::new("128-4", "t5-128-4"),
            RunSpec::new("128-1", "t5-128-1"),
            RunSpec::new("128-1 no-reg", "t5-128-1").patch(LW_BETA_RS, 0.0),
        ];
        let paper: &[PaperRow] = &[
            ("128-8", f64::NAN, 0.099, 0.412),
            ("256-8", f64::NAN, 0.155, 0.245),
            ("512-8", f64::NAN, 0.249, 0.059),
            ("512-4", f64::NAN, 0.347, 0.018),
            ("512-1", f64::NAN, 0.322, 0.047),
            ("512-1-noreg", f64::NAN, 0.9853, 9.3e-22),
        ];
        self.standard_table(
            "table5",
            "Table 5: effect of number of experts (ratio-mirrored)",
            specs,
            paper,
        )
    }

    // ------------------------------------------------------------------
    // Table 6: diversity measure comparison
    // ------------------------------------------------------------------
    pub fn table6(&self) -> Result<Vec<RunSummary>> {
        let specs = vec![
            RunSpec::new("Cosine", "t6-div-cosine"),
            RunSpec::new("Orthogonal", "t6-div-orthogonal"),
            RunSpec::new("Euclidean", "t6-div-euclidean"),
        ];
        let paper: &[PaperRow] = &[
            ("cos", 5.11, 0.482, 0.037),
            ("orth", 4.86, 0.06, 0.595),
            ("euc", 6.745, 0.263, 0.111),
        ];
        self.standard_table(
            "table6",
            "Table 6: effect of diversity measure",
            specs,
            paper,
        )
    }

    // ------------------------------------------------------------------
    // Table 7: similarity / divergence metric comparison
    // ------------------------------------------------------------------
    pub fn table7(&self) -> Result<Vec<RunSummary>> {
        let rows: Vec<(&str, &str, PaperRow)> = vec![
            ("Cosine", "t7-cosine", ("", 4.855, 0.082, 0.595)),
            ("Gaussian Kernel", "t7-gaussian", ("", 4.908, 0.269, 0.139)),
            ("Mahalanobis", "t7-mahalanobis", ("", 4.910, 0.246, 0.111)),
            ("Cross-Attention", "t7-xattn", ("", 4.878, 0.574, 0.007)),
            ("Wasserstein", "t7-wasserstein", ("", 4.884, 0.29, 0.067)),
            ("Hellinger", "t7-hellinger", ("", 4.964, 0.364, 0.043)),
            ("JS Divergence", "t7-js", ("", 4.979, 0.298, 0.08)),
            ("KL Divergence", "t7-kl", ("", 4.881, 0.261, 0.098)),
        ];
        let specs = rows
            .iter()
            .map(|(l, a, _)| RunSpec::new(l, a))
            .collect();
        let paper: Vec<PaperRow> = rows.iter().map(|r| r.2).collect();
        self.standard_table(
            "table7",
            "Table 7: similarity/divergence measures in routing",
            specs,
            &paper,
        )
    }

    // ------------------------------------------------------------------
    // Figure 1: per-layer normalized load heatmaps, vanilla vs LPR
    // ------------------------------------------------------------------
    /// Run the two fig-1 models once; reused by fig1/fig3/dispatch_replay.
    pub fn fig1_runs(&self) -> Result<(RunSummary, RunSummary)> {
        let v = self.run(RunSpec::new("vanilla", "fig1-vanilla"))?;
        let l = self.run(RunSpec::new("lpr", "fig1-lpr"))?;
        Ok((v, l))
    }

    pub fn fig1(&self) -> Result<()> {
        let runs = self.fig1_runs()?;
        self.fig1_from(&runs.0, &runs.1)
    }

    pub fn fig1_from(&self, v: &RunSummary, l: &RunSummary) -> Result<()> {
        let mut extra = String::new();
        for (label, s) in [("vanilla", v), ("lpr", l)] {
            let heat = ascii_heatmap(&s.eval_load);
            extra.push_str(&format!("\n#### {label}\n```\n{heat}```\n"));
            // CSV of normalized loads for external plotting
            let mut csv = String::from("layer,expert,normalized_load\n");
            for (l, row) in s.eval_load.normalized().iter().enumerate() {
                for (e, v) in row.iter().enumerate() {
                    csv.push_str(&format!("{l},{e},{v}\n"));
                }
            }
            std::fs::write(
                self.out_dir.join(format!("fig1-{label}.csv")),
                csv,
            )?;
        }
        let t = Table::new(
            "Figure 1: normalized expert load across layers \
             (see heatmaps below; CSVs in results/)",
            &["artifact", "output"],
        );
        self.emit("fig1", &t, &extra)?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Figure 3: convergence curves, high-Gini vs low-Gini router
    // ------------------------------------------------------------------
    pub fn fig3(&self) -> Result<()> {
        let runs = self.fig1_runs()?;
        self.fig3_from(&runs.0, &runs.1)
    }

    pub fn fig3_from(&self, a: &RunSummary, b: &RunSummary) -> Result<()> {
        let mut csv = String::from("step,vanilla_loss,lpr_loss\n");
        for (i, (x, y)) in a.loss_curve.iter().zip(&b.loss_curve).enumerate()
        {
            csv.push_str(&format!("{i},{x},{y}\n"));
        }
        std::fs::write(self.out_dir.join("fig3.csv"), &csv)?;
        let mut t = Table::new(
            "Figure 3: convergence vs routing balance",
            &["run", "final train loss", "test loss", "GINI"],
        );
        for s in [a, b] {
            t.row(vec![
                s.label.clone(),
                fmt_sci(s.train_loss_final),
                fmt_sci(s.test_loss),
                fmt_sci(s.gini),
            ]);
        }
        self.emit("fig3", &t, "\nloss curves: results/fig3.csv\n")?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Figure 4: specialization / balance trade-off over the reg sweep
    // ------------------------------------------------------------------
    pub fn fig4(&self) -> Result<()> {
        let strengths = [0.0f32, 0.005, 0.01, 0.04, 0.1, 0.5];
        let mut t = Table::new(
            "Figure 4: specialization (top-1 routing confidence) vs \
             balance (1 - GINI) across regularization strength",
            &["beta_rs", "balance (1-GINI)", "specialization proxy",
              "test loss"],
        );
        let mut csv =
            String::from("beta_rs,balance,specialization,test_loss\n");
        for &b in &strengths {
            let s = self
                .run(RunSpec::new(&format!("rs={b}"), "ab-base")
                    .patch(LW_BETA_RS, b))?;
            let bal = 1.0 - s.gini;
            t.row(vec![
                format!("{b}"),
                fmt_sci(bal),
                fmt_sci(s.top1_confidence),
                fmt_sci(s.test_loss),
            ]);
            csv.push_str(&format!(
                "{b},{bal},{},{}\n",
                s.top1_confidence, s.test_loss
            ));
        }
        std::fs::write(self.out_dir.join("fig4.csv"), &csv)?;
        self.emit("fig4", &t, "")?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Dispatch simulation: serving-time cost of imbalance (ours)
    // ------------------------------------------------------------------
    pub fn dispatch_report(&self) -> Result<()> {
        let mut t = Table::new(
            "Dispatch simulator: serving cost vs load skew \
             (64 experts, 8 devices, top-8, cf=1.25)",
            &[
                "routing skew", "GINI", "throughput tok/s", "p99 lat us",
                "drop %", "utilization",
            ],
        );
        for &skew in &[0.0, 0.3, 0.7, 1.0, 1.5, 2.0] {
            let mut sim = DispatchSim::new(SimConfig::default())?;
            let mut rng = Rng::new(7);
            for _ in 0..200 {
                let a = synthetic_assignments(&mut rng, 1024, 8, 64, skew);
                sim.step(&a);
            }
            let r = sim.report();
            t.row(vec![
                format!("zipf s={skew}"),
                fmt_sci(r.load_gini),
                format!("{:.0}", r.throughput_tok_per_s),
                format!("{:.0}", r.latency_p99_us),
                format!("{:.2}", 100.0 * r.drop_frac),
                format!("{:.3}", r.utilization),
            ]);
        }
        self.emit("dispatch", &t, "")?;
        Ok(())
    }

    /// End-to-end serving path: route real (cluster-structured) token
    /// streams through the engine facade (scoped backend over a
    /// compiled `RouterPlan`) and dispatch the flat routed batches
    /// straight into the simulator, per §2.4.1 metric.
    /// Unlike `dispatch_report` (synthetic Zipf assignments), the load
    /// skew here is produced by actual routing geometry.
    pub fn dispatch_routed(&self) -> Result<()> {
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(8);
        let (d, dz, e, k) = (64usize, 16usize, 64usize, 8usize);
        let (n_tokens, steps) = (1024usize, 50usize);
        let mut t = Table::new(
            &format!(
                "Dispatch via compiled routing engine ({e} experts, \
                 top-{k}, {threads} threads, Zipf-clustered tokens)"
            ),
            &[
                "metric", "GINI", "route ns/tok", "throughput tok/s",
                "p99 lat us", "utilization",
            ],
        );
        for metric in METRICS {
            let mut rng = Rng::new(23);
            let router = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
            let mut engine = build_layer_engine(
                router.plan().clone(),
                ExpertBank::new(&Rng::new(0), e, d, 1),
                Backend::Scoped { threads },
                OverflowPolicy::Drop,
                1.25,
            )?;
            let mut sim = DispatchSim::new(SimConfig {
                n_experts: e,
                top_k: k,
                ..SimConfig::default()
            })?;
            // Gaussian-mixture stream with Zipf-skewed cluster sizes
            // (the paper's §2.2.1 clusterability assumptions)
            let mix = MixtureStream::standard(&mut rng, d);
            let route_ns = run_routed_steps(
                &mut engine,
                &mix,
                &mut rng,
                &mut sim,
                steps,
                n_tokens,
                OverflowPolicy::Drop,
            );
            let r = sim.report();
            t.row(vec![
                metric.to_string(),
                fmt_sci(r.load_gini),
                format!(
                    "{:.0}",
                    route_ns as f64 / (steps * n_tokens) as f64
                ),
                format!("{:.0}", r.throughput_tok_per_s),
                format!("{:.0}", r.latency_p99_us),
                format!("{:.3}", r.utilization),
            ]);
        }
        self.emit("dispatch-routed", &t, "")?;
        Ok(())
    }

    /// Overflow-policy sweep: the three [`OverflowPolicy`] variants ×
    /// capacity factors on one skewed clustered stream, all routed
    /// through the compiled engine and compiled into dispatch plans.
    /// Shows the related-work claim that overflow policy is itself a
    /// balancing lever: at cf = 1.0, next-choice and least-loaded
    /// strictly cut the drop fraction vs greedy drop (pinned by
    /// `rerouting_strictly_beats_drop_on_skewed_stream`), and
    /// least-loaded additionally flattens the *computed* load, which
    /// the straggler-bound latency model rewards as throughput.
    pub fn dispatch_policies(&self) -> Result<()> {
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(8);
        let (d, dz, e, k) = (64usize, 16usize, 64usize, 8usize);
        let (n_tokens, steps) = (1024usize, 50usize);
        let mut t = Table::new(
            &format!(
                "Dispatch overflow policies × capacity factor \
                 ({e} experts, top-{k}, cosine router, skewed \
                 Zipf(1.6) clustered tokens, {threads} threads)"
            ),
            &[
                "policy", "cf", "GINI", "win-GINI", "min-max",
                "drop %", "reroute %", "throughput tok/s",
            ],
        );
        for &cf in &[1.0f64, 1.25, 1.5] {
            for policy in OverflowPolicy::ALL {
                // identical seed per cell: every policy sees the same
                // token stream and routed assignments
                let mut rng = Rng::new(23);
                let router =
                    synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
                let mut engine = build_layer_engine(
                    router.plan().clone(),
                    ExpertBank::new(&Rng::new(0), e, d, 1),
                    Backend::Scoped { threads },
                    policy,
                    cf,
                )?;
                let mut sim = DispatchSim::new(SimConfig {
                    n_experts: e,
                    top_k: k,
                    capacity_factor: cf,
                    ..SimConfig::default()
                })?;
                let mix = MixtureStream::skewed(&mut rng, d, 1.6);
                run_routed_steps(
                    &mut engine,
                    &mix,
                    &mut rng,
                    &mut sim,
                    steps,
                    n_tokens,
                    policy,
                );
                let r = sim.report();
                t.row(vec![
                    policy.name().to_string(),
                    format!("{cf}"),
                    fmt_sci(r.load_gini),
                    fmt_sci(r.window_gini),
                    fmt_sci(r.load_min_max),
                    format!("{:.2}", 100.0 * r.drop_frac),
                    format!("{:.2}", 100.0 * r.reroute_frac),
                    format!("{:.0}", r.throughput_tok_per_s),
                ]);
            }
        }
        self.emit(
            "dispatch-policies",
            &t,
            "\nGINI/min-max are over the *routed* load (policy-\
             invariant by construction at equal seeds); drop/reroute/\
             throughput are where the policies separate.\n",
        )?;
        Ok(())
    }

    /// Placement sweep: overflow policy × expert-placement planner on
    /// one skewed clustered stream, all routed through the compiled
    /// engine. The routed load (and therefore Gini/min-max and the
    /// drop fraction) is placement-invariant by construction —
    /// placement moves *experts across devices*, never tokens — so the
    /// planners separate exactly where the ISSUE says they should:
    /// straggler latency and stall fraction. `replans`/`moved` show
    /// the live-migration traffic the adoption guard let through.
    pub fn placement(&self) -> Result<()> {
        let (d, dz, e, k) = (64usize, 16usize, 64usize, 8usize);
        let (n_tokens, steps) = (1024usize, 50usize);
        let cf = 1.25f64;
        let mut t = Table::new(
            &format!(
                "Expert placement × overflow policy ({e} experts, 8 \
                 devices, top-{k}, cf={cf}, cosine router, skewed \
                 Zipf(1.6) clustered tokens)"
            ),
            &[
                "policy", "placement", "win-GINI", "min-max",
                "mean lat us", "p99 lat us", "stall %", "replans",
                "moved KiB",
            ],
        );
        for policy in OverflowPolicy::ALL {
            for placement in PlacementPolicy::ALL {
                // identical seed per cell: every placement sees the
                // same token stream and routed assignments
                let mut rng = Rng::new(23);
                let router =
                    synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
                let mut engine = build_layer_engine(
                    router.plan().clone(),
                    ExpertBank::new(&Rng::new(0), e, d, 1),
                    Backend::Scoped { threads: 1 },
                    policy,
                    cf,
                )?;
                let mut sim = DispatchSim::new(SimConfig {
                    n_experts: e,
                    top_k: k,
                    capacity_factor: cf,
                    ..SimConfig::default()
                })?;
                sim.set_placement(PlacementConfig {
                    policy: placement,
                    replan_every: 8,
                    bytes_per_expert: 4096,
                    ..PlacementConfig::default()
                });
                let mix = MixtureStream::skewed(&mut rng, d, 1.6);
                run_routed_steps(
                    &mut engine,
                    &mix,
                    &mut rng,
                    &mut sim,
                    steps,
                    n_tokens,
                    policy,
                );
                let r = sim.report();
                t.row(vec![
                    policy.name().to_string(),
                    r.placement.to_string(),
                    fmt_sci(r.window_gini),
                    fmt_sci(r.window_min_max),
                    format!("{:.0}", r.latency_mean_us),
                    format!("{:.0}", r.latency_p99_us),
                    format!("{:.1}", 100.0 * r.stall_frac),
                    format!("{}", r.replans),
                    format!("{:.0}", r.migrated_bytes as f64 / 1024.0),
                ]);
            }
        }
        self.emit(
            "placement",
            &t,
            "\nwin-GINI/min-max are over the *routed* load — identical \
             down a policy's rows because placement never changes what \
             was routed; latency/stall are where the planners win. \
             'moved KiB' is adopted live-migration traffic (charged to \
             step latency at the configured per-byte cost).\n",
        )?;
        Ok(())
    }

    /// Serving-runtime sweep: policy × worker count × arrival rate
    /// through the persistent-pool [`ServeRuntime`] (bounded queue,
    /// micro-batching, real expert FFN compute). Arrival rates are
    /// expressed as load fractions of this machine's *measured*
    /// full-forward capacity per worker count, so the sweep brackets
    /// saturation on any box: below 1.0 the latency percentiles sit
    /// near the batch service time, above it queueing delay takes over
    /// and p99 departs from p50 — the queueing-theory picture the
    /// related serving-dispatch work evaluates. Pure-Rust: needs no
    /// artifacts or PJRT runtime.
    pub fn serve_table(&self) -> Result<()> {
        let (d, dz, e, k, d_ff) = (32usize, 16, 32, 4, 64);
        let (req_tokens, n_requests) = (32usize, 256usize);
        let (max_batch, max_wait) = (256usize, 2_000u64);
        let mut t = Table::new(
            &format!(
                "Serving runtime: persistent pool + micro-batch queue \
                 ({e} experts top-{k}, cosine router, {req_tokens}-token \
                 requests, max_batch {max_batch}, skewed Zipf(1.6) \
                 clustered tokens)"
            ),
            &[
                "policy", "workers", "load", "rate tok/s", "p50 us",
                "p99 us", "throughput tok/s", "win-GINI", "rejected",
            ],
        );
        for &workers in &[1usize, 2, 4] {
            // calibrate this worker count's service capacity once,
            // through the same builder-constructed backend the cells
            // use
            let mut rng = Rng::new(23);
            let router =
                synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
            let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
            let mix = MixtureStream::skewed(&mut rng, d, 1.6);
            let mut cal = build_layer_engine(
                router.plan().clone(),
                bank.clone(),
                Backend::Pool { workers },
                OverflowPolicy::Drop,
                1.25,
            )?;
            let cap_tok_s = measure_engine_rate(
                &mut cal, &mix, &mut rng, max_batch, 3,
            );
            drop(cal);
            for policy in OverflowPolicy::ALL {
                for &load in &[0.5f64, 1.5] {
                    // identical seeds per cell: every cell sees the
                    // same router geometry and token stream
                    let mut rng = Rng::new(23);
                    let router = synthetic_lpr_router(
                        "cosine", &mut rng, d, dz, e, k,
                    );
                    let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
                    let mix = MixtureStream::skewed(&mut rng, d, 1.6);
                    let engine = build_layer_engine(
                        router.plan().clone(),
                        bank,
                        Backend::Pool { workers },
                        policy,
                        1.25,
                    )?;
                    let cfg = ServeConfig {
                        max_batch,
                        max_wait,
                        queue_tokens: 8 * max_batch,
                        ..ServeConfig::default()
                    };
                    let mut srv = ServeRuntime::with_engine(
                        engine.into_inner(),
                        cfg,
                    );
                    run_open_loop(
                        &mut srv,
                        &mix,
                        &mut rng,
                        n_requests,
                        req_tokens,
                        load * cap_tok_s,
                    );
                    let r = srv.report();
                    t.row(vec![
                        policy.name().to_string(),
                        format!("{workers}"),
                        format!("{load}"),
                        format!("{:.0}", load * cap_tok_s),
                        format!("{:.0}", r.latency_p50_us),
                        format!("{:.0}", r.latency_p99_us),
                        format!("{:.0}", r.throughput_tok_per_s),
                        fmt_sci(r.window_gini),
                        format!("{}", r.rejected),
                    ]);
                }
            }
        }
        self.emit(
            "serve",
            &t,
            "\nload = arrival rate / measured full-forward capacity at \
             that worker count; latencies are virtual-clock ticks (1 \
             tick = 1 us) including queue wait, micro-batch wait, \
             pipeline backpressure, and measured compute.\n",
        )?;
        Ok(())
    }

    /// Multi-layer model serving table: an L=4 stack built from a
    /// **synthesized checkpoint** round-tripped through the
    /// `coordinator::checkpoint` format and the `model::bridge` (the
    /// same path `lpr serve --ckpt` takes for trained checkpoints — no
    /// PJRT, works against the vendor stub), served through the
    /// persistent-pool `ServeRuntime`, with balance reported **per
    /// layer** over the rolling `[L, E]` tracker — the layer-resolved
    /// Gini/min-max resolution of the paper's per-layer plots, now
    /// measured at serving time. A second section drives the same
    /// stack through the layered dispatch simulator, whose step
    /// latency composes sequentially across layers (one imbalanced
    /// layer stalls the whole stack).
    pub fn model_serve_table(&self) -> Result<()> {
        let (n_layers, d, dz, e, k, d_ff) = (4usize, 32, 16, 32, 4, 64);
        let (req_tokens, n_requests) = (32usize, 192usize);
        let (max_batch, max_wait) = (256usize, 2_000u64);
        let workers = 2usize;
        let cf = 1.25f64;

        // checkpoint round-trip: synthesize → save → load → bridge
        let (meta, state) = bridge::synth_checkpoint_artifact(
            "model-serve", "cosine", n_layers, d, dz, e, k, d_ff, 23,
        )?;
        let ckpt_path = self.out_dir.join("model-serve.ckpt");
        crate::coordinator::checkpoint::save(
            &ckpt_path,
            &meta.name,
            0,
            &state,
        )?;
        let ck = crate::coordinator::checkpoint::load(&ckpt_path)?;
        let model = bridge::model_from_checkpoint(&meta, &ck)?;

        let mut t = Table::new(
            &format!(
                "Model serving: {n_layers}-layer LPR stack from a \
                 checkpoint file ({e} experts top-{k}, cosine, \
                 {workers} workers, skewed Zipf(1.6) tokens) — \
                 per-layer rolling balance"
            ),
            &["layer", "win-GINI", "min-max", "cv", "sim GINI", "sim min-max"],
        );
        let build_pool = |model: StackedModel| -> Result<Engine> {
            Ok(Engine::builder()
                .model(model)
                .backend(Backend::Pool { workers })
                .policy(OverflowPolicy::Drop)
                .capacity_factor(cf)
                .build()?)
        };
        let mut rng = Rng::new(23);
        let mix = MixtureStream::skewed(&mut rng, d, 1.6);
        let mut cal = build_pool(model.clone())?;
        let cap_tok_s =
            measure_engine_rate(&mut cal, &mix, &mut rng, max_batch, 3);
        drop(cal);
        let cfg = ServeConfig {
            max_batch,
            max_wait,
            queue_tokens: 8 * max_batch,
            ..ServeConfig::default()
        };
        let mut srv =
            ServeRuntime::with_engine(build_pool(model.clone())?.into_inner(), cfg);
        run_open_loop(
            &mut srv,
            &mix,
            &mut rng,
            n_requests,
            req_tokens,
            0.8 * cap_tok_s,
        );
        let rep = srv.report();

        // the same stack through the layered dispatch simulator, on
        // the scoped backend this time (the facade makes the swap a
        // one-word change)
        let mut engine = Engine::builder()
            .model(model)
            .backend(Backend::Scoped { threads: workers })
            .policy(OverflowPolicy::Drop)
            .capacity_factor(cf)
            .build()?;
        let mut sim = crate::dispatch::DispatchSim::new_layered(
            SimConfig {
                n_experts: e,
                top_k: k,
                capacity_factor: cf,
                ..SimConfig::default()
            },
            n_layers,
        )?;
        let mut rng = Rng::new(23);
        let mix = MixtureStream::skewed(&mut rng, d, 1.6);
        run_model_steps(&mut engine, &mix, &mut rng, &mut sim, 24, 512);
        let sim_rep = sim.report();

        for (lb, sb) in rep.layers.iter().zip(&sim_rep.layers) {
            t.row(vec![
                format!("L{}", lb.layer),
                fmt_sci(lb.gini),
                fmt_sci(lb.min_max),
                fmt_sci(lb.cv),
                fmt_sci(sb.gini),
                fmt_sci(sb.min_max),
            ]);
        }
        t.row(vec![
            "mean".to_string(),
            fmt_sci(rep.window_gini),
            fmt_sci(rep.window_min_max),
            fmt_sci(rep.window_cv),
            fmt_sci(
                sim_rep.layers.iter().map(|l| l.gini).sum::<f64>()
                    / n_layers as f64,
            ),
            fmt_sci(
                sim_rep.layers.iter().map(|l| l.min_max).sum::<f64>()
                    / n_layers as f64,
            ),
        ]);
        self.emit(
            "model-serve",
            &t,
            &format!(
                "\nruntime: {} requests, p50/p99 {:.0}/{:.0} us, {:.0} \
                 tok/s served at 0.8x measured capacity; sim: {} stacked \
                 steps, p99 {:.0} us, drop {:.2}% (layer-sequential \
                 straggler model). 'win-*' columns are the serving \
                 runtime's rolling [L, E] tracker; 'sim *' the layered \
                 simulator's.\n",
                rep.requests,
                rep.latency_p50_us,
                rep.latency_p99_us,
                rep.throughput_tok_per_s,
                sim_rep.steps,
                sim_rep.latency_p99_us,
                100.0 * sim_rep.drop_frac
            ),
        )?;
        Ok(())
    }


    /// Admission-lane overload study: a priority lane (own token
    /// quota, weight 8) and a best-effort catch-all in front of the
    /// pool engine, driven at 0.5x/1x/2x of measured capacity with a
    /// 3:1 best-effort-heavy mix. Under overload the best-effort lane
    /// sheds with explicit rejections while the priority lane keeps a
    /// bounded p99 — the serving-side complement of the paper's
    /// balanced-routing story (cf. the Least-Loaded Expert Parallelism
    /// serving work). Pure-Rust: needs no artifacts or PJRT runtime.
    pub fn admission_table(&self) -> Result<()> {
        let (d, dz, e, k, d_ff) = (32usize, 16, 32, 4, 64);
        let (req_tokens, n_requests) = (16usize, 384usize);
        let (max_batch, max_wait) = (128usize, 2_000u64);
        let workers = 2usize;
        let config = AdmissionConfig::parse(
            "lane priority\n  path_prefix /priority\n  quota 512\n\
             \x20 weight 8\nlane best-effort\n  quota 256\n",
        )?;
        config.validate(max_batch)?;
        // 3:1 best-effort-heavy traffic: the priority lane stays under
        // capacity even when the total offered load is 2x
        let prio = RequestMeta {
            path: "/priority/generate".to_string(),
            ..RequestMeta::default()
        };
        let best = RequestMeta::default();
        let metas =
            [prio, best.clone(), best.clone(), best];

        let mut t = Table::new(
            &format!(
                "Admission lanes under load: priority (quota 512, \
                 weight 8) vs best-effort catch-all ({e} experts \
                 top-{k}, cosine router, {req_tokens}-token requests, \
                 max_batch {max_batch}, 3:1 best-effort-heavy mix)"
            ),
            &[
                "load", "lane", "admitted", "shed", "p50 us", "p99 us",
                "depth tok",
            ],
        );
        // calibrate capacity once, same backend as the cells
        let mut rng = Rng::new(23);
        let router =
            synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
        let mix = MixtureStream::skewed(&mut rng, d, 1.6);
        let mut cal = build_layer_engine(
            router.plan().clone(),
            bank,
            Backend::Pool { workers },
            OverflowPolicy::Drop,
            1.25,
        )?;
        let cap_tok_s =
            measure_engine_rate(&mut cal, &mix, &mut rng, max_batch, 3);
        drop(cal);
        for &load in &[0.5f64, 1.0, 2.0] {
            // identical seeds per cell: same router, same stream
            let mut rng = Rng::new(23);
            let router =
                synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
            let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
            let mix = MixtureStream::skewed(&mut rng, d, 1.6);
            let engine = build_layer_engine(
                router.plan().clone(),
                bank,
                Backend::Pool { workers },
                OverflowPolicy::Drop,
                1.25,
            )?;
            let cfg = ServeConfig {
                max_batch,
                max_wait,
                queue_tokens: 8 * max_batch,
                ..ServeConfig::default()
            };
            let adm = config.compile(d, max_batch)?;
            let mut rt =
                AdmittedRuntime::new(engine.into_inner(), cfg, adm);
            run_admitted_open_loop(
                &mut rt,
                &mix,
                &mut rng,
                &metas,
                n_requests,
                req_tokens,
                load * cap_tok_s,
            );
            let rep = rt.report();
            for l in &rep.lanes {
                t.row(vec![
                    format!("{load}"),
                    l.name.clone(),
                    format!("{}", l.admitted),
                    format!("{}", l.rejected),
                    format!("{:.0}", l.latency_p50_us),
                    format!("{:.0}", l.latency_p99_us),
                    format!("{}", l.queue_depth_tokens),
                ]);
            }
        }
        self.emit(
            "admission",
            &t,
            "\nload = offered rate / measured capacity. The compiled \
             admission config routes /priority traffic to its own \
             quota-bounded lane flushed first (weight 8); past \
             saturation the catch-all lane absorbs the shedding \
             (explicit 503-style rejections) while priority latency \
             stays bounded by its quota.\n",
        )?;
        Ok(())
    }

    /// Autoregressive decode telemetry: greedy generation through the
    /// KV-cached continuous-batching session, reporting per-step
    /// routed-load balance (the paper's Gini / min-max lens at
    /// decode's one-token-per-sequence regime) and step latency. The
    /// decoder takes the full `train -> ckpt -> generate` route:
    /// synthesize a decoder checkpoint (attention + MoE leaves), save
    /// it, load it back, and bridge it. Pure-Rust: needs no artifacts
    /// or PJRT runtime.
    pub fn decode_table(&self) -> Result<()> {
        let (n_layers, d, dz, e, k, d_ff, heads) =
            (2usize, 32usize, 16, 16, 2, 64, 4);
        let (prompt, max_new) = (vec![3usize, 1, 4, 1, 5], 12usize);
        let join = vec![2usize, 7];

        // checkpoint round-trip through the attention-aware bridge
        let (meta, state) = bridge::synth_decoder_artifact(
            "decode", "cosine", n_layers, d, dz, e, k, d_ff, heads, 23,
        )?;
        let ckpt_path = self.out_dir.join("decode.ckpt");
        crate::coordinator::checkpoint::save(
            &ckpt_path,
            &meta.name,
            0,
            &state,
        )?;
        let ck = crate::coordinator::checkpoint::load(&ckpt_path)?;
        let (dec, summary) =
            bridge::decoder_from_checkpoint(&meta, &ck)?;
        anyhow::ensure!(
            summary.skipped.is_empty(),
            "decoder bridge skipped leaves: {summary}"
        );

        let (model, head) = dec.into_parts();
        // no-drop capacity factor: cached decode stays bitwise the
        // prefill forward (rust/tests/decode.rs pins this)
        let engine = Engine::builder()
            .model(model)
            .backend(Backend::Scoped { threads: 2 })
            .capacity_factor(e as f64)
            .build()?;
        let max_seq = prompt.len().max(join.len()) + max_new;
        let mut sess = DecodeSession::new(engine, head, 2, max_seq);
        sess.submit(GenRequest { prompt: prompt.clone(), max_new })?;

        let mut t = Table::new(
            &format!(
                "Autoregressive decode: {n_layers}-layer cosine \
                 decoder from a checkpoint ({e} experts top-{k}, \
                 {heads} heads, no-drop cf {e}), greedy KV-cached \
                 generation with a mid-stream join"
            ),
            &[
                "step", "seqs", "join", "toks", "mean GINI",
                "min-max", "us",
            ],
        );
        let mut stats = Vec::new();
        loop {
            // the second sequence joins mid-generation: continuous
            // batching admits it without disturbing the first
            if sess.steps() == 4 {
                sess.submit(GenRequest {
                    prompt: join.clone(),
                    max_new,
                })?;
            }
            match sess.step() {
                Some(s) => stats.push(s),
                None => break,
            }
        }
        for s in &stats {
            let nl = s.layers.len().max(1) as f64;
            t.row(vec![
                format!("{}", s.step),
                format!("{}", s.n_seqs),
                format!("{}", s.n_joined),
                format!("{}", s.n_tokens),
                fmt_sci(
                    s.layers.iter().map(|l| l.gini).sum::<f64>() / nl,
                ),
                fmt_sci(
                    s.layers.iter().map(|l| l.min_max).sum::<f64>()
                        / nl,
                ),
                format!("{:.1}", s.latency_ns as f64 / 1e3),
            ]);
        }
        let fin = sess.take_finished();
        let toks: usize = fin.iter().map(|f| f.tokens.len()).sum();
        let dropped: usize = stats.iter().map(|s| s.n_dropped).sum();
        self.emit(
            "decode",
            &t,
            &format!(
                "\n{} sequences finished ({} new tokens over {} \
                 steps, {} dropped). Each row's balance is that \
                 step's routed [L, E] load alone — decode routes one \
                 token per live sequence, the small-batch regime \
                 where balanced routing is hardest. cf = n_experts \
                 keeps cached decode bitwise equal to prefill \
                 (rust/tests/decode.rs pins this).\n",
                fin.len(),
                toks,
                stats.len(),
                dropped
            ),
        )?;
        Ok(())
    }

    /// Replay measured load distributions from fig-1 runs through the
    /// simulator: the end-to-end "LPR fixes serving" result.
    pub fn dispatch_replay(&self) -> Result<()> {
        let runs = self.fig1_runs()?;
        self.dispatch_replay_from(&runs.0, &runs.1)
    }

    pub fn dispatch_replay_from(
        &self,
        v: &RunSummary,
        l: &RunSummary,
    ) -> Result<()> {
        let mut t = Table::new(
            "Dispatch replay of trained routers (fig1 runs)",
            &[
                "router", "GINI", "throughput tok/s", "p99 lat us",
                "drop %", "utilization",
            ],
        );
        for (label, s) in [("vanilla", v), ("lpr", l)] {
            let load = s.eval_load.normalized()[0].clone();
            let k = 4.min(load.len());
            let mut sim = DispatchSim::new(SimConfig {
                n_experts: load.len(),
                n_devices: 8,
                top_k: k,
                ..SimConfig::default()
            })?;
            let mut rng = Rng::new(11);
            for _ in 0..200 {
                let a = assignments_from_load(&mut rng, &load, 1024, k);
                sim.step(&a);
            }
            let r = sim.report();
            t.row(vec![
                label.to_string(),
                fmt_sci(r.load_gini),
                format!("{:.0}", r.throughput_tok_per_s),
                format!("{:.0}", r.latency_p99_us),
                format!("{:.2}", 100.0 * r.drop_frac),
                format!("{:.3}", r.utilization),
            ]);
        }
        self.emit("dispatch-replay", &t, "")?;
        Ok(())
    }

    /// Run the complete campaign, sharing the fig-1 trainings across
    /// fig1/fig3/dispatch-replay. Ordered so the paper's headline table
    /// lands first if the run is interrupted.
    pub fn all(&self) -> Result<()> {
        self.table1()?;
        self.table2()?;
        let (v, l) = self.fig1_runs()?;
        self.fig1_from(&v, &l)?;
        self.fig3_from(&v, &l)?;
        self.dispatch_report()?;
        self.dispatch_routed()?;
        self.dispatch_policies()?;
        self.placement()?;
        self.serve_table()?;
        self.model_serve_table()?;
        self.admission_table()?;
        self.decode_table()?;
        self.dispatch_replay_from(&v, &l)?;
        self.table5()?;
        self.table6()?;
        self.table7()?;
        self.table3()?;
        self.table4()?;
        self.fig4()?;
        Ok(())
    }
}
