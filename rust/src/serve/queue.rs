//! Bounded submission queue + micro-batcher: the request front-end of
//! the serving runtime.
//!
//! Requests are token groups (`[n, d]` activation rows). The queue is
//! bounded in *tokens* (`capacity_tokens`): a submission that would
//! overflow it is refused with [`SubmitError::Full`] — back-pressure,
//! not silent buffering. Pending requests micro-batch FIFO:
//!
//! - a batch **flushes** when the pending tokens reach `max_batch`, or
//!   when the oldest pending request has waited `max_wait` ticks
//!   ([`BatchQueue::ready`]);
//! - a flushed batch is the longest FIFO prefix of whole requests that
//!   fits `max_batch` tokens — requests are never split and never
//!   reordered, and their tokens stay contiguous and in submission
//!   order inside the batch (property-tested below);
//! - a request larger than `max_batch` could never flush, so `submit`
//!   refuses it up front with [`SubmitError::TooLarge`].
//!
//! Time is a **virtual clock**: callers pass integer `now` ticks into
//! `submit`/`ready`, so tests drive the batcher deterministically and
//! the bench drivers map one tick to one microsecond. The queue itself
//! never reads a wall clock.

use std::collections::VecDeque;

/// Why a submission was refused. Implements `Display` +
/// `std::error::Error` and converts into the shared [`crate::Error`],
/// so callers print it instead of matching and formatting by hand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is at `capacity_tokens`; retry after a flush.
    Full,
    /// The request alone exceeds `max_batch` tokens and can never
    /// flush.
    TooLarge,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full => write!(
                f,
                "submission queue is full (back-pressure); retry after \
                 a flush"
            ),
            SubmitError::TooLarge => write!(
                f,
                "request exceeds max_batch tokens and can never flush"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One request's slice of a flushed batch: token rows
/// `start..start + n_tokens` of the batch buffer belong to request
/// `id`, in the request's own token order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchMember {
    pub id: u64,
    /// Submission tick.
    pub arrival: u64,
    /// First token row of this request inside the flushed batch.
    pub start: usize,
    pub n_tokens: usize,
}

#[derive(Debug)]
struct Pending {
    id: u64,
    arrival: u64,
    h: Vec<f32>,
}

/// The bounded FIFO micro-batcher. See the module docs for the flush
/// rules and the virtual-clock contract.
#[derive(Debug)]
pub struct BatchQueue {
    d: usize,
    max_batch: usize,
    max_wait: u64,
    capacity_tokens: usize,
    reqs: VecDeque<Pending>,
    pending_tokens: usize,
    next_id: u64,
    /// Retired request buffers, reused by later submissions so the
    /// steady-state queue allocates only when depth grows.
    spares: Vec<Vec<f32>>,
}

impl BatchQueue {
    /// `d` is the token width (`d_model`); `max_batch` the flush size
    /// in tokens; `max_wait` the oldest-request age (ticks) that forces
    /// a flush; `capacity_tokens` the submission bound.
    pub fn new(
        d: usize,
        max_batch: usize,
        max_wait: u64,
        capacity_tokens: usize,
    ) -> BatchQueue {
        assert!(d >= 1, "token width must be >= 1");
        assert!(max_batch >= 1, "max_batch must be >= 1");
        assert!(
            capacity_tokens >= max_batch,
            "queue capacity below max_batch can never fill a batch"
        );
        BatchQueue {
            d,
            max_batch,
            max_wait,
            capacity_tokens,
            reqs: VecDeque::new(),
            pending_tokens: 0,
            next_id: 0,
            spares: Vec::new(),
        }
    }

    /// Submit one request of `h.len() / d` tokens at tick `now`.
    /// Returns the request id used in the matching
    /// `serve::Completion`.
    pub fn submit(&mut self, h: &[f32], now: u64) -> Result<u64, SubmitError> {
        assert_eq!(h.len() % self.d, 0, "request must be [n, {}]", self.d);
        let n = h.len() / self.d;
        assert!(n > 0, "empty request");
        if n > self.max_batch {
            return Err(SubmitError::TooLarge);
        }
        if self.pending_tokens + n > self.capacity_tokens {
            return Err(SubmitError::Full);
        }
        let mut buf = self.spares.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(h);
        let id = self.next_id;
        self.next_id += 1;
        self.reqs.push_back(Pending { id, arrival: now, h: buf });
        self.pending_tokens += n;
        Ok(id)
    }

    /// Whether a micro-batch should flush at tick `now`: pending tokens
    /// reached `max_batch`, or the oldest request aged out.
    pub fn ready(&self, now: u64) -> bool {
        match self.reqs.front() {
            None => false,
            Some(front) => {
                self.pending_tokens >= self.max_batch
                    || now.saturating_sub(front.arrival) >= self.max_wait
            }
        }
    }

    /// Pop the next micro-batch: the longest FIFO prefix of whole
    /// pending requests fitting `max_batch` tokens. `batch_h` receives
    /// the concatenated `[tokens, d]` rows, `members` the per-request
    /// slices (both cleared first). Always pops at least one request
    /// when the queue is non-empty (every request fits `max_batch` by
    /// the `submit` contract). Panics on an empty queue.
    pub fn pop_batch(
        &mut self,
        batch_h: &mut Vec<f32>,
        members: &mut Vec<BatchMember>,
    ) {
        assert!(!self.reqs.is_empty(), "pop_batch on an empty queue");
        batch_h.clear();
        members.clear();
        let mut tokens = 0usize;
        while let Some(front) = self.reqs.front() {
            let n = front.h.len() / self.d;
            if tokens + n > self.max_batch {
                break;
            }
            let req = self.reqs.pop_front().unwrap();
            members.push(BatchMember {
                id: req.id,
                arrival: req.arrival,
                start: tokens,
                n_tokens: n,
            });
            batch_h.extend_from_slice(&req.h);
            tokens += n;
            self.pending_tokens -= n;
            self.spares.push(req.h);
        }
    }

    /// Pending requests.
    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    /// Pending tokens across all queued requests.
    pub fn pending_tokens(&self) -> usize {
        self.pending_tokens
    }

    /// Flush size in tokens.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    /// Token `j` of request `id` carries a recognizable value per dim.
    fn req_tokens(id: u64, n: usize, d: usize) -> Vec<f32> {
        (0..n * d)
            .map(|i| {
                let (j, c) = (i / d, i % d);
                (id * 1000 + j as u64 * 8 + c as u64) as f32
            })
            .collect()
    }

    #[test]
    fn flushes_on_max_batch_or_max_wait() {
        let mut q = BatchQueue::new(2, 4, 10, 64);
        let id0 = q.submit(&req_tokens(0, 2, 2), 100).unwrap();
        assert_eq!(id0, 0);
        assert!(!q.ready(100), "2 of 4 tokens, no wait yet");
        assert!(!q.ready(109), "age 9 < max_wait 10");
        assert!(q.ready(110), "oldest aged out");
        // a second request tips pending over max_batch -> size flush
        q.submit(&req_tokens(1, 3, 2), 101).unwrap();
        assert!(q.ready(101));
        let (mut h, mut m) = (Vec::new(), Vec::new());
        q.pop_batch(&mut h, &mut m);
        // only request 0 fits (2 + 3 > 4): requests are never split
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].id, 0);
        assert_eq!(m[0].start, 0);
        assert_eq!(m[0].n_tokens, 2);
        assert_eq!(h, req_tokens(0, 2, 2));
        assert_eq!(q.pending_tokens(), 3);
        // the leftover request still flushes by age
        assert!(!q.ready(101));
        assert!(q.ready(111));
    }

    #[test]
    fn bounded_queue_backpressure_and_too_large() {
        let mut q = BatchQueue::new(1, 4, 5, 6);
        assert_eq!(q.submit(&[0.0; 8], 0), Err(SubmitError::TooLarge));
        q.submit(&[0.0; 4], 0).unwrap();
        q.submit(&[0.0; 2], 0).unwrap();
        // 6 of 6 tokens pending: the next submission is refused
        assert_eq!(q.submit(&[0.0; 1], 0), Err(SubmitError::Full));
        let (mut h, mut m) = (Vec::new(), Vec::new());
        q.pop_batch(&mut h, &mut m);
        assert_eq!(m.len(), 1); // the 4-token request fills max_batch
        // capacity released: submissions succeed again
        q.submit(&[0.0; 4], 1).unwrap();
        assert_eq!(q.pending_tokens(), 6);
    }

    /// Satellite property: the micro-batcher never exceeds `max_batch`
    /// and never reorders tokens within a request (requests stay whole,
    /// contiguous, FIFO, with their token rows in submission order).
    #[test]
    fn batches_bounded_and_order_preserving() {
        forall(
            40,
            2027,
            |rng| {
                let d = 1 + rng.below(3);
                let max_batch = 1 + rng.below(12);
                let cap = max_batch * (1 + rng.below(3));
                let n_reqs = 1 + rng.below(20);
                let sizes: Vec<usize> = (0..n_reqs)
                    .map(|_| 1 + rng.below(max_batch))
                    .collect();
                (d, max_batch, cap, sizes)
            },
            |(d, max_batch, cap, sizes)| {
                let mut q = BatchQueue::new(*d, *max_batch, 3, *cap);
                let mut accepted: Vec<(u64, usize)> = Vec::new();
                let mut popped: Vec<u64> = Vec::new();
                let (mut h, mut m) = (Vec::new(), Vec::new());
                let drain =
                    |q: &mut BatchQueue,
                     popped: &mut Vec<u64>,
                     h: &mut Vec<f32>,
                     m: &mut Vec<BatchMember>,
                     now: u64,
                     all: bool|
                     -> Result<(), String> {
                        loop {
                            let due = if all {
                                !q.is_empty()
                            } else {
                                q.ready(now)
                            };
                            if !due {
                                break;
                            }
                            q.pop_batch(h, m);
                            let tokens: usize =
                                m.iter().map(|x| x.n_tokens).sum();
                            if tokens > *max_batch {
                                return Err(format!(
                                    "batch of {tokens} > max_batch \
                                     {max_batch}"
                                ));
                            }
                            let mut next_start = 0usize;
                            for mem in m.iter() {
                                if mem.start != next_start {
                                    return Err(
                                        "request rows not contiguous"
                                            .into(),
                                    );
                                }
                                next_start += mem.n_tokens;
                                let want = req_tokens(
                                    mem.id,
                                    mem.n_tokens,
                                    *d,
                                );
                                let got = &h[mem.start * d
                                    ..(mem.start + mem.n_tokens) * d];
                                if got != &want[..] {
                                    return Err(format!(
                                        "request {} tokens reordered",
                                        mem.id
                                    ));
                                }
                                popped.push(mem.id);
                            }
                        }
                        Ok(())
                    };
                for (i, &n) in sizes.iter().enumerate() {
                    let now = i as u64;
                    match q.submit(&req_tokens(i as u64, n, *d), now) {
                        Ok(id) => accepted.push((id, n)),
                        Err(SubmitError::Full) => {
                            // drain and retry once — must then fit
                            drain(
                                &mut q, &mut popped, &mut h, &mut m,
                                now, true,
                            )?;
                            let id = q
                                .submit(&req_tokens(i as u64, n, *d), now)
                                .map_err(|e| format!("{e:?} after drain"))?;
                            accepted.push((id, n));
                        }
                        Err(e) => return Err(format!("{e:?}")),
                    }
                    drain(&mut q, &mut popped, &mut h, &mut m, now, false)?;
                }
                let end = sizes.len() as u64;
                drain(&mut q, &mut popped, &mut h, &mut m, end, true)?;
                // every accepted request flushed exactly once, FIFO
                let want: Vec<u64> =
                    accepted.iter().map(|&(id, _)| id).collect();
                if popped != want {
                    return Err(format!(
                        "flush order {popped:?} != submit order {want:?}"
                    ));
                }
                Ok(())
            },
        );
    }
}
