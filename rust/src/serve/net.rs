//! Dependency-free TCP front-end: framed requests over a loopback or
//! LAN socket into the wall-clock [`Server`](super::Server).
//!
//! Two wire formats implement the same [`Wire`] trait:
//!
//! - [`LengthPrefixed`] — the native framing: a little-endian `u32`
//!   payload length, then `u16`-prefixed path and tenant strings, a
//!   priority byte, a declared token count, and the raw `f32` activation
//!   rows. Symmetric fixed-size responses. This is the format
//!   `lpr listen` speaks by default and the framing round-trip tests
//!   exercise (split reads, coalesced frames, oversized frames,
//!   partial-write shutdown).
//! - [`HttpWire`] — HTTP/1.1-shaped request lines (`POST /path`),
//!   `x-tenant` / `x-priority` headers, and the same `f32` body; lane
//!   shedding maps to `503 Service Unavailable`, oversized payloads to
//!   `413`, malformed framing to `400`. Shaped, not a full HTTP stack:
//!   enough for `curl --data-binary` smoke tests.
//!
//! [`NetServer`] binds a listener, accepts on a polling loop, and runs
//! one thread per connection: read a request, decode its
//! [`RequestMeta`], feed `Server::enqueue_with` → `await_completion`,
//! write the response. Admission refusals ([`AdmitError`]) are
//! *responses*, not connection errors — the connection keeps serving,
//! which is what makes lane shedding observable as explicit 503s.
//! Framing errors close the connection after a best-effort error
//! response (a split or half-written frame cannot be resynced).
//!
//! Connections serve requests sequentially (one in flight per
//! connection — pipeline by opening more connections), with an
//! optional keep-alive request cap ([`NetServer::start_with_limit`]):
//! after N responses the connection closes gracefully and the peer
//! reconnects. Shut the [`NetServer`] down before the
//! [`Server`](super::Server) so every in-flight `await_completion`
//! can land.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::admission::{AdmitError, RequestMeta};
use super::Server;

/// Response status on the wire. [`Status::http_code`] is the HTTP
/// mapping; [`Status::byte`] the length-prefixed encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Ok,
    /// The matched lane (and spill target) is at quota — shed.
    LaneFull,
    /// No admission lane matches the request.
    NoRoute,
    /// The request exceeds `max_batch` and can never flush.
    TooLarge,
    /// The frame itself was malformed or oversized.
    BadFrame,
}

impl Status {
    pub fn byte(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::LaneFull => 1,
            Status::NoRoute => 2,
            Status::TooLarge => 3,
            Status::BadFrame => 4,
        }
    }

    pub fn from_byte(b: u8) -> Option<Status> {
        Some(match b {
            0 => Status::Ok,
            1 => Status::LaneFull,
            2 => Status::NoRoute,
            3 => Status::TooLarge,
            4 => Status::BadFrame,
            _ => return None,
        })
    }

    /// The HTTP status line this maps to: admission back-pressure is
    /// an explicit 503, oversized payloads 413, bad framing 400.
    pub fn http_code(self) -> (u16, &'static str) {
        match self {
            Status::Ok => (200, "OK"),
            Status::LaneFull => (503, "Service Unavailable"),
            Status::NoRoute => (503, "Service Unavailable"),
            Status::TooLarge => (413, "Payload Too Large"),
            Status::BadFrame => (400, "Bad Request"),
        }
    }

    fn from_admit_error(e: &AdmitError) -> Status {
        match e {
            AdmitError::NoRoute { .. } => Status::NoRoute,
            AdmitError::LaneFull { .. } => Status::LaneFull,
            AdmitError::TooLarge { .. } => Status::TooLarge,
        }
    }
}

/// One decoded request from the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct NetRequest {
    pub meta: RequestMeta,
    /// Activation length (f32 count) the client declared, if the
    /// format carries one (cross-checked against the parsed `h.len()`
    /// by the server; the wire itself does not know `d_model`).
    pub declared_len: Option<u32>,
    /// Activation rows, row-major `[n, d_model]`.
    pub h: Vec<f32>,
}

/// One response on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetResponse {
    pub status: Status,
    /// The admitted request id (lane-encoded; 0 on errors).
    pub id: u64,
    pub n_tokens: u32,
    /// Submission → completion latency, µs (0 on errors).
    pub latency_us: u64,
}

/// Why a frame could not be read.
#[derive(Debug)]
pub enum FrameError {
    /// Clean end-of-stream between frames.
    Eof,
    /// No bytes arrived within the read timeout (poll again).
    Idle,
    /// The frame declares more bytes than the wire allows.
    Oversized { len: usize, max: usize },
    /// The bytes violate the framing (including mid-frame EOF).
    Malformed(String),
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Eof => write!(f, "connection closed"),
            FrameError::Idle => write!(f, "no request within timeout"),
            FrameError::Oversized { len, max } => write!(
                f,
                "frame of {len} bytes exceeds the {max}-byte limit"
            ),
            FrameError::Malformed(m) => write!(f, "malformed frame: {m}"),
            FrameError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// A request/response wire format. Implementations must tolerate
/// arbitrarily split and coalesced TCP reads (they see a raw byte
/// stream), surface frames larger than their configured bound as
/// [`FrameError::Oversized`] *before* buffering them, and report a
/// timeout before the first byte of a frame as [`FrameError::Idle`]
/// (so the connection loop can poll its stop flag).
pub trait Wire: Send + Sync + 'static {
    fn read_request(
        &self,
        r: &mut dyn Read,
    ) -> Result<NetRequest, FrameError>;
    fn write_response(
        &self,
        w: &mut dyn Write,
        resp: &NetResponse,
    ) -> std::io::Result<()>;
    fn name(&self) -> &'static str;
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Mid-frame stalls retry on the socket's read timeout; give up after
/// this many so a wedged peer cannot pin a connection thread forever.
const FRAME_STALL_RETRIES: usize = 600;

/// Read one byte, distinguishing idle (no data before timeout) from
/// EOF. Only valid at a frame boundary.
fn read_first(r: &mut dyn Read) -> Result<Option<u8>, FrameError> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if would_block(&e) => return Err(FrameError::Idle),
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
}

/// Fill `buf` completely; EOF mid-frame is malformed, timeouts retry
/// (bounded by [`FRAME_STALL_RETRIES`]).
fn read_exact_frame(
    r: &mut dyn Read,
    buf: &mut [u8],
) -> Result<(), FrameError> {
    let mut off = 0;
    let mut stalls = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => {
                return Err(FrameError::Malformed(
                    "connection closed mid-frame".to_string(),
                ))
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if would_block(&e) => {
                stalls += 1;
                if stalls > FRAME_STALL_RETRIES {
                    return Err(FrameError::Malformed(
                        "peer stalled mid-frame".to_string(),
                    ));
                }
            }
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

/// Byte-slice cursor for decoding a buffered frame payload.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        if self.i + n > self.b.len() {
            return Err(FrameError::Malformed(format!(
                "frame payload truncated at byte {}",
                self.i
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn str(&mut self, n: usize) -> Result<String, FrameError> {
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| {
            FrameError::Malformed("string field is not utf-8".to_string())
        })
    }
}

/// The native length-prefixed framing. Request frame (all integers
/// little-endian):
///
/// ```text
/// u32 payload_len
/// u16 path_len   | path bytes (utf-8)
/// u16 tenant_len | tenant bytes (0 = no tenant)
/// u8  priority
/// u32 h_len                    declared f32 count (integrity check)
/// f32 × h_len                  activation rows, n_tokens · d_model
/// ```
///
/// Response frame: `u32 payload_len (=21) | u8 status | u64 id |
/// u32 n_tokens | u64 latency_us`.
#[derive(Debug, Clone)]
pub struct LengthPrefixed {
    /// Largest accepted request payload, bytes.
    pub max_frame: usize,
}

impl Default for LengthPrefixed {
    fn default() -> LengthPrefixed {
        LengthPrefixed { max_frame: 1 << 20 }
    }
}

impl LengthPrefixed {
    /// Encode one request frame (the client side; tests and
    /// `examples/` use this).
    pub fn encode_request(meta: &RequestMeta, h: &[f32]) -> Vec<u8> {
        let tenant = meta.tenant.as_deref().unwrap_or("");
        let payload_len = 2
            + meta.path.len()
            + 2
            + tenant.len()
            + 1
            + 4
            + 4 * h.len();
        let mut out = Vec::with_capacity(4 + payload_len);
        out.extend_from_slice(&(payload_len as u32).to_le_bytes());
        out.extend_from_slice(&(meta.path.len() as u16).to_le_bytes());
        out.extend_from_slice(meta.path.as_bytes());
        out.extend_from_slice(&(tenant.len() as u16).to_le_bytes());
        out.extend_from_slice(tenant.as_bytes());
        out.push(meta.priority);
        out.extend_from_slice(&(h.len() as u32).to_le_bytes());
        for &x in h {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    /// Read one response frame (the client side).
    pub fn read_response(
        r: &mut dyn Read,
    ) -> Result<NetResponse, FrameError> {
        let mut len = [0u8; 4];
        read_exact_frame(r, &mut len)?;
        let len = u32::from_le_bytes(len) as usize;
        if len != 21 {
            return Err(FrameError::Malformed(format!(
                "response payload of {len} bytes, expected 21"
            )));
        }
        let mut buf = [0u8; 21];
        read_exact_frame(r, &mut buf)?;
        let mut c = Cur { b: &buf, i: 0 };
        let status = Status::from_byte(c.u8()?).ok_or_else(|| {
            FrameError::Malformed("unknown status byte".to_string())
        })?;
        let id = {
            let s = c.take(8)?;
            u64::from_le_bytes(s.try_into().expect("8 bytes"))
        };
        let n_tokens = c.u32()?;
        let latency_us = {
            let s = c.take(8)?;
            u64::from_le_bytes(s.try_into().expect("8 bytes"))
        };
        Ok(NetResponse { status, id, n_tokens, latency_us })
    }
}

impl Wire for LengthPrefixed {
    fn read_request(
        &self,
        r: &mut dyn Read,
    ) -> Result<NetRequest, FrameError> {
        // the length prefix arrives byte-split like everything else:
        // first byte decides idle/EOF, the rest must follow
        let b0 = match read_first(r)? {
            None => return Err(FrameError::Eof),
            Some(b) => b,
        };
        let mut rest = [0u8; 3];
        read_exact_frame(r, &mut rest)?;
        let len = u32::from_le_bytes([b0, rest[0], rest[1], rest[2]])
            as usize;
        if len > self.max_frame {
            return Err(FrameError::Oversized {
                len,
                max: self.max_frame,
            });
        }
        let mut payload = vec![0u8; len];
        read_exact_frame(r, &mut payload)?;
        let mut c = Cur { b: &payload, i: 0 };
        let path_len = c.u16()? as usize;
        let path = c.str(path_len)?;
        let tenant_len = c.u16()? as usize;
        let tenant = c.str(tenant_len)?;
        let priority = c.u8()?;
        let n_len = c.u32()?;
        let rest = c.take(payload.len() - c.i)?;
        if rest.len() % 4 != 0 {
            return Err(FrameError::Malformed(
                "activation bytes not a multiple of 4".to_string(),
            ));
        }
        let h: Vec<f32> = rest
            .chunks_exact(4)
            .map(|s| f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
            .collect();
        Ok(NetRequest {
            meta: RequestMeta {
                path,
                tenant: if tenant.is_empty() { None } else { Some(tenant) },
                priority,
            },
            declared_len: Some(n_len),
            h,
        })
    }

    fn write_response(
        &self,
        w: &mut dyn Write,
        resp: &NetResponse,
    ) -> std::io::Result<()> {
        let mut out = [0u8; 25];
        out[..4].copy_from_slice(&21u32.to_le_bytes());
        out[4] = resp.status.byte();
        out[5..13].copy_from_slice(&resp.id.to_le_bytes());
        out[13..17].copy_from_slice(&resp.n_tokens.to_le_bytes());
        out[17..25].copy_from_slice(&resp.latency_us.to_le_bytes());
        w.write_all(&out)?;
        w.flush()
    }

    fn name(&self) -> &'static str {
        "length-prefixed"
    }
}

/// HTTP/1.1-shaped wire: `POST <path> HTTP/1.1` request lines,
/// `x-tenant` / `x-priority` / `content-length` headers, raw
/// little-endian `f32` body. See the module docs for the status
/// mapping.
#[derive(Debug, Clone)]
pub struct HttpWire {
    /// Largest accepted body, bytes (headers are capped at 8 KiB).
    pub max_body: usize,
}

impl Default for HttpWire {
    fn default() -> HttpWire {
        HttpWire { max_body: 1 << 20 }
    }
}

const MAX_HEADER_BYTES: usize = 8 << 10;

impl HttpWire {
    /// Read one response (the client side): status line + headers;
    /// the id/latency/token fields ride in `x-` headers.
    pub fn read_response(
        r: &mut dyn Read,
    ) -> Result<NetResponse, FrameError> {
        let head = read_until_blank_line(r, None)?;
        let head = String::from_utf8(head).map_err(|_| {
            FrameError::Malformed("response head not utf-8".to_string())
        })?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let code: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|c| c.parse().ok())
            .ok_or_else(|| {
                FrameError::Malformed(format!(
                    "bad status line `{status_line}`"
                ))
            })?;
        let mut id = 0u64;
        let mut n_tokens = 0u32;
        let mut latency_us = 0u64;
        let mut status_hdr: Option<Status> = None;
        for line in lines {
            let Some((k, v)) = line.split_once(':') else { continue };
            let v = v.trim();
            match k.to_ascii_lowercase().as_str() {
                "x-request-id" => id = v.parse().unwrap_or(0),
                "x-tokens" => n_tokens = v.parse().unwrap_or(0),
                "x-latency-us" => latency_us = v.parse().unwrap_or(0),
                "x-status" => {
                    status_hdr = v.parse().ok().and_then(Status::from_byte)
                }
                _ => {}
            }
        }
        // x-status disambiguates the two 503 causes; fall back to the
        // code for foreign responses
        let status = status_hdr.unwrap_or(match code {
            200 => Status::Ok,
            413 => Status::TooLarge,
            503 => Status::LaneFull,
            _ => Status::BadFrame,
        });
        Ok(NetResponse { status, id, n_tokens, latency_us })
    }
}

/// Accumulate bytes until the `\r\n\r\n` head terminator (capped at
/// [`MAX_HEADER_BYTES`]). `first` is a byte already consumed by the
/// idle/EOF probe, if any.
fn read_until_blank_line(
    r: &mut dyn Read,
    first: Option<u8>,
) -> Result<Vec<u8>, FrameError> {
    let mut head: Vec<u8> = Vec::new();
    if let Some(b) = first {
        head.push(b);
    }
    let mut one = [0u8; 1];
    loop {
        if head.ends_with(b"\r\n\r\n") {
            head.truncate(head.len() - 4);
            return Ok(head);
        }
        if head.len() > MAX_HEADER_BYTES {
            return Err(FrameError::Oversized {
                len: head.len(),
                max: MAX_HEADER_BYTES,
            });
        }
        read_exact_frame(r, &mut one)?;
        head.push(one[0]);
    }
}

impl Wire for HttpWire {
    fn read_request(
        &self,
        r: &mut dyn Read,
    ) -> Result<NetRequest, FrameError> {
        let b0 = match read_first(r)? {
            None => return Err(FrameError::Eof),
            Some(b) => b,
        };
        let head = read_until_blank_line(r, Some(b0))?;
        let head = String::from_utf8(head).map_err(|_| {
            FrameError::Malformed("request head not utf-8".to_string())
        })?;
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split_whitespace();
        let (method, path) = (
            parts.next().unwrap_or(""),
            parts.next().unwrap_or(""),
        );
        if method != "POST" || path.is_empty() {
            return Err(FrameError::Malformed(format!(
                "expected `POST <path> HTTP/1.1`, got `{request_line}`"
            )));
        }
        let mut tenant: Option<String> = None;
        let mut priority = 0u8;
        let mut content_length: Option<usize> = None;
        for line in lines {
            let Some((k, v)) = line.split_once(':') else { continue };
            let v = v.trim();
            match k.to_ascii_lowercase().as_str() {
                "x-tenant" => {
                    if !v.is_empty() {
                        tenant = Some(v.to_string());
                    }
                }
                "x-priority" => {
                    priority = v.parse().map_err(|_| {
                        FrameError::Malformed(format!(
                            "x-priority `{v}` is not a u8"
                        ))
                    })?;
                }
                "content-length" => {
                    content_length = Some(v.parse().map_err(|_| {
                        FrameError::Malformed(format!(
                            "content-length `{v}` is not a number"
                        ))
                    })?);
                }
                _ => {}
            }
        }
        let Some(len) = content_length else {
            return Err(FrameError::Malformed(
                "missing content-length".to_string(),
            ));
        };
        if len > self.max_body {
            return Err(FrameError::Oversized { len, max: self.max_body });
        }
        if len % 4 != 0 {
            return Err(FrameError::Malformed(
                "body bytes not a multiple of 4".to_string(),
            ));
        }
        let mut body = vec![0u8; len];
        read_exact_frame(r, &mut body)?;
        let h: Vec<f32> = body
            .chunks_exact(4)
            .map(|s| f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
            .collect();
        Ok(NetRequest {
            meta: RequestMeta {
                path: path.to_string(),
                tenant,
                priority,
            },
            declared_len: None,
            h,
        })
    }

    fn write_response(
        &self,
        w: &mut dyn Write,
        resp: &NetResponse,
    ) -> std::io::Result<()> {
        let (code, phrase) = resp.status.http_code();
        write!(
            w,
            "HTTP/1.1 {code} {phrase}\r\n\
             x-status: {}\r\n\
             x-request-id: {}\r\n\
             x-tokens: {}\r\n\
             x-latency-us: {}\r\n\
             content-length: 0\r\n\
             \r\n",
            resp.status.byte(),
            resp.id,
            resp.n_tokens,
            resp.latency_us
        )?;
        w.flush()
    }

    fn name(&self) -> &'static str {
        "http"
    }
}

/// The polling read timeout connection threads use so they can notice
/// the stop flag between requests.
const CONN_POLL: Duration = Duration::from_millis(50);

/// A running TCP listener feeding a [`Server`](super::Server). Bind
/// with [`NetServer::start`]; stop with [`NetServer::shutdown`] (or
/// drop). The `lpr listen` command is a thin wrapper over this.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `server` over `wire`, with unbounded keep-alive (no
    /// per-connection request cap).
    pub fn start<W: Wire>(
        server: Arc<Server>,
        addr: &str,
        wire: W,
    ) -> std::io::Result<NetServer> {
        NetServer::start_with_limit(server, addr, wire, None)
    }

    /// [`NetServer::start`] with a keep-alive request cap: each
    /// connection serves at most `max_requests` responses (successes
    /// and admission refusals both count), then closes gracefully —
    /// the capping response is fully written and flushed before the
    /// close, so a well-behaved client sees N answers and then a clean
    /// EOF, never a torn frame. Long-lived peers are expected to
    /// reconnect; the cap bounds how long any one connection can pin a
    /// server thread and gives load balancers a natural rebalance
    /// point. `None` disables the cap.
    pub fn start_with_limit<W: Wire>(
        server: Arc<Server>,
        addr: &str,
        wire: W,
        max_requests: Option<usize>,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let wire = Arc::new(wire);
        let stop_accept = stop.clone();
        let accept = std::thread::Builder::new()
            .name("lpr-net-accept".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> =
                    Vec::new();
                while !stop_accept.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let server = server.clone();
                            let wire = wire.clone();
                            let stop = stop_accept.clone();
                            conns.retain(|c| !c.is_finished());
                            let h = std::thread::Builder::new()
                                .name("lpr-net-conn".into())
                                .spawn(move || {
                                    handle_conn(
                                        server,
                                        wire,
                                        stream,
                                        stop,
                                        max_requests,
                                    )
                                })
                                .expect("spawn connection thread");
                            conns.push(h);
                        }
                        Err(e) if would_block(&e) => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .expect("spawn accept thread");
        Ok(NetServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wait for every connection thread to finish its
    /// in-flight request, and return.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Serve one connection: requests in, responses out, until EOF, a
/// framing error, server stop, or the keep-alive request cap.
/// Admission refusals answer and keep the connection; framing errors
/// answer best-effort and close; the cap closes gracefully right
/// after its final flushed response.
fn handle_conn<W: Wire>(
    server: Arc<Server>,
    wire: Arc<W>,
    mut stream: TcpStream,
    stop: Arc<AtomicBool>,
    max_requests: Option<usize>,
) {
    let _ = stream.set_read_timeout(Some(CONN_POLL));
    let _ = stream.set_nodelay(true);
    let d = server.d_model();
    let reject = |status: Status| NetResponse {
        status,
        id: 0,
        n_tokens: 0,
        latency_us: 0,
    };
    let mut served = 0usize;
    loop {
        match wire.read_request(&mut stream) {
            Ok(req) => {
                let declared_mismatch = match req.declared_len {
                    Some(t) => t as usize != req.h.len(),
                    None => false,
                };
                if req.h.is_empty()
                    || req.h.len() % d != 0
                    || declared_mismatch
                {
                    if wire
                        .write_response(
                            &mut stream,
                            &reject(Status::BadFrame),
                        )
                        .is_err()
                    {
                        return;
                    }
                    served += 1;
                    if Some(served) == max_requests {
                        return;
                    }
                    continue;
                }
                let resp = match server.enqueue_with(&req.meta, &req.h) {
                    Ok(id) => {
                        let c = server.await_completion(id);
                        NetResponse {
                            status: Status::Ok,
                            id,
                            n_tokens: c.n_tokens as u32,
                            latency_us: c.latency,
                        }
                    }
                    Err(e) => reject(Status::from_admit_error(&e)),
                };
                if wire.write_response(&mut stream, &resp).is_err() {
                    return;
                }
                served += 1;
                if Some(served) == max_requests {
                    return;
                }
            }
            Err(FrameError::Idle) => {
                if stop.load(Ordering::Acquire) {
                    return;
                }
            }
            Err(FrameError::Eof) => return,
            Err(FrameError::Oversized { .. }) => {
                // answer if the peer still listens, then close: the
                // stream cannot be resynced past an unread frame
                let _ = wire
                    .write_response(&mut stream, &reject(Status::TooLarge));
                return;
            }
            Err(FrameError::Malformed(_)) => {
                let _ = wire
                    .write_response(&mut stream, &reject(Status::BadFrame));
                return;
            }
            Err(FrameError::Io(_)) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn meta(path: &str, tenant: Option<&str>, priority: u8) -> RequestMeta {
        RequestMeta {
            path: path.to_string(),
            tenant: tenant.map(str::to_string),
            priority,
        }
    }

    #[test]
    fn length_prefixed_round_trips_requests() {
        let wire = LengthPrefixed::default();
        let h = vec![0.5f32, -1.25, 3.0, 0.0];
        let m = meta("/v1/generate", Some("acme"), 7);
        let bytes = LengthPrefixed::encode_request(&m, &h);
        let req =
            wire.read_request(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(req.meta, m);
        assert_eq!(req.declared_len, Some(4));
        assert_eq!(req.h, h);
        // no tenant encodes as the empty string and decodes to None
        let m2 = meta("/x", None, 0);
        let req2 = wire
            .read_request(&mut Cursor::new(
                LengthPrefixed::encode_request(&m2, &h),
            ))
            .unwrap();
        assert_eq!(req2.meta.tenant, None);
    }

    #[test]
    fn length_prefixed_round_trips_responses() {
        let wire = LengthPrefixed::default();
        let resp = NetResponse {
            status: Status::LaneFull,
            id: (3u64 << 48) | 42,
            n_tokens: 9,
            latency_us: 12_345,
        };
        let mut buf = Vec::new();
        wire.write_response(&mut buf, &resp).unwrap();
        let got =
            LengthPrefixed::read_response(&mut Cursor::new(buf)).unwrap();
        assert_eq!(got, resp);
    }

    #[test]
    fn oversized_and_truncated_frames_are_typed_errors() {
        let wire = LengthPrefixed { max_frame: 64 };
        // length prefix larger than the bound: refused before any
        // payload is buffered
        let mut oversized = Vec::new();
        oversized.extend_from_slice(&1_000u32.to_le_bytes());
        match wire.read_request(&mut Cursor::new(oversized)) {
            Err(FrameError::Oversized { len: 1_000, max: 64 }) => {}
            other => panic!("expected Oversized, got {other:?}"),
        }
        // a frame cut mid-payload is malformed (EOF mid-frame)
        let full = LengthPrefixed::encode_request(
            &meta("/x", None, 0),
            &[1.0f32; 4],
        );
        let cut = full[..full.len() - 3].to_vec();
        match wire.read_request(&mut Cursor::new(cut)) {
            Err(FrameError::Malformed(_)) => {}
            other => panic!("expected Malformed, got {other:?}"),
        }
        // empty stream at a frame boundary is a clean EOF
        match wire.read_request(&mut Cursor::new(Vec::new())) {
            Err(FrameError::Eof) => {}
            other => panic!("expected Eof, got {other:?}"),
        }
    }

    #[test]
    fn http_wire_parses_shaped_requests() {
        let wire = HttpWire::default();
        let body: Vec<u8> = [0.5f32, 1.5]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let mut req = Vec::new();
        req.extend_from_slice(
            b"POST /v1/generate HTTP/1.1\r\n\
              X-Tenant: acme\r\n\
              X-Priority: 9\r\n\
              Content-Length: 8\r\n\
              \r\n",
        );
        req.extend_from_slice(&body);
        let got = wire.read_request(&mut Cursor::new(req)).unwrap();
        assert_eq!(got.meta, meta("/v1/generate", Some("acme"), 9));
        assert_eq!(got.declared_len, None);
        assert_eq!(got.h, vec![0.5f32, 1.5]);
        // a GET is not a submission
        let bad = b"GET / HTTP/1.1\r\n\r\n".to_vec();
        assert!(matches!(
            wire.read_request(&mut Cursor::new(bad)),
            Err(FrameError::Malformed(_))
        ));
    }

    #[test]
    fn http_wire_renders_status_mapping() {
        let wire = HttpWire::default();
        for (status, code) in [
            (Status::Ok, 200),
            (Status::LaneFull, 503),
            (Status::NoRoute, 503),
            (Status::TooLarge, 413),
            (Status::BadFrame, 400),
        ] {
            let mut buf = Vec::new();
            wire.write_response(
                &mut buf,
                &NetResponse {
                    status,
                    id: 7,
                    n_tokens: 2,
                    latency_us: 11,
                },
            )
            .unwrap();
            let text = String::from_utf8(buf.clone()).unwrap();
            assert!(
                text.starts_with(&format!("HTTP/1.1 {code} ")),
                "{text}"
            );
            // and the client parser round-trips the exact status
            let got =
                HttpWire::read_response(&mut Cursor::new(buf)).unwrap();
            assert_eq!(got.status, status);
            assert_eq!(got.id, 7);
            assert_eq!(got.latency_us, 11);
        }
    }

    #[test]
    fn status_bytes_round_trip() {
        for s in [
            Status::Ok,
            Status::LaneFull,
            Status::NoRoute,
            Status::TooLarge,
            Status::BadFrame,
        ] {
            assert_eq!(Status::from_byte(s.byte()), Some(s));
        }
        assert_eq!(Status::from_byte(99), None);
    }
}
