//! Persistent channel-fed worker pool: the long-lived twin of the
//! scoped-thread [`ServingEngine`](crate::router::ServingEngine) —
//! since PR 4 over a whole **model stack**, not a single router layer.
//!
//! [`ServingEngine`](crate::router::ServingEngine) spawns workers via `std::thread::scope` on every
//! batch — tens of microseconds of spawn+join per call, a fixed cost
//! PR 2 left on the table. [`PoolEngine`] spawns its workers **once**:
//! each worker owns its [`RouteBuffers`] / [`RouterBatch`] / FFN
//! scratch for the process lifetime, receives jobs over an `mpsc`
//! channel, and answers on a shared completion channel. Scratch buffers
//! *travel inside the job messages* (ownership ping-pong), so the pool
//! needs no `unsafe` and no locks: per-batch state the workers read
//! (input rows, the compiled [`DispatchPlan`], the gathered rows) is
//! shared read-only behind an [`Arc`] that the engine reclaims with
//! [`Arc::make_mut`] between batches — workers drop their clones when a
//! job completes, so steady-state batches never deep-copy it.
//!
//! # Multi-layer model serving
//!
//! The pool holds an `Arc<Vec<MoeLayer>>` — every layer's compiled
//! [`RouterPlan`](crate::router::RouterPlan) + `ExpertBank` — and every
//! job names its layer, so **one** set of persistent workers serves the
//! whole stack (no per-layer thread pools).
//! [`PoolEngine::forward_model`] runs the layers in order, each through
//! the same route → plan → FFN → combine stages, composing them with
//! the shared residual add ([`crate::model::residual_add`]): layer ℓ's
//! residual output is layer ℓ+1's input. The single-layer entry points
//! ([`PoolEngine::new`], [`PoolEngine::forward_full`],
//! [`PoolEngine::route_into`]) are the `L = 1` special case and keep
//! their PR 3 semantics bit-for-bit.
//!
//! # Determinism: bit-identical to the scoped path
//!
//! The pool runs the exact pipeline of
//! [`ServingEngine::forward_full`](crate::router::ServingEngine::forward_full) per layer and reuses the engine's
//! partition and merge primitives (`shard_span`, `merge_route_shard`,
//! `expert_group_bounds`, `run_expert_range`):
//!
//! 1. **route** — token shards by [`shard_span`]; shard `i` always runs
//!    on worker `i`; results merge in shard order after all workers
//!    answer.
//! 2. **plan + gather** — on the caller's thread, single-threaded.
//! 3. **experts** — the grouped rows are partitioned into per-worker
//!    segment lists by the active
//!    [`PlacementConfig`](crate::dispatch::PlacementConfig): the
//!    round-robin default reproduces the historical contiguous
//!    `expert_group_bounds` split exactly; load-aware placement
//!    LPT-packs whole expert buckets onto workers by this batch's
//!    executed counts; replication additionally splits the hottest
//!    buckets' rows across workers through the deterministic replica
//!    hash. Each worker computes its segments into its own buffer,
//!    which the caller copies segment-by-segment into the fixed
//!    destination ranges (completion *order* does not matter —
//!    destinations are disjoint and per-row compute is pure, so every
//!    partition yields identical bytes; only wall time moves).
//! 4. **combine** — on the caller's thread, fixed (token, slot) order.
//! 5. **residual** (model path) — fixed elementwise add on the caller's
//!    thread, feeding the next layer.
//!
//! Per-token routing and per-expert compute are pure and the partitions
//! depend only on `(n, workers)` / the plan's offsets, so pool outputs
//! are **bit-identical to the scoped engine for every worker count** —
//! per layer (pinned by `pool_forward_full_matches_scoped_engine`) and
//! for the whole stack (pinned by `pool_forward_model_matches_scoped`
//! here and the L=4 checkpoint acceptance test in `model::bridge`).
//!
//! Cost model vs the scoped path: one channel round-trip per worker per
//! stage (~a microsecond total) replaces per-batch spawn+join; the
//! expert stage pays one extra memcpy of its grouped output rows
//! (workers cannot safely write the caller's buffer without scoped
//! lifetimes). Both are far below the FFN compute they orchestrate.

use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::dispatch::placement::{
    ExpertPlacement, PlacementConfig, PlacementPolicy,
};
use crate::dispatch::plan::{capacity_for, DispatchPlan, OverflowPolicy};
use crate::experts::{combine_rows_opts, gather_rows, ExpertBank};
use crate::kernels::{GemmTiles, Kernel};
use crate::metrics::{LayerLoadTracker, LoadTracker, DEFAULT_LOAD_WINDOW};
use crate::model::cache::{KvCache, SeqSpan};
use crate::model::{residual_add, MoeLayer, ModelForward, StackedModel};
use crate::router::engine::{
    expert_group_bounds, merge_route_shard, run_expert_rows, shard_span,
};
use crate::router::{FullForward, RouteBuffers, RouterBatch, RouterPlan};

/// Per-batch state the workers read during one stage. Reclaimed with
/// `Arc::make_mut` between stages; see the module docs.
#[derive(Debug, Clone, Default)]
struct BatchShared {
    /// `[N, d]` input rows of the current layer (route stage only).
    h: Vec<f32>,
    /// Compiled dispatch plan (expert stage).
    plan: DispatchPlan,
    /// `[kept, d]` gathered rows (expert stage).
    xg: Vec<f32>,
}

/// A worker's process-lifetime scratch; travels inside job messages.
#[derive(Debug, Default)]
struct Scratch {
    buf: RouteBuffers,
    out: RouterBatch,
    hid: Vec<f32>,
    y: Vec<f32>,
    /// Grouped-row segments `[r0, r1)` this worker's expert job covers
    /// (placement-assigned); `y` holds their outputs concatenated in
    /// list order. The caller reads the list back to scatter `y` into
    /// the grouped output.
    segs: Vec<(u32, u32)>,
}

enum Job {
    /// Route token rows `span` of `shared.h` with layer `layer`'s plan
    /// into `scratch.out`.
    Route {
        layer: usize,
        shared: Arc<BatchShared>,
        span: Range<usize>,
        scratch: Box<Scratch>,
    },
    /// Run the grouped-row segments listed in `scratch.segs` over
    /// `shared.plan` / `shared.xg` with layer `layer`'s bank into
    /// `scratch.y` (pre-sized by the caller). Carries the engine's
    /// GEMM kernel and tile choices — workers only see the shared
    /// layer stack, so the knobs travel with the job.
    Experts {
        layer: usize,
        shared: Arc<BatchShared>,
        kernel: Kernel,
        tiles: GemmTiles,
        scratch: Box<Scratch>,
    },
}

enum Done {
    Ok {
        slot: usize,
        scratch: Box<Scratch>,
    },
    /// The job panicked on the worker; the engine re-raises on the
    /// caller's thread (its scratch unwound with the job). Without
    /// this, a worker panic would leave the engine blocked on `recv`
    /// forever — the scoped path propagates worker panics through
    /// `thread::scope`, and the pool must not regress that.
    Panicked { slot: usize },
}

struct Worker {
    /// Dropping the sender closes the channel; the worker thread exits.
    tx: Option<Sender<Job>>,
    handle: Option<JoinHandle<()>>,
}

/// Append segment `[r0, r1)` to a worker's list, merging with the
/// previous segment when adjacent (keeps replica runs and consecutive
/// whole buckets as one copy/compute span).
fn push_seg(segs: &mut Vec<(u32, u32)>, r0: u32, r1: u32) {
    if let Some(last) = segs.last_mut() {
        if last.1 == r0 {
            last.1 = r1;
            return;
        }
    }
    segs.push((r0, r1));
}

/// Execute one job to completion; the shared handle is dropped
/// *before* constructing the answer so the engine's `make_mut` never
/// observes a stale clone once the `Done` arrives.
fn run_job(layers: &[MoeLayer], slot: usize, job: Job) -> Done {
    match job {
        Job::Route { layer, shared, span, mut scratch } => {
            let plan = &layers[layer].plan;
            let d = plan.cfg.d_model;
            let hs = &shared.h[span.start * d..span.end * d];
            plan.forward_into(hs, &mut scratch.buf, &mut scratch.out);
            drop(shared);
            Done::Ok { slot, scratch }
        }
        Job::Experts { layer, shared, kernel, tiles, mut scratch } => {
            let d = layers[layer].plan.cfg.d_model;
            let Scratch { hid, y, segs, .. } = &mut *scratch;
            let mut off = 0usize;
            for &(r0, r1) in segs.iter() {
                let m = (r1 - r0) as usize;
                run_expert_rows(
                    &layers[layer].bank,
                    &shared.plan,
                    &shared.xg,
                    r0 as usize,
                    r1 as usize,
                    d,
                    kernel,
                    tiles,
                    hid,
                    &mut y[off..off + m * d],
                );
                off += m * d;
            }
            drop(shared);
            Done::Ok { slot, scratch }
        }
    }
}

fn worker_loop(
    slot: usize,
    layers: &[MoeLayer],
    rx: Receiver<Job>,
    done: Sender<Done>,
) {
    while let Ok(job) = rx.recv() {
        // a panicking job must still answer, or the engine deadlocks
        // waiting for this worker's Done (the panic message itself goes
        // to stderr via the default hook)
        let msg = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || run_job(layers, slot, job),
        ))
        .unwrap_or(Done::Panicked { slot });
        if done.send(msg).is_err() {
            return;
        }
    }
}

/// A persistent serving engine: long-lived workers over one shared
/// layer stack (`Arc<Vec<MoeLayer>>`), running the full route → plan →
/// expert FFN → combine path — per layer and, via
/// [`Self::forward_model`], across the whole residual stack — with zero
/// per-batch thread spawns. Outputs are bit-identical to
/// [`ServingEngine`](crate::router::ServingEngine) /
/// [`crate::model::ModelEngine`] for every worker count (see the module
/// docs).
#[derive(Debug)]
pub struct PoolEngine {
    layers: Arc<Vec<MoeLayer>>,
    d_model: usize,
    n_workers: usize,
    workers: Vec<Worker>,
    done_rx: Receiver<Done>,
    shared: Arc<BatchShared>,
    /// Worker scratch parked between jobs (slot `i` ↔ worker `i`, so
    /// each worker's buffers stay warm for *its* shard sizes).
    parked: Vec<Option<Box<Scratch>>>,
    /// Caller-thread scratch for inline (small-batch) stages.
    inline: Box<Scratch>,
    bounds: Vec<usize>,
    /// Per-worker segment lists built by `plan_groups` each batch.
    group_segs: Vec<Vec<(u32, u32)>>,
    /// Rolling `[L, E]` routed-load balance over this pool's batches.
    trackers: LayerLoadTracker,
    renormalize: bool,
    /// GEMM micro-kernel for the expert FFN stage; travels inside
    /// `Job::Experts` messages so the workers see it.
    kernel: Kernel,
    /// MC×KC×NC cache tiles for the FFN GEMMs; travels inside
    /// `Job::Experts` alongside the kernel. A pure cache knob — every
    /// kernel is bitwise tile-invariant.
    tiles: GemmTiles,
    /// Worker↔expert-group placement for the expert stage (the
    /// `Engine::builder().placement(..)` knob); round-robin default =
    /// the historical contiguous split.
    placement_cfg: PlacementConfig,
    /// Forward-layer counter feeding the deterministic replica hash.
    step: u64,
    /// One-slot scratch cache backing plain [`Self::forward_model`] on
    /// attention stacks (batch = one full-sequence prefill, reset every
    /// call); `None` on MoE-only stacks, whose path is unchanged.
    /// Attention always runs on the caller's thread — never on the
    /// workers — so worker count cannot move its bits.
    prefill: Option<KvCache>,
}

impl std::fmt::Debug for Worker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Worker")
            .field("alive", &self.handle.is_some())
            .finish()
    }
}

impl PoolEngine {
    /// Single-layer pool (the PR 3 entry point): equivalent to
    /// [`Self::from_model`] over `StackedModel::single(plan, bank)`.
    #[deprecated(
        note = "construct through lpr::engine::Engine::builder() with \
                Backend::Pool — the pool is a backend internal now"
    )]
    pub fn new(
        plan: RouterPlan,
        bank: ExpertBank,
        n_workers: usize,
    ) -> PoolEngine {
        PoolEngine::from_model(StackedModel::single(plan, bank), n_workers)
    }

    /// Spawn `n_workers` (clamped to at least 1) persistent workers
    /// over the model's layer stack. One worker still runs every stage
    /// inline on the caller's thread, like the scoped engine.
    pub fn from_model(model: StackedModel, n_workers: usize) -> PoolEngine {
        let n_workers = n_workers.max(1);
        let d_model = model.d_model();
        let experts: Vec<usize> = model
            .layers()
            .iter()
            .map(|l| l.plan.cfg.n_experts)
            .collect();
        let layers = Arc::new(model.into_layers());
        let prefill = if layers.iter().any(|l| l.attn.is_some()) {
            let mut c = KvCache::new(
                1,
                layers.len(),
                d_model,
                usize::MAX / 2,
            );
            let _ = c.alloc();
            Some(c)
        } else {
            None
        };
        let (done_tx, done_rx) = channel();
        let mut workers = Vec::with_capacity(n_workers);
        for slot in 0..n_workers {
            let (tx, rx) = channel::<Job>();
            let layers = layers.clone();
            let done = done_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("lpr-pool-{slot}"))
                .spawn(move || worker_loop(slot, &layers, rx, done))
                .expect("spawn pool worker");
            workers.push(Worker { tx: Some(tx), handle: Some(handle) });
        }
        PoolEngine {
            parked: (0..n_workers).map(|_| Some(Box::default())).collect(),
            inline: Box::default(),
            bounds: Vec::new(),
            group_segs: Vec::new(),
            shared: Arc::new(BatchShared::default()),
            trackers: LayerLoadTracker::with_experts(
                DEFAULT_LOAD_WINDOW,
                &experts,
            ),
            layers,
            d_model,
            n_workers,
            workers,
            done_rx,
            renormalize: false,
            kernel: Kernel::default(),
            tiles: GemmTiles::default(),
            placement_cfg: PlacementConfig::default(),
            step: 0,
            prefill,
        }
    }

    /// True when any layer carries an attention sublayer.
    pub fn has_attn(&self) -> bool {
        self.layers.iter().any(|l| l.attn.is_some())
    }

    /// Layer 0's compiled plan (the whole plan stack is reachable via
    /// [`Self::layer_plan`]).
    pub fn plan(&self) -> &RouterPlan {
        &self.layers[0].plan
    }

    pub fn layer_plan(&self, l: usize) -> &RouterPlan {
        &self.layers[l].plan
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Residual-stream width shared by every layer of the stack.
    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Rolling routed-load balance of **layer 0** (the PR 3 accessor;
    /// single-layer pools keep their old telemetry shape).
    pub fn tracker(&self) -> &LoadTracker {
        self.trackers.layer(0)
    }

    /// Rolling per-layer `[L, E]` balance over this pool's batches.
    pub fn layer_tracker(&self) -> &LayerLoadTracker {
        &self.trackers
    }

    /// Enable/disable gate-weight renormalization for partially-dropped
    /// tokens in every layer's combine (`--renormalize`); off by
    /// default.
    pub fn set_renormalize(&mut self, on: bool) {
        self.renormalize = on;
    }

    /// Select the GEMM micro-kernel for every layer's expert FFN stage
    /// (the `Engine::builder().kernel(..)` knob). Every kernel keeps
    /// the bit-identical-across-workers contract; [`Kernel::Naive`]
    /// (the default) additionally matches the historic goldens.
    pub fn set_kernel(&mut self, kernel: Kernel) {
        self.kernel = kernel;
    }

    /// Select the MC×KC×NC cache tiles for every layer's FFN GEMMs
    /// (the `Engine::builder().gemm_tiles(..)` knob). Tiles move cache
    /// behaviour, never bits; the caller (the builder) validates them.
    pub fn set_gemm_tiles(&mut self, tiles: GemmTiles) {
        self.tiles = tiles;
    }

    /// Adopt a placement policy for the expert stage's worker↔expert
    /// assignment (the `Engine::builder().placement(..)` knob). The
    /// round-robin default reproduces the historical contiguous
    /// `expert_group_bounds` split exactly; `LoadAware` LPT-packs
    /// whole expert buckets onto workers by each batch's executed
    /// counts; `Replicated` additionally splits the hottest buckets'
    /// rows across workers through the deterministic replica hash
    /// ([`ExpertPlacement::replica_for`] on the row's flat token
    /// slot). Per-row expert compute is pure, so every policy yields
    /// bit-identical outputs — the knob only moves where the FFN time
    /// is spent, shrinking the straggler worker on skewed batches.
    pub fn set_placement(&mut self, cfg: PlacementConfig) {
        self.placement_cfg = cfg;
    }

    /// The active placement knob.
    pub fn placement_cfg(&self) -> &PlacementConfig {
        &self.placement_cfg
    }

    /// Route `h` (`[N, d]` row-major) through **layer 0** into `out` on
    /// the persistent workers. Identical output to
    /// `ServingEngine::route_into` for every worker count.
    pub fn route_into(&mut self, h: &[f32], out: &mut RouterBatch) {
        let d = self.d_model;
        assert_eq!(h.len() % d, 0, "h must be [N, {d}]");
        let n = h.len() / d;
        self.route_stage(0, h, n, out);
        self.trackers.push(0, &out.load);
    }

    fn route_stage(
        &mut self,
        layer: usize,
        h: &[f32],
        n: usize,
        out: &mut RouterBatch,
    ) {
        let plan_cfg = &self.layers[layer].plan.cfg;
        let (e, k) = (plan_cfg.n_experts, plan_cfg.top_k);
        // tiny batches: channel round-trips dominate, route inline
        // (same threshold as the scoped engine)
        if self.n_workers == 1 || n < 2 * self.n_workers {
            self.layers[layer]
                .plan
                .forward_into(h, &mut self.inline.buf, out);
            return;
        }
        {
            let shared = Arc::make_mut(&mut self.shared);
            shared.h.clear();
            shared.h.extend_from_slice(h);
        }
        for slot in 0..self.n_workers {
            let scratch =
                self.parked[slot].take().expect("worker scratch parked");
            let job = Job::Route {
                layer,
                shared: self.shared.clone(),
                span: shard_span(n, self.n_workers, slot),
                scratch,
            };
            self.workers[slot]
                .tx
                .as_ref()
                .expect("pool alive")
                .send(job)
                .expect("pool worker died");
        }
        for _ in 0..self.n_workers {
            match self.done_rx.recv().expect("pool worker died") {
                Done::Ok { slot, scratch, .. } => {
                    self.parked[slot] = Some(scratch);
                }
                Done::Panicked { slot } => {
                    // the job's scratch unwound with it; repark a fresh
                    // one so a caller that catches this panic can keep
                    // using the pool (the worker itself survived)
                    self.parked[slot] = Some(Box::default());
                    panic!("pool worker {slot} panicked while routing")
                }
            }
        }
        // deterministic merge in shard order, same step as the scoped
        // engine
        out.reset(n, k, e);
        for slot in 0..self.n_workers {
            let scratch =
                self.parked[slot].as_ref().expect("scratch returned");
            merge_route_shard(
                out,
                &scratch.out,
                shard_span(n, self.n_workers, slot).start,
            );
        }
    }

    /// One layer's full expert-parallel path on the persistent pool:
    /// route → compile + gather → expert FFNs → combine. The shared
    /// stage core of [`Self::forward_full`] (layer 0) and
    /// [`Self::forward_model`] (every layer in turn).
    fn forward_layer(
        &mut self,
        layer: usize,
        h: &[f32],
        capacity_factor: f64,
        policy: OverflowPolicy,
        out: &mut FullForward,
    ) {
        let d = self.d_model;
        let e = self.layers[layer].plan.cfg.n_experts;
        assert_eq!(h.len() % d, 0, "h must be [N, {d}]");
        let n = h.len() / d;
        // 1. route (persistent workers, same shard/merge rule)
        self.route_stage(layer, h, n, &mut out.batch);
        self.trackers.push(layer, &out.batch.load);
        // 2. compile + gather on the caller thread into the shared
        // batch state, handing the caller a copy of the plan
        {
            let shared = Arc::make_mut(&mut self.shared);
            let cap =
                capacity_for(out.batch.topk_idx.len(), e, capacity_factor);
            shared.plan.compile_batch(&out.batch, cap, policy);
            gather_rows(&shared.plan, h, d, &mut shared.xg);
            out.plan.copy_from(&shared.plan);
        }
        let kept = self.shared.plan.kept();
        // 3. expert FFNs over contiguous per-expert ranges
        out.y.clear();
        out.y.resize(kept * d, 0.0);
        let groups = self.n_workers.min(e).max(1);
        if groups == 1 || kept < 2 * self.n_workers {
            self.layers[layer].bank.forward_all_tiled(
                self.kernel,
                self.tiles,
                &self.shared.plan,
                &self.shared.xg,
                &mut self.inline.hid,
                &mut out.y,
            );
        } else {
            self.plan_groups(groups);
            let mut outstanding = 0usize;
            for g in 0..groups {
                let rows: usize = self.group_segs[g]
                    .iter()
                    .map(|&(r0, r1)| (r1 - r0) as usize)
                    .sum();
                if rows == 0 {
                    continue; // no rows assigned to this worker
                }
                let mut scratch =
                    self.parked[g].take().expect("worker scratch parked");
                scratch.segs.clear();
                scratch.segs.extend_from_slice(&self.group_segs[g]);
                scratch.y.clear();
                scratch.y.resize(rows * d, 0.0);
                let job = Job::Experts {
                    layer,
                    shared: self.shared.clone(),
                    kernel: self.kernel,
                    tiles: self.tiles,
                    scratch,
                };
                self.workers[g]
                    .tx
                    .as_ref()
                    .expect("pool alive")
                    .send(job)
                    .expect("pool worker died");
                outstanding += 1;
            }
            // scatter each worker's segments into their fixed disjoint
            // ranges; completion order is irrelevant to the result
            for _ in 0..outstanding {
                match self.done_rx.recv().expect("pool worker died") {
                    Done::Ok { slot, scratch } => {
                        let mut off = 0usize;
                        for &(r0, r1) in &scratch.segs {
                            let len = (r1 - r0) as usize * d;
                            let dst = r0 as usize * d;
                            out.y[dst..dst + len]
                                .copy_from_slice(&scratch.y[off..off + len]);
                            off += len;
                        }
                        self.parked[slot] = Some(scratch);
                    }
                    Done::Panicked { slot } => {
                        self.parked[slot] = Some(Box::default());
                        panic!(
                            "pool worker {slot} panicked in expert \
                             compute"
                        )
                    }
                }
            }
        }
        // 4. gate-weighted combine, fixed (token, slot) order
        combine_rows_opts(
            &self.shared.plan,
            &out.batch.weights,
            &out.y,
            d,
            self.renormalize,
            &mut out.combined,
        );
        self.step += 1;
    }

    /// Partition the compiled plan's grouped rows into per-worker
    /// segment lists (`self.group_segs`) under the active placement
    /// policy. Every partition covers each grouped row exactly once,
    /// so the expert-stage output is identical bytes for all of them;
    /// the policies differ only in which worker computes what:
    ///
    /// - round-robin: the historical contiguous balanced split from
    ///   [`expert_group_bounds`] — the bit-identity oracle, and still
    ///   the default.
    /// - load-aware: LPT bin-packing of whole expert buckets onto
    ///   workers by this batch's executed counts (`plan.counts`). The
    ///   pool schedules the batch it is holding, so it plans from that
    ///   batch directly; windowed planning plus the migration-cost
    ///   model belong to [`crate::dispatch::DispatchSim`], where
    ///   moving an expert between devices actually moves bytes.
    /// - replicated: load-aware, plus the hottest buckets' rows split
    ///   across their replica workers row-by-row via the pure hash
    ///   [`ExpertPlacement::replica_for`]`(src[row], e, step)`,
    ///   emitted as maximal contiguous runs.
    fn plan_groups(&mut self, groups: usize) {
        if self.group_segs.len() < groups {
            self.group_segs.resize_with(groups, Vec::new);
        }
        for segs in self.group_segs.iter_mut() {
            segs.clear();
        }
        let plan = &self.shared.plan;
        match self.placement_cfg.policy {
            PlacementPolicy::RoundRobin => {
                expert_group_bounds(plan, groups, &mut self.bounds);
                for g in 0..groups {
                    let r0 = plan.offsets[self.bounds[g]];
                    let r1 = plan.offsets[self.bounds[g + 1]];
                    if r1 > r0 {
                        self.group_segs[g].push((r0, r1));
                    }
                }
            }
            PlacementPolicy::LoadAware | PlacementPolicy::Replicated => {
                let load: Vec<f64> =
                    plan.counts.iter().map(|&c| c as f64).collect();
                let placement = ExpertPlacement::plan(
                    &self.placement_cfg,
                    &load,
                    groups,
                );
                let step = self.step;
                for e in 0..plan.counts.len() {
                    let (r0, r1) = (plan.offsets[e], plan.offsets[e + 1]);
                    if r1 == r0 {
                        continue;
                    }
                    let reps = placement.replicas_of(e);
                    if reps.len() == 1 {
                        push_seg(&mut self.group_segs[reps[0]], r0, r1);
                        continue;
                    }
                    // deterministic per-row replica choice, emitted as
                    // maximal runs
                    let mut start = r0;
                    let mut dev = placement.replica_for(
                        plan.src[r0 as usize] as usize,
                        e,
                        step,
                    );
                    for r in r0 + 1..r1 {
                        let next = placement.replica_for(
                            plan.src[r as usize] as usize,
                            e,
                            step,
                        );
                        if next != dev {
                            push_seg(&mut self.group_segs[dev], start, r);
                            start = r;
                            dev = next;
                        }
                    }
                    push_seg(&mut self.group_segs[dev], start, r1);
                }
            }
        }
    }

    /// The full expert-parallel data path for one batch through
    /// **layer 0** — the drop-in twin of
    /// [`ServingEngine::forward_full`](crate::router::ServingEngine::forward_full) (the expert bank lives in the
    /// pool, so it is not a parameter). Bit-identical to the scoped
    /// path for every worker count.
    #[deprecated(
        note = "use the engine facade: Engine::builder()…backend(\
                Backend::Pool { .. }).build() and MoeEngine::forward"
    )]
    pub fn forward_full(
        &mut self,
        h: &[f32],
        capacity_factor: f64,
        policy: OverflowPolicy,
        out: &mut FullForward,
    ) {
        self.forward_layer(0, h, capacity_factor, policy, out);
    }

    /// Run the whole `L`-layer stack on the persistent pool: per layer
    /// the same four stages as [`Self::forward_full`], composed with
    /// the shared residual add — the drop-in twin of
    /// [`crate::model::ModelEngine::forward`], bit-identical to it for
    /// every worker count. The final residual stream lands in
    /// `out.hidden`; each layer's pipeline state stays inspectable in
    /// `out.layers`.
    pub fn forward_model(
        &mut self,
        h: &[f32],
        capacity_factor: f64,
        policy: OverflowPolicy,
        out: &mut ModelForward,
    ) {
        let d = self.d_model;
        assert_eq!(h.len() % d, 0, "h must be [N, {d}]");
        if let Some(mut cache) = self.prefill.take() {
            cache.reset(0);
            let n = h.len() / d;
            let spans = [SeqSpan { slot: 0, n_tokens: n }];
            let spans = if n == 0 { &[][..] } else { &spans[..] };
            self.forward_model_seqs(
                h,
                spans,
                capacity_factor,
                policy,
                &mut cache,
                out,
            );
            self.prefill = Some(cache);
            return;
        }
        let n_layers = self.layers.len();
        out.ensure_layers(n_layers);
        let ModelForward { layers: louts, hidden, h_cur, .. } = out;
        h_cur.clear();
        h_cur.extend_from_slice(h);
        for l in 0..n_layers {
            self.forward_layer(
                l,
                &h_cur[..],
                capacity_factor,
                policy,
                &mut louts[l],
            );
            residual_add(&h_cur[..], &louts[l].combined, hidden);
            if l + 1 < n_layers {
                std::mem::swap(&mut *h_cur, &mut *hidden);
            }
        }
    }

    /// The pool twin of
    /// [`ModelEngine::forward_seqs`](crate::model::ModelEngine::forward_seqs):
    /// run the stack over a ragged step batch whose rows concatenate
    /// `spans` in span order, each span extending one cached sequence.
    /// Attention runs span-by-span on the caller's thread against the
    /// span's cache slot; the MoE stages run the coalesced batch on the
    /// persistent workers — so the output is bit-identical to the
    /// scoped path for every worker count, and decode ≡ prefill
    /// whenever the capacity factor admits every token.
    pub fn forward_model_seqs(
        &mut self,
        h: &[f32],
        spans: &[SeqSpan],
        capacity_factor: f64,
        policy: OverflowPolicy,
        cache: &mut KvCache,
        out: &mut ModelForward,
    ) {
        let d = self.d_model;
        assert_eq!(h.len() % d, 0, "h must be [N, {d}]");
        let n = h.len() / d;
        let spanned: usize = spans.iter().map(|s| s.n_tokens).sum();
        assert_eq!(spanned, n, "spans must cover the batch exactly");
        let n_layers = self.layers.len();
        assert_eq!(cache.n_layers(), n_layers, "cache depth mismatch");
        assert_eq!(cache.d_model(), d, "cache width mismatch");
        for s in spans {
            assert!(s.n_tokens >= 1, "spans must carry tokens");
            cache
                .check_capacity(s.slot, s.n_tokens)
                .expect("kv capacity must be pre-checked by the caller");
        }
        out.ensure_layers(n_layers);
        let ModelForward { layers: louts, hidden, h_cur, attn_scratch } =
            out;
        h_cur.clear();
        h_cur.extend_from_slice(h);
        for l in 0..n_layers {
            if let Some(attn) = &self.layers[l].attn {
                let mut off = 0usize;
                for s in spans {
                    let rows =
                        &mut h_cur[off * d..(off + s.n_tokens) * d];
                    let (k, v) = cache.layer_mut(s.slot, l);
                    attn.forward(rows, s.n_tokens, k, v, attn_scratch);
                    off += s.n_tokens;
                }
            }
            self.forward_layer(
                l,
                &h_cur[..],
                capacity_factor,
                policy,
                &mut louts[l],
            );
            residual_add(&h_cur[..], &louts[l].combined, hidden);
            if l + 1 < n_layers {
                std::mem::swap(&mut *h_cur, &mut *hidden);
            }
        }
        for s in spans {
            cache.advance(s.slot, s.n_tokens);
        }
    }
}

impl Drop for PoolEngine {
    fn drop(&mut self) {
        // close every job channel, then join — workers exit when their
        // receiver disconnects
        for w in &mut self.workers {
            w.tx = None;
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points ARE the parity oracles
mod tests {
    use super::*;
    use crate::model::{synthetic_stacked_model, ModelEngine};
    use crate::router::{synthetic_lpr_router, ServingEngine};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Acceptance: the pool's full forward is bit-identical to the
    /// scoped-thread path for worker counts {1, 2, 3, 8}, across
    /// metrics, batch sizes, and overflow policies.
    #[test]
    fn pool_forward_full_matches_scoped_engine() {
        let mut rng = Rng::new(91);
        let (d, dz, e, k, ff) = (16usize, 8, 8, 3, 12);
        let bank = ExpertBank::new(&Rng::new(3), e, d, ff);
        for metric in ["cosine", "kl"] {
            let r = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
            let plan = r.plan().clone();
            for n in [5usize, 97] {
                let h = rand_vec(&mut rng, n * d);
                for policy in OverflowPolicy::ALL {
                    let mut scoped = ServingEngine::new(plan.clone(), 1);
                    let mut want = FullForward::new();
                    scoped.forward_full(&h, &bank, 1.0, policy, &mut want);
                    for workers in [1usize, 2, 3, 8] {
                        let mut pool = PoolEngine::new(
                            plan.clone(),
                            bank.clone(),
                            workers,
                        );
                        let mut got = FullForward::new();
                        pool.forward_full(&h, 1.0, policy, &mut got);
                        assert_eq!(
                            got.combined, want.combined,
                            "{metric}: n={n} w={workers} {} combined \
                             diverged",
                            policy.name()
                        );
                        assert_eq!(got.plan, want.plan);
                        assert_eq!(got.batch, want.batch);
                    }
                }
            }
        }
    }

    /// Acceptance (stack contract): an L=3 `forward_model` on the pool
    /// is bit-identical to the scoped `ModelEngine` for worker counts
    /// {1, 2, 3, 8} — final residual stream, every layer's combined
    /// output, batches, and plans.
    #[test]
    fn pool_forward_model_matches_scoped() {
        let model = synthetic_stacked_model(
            "cosine",
            &Rng::new(7),
            3,
            16,
            8,
            6,
            2,
            10,
        );
        let mut rng = Rng::new(13);
        for n in [5usize, 61] {
            let h = rand_vec(&mut rng, n * 16);
            for policy in OverflowPolicy::ALL {
                let mut scoped = ModelEngine::new(model.clone(), 1);
                let mut want = ModelForward::new();
                scoped.forward(&h, 1.0, policy, &mut want);
                for workers in [1usize, 2, 3, 8] {
                    let mut pool =
                        PoolEngine::from_model(model.clone(), workers);
                    let mut got = ModelForward::new();
                    pool.forward_model(&h, 1.0, policy, &mut got);
                    assert_eq!(
                        got.hidden, want.hidden,
                        "n={n} w={workers} {} hidden diverged",
                        policy.name()
                    );
                    for l in 0..3 {
                        assert_eq!(
                            got.layers[l].combined,
                            want.layers[l].combined,
                            "layer {l}"
                        );
                        assert_eq!(got.layers[l].batch, want.layers[l].batch);
                        assert_eq!(got.layers[l].plan, want.layers[l].plan);
                    }
                    // per-layer telemetry resolved on both sides
                    assert_eq!(pool.layer_tracker().n_layers(), 3);
                    assert_eq!(
                        pool.layer_tracker().layer(1).windowed(),
                        got.layers[1].batch.load
                    );
                }
            }
        }
    }

    /// Decode tentpole: on an attention stack, the pool's plain
    /// `forward_model` (internal prefill) and its span path both match
    /// the scoped `ModelEngine` bitwise for worker counts {1, 2, 3, 8}
    /// — attention runs on the caller's thread in both backends, so
    /// parallelism cannot move its bits.
    #[test]
    fn pool_attn_forward_matches_scoped() {
        use crate::model::synthetic_decoder_model;
        let (model, _head) = synthetic_decoder_model(
            "cosine",
            &Rng::new(7),
            2,
            16,
            8,
            6,
            2,
            10,
            4,
            32,
        )
        .into_parts();
        let cf = 6.0; // = n_experts: admits every token
        let mut rng = Rng::new(23);
        let t = 5;
        let h = rand_vec(&mut rng, t * 16);
        let mut scoped = ModelEngine::new(model.clone(), 1);
        let mut want = ModelForward::new();
        scoped.forward(&h, cf, OverflowPolicy::Drop, &mut want);
        for workers in [1usize, 2, 3, 8] {
            let mut pool = PoolEngine::from_model(model.clone(), workers);
            assert!(pool.has_attn());
            let mut got = ModelForward::new();
            pool.forward_model(&h, cf, OverflowPolicy::Drop, &mut got);
            assert_eq!(got.hidden, want.hidden, "w={workers} prefill");
            // token-at-a-time through an external cache
            let mut cache = KvCache::new(1, 2, 16, t);
            let slot = cache.alloc().unwrap();
            let mut dec = Vec::new();
            for i in 0..t {
                pool.forward_model_seqs(
                    &h[i * 16..(i + 1) * 16],
                    &[SeqSpan { slot, n_tokens: 1 }],
                    cf,
                    OverflowPolicy::Drop,
                    &mut cache,
                    &mut got,
                );
                dec.extend_from_slice(&got.hidden);
            }
            assert_eq!(dec, want.hidden, "w={workers} decode");
        }
    }

    #[test]
    fn pool_route_matches_scoped_engine() {
        let mut rng = Rng::new(19);
        let (d, dz, e, k) = (16usize, 8, 6, 2);
        let r = synthetic_lpr_router("xattn", &mut rng, d, dz, e, k);
        let plan = r.plan().clone();
        let bank = ExpertBank::new(&Rng::new(1), e, d, 8);
        for n in [1usize, 7, 103] {
            let h = rand_vec(&mut rng, n * d);
            let mut scoped = ServingEngine::new(plan.clone(), 1);
            let want = scoped.route(&h);
            for workers in [1usize, 2, 3, 8] {
                let mut pool =
                    PoolEngine::new(plan.clone(), bank.clone(), workers);
                let mut got = RouterBatch::new();
                pool.route_into(&h, &mut got);
                assert_eq!(got, want, "n={n} workers={workers}");
                assert_eq!(pool.tracker().total_steps(), 1);
            }
        }
    }

    /// Renormalized combines go through the same pool path and stay
    /// bit-identical to the scoped engine with the option on.
    #[test]
    fn pool_renormalize_matches_scoped_engine() {
        let mut rng = Rng::new(37);
        let (d, dz, e, k, ff, n) = (16usize, 8, 8, 3, 10, 64);
        let r = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(5), e, d, ff);
        let h = rand_vec(&mut rng, n * d);
        let mut scoped = ServingEngine::new(r.plan().clone(), 2);
        scoped.set_renormalize(true);
        let mut want = FullForward::new();
        // cf=0.5 halves the total bin space, so drops are guaranteed
        scoped.forward_full(
            &h,
            &bank,
            0.5,
            OverflowPolicy::Drop,
            &mut want,
        );
        assert!(want.plan.n_dropped > 0, "cf=0.5 must drop");
        let mut pool = PoolEngine::new(r.plan().clone(), bank, 3);
        pool.set_renormalize(true);
        let mut got = FullForward::new();
        pool.forward_full(&h, 0.5, OverflowPolicy::Drop, &mut got);
        assert_eq!(got.combined, want.combined);
    }

    /// Steady-state reuse: interleaved batch sizes through one pool
    /// reproduce their first results exactly (buffers fully overwrite).
    #[test]
    fn pool_reuses_buffers_across_batches() {
        let mut rng = Rng::new(53);
        let (d, dz, e, k, ff) = (16usize, 8, 6, 2, 8);
        let r = synthetic_lpr_router("gaussian", &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(2), e, d, ff);
        let mut pool = PoolEngine::new(r.plan().clone(), bank, 2);
        let mut out = FullForward::new();
        let h1 = rand_vec(&mut rng, 48 * d);
        let h2 = rand_vec(&mut rng, 6 * d);
        pool.forward_full(&h1, 1.25, OverflowPolicy::NextChoice, &mut out);
        let first = out.combined.clone();
        pool.forward_full(&h2, 1.25, OverflowPolicy::NextChoice, &mut out);
        assert_eq!(out.combined.len(), 6 * d);
        assert_eq!(out.plan.n, 6);
        pool.forward_full(&h1, 1.25, OverflowPolicy::NextChoice, &mut out);
        assert_eq!(out.combined, first);
        assert_eq!(pool.tracker().total_steps(), 3);
    }

    /// One pool serves interleaved model/single-layer traffic without
    /// cross-talk: the shared per-batch state fully overwrites.
    #[test]
    fn pool_model_reuses_buffers_across_batches() {
        let model = synthetic_stacked_model(
            "gaussian",
            &Rng::new(3),
            2,
            16,
            8,
            6,
            2,
            8,
        );
        let mut pool = PoolEngine::from_model(model, 2);
        let mut rng = Rng::new(8);
        let mut out = ModelForward::new();
        let h1 = rand_vec(&mut rng, 40 * 16);
        let h2 = rand_vec(&mut rng, 5 * 16);
        pool.forward_model(&h1, 1.25, OverflowPolicy::Drop, &mut out);
        let first = out.hidden.clone();
        pool.forward_model(&h2, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.hidden.len(), 5 * 16);
        pool.forward_model(&h1, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.hidden, first);
        assert_eq!(pool.layer_tracker().layer(0).total_steps(), 3);
        assert_eq!(pool.n_layers(), 2);
    }

    /// Satellite: per kernel, the pool is bit-identical to the scoped
    /// engine running the *same* kernel, for worker counts {1, 2, 3,
    /// 8} — the cross-backend half of the kernel determinism contract.
    /// Satellite (bit-identity with the placement knob engaged): under
    /// load-aware and replicated placement the pool stays bit-identical
    /// to the scoped engine for worker counts {1, 2, 3, 8} — placement
    /// re-partitions *where* grouped rows compute, never their values.
    /// Runs each pool twice so the step counter advances the replica
    /// hash between batches.
    #[test]
    fn pool_placement_bit_identical_to_scoped() {
        let mut rng = Rng::new(101);
        let (d, dz, e, k, ff) = (16usize, 8, 8, 3, 12);
        let bank = ExpertBank::new(&Rng::new(3), e, d, ff);
        let r = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let plan = r.plan().clone();
        for n in [5usize, 97] {
            let h = rand_vec(&mut rng, n * d);
            let mut scoped = ServingEngine::new(plan.clone(), 1);
            let mut want = FullForward::new();
            scoped.forward_full(
                &h,
                &bank,
                1.0,
                OverflowPolicy::Drop,
                &mut want,
            );
            for policy in
                [PlacementPolicy::LoadAware, PlacementPolicy::Replicated]
            {
                for workers in [1usize, 2, 3, 8] {
                    let mut pool = PoolEngine::new(
                        plan.clone(),
                        bank.clone(),
                        workers,
                    );
                    pool.set_placement(PlacementConfig::with_policy(
                        policy,
                    ));
                    let mut got = FullForward::new();
                    for batch in 0..2 {
                        pool.forward_full(
                            &h,
                            1.0,
                            OverflowPolicy::Drop,
                            &mut got,
                        );
                        assert_eq!(
                            got.combined,
                            want.combined,
                            "{} n={n} w={workers} batch={batch} \
                             diverged",
                            policy.name()
                        );
                        assert_eq!(got.plan, want.plan);
                    }
                }
            }
        }
    }

    #[test]
    fn pool_matches_scoped_engine_for_every_kernel() {
        let mut rng = Rng::new(97);
        let (d, dz, e, k, ff) = (16usize, 8, 6, 2, 24);
        let bank = ExpertBank::new(&Rng::new(6), e, d, ff);
        let r = synthetic_lpr_router("dot", &mut rng, d, dz, e, k);
        let plan = r.plan().clone();
        let h = rand_vec(&mut rng, 53 * d);
        for kernel in Kernel::ALL {
            for tiles in [GemmTiles::default(), GemmTiles::new(2, 3, 5)] {
                let mut scoped = ServingEngine::new(plan.clone(), 3);
                scoped.set_kernel(kernel);
                scoped.set_gemm_tiles(tiles);
                let mut want = FullForward::new();
                scoped.forward_full(
                    &h,
                    &bank,
                    1.0,
                    OverflowPolicy::Drop,
                    &mut want,
                );
                for workers in [1usize, 2, 3, 8] {
                    let mut pool = PoolEngine::new(
                        plan.clone(),
                        bank.clone(),
                        workers,
                    );
                    pool.set_kernel(kernel);
                    pool.set_gemm_tiles(tiles);
                    let mut got = FullForward::new();
                    pool.forward_full(
                        &h,
                        1.0,
                        OverflowPolicy::Drop,
                        &mut got,
                    );
                    assert_eq!(
                        got.combined,
                        want.combined,
                        "kernel {} tiles {tiles} w={workers} \
                         diverged from scoped",
                        kernel.name()
                    );
                }
            }
        }
    }
}
