//! Compiled admission control: multi-tenant lanes in front of the
//! serving queue.
//!
//! The paper's balance story (routed-load Gini 0.70 → 0.035) is about
//! experts; this module applies the same discipline one layer up, to
//! *requests*. An [`AdmissionConfig`] declares **lanes** as data — each
//! lane matches on request path / tenant / priority and owns its own
//! bounded [`BatchQueue`] (token quota), flush weight, and
//! back-pressure policy — and is validated into typed
//! [`AdmissionError`]s exactly like `Engine::builder()`. Validation
//! then **compiles** the match rules once into a [`CompiledMatcher`]
//! (exact-path table + prefix byte-trie + pathless list) evaluated per
//! request with zero steady-state allocation; the naive first-match
//! linear scan is kept as [`Admission::classify_reference`], the
//! parity oracle the property tests pin the compiled tree against
//! (same pattern as `Router::forward_reference`).
//!
//! Semantics:
//!
//! - **Matching** is first-match-wins in config order. The compiled
//!   tree returns the *minimum* config index among matching lanes,
//!   which is the same thing; validation rejects lanes a strictly more
//!   general earlier lane shadows ([`AdmissionError::ShadowedLane`]),
//!   so dead config is a typed error, not a silent no-op.
//! - **Quota** bounds each lane's queue in tokens. A full lane either
//!   **sheds** the submission with an explicit 503-style rejection
//!   ([`AdmitError::LaneFull`]) or **spills** it into one named
//!   fallback lane ([`BackPressure::Spill`]; one hop only — spill
//!   chains are rejected at validation).
//! - **Weight** orders flushing: when several lanes have a due batch,
//!   the highest weight flushes first (ties break on config order), so
//!   under overload high-weight lanes keep bounded latency while
//!   low-weight lanes absorb the shedding.
//! - **Stats** (`admitted` / `rejected` / queue depth / per-lane
//!   latency percentiles) accumulate per lane and flow into
//!   [`ServeReport::lanes`](super::ServeReport::lanes).
//!
//! [`AdmittedRuntime`] couples an [`Admission`] with the virtual-clock
//! [`ServeRuntime`] for deterministic overload tests and benches; the
//! wall-clock `serve::Server` fronts itself with the same `Admission`
//! type. Request ids are globally unique across lanes: the lane index
//! lives in the top 16 bits ([`lane_of_id`]), the lane-local FIFO
//! counter in the low 48.

use super::queue::{BatchMember, BatchQueue, SubmitError};
use super::{Completion, ServeConfig, ServeReport, ServeRuntime};
use crate::engine::MoeEngine;
use crate::metrics::percentile_nearest_rank;

/// Lanes are indexed by `u16` in the compiled matcher and in the
/// request-id encoding.
pub const MAX_LANES: usize = u16::MAX as usize;

const LANE_ID_SHIFT: u32 = 48;

/// The lane index encoded in a request id returned by
/// [`Admission::submit`].
pub fn lane_of_id(id: u64) -> usize {
    (id >> LANE_ID_SHIFT) as usize
}

fn global_id(lane: usize, local: u64) -> u64 {
    debug_assert!(local < (1u64 << LANE_ID_SHIFT));
    ((lane as u64) << LANE_ID_SHIFT) | local
}

/// Request attributes the admission layer matches on. The network
/// front-end (`serve::net`) decodes one of these per request; embedders
/// fill it directly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestMeta {
    /// Request path, `/`-rooted (e.g. `/v1/generate`).
    pub path: String,
    /// Tenant header, if the client sent one.
    pub tenant: Option<String>,
    /// Client priority, 0 (lowest) to 255.
    pub priority: u8,
}

impl Default for RequestMeta {
    fn default() -> RequestMeta {
        RequestMeta { path: "/".to_string(), tenant: None, priority: 0 }
    }
}

/// How a lane matches the request path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PathMatch {
    /// The path equals this string exactly.
    Exact(String),
    /// The path starts with this string.
    Prefix(String),
}

/// What a lane does with a submission its quota cannot absorb.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackPressure {
    /// Refuse with [`AdmitError::LaneFull`] (a 503 on the wire).
    Shed,
    /// Try the named lane's queue instead (one hop; the target must
    /// itself shed).
    Spill(String),
}

/// One lane of an [`AdmissionConfig`]: match rules + queue policy.
/// Construct with [`LaneSpec::new`] (catch-all, quota 8192 tokens,
/// weight 1, max_wait 2000 ticks, shed) and set fields directly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneSpec {
    pub name: String,
    /// Path rule; `None` matches every path.
    pub path: Option<PathMatch>,
    /// Tenant rule; `None` matches every tenant (including none).
    pub tenant: Option<String>,
    /// Minimum request priority; `None` matches every priority.
    pub min_priority: Option<u8>,
    /// Lane queue bound, tokens (must be >= the engine `max_batch`).
    pub quota: usize,
    /// Flush priority: when several lanes are due, the highest weight
    /// flushes first (ties break on config order). Must be >= 1.
    pub weight: u32,
    /// Oldest-request age (ticks) that forces this lane to flush.
    pub max_wait: u64,
    pub overflow: BackPressure,
}

impl LaneSpec {
    pub fn new(name: &str) -> LaneSpec {
        LaneSpec {
            name: name.to_string(),
            path: None,
            tenant: None,
            min_priority: None,
            quota: 8_192,
            weight: 1,
            max_wait: 2_000,
            overflow: BackPressure::Shed,
        }
    }

    /// A canonical request this lane's own rules accept — the traffic
    /// generator `serve-bench --lanes` uses to aim load at each lane.
    /// (An *earlier* lane may still capture it; classify to find out.)
    pub fn example_meta(&self) -> RequestMeta {
        RequestMeta {
            path: match &self.path {
                Some(PathMatch::Exact(p)) | Some(PathMatch::Prefix(p)) => {
                    p.clone()
                }
                None => "/".to_string(),
            },
            tenant: self.tenant.clone(),
            priority: self.min_priority.unwrap_or(0),
        }
    }
}

/// Does `spec` accept `meta`? The single matching rule both evaluators
/// share.
fn lane_matches(spec: &LaneSpec, meta: &RequestMeta) -> bool {
    let path_ok = match &spec.path {
        None => true,
        Some(PathMatch::Exact(p)) => meta.path == *p,
        Some(PathMatch::Prefix(p)) => meta.path.starts_with(p.as_str()),
    };
    let tenant_ok = match spec.tenant.as_deref() {
        None => true,
        Some(t) => meta.tenant.as_deref() == Some(t),
    };
    let prio_ok = match spec.min_priority {
        None => true,
        Some(mp) => meta.priority >= mp,
    };
    path_ok && tenant_ok && prio_ok
}

/// Does every request lane `a` accepts also match lane `b`'s rules
/// rule-by-rule? Used to reject config where an earlier lane shadows a
/// later one. (Conservative per-rule containment: it cannot prove
/// cross-rule containments, which is fine — validation only *rejects*
/// on `true`.)
fn covers(a: &LaneSpec, b: &LaneSpec) -> bool {
    let path = match (&a.path, &b.path) {
        (None, _) => true,
        (Some(_), None) => false,
        (Some(PathMatch::Exact(p)), Some(PathMatch::Exact(q))) => p == q,
        (Some(PathMatch::Exact(_)), Some(PathMatch::Prefix(_))) => false,
        (Some(PathMatch::Prefix(p)), Some(PathMatch::Exact(q)))
        | (Some(PathMatch::Prefix(p)), Some(PathMatch::Prefix(q))) => {
            q.starts_with(p.as_str())
        }
    };
    let tenant = match (&a.tenant, &b.tenant) {
        (None, _) => true,
        (Some(_), None) => false,
        (Some(s), Some(t)) => s == t,
    };
    let prio = match (a.min_priority, b.min_priority) {
        (None, _) => true,
        (Some(p), None) => p == 0,
        (Some(p), Some(q)) => p <= q,
    };
    path && tenant && prio
}

/// Why an [`AdmissionConfig`] was rejected. Every variant names the
/// offending lane/value (the `EngineBuildError` convention) and has a
/// stable [`AdmissionError::code`] the conformance fixtures assert.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// The config declares no lanes at all.
    NoLanes,
    /// A lane's name is empty.
    EmptyLaneName,
    /// Two lanes share a name.
    DuplicateLane(String),
    /// More than [`MAX_LANES`] lanes.
    TooManyLanes(usize),
    /// A path rule does not start with `/`.
    BadPath { lane: String, path: String },
    /// A lane quota of zero could never admit anything.
    ZeroQuota(String),
    /// A lane quota below the engine `max_batch` could never fill a
    /// batch (the `BatchQueue` capacity invariant, as a typed error).
    QuotaBelowBatch { lane: String, quota: usize, max_batch: usize },
    /// A lane weight of zero has no defined flush order.
    ZeroWeight(String),
    /// `overflow spill` names a lane that does not exist.
    SpillUnknownLane { lane: String, target: String },
    /// A lane spills into itself.
    SpillSelf(String),
    /// A lane spills into a lane that itself spills (chains are
    /// disallowed: spilling is one hop).
    SpillChain { lane: String, target: String },
    /// An earlier, strictly more general lane captures every request
    /// this lane matches — the lane is dead config.
    ShadowedLane { lane: String, by: String },
    /// The config text itself could not be parsed.
    Syntax { line: usize, msg: String },
}

impl AdmissionError {
    /// Stable machine-readable code, asserted by the malformed-config
    /// conformance fixtures.
    pub fn code(&self) -> &'static str {
        match self {
            AdmissionError::NoLanes => "no-lanes",
            AdmissionError::EmptyLaneName => "empty-lane-name",
            AdmissionError::DuplicateLane(_) => "duplicate-lane",
            AdmissionError::TooManyLanes(_) => "too-many-lanes",
            AdmissionError::BadPath { .. } => "bad-path",
            AdmissionError::ZeroQuota(_) => "zero-quota",
            AdmissionError::QuotaBelowBatch { .. } => "quota-below-batch",
            AdmissionError::ZeroWeight(_) => "zero-weight",
            AdmissionError::SpillUnknownLane { .. } => "spill-unknown-lane",
            AdmissionError::SpillSelf(_) => "spill-self",
            AdmissionError::SpillChain { .. } => "spill-chain",
            AdmissionError::ShadowedLane { .. } => "shadowed-lane",
            AdmissionError::Syntax { .. } => "syntax",
        }
    }
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::NoLanes => {
                write!(f, "admission config declares no lanes")
            }
            AdmissionError::EmptyLaneName => {
                write!(f, "a lane has an empty name")
            }
            AdmissionError::DuplicateLane(n) => {
                write!(f, "duplicate lane `{n}`: lane names must be unique")
            }
            AdmissionError::TooManyLanes(n) => write!(
                f,
                "config declares {n} lanes; at most {MAX_LANES} are \
                 supported"
            ),
            AdmissionError::BadPath { lane, path } => write!(
                f,
                "lane `{lane}`: path `{path}` must start with '/'"
            ),
            AdmissionError::ZeroQuota(lane) => write!(
                f,
                "lane `{lane}`: quota must be >= 1 token"
            ),
            AdmissionError::QuotaBelowBatch { lane, quota, max_batch } => {
                write!(
                    f,
                    "lane `{lane}`: quota {quota} tokens is below \
                     max_batch {max_batch}, so its queue could never \
                     fill a batch"
                )
            }
            AdmissionError::ZeroWeight(lane) => write!(
                f,
                "lane `{lane}`: weight must be >= 1"
            ),
            AdmissionError::SpillUnknownLane { lane, target } => write!(
                f,
                "lane `{lane}` spills into `{target}`, which is not a \
                 configured lane"
            ),
            AdmissionError::SpillSelf(lane) => write!(
                f,
                "lane `{lane}` spills into itself"
            ),
            AdmissionError::SpillChain { lane, target } => write!(
                f,
                "lane `{lane}` spills into `{target}`, which itself \
                 spills; spilling is one hop (the target must shed)"
            ),
            AdmissionError::ShadowedLane { lane, by } => write!(
                f,
                "lane `{lane}` is unreachable: earlier lane `{by}` \
                 matches everything it matches"
            ),
            AdmissionError::Syntax { line, msg } => {
                write!(f, "admission config line {line}: {msg}")
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Why one *request* was refused at admission. Maps to 503-style
/// responses on the wire; implements `Display` + `Error` and converts
/// into the shared [`crate::Error`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    /// No lane matched the request.
    NoRoute { path: String },
    /// The matched lane (and its spill target, if any) is at quota.
    LaneFull { lane: String },
    /// The request alone exceeds `max_batch` tokens and can never
    /// flush.
    TooLarge { lane: String, max_batch: usize },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::NoRoute { path } => write!(
                f,
                "no admission lane matches request path `{path}`"
            ),
            AdmitError::LaneFull { lane } => write!(
                f,
                "lane `{lane}` is at its token quota (back-pressure); \
                 retry after a flush"
            ),
            AdmitError::TooLarge { lane, max_batch } => write!(
                f,
                "request exceeds lane `{lane}`'s max_batch \
                 ({max_batch} tokens) and can never flush"
            ),
        }
    }
}

impl std::error::Error for AdmitError {}

/// The declarative admission config: an ordered list of lanes,
/// first-match-wins. Parse one from text with
/// [`AdmissionConfig::parse`], validate + compile it with
/// [`AdmissionConfig::compile`].
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct AdmissionConfig {
    pub lanes: Vec<LaneSpec>,
}

fn num<T: std::str::FromStr>(
    line: usize,
    key: &str,
    s: &str,
) -> Result<T, AdmissionError> {
    s.parse().map_err(|_| AdmissionError::Syntax {
        line,
        msg: format!("`{key}` expects a number, got `{s}`"),
    })
}

impl AdmissionConfig {
    /// Parse the line-based config text (the same format the
    /// conformance fixtures and `--lanes FILE` use):
    ///
    /// ```text
    /// # comment
    /// lane realtime
    ///   path_prefix /v1/generate
    ///   tenant acme
    ///   min_priority 4
    ///   quota 4096
    ///   weight 8
    ///   max_wait 500
    ///   overflow spill bulk
    /// lane bulk
    ///   quota 1024
    /// ```
    ///
    /// `lane NAME` opens a lane; the keys that follow set its fields
    /// (`path` is an exact match, `path_prefix` a prefix match;
    /// `overflow` is `shed` or `spill LANE`). Indentation is free-form.
    /// Unrecognized directives are [`AdmissionError::Syntax`] — parsing
    /// is validation too.
    pub fn parse(text: &str) -> Result<AdmissionConfig, AdmissionError> {
        let mut lanes: Vec<LaneSpec> = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let ln = i + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let key = it.next().expect("non-empty trimmed line");
            let rest: Vec<&str> = it.collect();
            if key == "lane" {
                match rest.as_slice() {
                    [name] => lanes.push(LaneSpec::new(name)),
                    _ => {
                        return Err(AdmissionError::Syntax {
                            line: ln,
                            msg: "expected `lane NAME`".to_string(),
                        })
                    }
                }
                continue;
            }
            let Some(lane) = lanes.last_mut() else {
                return Err(AdmissionError::Syntax {
                    line: ln,
                    msg: format!("`{key}` before any `lane`"),
                });
            };
            match (key, rest.as_slice()) {
                ("path", [p]) => {
                    lane.path = Some(PathMatch::Exact(p.to_string()))
                }
                ("path_prefix", [p]) => {
                    lane.path = Some(PathMatch::Prefix(p.to_string()))
                }
                ("tenant", [t]) => lane.tenant = Some(t.to_string()),
                ("min_priority", [n]) => {
                    lane.min_priority = Some(num(ln, key, n)?)
                }
                ("quota", [n]) => lane.quota = num(ln, key, n)?,
                ("weight", [n]) => lane.weight = num(ln, key, n)?,
                ("max_wait", [n]) => lane.max_wait = num(ln, key, n)?,
                ("overflow", ["shed"]) => {
                    lane.overflow = BackPressure::Shed
                }
                ("overflow", ["spill", t]) => {
                    lane.overflow = BackPressure::Spill(t.to_string())
                }
                _ => {
                    return Err(AdmissionError::Syntax {
                        line: ln,
                        msg: format!("unrecognized directive `{line}`"),
                    })
                }
            }
        }
        Ok(AdmissionConfig { lanes })
    }

    /// Validate the config against an engine `max_batch` without
    /// building queues. [`AdmissionConfig::compile`] runs this first;
    /// it is public so config can be checked before an engine exists.
    pub fn validate(&self, max_batch: usize) -> Result<(), AdmissionError> {
        if self.lanes.is_empty() {
            return Err(AdmissionError::NoLanes);
        }
        if self.lanes.len() > MAX_LANES {
            return Err(AdmissionError::TooManyLanes(self.lanes.len()));
        }
        for (j, lane) in self.lanes.iter().enumerate() {
            if lane.name.is_empty() {
                return Err(AdmissionError::EmptyLaneName);
            }
            if self.lanes[..j].iter().any(|l| l.name == lane.name) {
                return Err(AdmissionError::DuplicateLane(lane.name.clone()));
            }
            if let Some(
                PathMatch::Exact(p) | PathMatch::Prefix(p),
            ) = &lane.path
            {
                if !p.starts_with('/') {
                    return Err(AdmissionError::BadPath {
                        lane: lane.name.clone(),
                        path: p.clone(),
                    });
                }
            }
            if lane.quota == 0 {
                return Err(AdmissionError::ZeroQuota(lane.name.clone()));
            }
            if lane.quota < max_batch {
                return Err(AdmissionError::QuotaBelowBatch {
                    lane: lane.name.clone(),
                    quota: lane.quota,
                    max_batch,
                });
            }
            if lane.weight == 0 {
                return Err(AdmissionError::ZeroWeight(lane.name.clone()));
            }
            if let BackPressure::Spill(target) = &lane.overflow {
                let Some(t) =
                    self.lanes.iter().find(|l| l.name == *target)
                else {
                    return Err(AdmissionError::SpillUnknownLane {
                        lane: lane.name.clone(),
                        target: target.clone(),
                    });
                };
                if t.name == lane.name {
                    return Err(AdmissionError::SpillSelf(
                        lane.name.clone(),
                    ));
                }
                if t.overflow != BackPressure::Shed {
                    return Err(AdmissionError::SpillChain {
                        lane: lane.name.clone(),
                        target: target.clone(),
                    });
                }
            }
            if let Some(by) =
                self.lanes[..j].iter().find(|l| covers(l, lane))
            {
                return Err(AdmissionError::ShadowedLane {
                    lane: lane.name.clone(),
                    by: by.name.clone(),
                });
            }
        }
        Ok(())
    }

    /// Validate and compile: match rules become a [`CompiledMatcher`],
    /// each lane gets its own [`BatchQueue`] of `quota` tokens over
    /// `d_model`-wide rows flushing at `max_batch`.
    pub fn compile(
        &self,
        d_model: usize,
        max_batch: usize,
    ) -> Result<Admission, AdmissionError> {
        self.validate(max_batch)?;
        let matcher = CompiledMatcher::build(&self.lanes);
        let lanes: Vec<LaneState> = self
            .lanes
            .iter()
            .map(|l| LaneState {
                queue: BatchQueue::new(
                    d_model,
                    max_batch,
                    l.max_wait,
                    l.quota,
                ),
                admitted: 0,
                rejected: 0,
                spilled_in: 0,
                latencies: Vec::new(),
                latency_sum: 0.0,
            })
            .collect();
        let spill: Vec<Option<usize>> = self
            .lanes
            .iter()
            .map(|l| match &l.overflow {
                BackPressure::Shed => None,
                BackPressure::Spill(t) => {
                    self.lanes.iter().position(|x| x.name == *t)
                }
            })
            .collect();
        // flush order: descending weight, ties in config order — the
        // deterministic priority the overload tests pin
        let mut order: Vec<u16> = (0..self.lanes.len() as u16).collect();
        order.sort_by_key(|&i| {
            (std::cmp::Reverse(self.lanes[i as usize].weight), i)
        });
        Ok(Admission {
            specs: self.lanes.clone(),
            matcher,
            lanes,
            spill,
            order,
            d_model,
            max_batch,
            unrouted: 0,
        })
    }
}

/// One node of the prefix byte-trie: sorted outgoing edges plus the
/// (config-ordered) prefix lanes terminating here.
#[derive(Debug, Default)]
struct TrieNode {
    edges: Vec<(u8, u32)>,
    lanes: Vec<u16>,
}

/// Per-lane non-path rules, indexed by lane for the compiled
/// evaluation.
#[derive(Debug)]
struct RestPred {
    tenant: Option<String>,
    min_priority: Option<u8>,
}

/// The compiled matcher tree: a sorted exact-path table (binary
/// search), a byte-trie over path prefixes, and the pathless lanes.
/// Built once by [`AdmissionConfig::compile`]; evaluation walks
/// pre-built vectors only — no allocation, no hashing.
#[derive(Debug)]
pub struct CompiledMatcher {
    preds: Vec<RestPred>,
    /// `(path, lanes)` sorted by path; lane lists ascend in config
    /// order.
    exact: Vec<(String, Vec<u16>)>,
    trie: Vec<TrieNode>,
    pathless: Vec<u16>,
}

impl CompiledMatcher {
    fn build(specs: &[LaneSpec]) -> CompiledMatcher {
        let preds = specs
            .iter()
            .map(|s| RestPred {
                tenant: s.tenant.clone(),
                min_priority: s.min_priority,
            })
            .collect();
        let mut exact: Vec<(String, Vec<u16>)> = Vec::new();
        let mut trie = vec![TrieNode::default()];
        let mut pathless: Vec<u16> = Vec::new();
        for (i, spec) in specs.iter().enumerate() {
            let li = i as u16;
            match &spec.path {
                None => pathless.push(li),
                Some(PathMatch::Exact(p)) => {
                    match exact
                        .binary_search_by(|(q, _)| q.as_str().cmp(p))
                    {
                        Ok(pos) => exact[pos].1.push(li),
                        Err(pos) => {
                            exact.insert(pos, (p.clone(), vec![li]))
                        }
                    }
                }
                Some(PathMatch::Prefix(p)) => {
                    let mut node = 0usize;
                    for &b in p.as_bytes() {
                        let found = trie[node]
                            .edges
                            .iter()
                            .find(|&&(eb, _)| eb == b)
                            .map(|&(_, next)| next as usize);
                        node = match found {
                            Some(next) => next,
                            None => {
                                trie.push(TrieNode::default());
                                let next = trie.len() - 1;
                                trie[node].edges.push((b, next as u32));
                                next
                            }
                        };
                    }
                    trie[node].lanes.push(li);
                }
            }
        }
        for n in &mut trie {
            n.edges.sort_unstable_by_key(|&(b, _)| b);
        }
        CompiledMatcher { preds, exact, trie, pathless }
    }

    /// First lane in the (config-ascending) candidate list whose
    /// non-path rules accept `meta`.
    fn first_rest_match(
        &self,
        lanes: &[u16],
        meta: &RequestMeta,
    ) -> Option<u16> {
        lanes.iter().copied().find(|&li| {
            let p = &self.preds[li as usize];
            let tenant_ok = match p.tenant.as_deref() {
                None => true,
                Some(t) => meta.tenant.as_deref() == Some(t),
            };
            let prio_ok = match p.min_priority {
                None => true,
                Some(mp) => meta.priority >= mp,
            };
            tenant_ok && prio_ok
        })
    }

    /// The first-match-wins lane for `meta`, or `None`. Computed as
    /// the minimum config index over the exact-table hit, every trie
    /// node on the path's byte walk, and the pathless list — which is
    /// exactly the linear scan's answer (property-pinned against
    /// [`Admission::classify_reference`]). Zero allocation.
    pub fn evaluate(&self, meta: &RequestMeta) -> Option<usize> {
        let mut best = u16::MAX;
        if let Ok(pos) = self
            .exact
            .binary_search_by(|(q, _)| q.as_str().cmp(&meta.path))
        {
            if let Some(li) =
                self.first_rest_match(&self.exact[pos].1, meta)
            {
                best = best.min(li);
            }
        }
        let bytes = meta.path.as_bytes();
        let mut node = Some(0usize);
        let mut i = 0;
        while let Some(n) = node {
            if let Some(li) = self.first_rest_match(&self.trie[n].lanes, meta)
            {
                best = best.min(li);
            }
            if i >= bytes.len() {
                break;
            }
            node = self.trie[n]
                .edges
                .binary_search_by_key(&bytes[i], |&(eb, _)| eb)
                .ok()
                .map(|pos| self.trie[n].edges[pos].1 as usize);
            i += 1;
        }
        if let Some(li) = self.first_rest_match(&self.pathless, meta) {
            best = best.min(li);
        }
        if best == u16::MAX { None } else { Some(best as usize) }
    }
}

/// Live per-lane state: the lane's own bounded queue plus its stats.
#[derive(Debug)]
struct LaneState {
    queue: BatchQueue,
    admitted: usize,
    rejected: usize,
    /// Submissions admitted here after overflowing their matched lane.
    spilled_in: usize,
    latencies: Vec<f64>,
    latency_sum: f64,
}

/// Per-lane telemetry, reported in
/// [`ServeReport::lanes`](super::ServeReport::lanes).
#[derive(Debug, Clone, Default)]
pub struct LaneStats {
    pub name: String,
    pub weight: u32,
    /// Submissions this lane's queue accepted (including spill-ins).
    pub admitted: usize,
    /// Submissions refused while this lane was the matched lane.
    pub rejected: usize,
    /// Of `admitted`, how many overflowed here from another lane.
    pub spilled_in: usize,
    /// Requests completed (latency samples recorded).
    pub completed: usize,
    /// Tokens still queued in this lane.
    pub queue_depth_tokens: usize,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
}

/// A compiled admission front: the matcher tree plus one live
/// [`BatchQueue`] per lane. Built by [`AdmissionConfig::compile`];
/// both the virtual-clock [`AdmittedRuntime`] and the wall-clock
/// `serve::Server` drive one of these.
#[derive(Debug)]
pub struct Admission {
    specs: Vec<LaneSpec>,
    matcher: CompiledMatcher,
    lanes: Vec<LaneState>,
    spill: Vec<Option<usize>>,
    /// Lane indices in flush order (descending weight, config order).
    order: Vec<u16>,
    d_model: usize,
    max_batch: usize,
    /// Submissions no lane matched ([`AdmitError::NoRoute`]).
    unrouted: usize,
}

impl Admission {
    /// A single catch-all lane over the runtime config's queue bounds —
    /// what `Server::start` uses when no admission config is given, so
    /// the un-fronted server keeps its exact pre-admission semantics.
    pub fn single(d_model: usize, cfg: &ServeConfig) -> Admission {
        let mut lane = LaneSpec::new("default");
        lane.quota = cfg.queue_tokens;
        lane.max_wait = cfg.max_wait;
        AdmissionConfig { lanes: vec![lane] }
            .compile(d_model, cfg.max_batch)
            .expect("a single catch-all lane over a valid ServeConfig \
                     cannot fail validation")
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane_name(&self, lane: usize) -> &str {
        &self.specs[lane].name
    }

    /// The validated lane specs, config order.
    pub fn specs(&self) -> &[LaneSpec] {
        &self.specs
    }

    /// The compiled first-match lane for `meta` (the hot path).
    pub fn classify(&self, meta: &RequestMeta) -> Option<usize> {
        self.matcher.evaluate(meta)
    }

    /// The naive first-match-wins linear scan over the lane specs: the
    /// parity oracle [`Self::classify`] is property-tested bit-equal
    /// to (the `Router::forward_reference` pattern).
    pub fn classify_reference(&self, meta: &RequestMeta) -> Option<usize> {
        self.specs.iter().position(|s| lane_matches(s, meta))
    }

    /// Classify and enqueue one request of `h.len() / d_model` token
    /// rows at tick `now`. On success the returned id encodes the
    /// admitting lane ([`lane_of_id`]). A full lane spills once if
    /// configured, else sheds; rejections are charged to the *matched*
    /// lane's stats.
    pub fn submit(
        &mut self,
        meta: &RequestMeta,
        h: &[f32],
        now: u64,
    ) -> Result<u64, AdmitError> {
        let Some(lane) = self.matcher.evaluate(meta) else {
            self.unrouted += 1;
            return Err(AdmitError::NoRoute { path: meta.path.clone() });
        };
        match self.lanes[lane].queue.submit(h, now) {
            Ok(local) => {
                self.lanes[lane].admitted += 1;
                return Ok(global_id(lane, local));
            }
            Err(SubmitError::TooLarge) => {
                self.lanes[lane].rejected += 1;
                return Err(AdmitError::TooLarge {
                    lane: self.specs[lane].name.clone(),
                    max_batch: self.max_batch,
                });
            }
            Err(SubmitError::Full) => {}
        }
        if let Some(target) = self.spill[lane] {
            if let Ok(local) = self.lanes[target].queue.submit(h, now) {
                self.lanes[target].admitted += 1;
                self.lanes[target].spilled_in += 1;
                return Ok(global_id(target, local));
            }
        }
        self.lanes[lane].rejected += 1;
        Err(AdmitError::LaneFull { lane: self.specs[lane].name.clone() })
    }

    /// Pop the next due micro-batch across lanes, highest weight
    /// first, rewriting member ids to their global (lane-encoded)
    /// form. `all` pops regardless of flush conditions (drain).
    /// Returns the flushed lane, or `None` when nothing is due.
    pub fn pop_due(
        &mut self,
        now: u64,
        all: bool,
        h: &mut Vec<f32>,
        m: &mut Vec<BatchMember>,
    ) -> Option<usize> {
        for &li in &self.order {
            let lane = li as usize;
            let q = &mut self.lanes[lane].queue;
            let due = if all { !q.is_empty() } else { q.ready(now) };
            if due {
                q.pop_batch(h, m);
                for mem in m.iter_mut() {
                    mem.id = global_id(lane, mem.id);
                }
                return Some(lane);
            }
        }
        None
    }

    /// Record a flushed batch's completions against `lane`'s latency
    /// stats (the batch [`Self::pop_due`] returned that lane for).
    pub fn record(&mut self, lane: usize, completions: &[Completion]) {
        let st = &mut self.lanes[lane];
        for c in completions {
            st.latencies.push(c.latency as f64);
            st.latency_sum += c.latency as f64;
        }
    }

    /// Tokens queued across all lanes.
    pub fn pending_tokens(&self) -> usize {
        self.lanes.iter().map(|l| l.queue.pending_tokens()).sum()
    }

    /// Whether every lane queue is empty.
    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.queue.is_empty())
    }

    pub fn total_admitted(&self) -> usize {
        self.lanes.iter().map(|l| l.admitted).sum()
    }

    /// All refusals: per-lane sheds plus unrouted submissions.
    pub fn total_rejected(&self) -> usize {
        self.unrouted + self.lanes.iter().map(|l| l.rejected).sum::<usize>()
    }

    /// Submissions no lane matched.
    pub fn unrouted(&self) -> usize {
        self.unrouted
    }

    /// Per-lane stats snapshots, config order.
    pub fn lane_stats(&self) -> Vec<LaneStats> {
        self.specs
            .iter()
            .zip(&self.lanes)
            .map(|(spec, st)| {
                let mut lat = st.latencies.clone();
                lat.sort_by(f64::total_cmp);
                LaneStats {
                    name: spec.name.clone(),
                    weight: spec.weight,
                    admitted: st.admitted,
                    rejected: st.rejected,
                    spilled_in: st.spilled_in,
                    completed: st.latencies.len(),
                    queue_depth_tokens: st.queue.pending_tokens(),
                    latency_mean_us: st.latency_sum
                        / st.latencies.len().max(1) as f64,
                    latency_p50_us: percentile_nearest_rank(&lat, 0.5),
                    latency_p99_us: percentile_nearest_rank(&lat, 0.99),
                }
            })
            .collect()
    }
}

/// The virtual-clock serving loop behind an [`Admission`] front:
/// submissions classify into lanes, due batches flush highest-weight
/// first through [`ServeRuntime::run_batch`], and the report carries
/// per-lane stats. Deterministic under
/// [`ServeConfig::service_ticks`] — the overload tests and
/// `serve-bench --lanes` drive this.
pub struct AdmittedRuntime<E: MoeEngine = Box<dyn MoeEngine>> {
    rt: ServeRuntime<E>,
    adm: Admission,
    h: Vec<f32>,
    m: Vec<BatchMember>,
    done: Vec<Completion>,
}

impl<E: MoeEngine> AdmittedRuntime<E> {
    /// Couple an admission front with a fresh runtime over `engine`.
    /// The admission must have been compiled against the same
    /// `d_model` and `max_batch` as `cfg`.
    pub fn new(
        engine: E,
        cfg: ServeConfig,
        adm: Admission,
    ) -> AdmittedRuntime<E> {
        assert_eq!(
            adm.d_model(),
            engine.d_model(),
            "admission compiled for a different d_model"
        );
        assert_eq!(
            adm.max_batch(),
            cfg.max_batch,
            "admission compiled for a different max_batch"
        );
        AdmittedRuntime {
            rt: ServeRuntime::with_engine(engine, cfg),
            adm,
            h: Vec::new(),
            m: Vec::new(),
            done: Vec::new(),
        }
    }

    pub fn admission(&self) -> &Admission {
        &self.adm
    }

    pub fn runtime(&self) -> &ServeRuntime<E> {
        &self.rt
    }

    /// Classify + enqueue at tick `now`; see [`Admission::submit`].
    pub fn submit(
        &mut self,
        meta: &RequestMeta,
        h: &[f32],
        now: u64,
    ) -> Result<u64, AdmitError> {
        self.adm.submit(meta, h, now)
    }

    fn flush(&mut self, now: u64, all: bool) -> &[Completion] {
        self.done.clear();
        while let Some(lane) =
            self.adm.pop_due(now, all, &mut self.h, &mut self.m)
        {
            let completed = self.rt.run_batch(&self.h, &self.m, now);
            self.adm.record(lane, completed);
            self.done.extend_from_slice(completed);
        }
        &self.done
    }

    /// Advance to tick `now`: flush every due lane batch (highest
    /// weight first) and return the completions.
    pub fn poll(&mut self, now: u64) -> &[Completion] {
        self.flush(now, false)
    }

    /// Flush everything still queued in every lane (end of run /
    /// shutdown drain).
    pub fn drain(&mut self, now: u64) -> &[Completion] {
        self.flush(now, true)
    }

    /// The runtime's aggregate report with admission-side rejections
    /// merged in and per-lane stats attached.
    pub fn report(&self) -> ServeReport {
        let mut rep = self.rt.report();
        rep.rejected += self.adm.total_rejected();
        rep.lanes = self.adm.lane_stats();
        rep
    }
}

/// Drive `n_requests` open-loop requests of `req_tokens` tokens
/// through an admitted runtime: Poisson arrivals at `rate_tok_per_s`
/// (1 tick = 1 µs), request metas drawn uniformly from `metas` (one
/// canonical meta per lane gives an even tenant mix), payload tokens
/// from `mix`, refused submissions counted per lane (no retry), and a
/// final drain. The admitted twin of [`super::run_open_loop`].
pub fn run_admitted_open_loop<E: MoeEngine>(
    runtime: &mut AdmittedRuntime<E>,
    mix: &crate::data::MixtureStream,
    rng: &mut crate::util::rng::Rng,
    metas: &[RequestMeta],
    n_requests: usize,
    req_tokens: usize,
    rate_tok_per_s: f64,
) {
    assert!(rate_tok_per_s > 0.0, "arrival rate must be positive");
    assert!(!metas.is_empty(), "need at least one request meta");
    assert!(
        req_tokens <= runtime.adm.max_batch(),
        "req_tokens {req_tokens} exceeds max_batch {} — requests \
         would never fit a micro-batch",
        runtime.adm.max_batch()
    );
    let mean_gap_us = req_tokens as f64 / rate_tok_per_s * 1e6;
    let mut h = Vec::new();
    let mut now = 0u64;
    for _ in 0..n_requests {
        let gap = (-(1.0 - rng.f64()).ln() * mean_gap_us).max(1.0);
        now += gap as u64;
        runtime.poll(now);
        mix.fill(rng, req_tokens, &mut h);
        let meta = &metas[rng.below(metas.len())];
        let _ = runtime.submit(meta, &h, now);
    }
    runtime.drain(now);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureStream;
    use crate::engine::{Backend, Engine};
    use crate::experts::ExpertBank;
    use crate::router::synthetic_lpr_router;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    const D: usize = 8;

    fn tiny_engine(seed: u64) -> Box<dyn MoeEngine> {
        let mut rng = Rng::new(seed);
        let r = synthetic_lpr_router("cosine", &mut rng, D, 4, 4, 2);
        let bank = ExpertBank::new(&Rng::new(9), 4, D, 6);
        Engine::builder()
            .layer(r.plan().clone(), bank)
            .backend(Backend::Scoped { threads: 1 })
            .build()
            .unwrap()
            .into_inner()
    }

    fn meta(path: &str, tenant: Option<&str>, priority: u8) -> RequestMeta {
        RequestMeta {
            path: path.to_string(),
            tenant: tenant.map(str::to_string),
            priority,
        }
    }

    #[test]
    fn parse_round_trips_the_documented_format() {
        let text = "\
# comment
lane realtime
  path_prefix /v1/generate
  tenant acme
  min_priority 4
  quota 4096
  weight 8
  max_wait 500
  overflow spill bulk

lane bulk
  path /v1/batch
  quota 1024
";
        let cfg = AdmissionConfig::parse(text).unwrap();
        assert_eq!(cfg.lanes.len(), 2);
        let rt = &cfg.lanes[0];
        assert_eq!(rt.name, "realtime");
        assert_eq!(
            rt.path,
            Some(PathMatch::Prefix("/v1/generate".to_string()))
        );
        assert_eq!(rt.tenant.as_deref(), Some("acme"));
        assert_eq!(rt.min_priority, Some(4));
        assert_eq!(rt.quota, 4096);
        assert_eq!(rt.weight, 8);
        assert_eq!(rt.max_wait, 500);
        assert_eq!(rt.overflow, BackPressure::Spill("bulk".to_string()));
        let bulk = &cfg.lanes[1];
        assert_eq!(bulk.path, Some(PathMatch::Exact("/v1/batch".into())));
        assert_eq!(bulk.quota, 1024);
        assert_eq!(bulk.weight, 1, "default");
        assert_eq!(bulk.overflow, BackPressure::Shed, "default");
        cfg.validate(64).unwrap();
    }

    /// Every validation failure is a typed error naming the offending
    /// lane/value, with the stable code the fixtures assert — the
    /// `EngineBuildError` convention.
    #[test]
    fn validation_rejects_bad_configs_with_typed_errors() {
        let lane = |n: &str| LaneSpec::new(n);
        let cases: Vec<(Vec<LaneSpec>, &str)> = vec![
            (vec![], "no-lanes"),
            (vec![lane("")], "empty-lane-name"),
            (vec![lane("a"), lane("a")], "duplicate-lane"),
            (
                vec![{
                    let mut l = lane("a");
                    l.path = Some(PathMatch::Exact("api".into()));
                    l
                }],
                "bad-path",
            ),
            (
                vec![{
                    let mut l = lane("a");
                    l.quota = 0;
                    l
                }],
                "zero-quota",
            ),
            (
                vec![{
                    let mut l = lane("a");
                    l.quota = 2;
                    l
                }],
                "quota-below-batch",
            ),
            (
                vec![{
                    let mut l = lane("a");
                    l.weight = 0;
                    l
                }],
                "zero-weight",
            ),
            (
                vec![{
                    let mut l = lane("a");
                    l.overflow = BackPressure::Spill("ghost".into());
                    l
                }],
                "spill-unknown-lane",
            ),
            (
                vec![{
                    let mut l = lane("a");
                    l.overflow = BackPressure::Spill("a".into());
                    l
                }],
                "spill-self",
            ),
            (
                vec![
                    {
                        let mut l = lane("a");
                        l.overflow = BackPressure::Spill("b".into());
                        l
                    },
                    {
                        let mut l = lane("b");
                        l.overflow = BackPressure::Spill("a".into());
                        l.path = Some(PathMatch::Prefix("/b".into()));
                        l
                    },
                ],
                "spill-chain",
            ),
            (
                vec![lane("all"), {
                    let mut l = lane("dead");
                    l.path = Some(PathMatch::Prefix("/x".into()));
                    l
                }],
                "shadowed-lane",
            ),
        ];
        for (lanes, code) in cases {
            let err = AdmissionConfig { lanes: lanes.clone() }
                .validate(4)
                .unwrap_err();
            assert_eq!(err.code(), code, "{err}");
            assert!(!err.to_string().is_empty());
            // compile surfaces the identical error
            let cerr = AdmissionConfig { lanes }
                .compile(D, 4)
                .map(|_| ())
                .unwrap_err();
            assert_eq!(cerr, err);
        }
        let many: Vec<LaneSpec> =
            (0..=MAX_LANES).map(|i| lane(&format!("l{i}"))).collect();
        let err =
            AdmissionConfig { lanes: many }.validate(4).unwrap_err();
        assert_eq!(err.code(), "too-many-lanes");
    }

    #[test]
    fn parse_errors_are_typed_syntax_errors() {
        for text in [
            "bogus",
            "lane",
            "lane a b",
            "quota 4",                   // field before any lane
            "lane a\n  quota none",      // non-numeric
            "lane a\n  overflow maybe",  // unknown policy
        ] {
            let err = AdmissionConfig::parse(text).unwrap_err();
            assert_eq!(err.code(), "syntax", "{text:?} -> {err}");
        }
        // the error names the 1-based line
        let err =
            AdmissionConfig::parse("lane a\nwat").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
    }

    /// Exact table, prefix trie, and pathless list all feed the same
    /// first-match-wins answer; overlapping rules resolve to the
    /// minimum config index.
    #[test]
    fn compiled_matcher_first_match_semantics() {
        let mut exact = LaneSpec::new("exact-acme");
        exact.path = Some(PathMatch::Exact("/v1/gen".into()));
        exact.tenant = Some("acme".into());
        let mut deep = LaneSpec::new("deep");
        deep.path = Some(PathMatch::Prefix("/v1/gen".into()));
        let mut wide = LaneSpec::new("wide");
        wide.path = Some(PathMatch::Prefix("/v1".into()));
        let mut vip = LaneSpec::new("vip");
        vip.min_priority = Some(5);
        let cfg =
            AdmissionConfig { lanes: vec![exact, deep, wide, vip] };
        let adm = cfg.compile(D, 4).unwrap();
        let cases = [
            (meta("/v1/gen", Some("acme"), 0), Some(0)),
            (meta("/v1/gen", Some("umbrella"), 0), Some(1)),
            (meta("/v1/gen/fast", None, 0), Some(1)),
            (meta("/v1/embed", None, 0), Some(2)),
            (meta("/v2/gen", None, 5), Some(3)),
            (meta("/v2/gen", None, 4), None),
            (meta("/", None, 9), Some(3)),
        ];
        for (m, want) in cases {
            assert_eq!(adm.classify(&m), want, "{m:?}");
            assert_eq!(adm.classify_reference(&m), want, "{m:?}");
        }
    }

    fn random_path(rng: &mut Rng) -> String {
        const SEGS: [&str; 4] = ["/api", "/chat", "/v2", "/x"];
        const TAILS: [&str; 3] = ["", "/gen", "/raw"];
        let mut p = SEGS[rng.below(SEGS.len())].to_string();
        if rng.below(2) == 0 {
            p.push_str(SEGS[rng.below(SEGS.len())]);
        }
        p.push_str(TAILS[rng.below(TAILS.len())]);
        p
    }

    /// Satellite property: the compiled matcher tree is bit-equal to
    /// the naive linear-scan reference on random valid configs and
    /// random requests.
    #[test]
    fn compiled_matcher_equals_reference_on_random_configs() {
        const TENANTS: [&str; 3] = ["acme", "globex", "umbrella"];
        forall(
            60,
            3117,
            |rng| {
                let mut cfg = AdmissionConfig::default();
                let want = 1 + rng.below(6);
                // rejection-sample lanes: keep a candidate only if the
                // config stays valid (no shadowing etc.)
                for t in 0..24 {
                    if cfg.lanes.len() >= want {
                        break;
                    }
                    let mut lane = LaneSpec::new(&format!("l{t}"));
                    lane.path = match rng.below(4) {
                        0 => None,
                        1 => Some(PathMatch::Exact(random_path(rng))),
                        _ => Some(PathMatch::Prefix(random_path(rng))),
                    };
                    if rng.below(2) == 0 {
                        lane.tenant =
                            Some(TENANTS[rng.below(3)].to_string());
                    }
                    if rng.below(2) == 0 {
                        lane.min_priority =
                            Some((rng.below(4) * 3) as u8);
                    }
                    cfg.lanes.push(lane);
                    if cfg.validate(16).is_err() {
                        cfg.lanes.pop();
                    }
                }
                let metas: Vec<RequestMeta> = (0..40)
                    .map(|_| RequestMeta {
                        path: random_path(rng),
                        tenant: if rng.below(3) == 0 {
                            None
                        } else {
                            Some(TENANTS[rng.below(3)].to_string())
                        },
                        priority: rng.below(12) as u8,
                    })
                    .collect();
                (cfg, metas)
            },
            |(cfg, metas)| {
                if cfg.lanes.is_empty() {
                    return Ok(()); // nothing sampled valid this case
                }
                let adm = cfg
                    .compile(D, 16)
                    .map_err(|e| format!("compile: {e}"))?;
                for m in metas {
                    let fast = adm.classify(m);
                    let slow = adm.classify_reference(m);
                    if fast != slow {
                        return Err(format!(
                            "compiled {fast:?} != reference {slow:?} \
                             for {m:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Satellite property (determinism pin): admission never reorders
    /// requests within a lane — per-lane completion order equals
    /// per-lane admission order — and `admitted + rejected` conserves
    /// submissions exactly.
    #[test]
    fn admission_preserves_within_lane_fifo_and_conserves() {
        forall(
            12,
            4243,
            |rng| {
                let n = 10 + rng.below(40);
                let reqs: Vec<(bool, usize, u64)> = (0..n)
                    .map(|_| {
                        (
                            rng.below(2) == 0,
                            1 + rng.below(4),
                            rng.below(6) as u64,
                        )
                    })
                    .collect();
                reqs
            },
            |reqs| {
                let max_batch = 8;
                let mut hi = LaneSpec::new("hi");
                hi.path = Some(PathMatch::Prefix("/hi".into()));
                hi.quota = max_batch;
                hi.weight = 4;
                hi.max_wait = 6;
                let mut lo = LaneSpec::new("lo");
                lo.quota = max_batch;
                lo.max_wait = 6;
                let adm = AdmissionConfig { lanes: vec![hi, lo] }
                    .compile(D, max_batch)
                    .map_err(|e| e.to_string())?;
                let cfg = ServeConfig {
                    max_batch,
                    max_wait: 6,
                    service_ticks: Some(3),
                    ..ServeConfig::default()
                };
                let mut rt =
                    AdmittedRuntime::new(tiny_engine(5), cfg, adm);
                let mut accepted: Vec<Vec<u64>> = vec![vec![], vec![]];
                let mut now = 0u64;
                let mut done: Vec<Completion> = Vec::new();
                for &(is_hi, n_tok, gap) in reqs {
                    now += gap;
                    done.extend_from_slice(rt.poll(now));
                    let m = if is_hi {
                        meta("/hi/req", None, 0)
                    } else {
                        meta("/other", None, 0)
                    };
                    let h = vec![0.1f32; n_tok * D];
                    if let Ok(id) = rt.submit(&m, &h, now) {
                        accepted[lane_of_id(id)].push(id);
                    }
                }
                done.extend_from_slice(rt.drain(now));
                // per-lane completion order == per-lane admission order
                for lane in 0..2 {
                    let got: Vec<u64> = done
                        .iter()
                        .map(|c| c.id)
                        .filter(|&id| lane_of_id(id) == lane)
                        .collect();
                    if got != accepted[lane] {
                        return Err(format!(
                            "lane {lane} reordered: {got:?} != \
                             {accepted:?}"
                        ));
                    }
                }
                // exact conservation, including per-lane stats
                let rep = rt.report();
                let n_acc: usize =
                    accepted.iter().map(Vec::len).sum();
                if rep.requests != n_acc {
                    return Err(format!(
                        "completed {} != accepted {n_acc}",
                        rep.requests
                    ));
                }
                if rep.requests + rep.rejected != reqs.len() {
                    return Err(format!(
                        "requests {} + rejected {} != submissions {}",
                        rep.requests,
                        rep.rejected,
                        reqs.len()
                    ));
                }
                let stats = rt.admission().lane_stats();
                for (lane, st) in stats.iter().enumerate() {
                    if st.admitted != accepted[lane].len() {
                        return Err(format!(
                            "lane {lane} admitted {} != {}",
                            st.admitted,
                            accepted[lane].len()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Weight orders flushing: when two lanes are due at the same tick
    /// the higher-weight lane's batch enters the engine first.
    #[test]
    fn higher_weight_lane_flushes_first() {
        let mut hi = LaneSpec::new("hi");
        hi.path = Some(PathMatch::Prefix("/hi".into()));
        hi.quota = 64;
        hi.weight = 8;
        hi.max_wait = 5;
        let mut lo = LaneSpec::new("lo");
        lo.quota = 64;
        lo.weight = 1;
        lo.max_wait = 5;
        let adm = AdmissionConfig { lanes: vec![hi, lo] }
            .compile(D, 4)
            .unwrap();
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait: 5,
            service_ticks: Some(10),
            ..ServeConfig::default()
        };
        let mut rt = AdmittedRuntime::new(tiny_engine(6), cfg, adm);
        let h = vec![0.2f32; 2 * D];
        // submit low-priority first so config order alone cannot win
        let lo_id = rt.submit(&meta("/other", None, 0), &h, 0).unwrap();
        let hi_id = rt.submit(&meta("/hi/x", None, 0), &h, 0).unwrap();
        let done = rt.poll(5).to_vec(); // both lanes age out together
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, hi_id, "high weight flushes first");
        assert_eq!(done[1].id, lo_id);
        // serial engine: the high-weight batch finished first
        assert!(done[0].done_at < done[1].done_at);
        assert_eq!(done[0].done_at, 15);
        assert_eq!(done[1].done_at, 25);
    }

    /// Spill-once back-pressure: a full lane overflows into its
    /// configured target; when the target is full too, the submission
    /// sheds and is charged to the *matched* lane.
    #[test]
    fn spill_overflows_once_then_sheds() {
        let mut a = LaneSpec::new("a");
        a.path = Some(PathMatch::Prefix("/a".into()));
        a.quota = 8;
        a.overflow = BackPressure::Spill("b".into());
        let mut b = LaneSpec::new("b");
        b.quota = 8;
        let mut adm = AdmissionConfig { lanes: vec![a, b] }
            .compile(D, 8)
            .unwrap();
        let m = meta("/a/x", None, 0);
        let full = vec![0.0f32; 8 * D];
        let part = vec![0.0f32; 4 * D];
        let id0 = adm.submit(&m, &full, 0).unwrap();
        assert_eq!(lane_of_id(id0), 0);
        // lane a is at quota: the next submission spills into b
        let id1 = adm.submit(&m, &part, 1).unwrap();
        assert_eq!(lane_of_id(id1), 1);
        // b cannot absorb 8 more tokens either: shed, charged to a
        let err = adm.submit(&m, &full, 2).unwrap_err();
        assert_eq!(err, AdmitError::LaneFull { lane: "a".into() });
        let stats = adm.lane_stats();
        assert_eq!((stats[0].admitted, stats[0].rejected), (1, 1));
        assert_eq!((stats[1].admitted, stats[1].spilled_in), (1, 1));
        assert_eq!(adm.total_admitted(), 2);
        assert_eq!(adm.total_rejected(), 1);
        // an unmatched path is a typed NoRoute, counted as unrouted
        let err = adm
            .submit(&meta("/zzz", None, 0), &part, 3)
            .unwrap_err();
        assert!(matches!(err, AdmitError::NoRoute { .. }));
        assert_eq!(adm.unrouted(), 1);
        assert_eq!(adm.total_rejected(), 2);
    }

    /// The implicit single catch-all lane admits everything a bare
    /// `BatchQueue` would — the un-fronted Server's semantics.
    #[test]
    fn single_catch_all_lane_admits_everything() {
        let cfg = ServeConfig {
            max_batch: 4,
            queue_tokens: 16,
            ..ServeConfig::default()
        };
        let mut adm = Admission::single(D, &cfg);
        assert_eq!(adm.n_lanes(), 1);
        assert_eq!(adm.lane_name(0), "default");
        for m in [
            RequestMeta::default(),
            meta("/any/path", Some("acme"), 9),
        ] {
            assert_eq!(adm.classify(&m), Some(0));
        }
        let h = vec![0.0f32; 2 * D];
        let id = adm.submit(&RequestMeta::default(), &h, 0).unwrap();
        assert_eq!(lane_of_id(id), 0);
        // oversized requests keep the typed refusal
        let big = vec![0.0f32; 5 * D];
        assert!(matches!(
            adm.submit(&RequestMeta::default(), &big, 0),
            Err(AdmitError::TooLarge { .. })
        ));
    }

    /// Lane stats aggregate recorded completions with the shared
    /// nearest-rank percentile convention.
    #[test]
    fn lane_stats_percentiles() {
        let mut adm = Admission::single(D, &ServeConfig::default());
        adm.record(
            0,
            &[
                Completion { id: 0, n_tokens: 1, latency: 10, done_at: 10 },
                Completion { id: 1, n_tokens: 1, latency: 20, done_at: 20 },
            ],
        );
        let st = &adm.lane_stats()[0];
        assert_eq!(st.completed, 2);
        assert_eq!(st.latency_mean_us, 15.0);
        assert_eq!(st.latency_p50_us, 10.0);
        assert_eq!(st.latency_p99_us, 20.0);
    }
}
