//! Wall-clock serving front-end: the deployable loop over the
//! virtual-clock [`ServeRuntime`] core — the ROADMAP "wall-clock
//! ingestion" item.
//!
//! [`ServeRuntime`] is event-driven on a virtual clock: deterministic,
//! test-friendly, and driven entirely by the caller stamping `now`
//! onto `submit`/`poll`. [`Server`] wraps one runtime (over any boxed
//! [`MoeEngine`](crate::engine::MoeEngine) the builder produced) and
//! supplies the missing real-time half **without forking the
//! deterministic core**:
//!
//! - `enqueue` / `enqueue_with` stamp real `Instant`-derived
//!   microsecond arrivals (1 tick = 1 µs since server start) onto a
//!   server-owned [`Admission`] front — one catch-all lane by default
//!   ([`Server::start`]), or any compiled multi-lane
//!   [`AdmissionConfig`](super::AdmissionConfig) via
//!   [`Server::with_admission`];
//! - a background flusher thread pops due micro-batches every
//!   `poll_interval` (highest-weight lane first) and forwards them via
//!   [`ServeRuntime::run_batch`], so batches flush by size *and* by
//!   age with no caller in the loop;
//! - `await_completion` blocks (condvar) until the request's
//!   [`Completion`] lands — the blocking client API a driver thread
//!   pool needs.
//!
//! The virtual-clock semantics are untouched: the same `ServeRuntime`
//! code path computes batch start (`max(now, busy_until)`), service
//! time (measured, or the [`crate::serve::ServeConfig::service_ticks`]
//! override for deterministic tests), and per-request latency.
//! Virtual-clock tests stay bit-identical; the server only chooses
//! *which* `now` to pass.
//!
//! Lock split & order: the [`Admission`] (every lane queue plus the
//! admission counters) lives behind its **own** lock, separate from
//! the runtime (engine) lock. `enqueue` takes only the admission lock
//! — held for a classify + memcpy — so submissions land even while a
//! batch forward holds the runtime lock for its full service time
//! (pinned by `enqueue_lands_while_a_batch_forward_is_in_flight`).
//! The flusher takes the admission lock (pop one due batch), releases
//! it, then the runtime lock (forward, via
//! [`ServeRuntime::run_batch`]), releases it, then the admission lock
//! again (latency record) and the completion map — strictly one lock
//! at a time, so no ordering cycle exists. Admission refusals are
//! counted per lane under the admission lock and merged into
//! [`ServeReport::rejected`] (with per-lane detail in
//! [`ServeReport::lanes`]) by [`Server::report`].
//!
//! Unclaimed completions are retained in a **bounded** buffer (the
//! [`DONE_RETAIN`] most recent); older unclaimed records are discarded
//! oldest-first, so fire-and-forget clients cannot leak memory — but
//! `await_completion` on a discarded id would block forever: claim
//! completions promptly, or use `try_completion`.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::admission::{Admission, AdmitError, RequestMeta};
use super::{
    BatchMember, Completion, ServeReport, ServeRuntime, SubmitError,
};

/// Unclaimed completions retained before the oldest are discarded.
pub const DONE_RETAIN: usize = 16_384;

/// Bounded unclaimed-completion buffer: completion records by id, with
/// insertion order tracked for oldest-first eviction. `order` may hold
/// ids already claimed (stale); eviction pops them harmlessly, and its
/// length bound (`DONE_RETAIN`) bounds the map too.
#[derive(Default)]
struct DoneMap {
    map: HashMap<u64, Completion>,
    order: VecDeque<u64>,
}

impl DoneMap {
    fn insert(&mut self, c: Completion) {
        self.map.insert(c.id, c);
        self.order.push_back(c.id);
        while self.order.len() > DONE_RETAIN {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
    }
}

struct Shared {
    rt: Mutex<ServeRuntime>,
    /// The admission front (lane queues + counters), behind its own
    /// lock (never the runtime's) so `enqueue` lands while a batch
    /// forward is in flight.
    adm: Mutex<Admission>,
    /// Completions not yet claimed by `await_completion`.
    done: Mutex<DoneMap>,
    cv: Condvar,
    stop: AtomicBool,
    t0: Instant,
    /// Engine model width, cached so request validation (`net.rs`)
    /// never needs the runtime lock.
    d_model: usize,
}

impl Shared {
    fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// One flusher step: pop every due micro-batch (admission lock
    /// only, highest-weight lane first), forward each through the
    /// runtime (runtime lock only), record lane latency, and publish
    /// completions. `final_drain` flushes everything still queued
    /// (shutdown), regardless of the flush conditions. `h`/`m` are
    /// flusher-owned scratch so the steady state stays
    /// allocation-free.
    fn pump(
        &self,
        final_drain: bool,
        h: &mut Vec<f32>,
        m: &mut Vec<BatchMember>,
    ) {
        loop {
            let now = self.now_us();
            let lane = {
                let mut adm =
                    self.adm.lock().expect("admission front poisoned");
                adm.pop_due(now, final_drain, h, m)
            }; // admission lock released: submissions land during the forward
            let Some(lane) = lane else { return };
            let completed: Vec<Completion> = {
                let mut rt =
                    self.rt.lock().expect("serve runtime poisoned");
                rt.run_batch(h, m, now).to_vec()
            };
            self.adm
                .lock()
                .expect("admission front poisoned")
                .record(lane, &completed);
            if !completed.is_empty() {
                let mut done = self.done.lock().expect("completion map");
                for c in completed {
                    done.insert(c);
                }
                self.cv.notify_all();
            }
        }
    }
}

/// A running wall-clock server. Construct with [`Server::start`] (one
/// catch-all admission lane) or [`Server::with_admission`] (a compiled
/// multi-lane config); `&Server` is shareable across client threads
/// (`enqueue` / `await_completion` take `&self`). Dropping the server
/// stops and joins the flusher after a final drain.
///
/// ```no_run
/// use lpr::engine::{Backend, Engine};
/// use lpr::model::synthetic_stacked_model;
/// use lpr::serve::{Server, ServeConfig, ServeRuntime};
/// use lpr::util::rng::Rng;
///
/// let model =
///     synthetic_stacked_model("cosine", &Rng::new(7), 2, 8, 4, 4, 2, 6);
/// let engine = Engine::builder()
///     .model(model)
///     .backend(Backend::Pool { workers: 2 })
///     .build()?;
/// let cfg = ServeConfig { max_batch: 64, ..ServeConfig::default() };
/// let server =
///     Server::start(ServeRuntime::with_engine(engine.into_inner(), cfg));
/// let id = server.enqueue(&vec![0.0f32; 4 * 8])?;
/// let completion = server.await_completion(id);
/// assert_eq!(completion.n_tokens, 4);
/// let report = server.shutdown();
/// # Ok::<(), lpr::Error>(())
/// ```
pub struct Server {
    shared: Arc<Shared>,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving `rt` with a single catch-all admission lane and
    /// the default 200 µs flusher cadence.
    pub fn start(rt: ServeRuntime) -> Server {
        Server::with_poll_interval(rt, Duration::from_micros(200))
    }

    /// Start serving `rt` with a single catch-all admission lane
    /// (quota/age from the runtime's [`super::ServeConfig`] — the
    /// pre-admission server semantics, exactly), waking the background
    /// flusher every `poll_interval` (the granularity at which
    /// age-based flushes and completions are observed; latency floors
    /// at roughly one interval).
    pub fn with_poll_interval(
        rt: ServeRuntime,
        poll_interval: Duration,
    ) -> Server {
        let adm = Admission::single(rt.engine().d_model(), rt.config());
        Server::with_admission(rt, adm, poll_interval)
    }

    /// Start serving `rt` behind a compiled multi-lane [`Admission`]
    /// (from [`super::AdmissionConfig::compile`]). The admission must
    /// agree with the runtime on `d_model` and `max_batch` — a
    /// mismatch would let one side build batches the other refuses.
    pub fn with_admission(
        rt: ServeRuntime,
        adm: Admission,
        poll_interval: Duration,
    ) -> Server {
        let d_model = rt.engine().d_model();
        assert_eq!(
            adm.d_model(),
            d_model,
            "admission d_model must match the engine"
        );
        assert_eq!(
            adm.max_batch(),
            rt.config().max_batch,
            "admission max_batch must match the serve config"
        );
        let shared = Arc::new(Shared {
            rt: Mutex::new(rt),
            adm: Mutex::new(adm),
            done: Mutex::new(DoneMap::default()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            t0: Instant::now(),
            d_model,
        });
        let worker = shared.clone();
        let flusher = std::thread::Builder::new()
            .name("lpr-serve-clock".into())
            .spawn(move || {
                let (mut h, mut m) = (Vec::new(), Vec::new());
                loop {
                    if worker.stop.load(Ordering::Acquire) {
                        // final drain so every accepted request
                        // completes and no awaiter is left blocked
                        worker.pump(true, &mut h, &mut m);
                        return;
                    }
                    worker.pump(false, &mut h, &mut m);
                    std::thread::sleep(poll_interval);
                }
            })
            .expect("spawn serve clock thread");
        Server { shared, flusher: Some(flusher) }
    }

    /// Microseconds since the server started — the tick domain of every
    /// [`Completion`] this server reports.
    pub fn now_us(&self) -> u64 {
        self.shared.now_us()
    }

    /// Engine model width: requests carry `h.len() / d_model()` token
    /// rows. Lock-free (cached at construction).
    pub fn d_model(&self) -> usize {
        self.shared.d_model
    }

    /// Submit one request of `h.len() / d` token rows with the default
    /// [`RequestMeta`] (path `/`, no tenant, priority 0), stamped with
    /// the current wall clock. Back-pressure — a full (or unmatched)
    /// lane — surfaces as [`SubmitError::Full`] (counted in
    /// [`ServeReport::rejected`]); oversized requests as
    /// [`SubmitError::TooLarge`]. Takes only the admission lock (held
    /// for a classify + memcpy), never the runtime lock — a submission
    /// lands even while a batch forward is computing.
    pub fn enqueue(&self, h: &[f32]) -> Result<u64, SubmitError> {
        self.enqueue_with(&RequestMeta::default(), h).map_err(|e| {
            match e {
                AdmitError::TooLarge { .. } => SubmitError::TooLarge,
                AdmitError::LaneFull { .. }
                | AdmitError::NoRoute { .. } => SubmitError::Full,
            }
        })
    }

    /// Submit one request routed by `meta` through the compiled
    /// admission config; refusals keep their typed [`AdmitError`]
    /// detail (which lane shed, or that no lane matched). The returned
    /// id encodes the admitting lane
    /// ([`super::lane_of_id`]).
    pub fn enqueue_with(
        &self,
        meta: &RequestMeta,
        h: &[f32],
    ) -> Result<u64, AdmitError> {
        let now = self.shared.now_us();
        self.shared
            .adm
            .lock()
            .expect("admission front poisoned")
            .submit(meta, h, now)
    }

    /// The completion for `id`, if it has already been served (consumes
    /// the record).
    pub fn try_completion(&self, id: u64) -> Option<Completion> {
        self.shared.done.lock().expect("completion map").map.remove(&id)
    }

    /// Block until request `id` completes and return its
    /// [`Completion`] (consumes the record). Only pass ids returned by
    /// [`Server::enqueue`], and claim promptly: a never-enqueued id —
    /// or one whose unclaimed record aged past the [`DONE_RETAIN`]
    /// retention bound — never arrives, so this would block forever.
    pub fn await_completion(&self, id: u64) -> Completion {
        let mut done = self.shared.done.lock().expect("completion map");
        loop {
            if let Some(c) = done.map.remove(&id) {
                return c;
            }
            done = self.shared.cv.wait(done).expect("completion map");
        }
    }

    /// Tokens currently queued across every lane (not yet flushed into
    /// a batch).
    pub fn pending_tokens(&self) -> usize {
        self.shared
            .adm
            .lock()
            .expect("admission front poisoned")
            .pending_tokens()
    }

    /// Aggregate telemetry for everything served so far (same schema as
    /// the virtual-clock runtime's report), with admission-side
    /// rejections merged in and per-lane stats attached
    /// ([`ServeReport::lanes`]).
    pub fn report(&self) -> ServeReport {
        let mut rep =
            self.shared.rt.lock().expect("serve runtime poisoned").report();
        let adm = self.shared.adm.lock().expect("admission front poisoned");
        rep.rejected += adm.total_rejected();
        rep.lanes = adm.lane_stats();
        rep
    }

    /// Stop the flusher, drain everything still queued, wake every
    /// awaiter, and return the final report.
    pub fn shutdown(mut self) -> ServeReport {
        self.stop_and_join();
        self.report()
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        if let Some(h) = self.flusher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::plan::OverflowPolicy;
    use crate::engine::{Backend, Engine, EngineOutput, MoeEngine};
    use crate::metrics::LayerLoadTracker;
    use crate::model::{synthetic_stacked_model, ModelForward};
    use crate::router::RouterBatch;
    use crate::serve::{lane_of_id, AdmissionConfig, ServeConfig};
    use crate::util::rng::Rng;

    const D: usize = 8;

    fn start_server(
        max_batch: usize,
        max_wait: u64,
        service_ticks: Option<u64>,
    ) -> Server {
        let model = synthetic_stacked_model(
            "cosine",
            &Rng::new(5),
            2,
            D,
            4,
            4,
            2,
            6,
        );
        let engine = Engine::builder()
            .model(model)
            .backend(Backend::Pool { workers: 2 })
            .policy(OverflowPolicy::LeastLoaded)
            .capacity_factor(1.25)
            .build()
            .unwrap();
        let cfg = ServeConfig {
            max_batch,
            max_wait,
            queue_tokens: 16 * max_batch,
            service_ticks,
            ..ServeConfig::default()
        };
        Server::with_poll_interval(
            ServeRuntime::with_engine(engine.into_inner(), cfg),
            Duration::from_micros(200),
        )
    }

    /// Acceptance: the wall-clock server round-trips a real-time
    /// request batch end-to-end — size-flushed and age-flushed — under
    /// a fixed service-time override for determinism of the service
    /// accounting.
    #[test]
    fn server_round_trips_requests_end_to_end() {
        // max_wait 50ms: far above the gap between the two enqueues
        // below (so they cannot age-flush apart under a slow
        // scheduler), far below test-timeout territory for the
        // age-flushed third request
        let server = start_server(4, 50_000, Some(10));
        // two 2-token requests fill max_batch -> size flush
        let a = vec![0.25f32; 2 * D];
        let id0 = server.enqueue(&a).unwrap();
        let id1 = server.enqueue(&a).unwrap();
        let c0 = server.await_completion(id0);
        let c1 = server.await_completion(id1);
        assert_eq!(c0.n_tokens, 2);
        assert_eq!(c1.n_tokens, 2);
        // both flushed in one batch: identical completion tick, and
        // the fixed override bounds latency from below
        assert_eq!(c0.done_at, c1.done_at);
        assert!(c0.latency >= 10);
        // a lone 1-token request flushes by age (max_wait 1ms)
        let b = vec![0.5f32; D];
        let id2 = server.enqueue(&b).unwrap();
        let c2 = server.await_completion(id2);
        assert_eq!(c2.n_tokens, 1);
        assert!(c2.done_at > c0.done_at);
        // completions are consumed exactly once
        assert_eq!(server.try_completion(id0), None);
        let rep = server.shutdown();
        assert_eq!(rep.requests, 3);
        assert_eq!(rep.tokens, 5);
        assert_eq!(rep.batches, 2);
        assert_eq!(rep.rejected, 0);
        // the default front is one catch-all lane, reported as such
        assert_eq!(rep.lanes.len(), 1);
        assert_eq!(rep.lanes[0].name, "default");
        assert_eq!(rep.lanes[0].admitted, 3);
    }

    /// Concurrent clients: blocking enqueue/await from several threads
    /// all round-trip, and shutdown's final drain leaves nobody
    /// waiting.
    #[test]
    fn concurrent_clients_all_complete() {
        let server = start_server(64, 2_000, Some(5));
        std::thread::scope(|scope| {
            for t in 0..4 {
                let server = &server;
                scope.spawn(move || {
                    for _ in 0..8 {
                        let h = vec![t as f32; 3 * D];
                        let id = server.enqueue(&h).unwrap();
                        let c = server.await_completion(id);
                        assert_eq!(c.n_tokens, 3);
                    }
                });
            }
        });
        let rep = server.shutdown();
        assert_eq!(rep.requests, 4 * 8);
        assert_eq!(rep.tokens, 4 * 8 * 3);
        assert!(rep.batches >= 1);
        assert!(rep.window_gini >= 0.0);
    }

    /// An engine whose forward sleeps: stands in for a long batch so
    /// the lock-split test can catch `enqueue` blocking behind it.
    struct SlowEngine {
        inner: Box<dyn MoeEngine>,
        delay: Duration,
        in_forward: Arc<AtomicBool>,
    }

    impl MoeEngine for SlowEngine {
        fn forward(&mut self, h: &[f32], n: usize) -> EngineOutput<'_> {
            self.in_forward.store(true, Ordering::SeqCst);
            std::thread::sleep(self.delay);
            let out = self.inner.forward(h, n);
            self.in_forward.store(false, Ordering::SeqCst);
            out
        }
        fn route_into(&mut self, h: &[f32], out: &mut RouterBatch) {
            self.inner.route_into(h, out)
        }
        fn balance(&self) -> &LayerLoadTracker {
            self.inner.balance()
        }
        fn capacity_factor(&self) -> f64 {
            self.inner.capacity_factor()
        }
        fn policy(&self) -> OverflowPolicy {
            self.inner.policy()
        }
        fn layers(&self) -> usize {
            self.inner.layers()
        }
        fn d_model(&self) -> usize {
            self.inner.d_model()
        }
        fn last(&self) -> &ModelForward {
            self.inner.last()
        }
    }

    /// Satellite (lock split): a submission must land while a batch
    /// forward holds the runtime lock — `enqueue` takes only the
    /// admission lock. Before the split this blocked for the full
    /// (here 80 ms) service time.
    #[test]
    fn enqueue_lands_while_a_batch_forward_is_in_flight() {
        let model = synthetic_stacked_model(
            "cosine",
            &Rng::new(5),
            2,
            D,
            4,
            4,
            2,
            6,
        );
        let engine = Engine::builder()
            .model(model)
            .backend(Backend::Pool { workers: 2 })
            .build()
            .unwrap();
        let in_forward = Arc::new(AtomicBool::new(false));
        let slow = SlowEngine {
            inner: engine.into_inner(),
            delay: Duration::from_millis(80),
            in_forward: in_forward.clone(),
        };
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait: 1, // age-flush essentially immediately
            queue_tokens: 64,
            service_ticks: Some(1),
            ..ServeConfig::default()
        };
        let server = Server::with_poll_interval(
            ServeRuntime::with_engine(
                Box::new(slow) as Box<dyn MoeEngine>,
                cfg,
            ),
            Duration::from_micros(100),
        );
        let h = vec![0.5f32; 2 * D];
        let id0 = server.enqueue(&h).unwrap();
        // wait until the flusher is inside the slow forward for id0
        while !in_forward.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        let t0 = Instant::now();
        let id1 = server.enqueue(&h).unwrap();
        let took = t0.elapsed();
        assert!(
            took < Duration::from_millis(40),
            "enqueue blocked {took:?} behind an in-flight forward"
        );
        // and the queue really absorbed it mid-forward
        assert_eq!(server.await_completion(id0).n_tokens, 2);
        assert_eq!(server.await_completion(id1).n_tokens, 2);
        let rep = server.shutdown();
        assert_eq!(rep.requests, 2);
        assert_eq!(rep.rejected, 0);
    }

    /// The unclaimed-completion buffer is bounded: oldest records are
    /// discarded past the retention cap, newest are kept.
    #[test]
    fn done_map_retention_is_bounded() {
        let mut dm = DoneMap::default();
        let last = DONE_RETAIN as u64 + 9;
        for id in 0..=last {
            dm.insert(Completion {
                id,
                n_tokens: 1,
                latency: 1,
                done_at: 1,
            });
        }
        assert_eq!(dm.map.len(), DONE_RETAIN);
        assert_eq!(dm.order.len(), DONE_RETAIN);
        assert!(!dm.map.contains_key(&0), "oldest evicted");
        assert!(dm.map.contains_key(&last), "newest kept");
    }

    /// Oversized requests are refused with the typed error, and the
    /// server keeps serving.
    #[test]
    fn oversized_request_is_refused() {
        let server = start_server(4, 500, Some(1));
        let too_big = vec![0.0f32; 5 * D];
        assert_eq!(server.enqueue(&too_big), Err(SubmitError::TooLarge));
        let ok = vec![0.0f32; 2 * D];
        let id = server.enqueue(&ok).unwrap();
        assert_eq!(server.await_completion(id).n_tokens, 2);
        drop(server); // Drop also stops the flusher cleanly
    }

    /// A compiled multi-lane config over the wall clock: metas route
    /// to their lanes (visible in the id encoding and per-lane
    /// report), and a full lane sheds with the typed refusal while the
    /// other lane keeps admitting.
    #[test]
    fn lanes_route_and_shed_over_the_wall_clock() {
        let model = synthetic_stacked_model(
            "cosine",
            &Rng::new(5),
            2,
            D,
            4,
            4,
            2,
            6,
        );
        let engine = Engine::builder()
            .model(model)
            .backend(Backend::Pool { workers: 2 })
            .build()
            .unwrap();
        // max_wait far above test duration and max_batch above the
        // submitted tokens: nothing flushes until the shutdown drain,
        // so the quota arithmetic below is deterministic
        let cfg = ServeConfig {
            max_batch: 8,
            max_wait: 60_000_000,
            queue_tokens: 64,
            service_ticks: Some(1),
            ..ServeConfig::default()
        };
        let adm = AdmissionConfig::parse(
            "lane hi\n  path_prefix /hi\n  quota 8\n  weight 4\n\
             lane rest\n  quota 64\n",
        )
        .unwrap()
        .compile(D, cfg.max_batch)
        .unwrap();
        let server = Server::with_admission(
            ServeRuntime::with_engine(engine.into_inner(), cfg),
            adm,
            Duration::from_micros(200),
        );
        let hi = RequestMeta {
            path: "/hi/generate".to_string(),
            ..RequestMeta::default()
        };
        let h3 = vec![0.5f32; 3 * D];
        // two 3-token requests fit the hi quota (6 <= 8), a third
        // (9 > 8) sheds; 6 < max_batch 8 so no size flush races this
        let a = server.enqueue_with(&hi, &h3).unwrap();
        let b = server.enqueue_with(&hi, &h3).unwrap();
        assert_eq!(lane_of_id(a), 0);
        assert_eq!(lane_of_id(b), 0);
        match server.enqueue_with(&hi, &h3) {
            Err(AdmitError::LaneFull { lane }) => assert_eq!(lane, "hi"),
            other => panic!("expected hi to shed, got {other:?}"),
        }
        // the catch-all lane still admits (default meta → lane 1)
        let c = server.enqueue(&h3).unwrap();
        assert_eq!(lane_of_id(c), 1);
        // shutdown drains every lane: all three admitted requests
        // complete (requests == 3 below) even though nothing was due
        let rep = server.shutdown();
        assert_eq!(rep.requests, 3);
        assert_eq!(rep.rejected, 1);
        assert_eq!(rep.lanes.len(), 2);
        assert_eq!(rep.lanes[0].name, "hi");
        assert_eq!(rep.lanes[0].admitted, 2);
        assert_eq!(rep.lanes[0].rejected, 1);
        assert_eq!(rep.lanes[1].name, "rest");
        assert_eq!(rep.lanes[1].admitted, 1);
        assert_eq!(rep.lanes[1].rejected, 0);
    }
}
