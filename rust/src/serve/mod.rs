//! Serving runtime: a request front-end over the engine facade.
//!
//! PR 2 ended with a per-batch pipeline (`route → DispatchPlan →
//! expert FFN → combine`) but no way to *feed* it from a stream of
//! requests. This module supplies the serving story around the
//! [`crate::engine::MoeEngine`] facade:
//!
//! - [`queue::BatchQueue`] — a bounded submission queue that
//!   micro-batches incoming token groups FIFO: flush on `max_batch`
//!   tokens or when the oldest request has waited `max_wait` ticks;
//!   requests are never split or reordered.
//! - [`pool::PoolEngine`] — the persistent channel-fed worker backend
//!   behind `engine::Backend::Pool`: the full data path — single layer
//!   or a whole [`crate::model::StackedModel`] — with the workers'
//!   `RouteBuffers` / scratch owned for the process lifetime;
//!   bit-identical to the scoped backend for every worker count.
//! - [`ServeRuntime`] — the **virtual-clock** core: generic over any
//!   [`MoeEngine`] (build one with `Engine::builder()`, hand it to
//!   [`ServeRuntime::with_engine`]), it glues queue + engine together
//!   and keeps the serving telemetry: per-request latency percentiles
//!   (nearest-rank, the same [`percentile_nearest_rank`] convention as
//!   `DispatchSim`) and windowed per-layer `[L, E]` balance stats from
//!   the engine's [`crate::metrics::LayerLoadTracker`].
//! - [`server::Server`] — the **wall-clock** front-end: owns a
//!   `ServeRuntime<Box<dyn MoeEngine>>` plus its own
//!   separately-locked [`BatchQueue`], stamps real `Instant`-derived
//!   microsecond arrivals onto submissions, runs flushes on a
//!   background thread (batches enter the runtime via
//!   [`ServeRuntime::run_batch`], so `enqueue` never waits on a
//!   forward), and exposes blocking `enqueue` / `await_completion` —
//!   the deployable server loop over the same deterministic core.
//! - [`admission::Admission`] — the compiled multi-lane admission
//!   layer in front of either clock: [`admission::AdmissionConfig`]
//!   declares lanes as data (path/tenant/priority match, per-lane
//!   token quota, weight, back-pressure policy), validates into typed
//!   [`admission::AdmissionError`]s like `EngineBuilder`, and compiles
//!   once into a matcher evaluated per request with zero steady-state
//!   allocation. Per-lane stats land in [`ServeReport::lanes`].
//! - [`net::NetServer`] — the dependency-free TCP front-end: a
//!   length-prefixed framing (HTTP/1.1-shaped lines behind the same
//!   [`net::Wire`] trait) feeding `Server::enqueue_with` /
//!   `await_completion`, with admission refusals answered as explicit
//!   503-style responses.
//!
//! # Time model
//!
//! The runtime is event-driven on a **virtual clock** (integer ticks;
//! the bench drivers and the wall-clock [`Server`] use 1 tick = 1 µs).
//! Callers stamp `submit`/`poll` with `now`; a flushed batch *starts*
//! at `max(now, busy_until)` — the engine serves batches in order —
//! and *completes* `service` ticks later, where `service` is the
//! measured wall time of the real engine forward (or a fixed
//! [`ServeConfig::service_ticks`] override, which makes tests fully
//! deterministic). A request's latency is `completion − arrival`:
//! queueing delay, micro-batch wait, pipeline backpressure, and real
//! compute all land in the percentiles, which is what turns
//! arrival-rate sweeps into the queueing-behavior curves the related
//! serving-dispatch work evaluates.
//!
//! [`run_open_loop`] is the single traffic protocol (Poisson arrivals
//! from a seeded [`Rng`] over a [`MixtureStream`]) shared by
//! `serve-bench`, `repro serve`, `benches/micro.rs`, and
//! `examples/serving_sim.rs` — change the measurement protocol here,
//! not per call site. [`measure_engine_rate`] is the capacity
//! calibration: it times the **configured** backend (scoped or pool,
//! any layer count), so load fractions are honest for whichever engine
//! the builder selected.

pub mod admission;
pub mod net;
pub mod pool;
pub mod queue;
pub mod server;

pub use admission::{
    lane_of_id, run_admitted_open_loop, Admission, AdmissionConfig,
    AdmissionError, AdmittedRuntime, AdmitError, BackPressure, LaneSpec,
    LaneStats, PathMatch, RequestMeta, MAX_LANES,
};
pub use net::{
    FrameError, HttpWire, LengthPrefixed, NetRequest, NetResponse,
    NetServer, Status, Wire,
};
pub use pool::PoolEngine;
pub use queue::{BatchMember, BatchQueue, SubmitError};
pub use server::Server;

use crate::data::MixtureStream;
use crate::dispatch::plan::OverflowPolicy;
use crate::engine::{Backend, Engine, MoeEngine};
use crate::experts::ExpertBank;
use crate::metrics::{percentile_nearest_rank, LayerBalance};
use crate::model::{ModelForward, StackedModel};
use crate::router::{FullForward, RouterPlan};
use crate::util::rng::Rng;

/// Configuration of a [`ServeRuntime`].
///
/// The queue/clock fields (`max_batch`, `max_wait`, `queue_tokens`,
/// `service_ticks`) always apply. The engine-side fields (`n_workers`,
/// `capacity_factor`, `policy`, `renormalize`) are consumed only by
/// the deprecated [`ServeRuntime::new`] / [`ServeRuntime::from_model`]
/// shims, which build a pool engine from them — with
/// [`ServeRuntime::with_engine`] that configuration lives on the
/// engine's builder instead.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Persistent pool workers (legacy shims only; must be >= 1 there).
    pub n_workers: usize,
    /// Micro-batch flush size, tokens.
    pub max_batch: usize,
    /// Oldest-request age (ticks) that forces a flush.
    pub max_wait: u64,
    /// Submission-queue bound, tokens (back-pressure past this).
    pub queue_tokens: usize,
    /// Expert capacity factor per batch (legacy shims only).
    pub capacity_factor: f64,
    /// Overflow policy applied at dispatch-plan build (legacy shims
    /// only).
    pub policy: OverflowPolicy,
    /// Renormalize surviving gate weights of partially-dropped tokens
    /// (legacy shims only).
    pub renormalize: bool,
    /// Fixed per-batch service time in ticks; `None` measures the real
    /// engine forward (tests pin this for determinism).
    pub service_ticks: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            n_workers: 1,
            max_batch: 1024,
            max_wait: 2_000,
            queue_tokens: 8_192,
            capacity_factor: 1.25,
            policy: OverflowPolicy::Drop,
            renormalize: false,
            service_ticks: None,
        }
    }
}

/// One finished request, as returned by [`ServeRuntime::poll`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub id: u64,
    pub n_tokens: usize,
    /// Submission → completion, ticks.
    pub latency: u64,
    /// Completion tick.
    pub done_at: u64,
}

/// Aggregate serving telemetry; see [`ServeRuntime::report`].
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub requests: usize,
    pub tokens: usize,
    pub batches: usize,
    /// Submissions refused by the bounded queue (back-pressure).
    pub rejected: usize,
    pub mean_batch_tokens: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    /// Completed tokens over first-arrival → last-completion time.
    pub throughput_tok_per_s: f64,
    /// Rolling routed-load balance over the engine's window — the mean
    /// over MoE layers (the paper's model-level convention; identical
    /// to the single window for one-layer runtimes).
    pub window_gini: f64,
    pub window_min_max: f64,
    pub window_cv: f64,
    /// Layer-resolved rolling balance (`[L, E]` tracking), layer order.
    pub layers: Vec<LayerBalance>,
    /// Per-lane admission stats (empty unless an
    /// [`admission::Admission`] front-end produced this report).
    pub lanes: Vec<LaneStats>,
}

impl ServeReport {
    /// Render this report as one `BENCH_serve.json` row — the single
    /// schema shared by `lpr serve-bench` and the `benches/micro.rs`
    /// serve sweep, so the CI perf artifact cannot fork formats
    /// between emitters.
    pub fn bench_json_row(
        &self,
        policy: OverflowPolicy,
        workers: usize,
        rate_tok_s: f64,
        load: f64,
        req_tokens: usize,
    ) -> String {
        format!(
            "{{\"name\": \"serve/{}\", \"workers\": {}, \
             \"rate_tok_s\": {:.0}, \"load\": {:.2}, \
             \"req_tokens\": {}, \"p50_us\": {:.1}, \
             \"p99_us\": {:.1}, \"mean_us\": {:.1}, \
             \"throughput_tok_s\": {:.0}, \"win_gini\": {:.4}, \
             \"rejected\": {}}}",
            policy.name(),
            workers,
            rate_tok_s,
            load,
            req_tokens,
            self.latency_p50_us,
            self.latency_p99_us,
            self.latency_mean_us,
            self.throughput_tok_per_s,
            self.window_gini,
            self.rejected
        )
    }
}

/// The serving runtime: bounded queue → micro-batcher → engine facade
/// → latency/balance telemetry, generic over the engine
/// ([`MoeEngine`]); the default type parameter is the boxed facade an
/// [`Engine::into_inner`] yields. See the module docs for the time
/// model.
pub struct ServeRuntime<E: MoeEngine = Box<dyn MoeEngine>> {
    cfg: ServeConfig,
    engine: E,
    d_model: usize,
    queue: BatchQueue,
    batch_h: Vec<f32>,
    members: Vec<BatchMember>,
    completions: Vec<Completion>,
    latencies: Vec<f64>,
    latency_sum: f64,
    /// Virtual tick until which the engine is busy with earlier
    /// batches.
    busy_until: u64,
    n_batches: usize,
    tokens_done: usize,
    rejected: usize,
    first_arrival: Option<u64>,
    last_done: u64,
}

impl ServeRuntime<Box<dyn MoeEngine>> {
    /// Single-layer runtime over a pool engine built from the config's
    /// engine-side fields — the PR 3 entry point.
    #[deprecated(
        note = "build an engine with lpr::engine::Engine::builder() and \
                use ServeRuntime::with_engine"
    )]
    #[allow(deprecated)] // a deprecated shim may call its sibling shim
    pub fn new(
        plan: RouterPlan,
        bank: ExpertBank,
        cfg: ServeConfig,
    ) -> ServeRuntime {
        ServeRuntime::from_model(StackedModel::single(plan, bank), cfg)
    }

    /// Whole-stack runtime over a pool engine built from the config's
    /// engine-side fields — the PR 4 entry point. Degenerate legacy
    /// configs keep their documented pre-facade semantics instead of
    /// the builder's typed rejections: `n_workers: 0` is clamped to 1,
    /// and a non-finite/non-positive `capacity_factor` degrades to the
    /// minimum (capacity 1 per expert bin — exactly what
    /// `dispatch::capacity_for` produced for those values).
    #[deprecated(
        note = "build an engine with lpr::engine::Engine::builder() and \
                use ServeRuntime::with_engine"
    )]
    pub fn from_model(model: StackedModel, cfg: ServeConfig) -> ServeRuntime {
        // capacity_for(n, e, cf): (fair·cf).ceil().max(1) — so legacy
        // 0/negative/NaN cf yielded capacity 1 (reproduced by the
        // smallest positive cf) and +inf yielded effectively unlimited
        // bins (reproduced by f64::MAX)
        let cf = cfg.capacity_factor;
        let cf = if cf.is_nan() || cf <= 0.0 {
            f64::MIN_POSITIVE
        } else if cf.is_infinite() {
            f64::MAX
        } else {
            cf
        };
        let engine = Engine::builder()
            .model(model)
            .backend(Backend::Pool { workers: cfg.n_workers.max(1) })
            .policy(cfg.policy)
            .capacity_factor(cf)
            .renormalize(cfg.renormalize)
            .build()
            .expect("a validated StackedModel cannot fail engine build");
        ServeRuntime::with_engine(engine.into_inner(), cfg)
    }
}

impl<E: MoeEngine> ServeRuntime<E> {
    /// The runtime over any engine the builder produced — scoped or
    /// pool, single-layer or stacked (`Engine` itself, its boxed
    /// [`Engine::into_inner`] form, or any other [`MoeEngine`]). Only
    /// the queue/clock fields of `cfg` apply; capacity factor, policy,
    /// and renormalization live on the engine.
    pub fn with_engine(engine: E, cfg: ServeConfig) -> ServeRuntime<E> {
        let d_model = engine.d_model();
        let queue = BatchQueue::new(
            d_model,
            cfg.max_batch,
            cfg.max_wait,
            cfg.queue_tokens,
        );
        ServeRuntime {
            engine,
            d_model,
            queue,
            batch_h: Vec::new(),
            members: Vec::new(),
            completions: Vec::new(),
            latencies: Vec::new(),
            latency_sum: 0.0,
            busy_until: 0,
            n_batches: 0,
            tokens_done: 0,
            rejected: 0,
            first_arrival: None,
            last_done: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The engine behind this runtime.
    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// The engine's rolling routed-load balance window (layer 0).
    pub fn tracker(&self) -> &crate::metrics::LoadTracker {
        self.engine.balance().layer(0)
    }

    /// The engine's per-layer `[L, E]` rolling balance windows.
    pub fn layer_tracker(&self) -> &crate::metrics::LayerLoadTracker {
        self.engine.balance()
    }

    /// The last flushed batch's **layer-0** forward (routed batch,
    /// dispatch plan, combined rows) — request `i` of the batch owns
    /// token rows `members[i].start..start + n_tokens` of `combined`
    /// (and of [`Self::last_model_forward`]'s `hidden`).
    pub fn last_forward(&self) -> &FullForward {
        &self.engine.last().layers[0]
    }

    /// The last flushed batch's whole-stack forward: per-layer pipeline
    /// state plus the final residual stream.
    pub fn last_model_forward(&self) -> &ModelForward {
        self.engine.last()
    }

    /// Members of the last flushed batch, in FIFO order.
    pub fn last_members(&self) -> &[BatchMember] {
        &self.members
    }

    /// Pending tokens in the submission queue.
    pub fn pending_tokens(&self) -> usize {
        self.queue.pending_tokens()
    }

    /// Calibrate this runtime's steady-state service rate (tokens per
    /// second) **through its own engine** — whichever backend the
    /// builder selected — so load fractions derived from it are honest
    /// per backend (the pool-hardcoded free function mis-stated scoped
    /// engines' capacity). Calibration batches bypass the queue and the
    /// latency stats but do enter the engine's rolling balance window;
    /// run it before serving traffic.
    pub fn measure_service_rate(
        &mut self,
        mix: &MixtureStream,
        rng: &mut Rng,
        n_tokens: usize,
        reps: usize,
    ) -> f64 {
        measure_engine_rate(&mut self.engine, mix, rng, n_tokens, reps)
    }

    /// Submit a request of `h.len() / d` token rows at tick `now`.
    /// [`SubmitError::Full`] submissions are counted in
    /// [`ServeReport::rejected`].
    pub fn submit(&mut self, h: &[f32], now: u64) -> Result<u64, SubmitError> {
        match self.queue.submit(h, now) {
            Ok(id) => {
                self.first_arrival.get_or_insert(now);
                Ok(id)
            }
            Err(e) => {
                if e == SubmitError::Full {
                    self.rejected += 1;
                }
                Err(e)
            }
        }
    }

    /// Advance the runtime to tick `now`: flush every micro-batch the
    /// queue considers due and return the requests completed by those
    /// flushes.
    pub fn poll(&mut self, now: u64) -> &[Completion] {
        self.completions.clear();
        while self.queue.ready(now) {
            self.flush_one(now);
        }
        &self.completions
    }

    /// Flush everything still queued (end of a run), regardless of the
    /// flush conditions.
    pub fn drain(&mut self, now: u64) -> &[Completion] {
        self.completions.clear();
        while !self.queue.is_empty() {
            self.flush_one(now);
        }
        &self.completions
    }

    /// Run one externally-popped micro-batch (`batch_h` rows plus the
    /// member slices a caller-owned [`BatchQueue::pop_batch`] produced)
    /// at tick `now`, with exactly the same service-time and latency
    /// accounting as an internally-flushed batch; returns the requests
    /// it completed. This is the wall-clock [`Server`]'s entry point:
    /// it keeps its submission queue behind a separate lock so
    /// `enqueue` lands while a forward holds the runtime, and feeds the
    /// popped batches through here.
    pub fn run_batch(
        &mut self,
        batch_h: &[f32],
        members: &[BatchMember],
        now: u64,
    ) -> &[Completion] {
        assert!(!members.is_empty(), "run_batch on an empty batch");
        self.completions.clear();
        self.batch_h.clear();
        self.batch_h.extend_from_slice(batch_h);
        self.members.clear();
        self.members.extend_from_slice(members);
        // batches pop FIFO, so the first member of the first external
        // batch carries the stream's first arrival
        let arrival = members[0].arrival;
        let fa = self.first_arrival.get_or_insert(arrival);
        *fa = (*fa).min(arrival);
        self.forward_current(now);
        &self.completions
    }

    fn flush_one(&mut self, now: u64) {
        self.queue.pop_batch(&mut self.batch_h, &mut self.members);
        self.forward_current(now);
    }

    /// Forward `self.batch_h` / `self.members` (however they were
    /// filled) and record completions against the virtual clock.
    fn forward_current(&mut self, now: u64) {
        let n = self.batch_h.len() / self.d_model;
        let t0 = std::time::Instant::now();
        self.engine.forward(&self.batch_h, n);
        let measured_us = (t0.elapsed().as_nanos() / 1_000).max(1) as u64;
        let service = self.cfg.service_ticks.unwrap_or(measured_us);
        // the engine serves batches in order: this batch starts when
        // the previous one finished (or now, if the engine sat idle)
        let start = now.max(self.busy_until);
        let done = start + service;
        self.busy_until = done;
        self.last_done = self.last_done.max(done);
        for m in &self.members {
            let latency = done.saturating_sub(m.arrival);
            self.latencies.push(latency as f64);
            self.latency_sum += latency as f64;
            self.tokens_done += m.n_tokens;
            self.completions.push(Completion {
                id: m.id,
                n_tokens: m.n_tokens,
                latency,
                done_at: done,
            });
        }
        self.n_batches += 1;
    }

    /// Aggregate latency/throughput/balance telemetry for everything
    /// served so far.
    pub fn report(&self) -> ServeReport {
        let mut lat = self.latencies.clone();
        lat.sort_by(f64::total_cmp);
        let requests = lat.len();
        let elapsed_us = self
            .last_done
            .saturating_sub(self.first_arrival.unwrap_or(0))
            .max(1);
        let balance = self.engine.balance();
        ServeReport {
            requests,
            tokens: self.tokens_done,
            batches: self.n_batches,
            rejected: self.rejected,
            mean_batch_tokens: self.tokens_done as f64
                / self.n_batches.max(1) as f64,
            latency_mean_us: self.latency_sum / requests.max(1) as f64,
            latency_p50_us: percentile_nearest_rank(&lat, 0.5),
            latency_p99_us: percentile_nearest_rank(&lat, 0.99),
            throughput_tok_per_s: if requests == 0 {
                0.0
            } else {
                self.tokens_done as f64 / (elapsed_us as f64 * 1e-6)
            },
            window_gini: balance.mean_gini(),
            window_min_max: balance.mean_min_max(),
            window_cv: balance.mean_cv(),
            layers: balance.per_layer(),
            lanes: Vec::new(),
        }
    }
}

/// Drive `n_requests` open-loop requests of `req_tokens` tokens through
/// `runtime`: Poisson arrivals at `rate_tok_per_s` (virtual tokens per
/// second, 1 tick = 1 µs), tokens sampled from `mix`, queue-full
/// submissions counted as rejected (no retry), and a final drain. The
/// single traffic protocol behind `serve-bench`, `repro serve`, the
/// micro benches, and the serving example.
pub fn run_open_loop<E: MoeEngine>(
    runtime: &mut ServeRuntime<E>,
    mix: &MixtureStream,
    rng: &mut Rng,
    n_requests: usize,
    req_tokens: usize,
    rate_tok_per_s: f64,
) {
    assert!(rate_tok_per_s > 0.0, "arrival rate must be positive");
    // a TooLarge request can never flush; every submission would be
    // silently discarded (neither completed nor rejected), zeroing the
    // whole report — refuse the misconfiguration loudly instead
    assert!(
        req_tokens <= runtime.config().max_batch,
        "req_tokens {req_tokens} exceeds max_batch {} — requests \
         would never fit a micro-batch",
        runtime.config().max_batch
    );
    let mean_gap_us = req_tokens as f64 / rate_tok_per_s * 1e6;
    let mut h = Vec::new();
    let mut now = 0u64;
    for _ in 0..n_requests {
        // exponential inter-arrival: -ln(1 - U) * mean, U in [0, 1)
        let gap = (-(1.0 - rng.f64()).ln() * mean_gap_us).max(1.0);
        now += gap as u64;
        runtime.poll(now);
        mix.fill(rng, req_tokens, &mut h);
        let _ = runtime.submit(&h, now);
    }
    runtime.drain(now);
}

/// Measure an engine's steady-state forward service rate (tokens per
/// second) over `reps` batches of `n_tokens` — through **whichever
/// backend and stack the builder selected**, so multi-layer and scoped
/// runtimes calibrate against their real cost. The calibration
/// `serve`, `serve-bench`, and `repro serve` use to express arrival
/// rates as load fractions of this machine's capacity, so rate sweeps
/// saturate on every box instead of only on the one they were tuned
/// on.
pub fn measure_engine_rate<E: MoeEngine + ?Sized>(
    engine: &mut E,
    mix: &MixtureStream,
    rng: &mut Rng,
    n_tokens: usize,
    reps: usize,
) -> f64 {
    let mut h = Vec::new();
    mix.fill(rng, n_tokens, &mut h);
    engine.forward(&h, n_tokens); // warm
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        mix.fill(rng, n_tokens, &mut h);
        let t0 = std::time::Instant::now();
        engine.forward(&h, n_tokens);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    n_tokens as f64 / best.max(1e-9)
}

/// Pool-only calibration kept for compatibility; it cannot see scoped
/// backends, which is exactly the bug [`measure_engine_rate`] fixes.
#[deprecated(
    note = "use measure_engine_rate (or ServeRuntime::measure_service_rate) \
            — this path hard-assumes the pool backend"
)]
#[allow(clippy::too_many_arguments)]
pub fn measure_service_rate(
    pool: &mut PoolEngine,
    mix: &MixtureStream,
    rng: &mut Rng,
    n_tokens: usize,
    reps: usize,
    capacity_factor: f64,
    policy: OverflowPolicy,
) -> f64 {
    let mut h = Vec::new();
    let mut out = ModelForward::new();
    mix.fill(rng, n_tokens, &mut h);
    pool.forward_model(&h, capacity_factor, policy, &mut out); // warm
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        mix.fill(rng, n_tokens, &mut h);
        let t0 = std::time::Instant::now();
        pool.forward_model(&h, capacity_factor, policy, &mut out);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    n_tokens as f64 / best.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::{synthetic_lpr_router, ServingEngine};

    fn tiny_setup(
        seed: u64,
    ) -> (crate::router::Router, ExpertBank, MixtureStream, Rng) {
        let mut rng = Rng::new(seed);
        let (d, dz, e, k) = (8usize, 4, 4, 2);
        let r = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(9), e, d, 6);
        let mix = MixtureStream::standard(&mut rng, d);
        (r, bank, mix, rng)
    }

    /// Facade-built pool runtime over a single layer, engine-side
    /// options on the builder.
    fn facade_runtime(
        plan: RouterPlan,
        bank: ExpertBank,
        cfg: ServeConfig,
        policy: OverflowPolicy,
        cf: f64,
    ) -> ServeRuntime {
        let engine = Engine::builder()
            .layer(plan, bank)
            .backend(Backend::Pool { workers: cfg.n_workers })
            .policy(policy)
            .capacity_factor(cf)
            .build()
            .unwrap();
        ServeRuntime::with_engine(engine.into_inner(), cfg)
    }

    /// Deterministic latency accounting on the virtual clock: queue
    /// wait, micro-batch flush rules, and pipeline backpressure all
    /// land in per-request latencies exactly.
    #[test]
    fn latency_accounting_is_exact_on_virtual_clock() {
        let (r, bank, mix, mut rng) = tiny_setup(1);
        let cfg = ServeConfig {
            n_workers: 1,
            max_batch: 4,
            max_wait: 10,
            queue_tokens: 64,
            service_ticks: Some(7),
            ..ServeConfig::default()
        };
        let mut rt = facade_runtime(
            r.plan().clone(),
            bank,
            cfg,
            OverflowPolicy::Drop,
            1.25,
        );
        let mut h = Vec::new();
        // r0 (2 tokens) at t=0: below max_batch, not aged — no flush
        mix.fill(&mut rng, 2, &mut h);
        let r0 = rt.submit(&h, 0).unwrap();
        assert!(rt.poll(0).is_empty());
        assert!(rt.poll(9).is_empty(), "age 9 < max_wait 10");
        // r1 (2 tokens) at t=9 fills the batch: flush on that poll
        mix.fill(&mut rng, 2, &mut h);
        let r1 = rt.submit(&h, 9).unwrap();
        let done: Vec<Completion> = rt.poll(9).to_vec();
        assert_eq!(done.len(), 2);
        // batch starts at t=9 (engine idle), completes at 9 + 7 = 16
        assert_eq!(done[0], Completion { id: r0, n_tokens: 2, latency: 16, done_at: 16 });
        assert_eq!(done[1], Completion { id: r1, n_tokens: 2, latency: 7, done_at: 16 });
        // r2 (1 token) at t=11: flushes only once aged out at t=21,
        // and the engine is free by then (busy_until = 16)
        mix.fill(&mut rng, 1, &mut h);
        let r2 = rt.submit(&h, 11).unwrap();
        assert!(rt.poll(20).is_empty());
        let done: Vec<Completion> = rt.poll(21).to_vec();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0], Completion { id: r2, n_tokens: 1, latency: 17, done_at: 28 });
        // r3 at t=22 drains immediately but queues behind busy_until=28
        mix.fill(&mut rng, 1, &mut h);
        let r3 = rt.submit(&h, 22).unwrap();
        let done: Vec<Completion> = rt.drain(22).to_vec();
        assert_eq!(done[0], Completion { id: r3, n_tokens: 1, latency: 13, done_at: 35 });
        let rep = rt.report();
        assert_eq!(rep.requests, 4);
        assert_eq!(rep.tokens, 6);
        assert_eq!(rep.batches, 3);
        assert_eq!(rep.rejected, 0);
        // nearest-rank over sorted [7, 13, 16, 17]
        assert_eq!(rep.latency_p50_us, 13.0);
        assert_eq!(rep.latency_p99_us, 17.0);
    }

    /// The runtime's combined output for a flushed batch equals the
    /// scoped engine's forward over the same concatenated tokens.
    #[test]
    #[allow(deprecated)] // the scoped forward_full is the parity oracle
    fn flushed_batch_matches_scoped_engine_forward() {
        let (r, bank, mix, mut rng) = tiny_setup(2);
        let d = 8usize;
        let cfg = ServeConfig {
            n_workers: 2,
            max_batch: 8,
            max_wait: 100,
            queue_tokens: 64,
            service_ticks: Some(1),
            ..ServeConfig::default()
        };
        let mut rt = facade_runtime(
            r.plan().clone(),
            bank.clone(),
            cfg,
            OverflowPolicy::LeastLoaded,
            1.25,
        );
        let (mut a, mut b) = (Vec::new(), Vec::new());
        mix.fill(&mut rng, 3, &mut a);
        mix.fill(&mut rng, 5, &mut b);
        rt.submit(&a, 0).unwrap();
        rt.submit(&b, 1).unwrap();
        let done = rt.poll(1).to_vec();
        assert_eq!(done.len(), 2);
        let mut h = a.clone();
        h.extend_from_slice(&b);
        let mut scoped = ServingEngine::new(r.plan().clone(), 1);
        let mut want = FullForward::new();
        scoped.forward_full(
            &h,
            &bank,
            1.25,
            OverflowPolicy::LeastLoaded,
            &mut want,
        );
        assert_eq!(rt.last_forward().combined, want.combined);
        // member slices address the combined rows per request
        let m = rt.last_members();
        assert_eq!((m[0].start, m[0].n_tokens), (0, 3));
        assert_eq!((m[1].start, m[1].n_tokens), (3, 5));
        assert_eq!(rt.last_forward().combined.len(), 8 * d);
    }

    /// A multi-layer runtime serves whole-stack forwards: the flushed
    /// batch's residual stream equals a scoped facade engine over the
    /// same concatenated tokens, and the report resolves per-layer
    /// balance.
    #[test]
    fn model_runtime_matches_scoped_stack_and_reports_layers() {
        use crate::model::synthetic_stacked_model;
        let (d, n_layers) = (8usize, 3usize);
        let mut rng = Rng::new(6);
        let model = synthetic_stacked_model(
            "cosine",
            &Rng::new(4),
            n_layers,
            d,
            4,
            4,
            2,
            6,
        );
        let mix = MixtureStream::standard(&mut rng, d);
        let cfg = ServeConfig {
            max_batch: 8,
            max_wait: 100,
            queue_tokens: 64,
            service_ticks: Some(1),
            ..ServeConfig::default()
        };
        let pool = Engine::builder()
            .model(model.clone())
            .backend(Backend::Pool { workers: 2 })
            .build()
            .unwrap();
        let mut rt = ServeRuntime::with_engine(pool.into_inner(), cfg);
        // valid (empty) before the first flush — the PR 3 contract
        assert!(rt.last_forward().combined.is_empty());
        assert!(rt.last_model_forward().hidden.is_empty());
        let (mut a, mut b) = (Vec::new(), Vec::new());
        mix.fill(&mut rng, 3, &mut a);
        mix.fill(&mut rng, 5, &mut b);
        rt.submit(&a, 0).unwrap();
        rt.submit(&b, 1).unwrap();
        assert_eq!(rt.poll(1).len(), 2);
        let mut h = a.clone();
        h.extend_from_slice(&b);
        let mut scoped = Engine::builder()
            .model(model)
            .backend(Backend::Scoped { threads: 1 })
            .build()
            .unwrap();
        let want = scoped.forward(&h, 8);
        assert_eq!(rt.last_model_forward().hidden, want.hidden);
        assert_eq!(rt.last_forward().combined, want.layers[0].combined);
        let rep = rt.report();
        assert_eq!(rep.layers.len(), n_layers);
        // mean-over-layers aggregation matches the layer rows
        let mean: f64 = rep.layers.iter().map(|l| l.gini).sum::<f64>()
            / n_layers as f64;
        assert!((rep.window_gini - mean).abs() < 1e-12);
        assert_eq!(rt.layer_tracker().n_layers(), n_layers);
    }

    /// The deprecated constructors are thin shims over the facade:
    /// outputs stay bit-identical to the builder path.
    #[test]
    #[allow(deprecated)]
    fn legacy_constructors_match_facade_runtime() {
        let (r, bank, mix, mut rng) = tiny_setup(11);
        let cfg = ServeConfig {
            n_workers: 2,
            max_batch: 8,
            max_wait: 100,
            queue_tokens: 64,
            service_ticks: Some(3),
            policy: OverflowPolicy::NextChoice,
            capacity_factor: 1.0,
            ..ServeConfig::default()
        };
        let mut legacy =
            ServeRuntime::new(r.plan().clone(), bank.clone(), cfg.clone());
        let mut facade = facade_runtime(
            r.plan().clone(),
            bank,
            cfg,
            OverflowPolicy::NextChoice,
            1.0,
        );
        let mut h = Vec::new();
        mix.fill(&mut rng, 8, &mut h);
        legacy.submit(&h, 0).unwrap();
        facade.submit(&h, 0).unwrap();
        assert_eq!(legacy.poll(0).to_vec(), facade.poll(0).to_vec());
        assert_eq!(
            legacy.last_forward().combined,
            facade.last_forward().combined
        );
        assert_eq!(
            legacy.last_model_forward().hidden,
            facade.last_model_forward().hidden
        );
    }

    #[test]
    fn bench_json_row_is_valid_and_stable() {
        let rep = ServeReport {
            requests: 2,
            tokens: 8,
            latency_p50_us: 5.0,
            latency_p99_us: 9.0,
            throughput_tok_per_s: 1234.0,
            ..ServeReport::default()
        };
        let row =
            rep.bench_json_row(OverflowPolicy::NextChoice, 2, 1000.0, 0.5, 4);
        let j = crate::util::json::Json::parse(&row).unwrap();
        assert_eq!(j.at("name").as_str(), Some("serve/next-choice"));
        assert_eq!(j.at("workers").as_f64(), Some(2.0));
        assert_eq!(j.at("p50_us").as_f64(), Some(5.0));
        assert_eq!(j.at("throughput_tok_s").as_f64(), Some(1234.0));
    }

    #[test]
    fn bounded_queue_counts_rejections() {
        let (r, bank, mix, mut rng) = tiny_setup(3);
        let cfg = ServeConfig {
            n_workers: 1,
            max_batch: 4,
            max_wait: 1_000_000, // never age-flush
            queue_tokens: 4,
            service_ticks: Some(1),
            ..ServeConfig::default()
        };
        let mut rt = facade_runtime(
            r.plan().clone(),
            bank,
            cfg,
            OverflowPolicy::Drop,
            1.25,
        );
        let mut h = Vec::new();
        mix.fill(&mut rng, 3, &mut h);
        rt.submit(&h, 0).unwrap();
        mix.fill(&mut rng, 2, &mut h);
        assert_eq!(rt.submit(&h, 1), Err(SubmitError::Full));
        assert_eq!(rt.report().rejected, 1);
        rt.drain(2);
        assert_eq!(rt.report().requests, 1);
    }

    /// Open-loop smoke: the shared traffic driver conserves requests
    /// and produces a coherent report under a fixed service time.
    #[test]
    fn open_loop_driver_serves_all_accepted_requests() {
        let (r, bank, mix, mut rng) = tiny_setup(4);
        let cfg = ServeConfig {
            n_workers: 2,
            max_batch: 16,
            max_wait: 50,
            queue_tokens: 256,
            service_ticks: Some(5),
            ..ServeConfig::default()
        };
        let mut rt = facade_runtime(
            r.plan().clone(),
            bank,
            cfg,
            OverflowPolicy::Drop,
            1.25,
        );
        run_open_loop(&mut rt, &mix, &mut rng, 40, 4, 1_000_000.0);
        let rep = rt.report();
        assert_eq!(rep.requests + rep.rejected, 40);
        assert_eq!(rep.tokens, rep.requests * 4);
        assert!(rep.batches >= 1);
        assert!(rep.latency_p50_us >= 5.0, "at least the service time");
        assert!(rep.latency_p99_us >= rep.latency_p50_us);
        assert!(rep.throughput_tok_per_s > 0.0);
        assert!(rep.window_gini >= 0.0);
        // every batch respected max_batch
        assert!(rep.mean_batch_tokens <= 16.0);
    }

    /// Satellite: calibration runs through whichever backend the
    /// builder selected — a scoped runtime measures its own engine,
    /// not a hard-coded pool.
    #[test]
    fn measure_service_rate_uses_the_configured_backend() {
        let (r, bank, mix, mut rng) = tiny_setup(5);
        for backend in
            [Backend::Scoped { threads: 1 }, Backend::Pool { workers: 2 }]
        {
            let engine = Engine::builder()
                .layer(r.plan().clone(), bank.clone())
                .backend(backend)
                .build()
                .unwrap();
            let mut rt = ServeRuntime::with_engine(
                engine.into_inner(),
                ServeConfig { max_batch: 16, ..ServeConfig::default() },
            );
            let rate = rt.measure_service_rate(&mix, &mut rng, 16, 2);
            assert!(
                rate.is_finite() && rate > 0.0,
                "{backend:?}: bad rate {rate}"
            );
            // the calibration really drove this runtime's engine
            assert!(rt.tracker().total_steps() >= 3);
            // and the runtime still serves normally afterwards
            let mut h = Vec::new();
            mix.fill(&mut rng, 4, &mut h);
            rt.submit(&h, 0).unwrap();
            assert_eq!(rt.drain(0).len(), 1);
        }
    }
}
