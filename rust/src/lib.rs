//! # lpr — Latent Prototype Routing, reproduced as a three-layer stack
//!
//! Reproduction of *"Latent Prototype Routing: Achieving Near-Perfect
//! Load Balancing in Mixture-of-Experts"* (Yang, 2025) as a
//! Rust + JAX + Pallas system:
//!
//! - **L1/L2 (build time, python)** — Pallas MoE kernels + JAX model,
//!   AOT-lowered to HLO text under `artifacts/` (`make artifacts`).
//! - **L3 (this crate)** — the runtime coordinator: PJRT execution with
//!   device-resident training state, data pipeline, load-balance
//!   metrics, an expert-parallel dispatch simulator, a compiled
//!   pure-Rust serving router, and the experiment harness reproducing
//!   every table/figure of the paper.
//!
//! The serving hot path is a compile-then-route design:
//! [`router::RouterPlan`] precompiles parameters (projected prototypes,
//! fused score kernel, prototype-side constants) and routes batches
//! into flat `[N*k]` buffers with zero steady-state allocation;
//! [`router::ServingEngine`] shards batches across scoped worker
//! threads with bit-identical outputs for every thread count (the
//! thread-determinism contract is documented in `router::engine`). The
//! flat id buffer feeds [`dispatch::DispatchSim`] directly.
//!
//! Start with [`runtime::Runtime`] + [`coordinator::Trainer`] for
//! training, [`router::RouterPlan`] + [`router::ServingEngine`] +
//! [`dispatch::DispatchSim`] for serving-path studies
//! ([`router::Router`] remains as a compatibility façade), and
//! [`report::Reporter`] for the paper's experiments. See `examples/`
//! for end-to-end drivers.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod dispatch;
pub mod metrics;
pub mod report;
pub mod router;
pub mod runtime;
pub mod util;

/// Default artifacts directory (relative to the repo root); override
/// with env `LPR_ARTIFACTS`.
pub fn default_art_dir() -> std::path::PathBuf {
    std::env::var("LPR_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Default results directory for experiment reports; override with env
/// `LPR_RESULTS`.
pub fn default_out_dir() -> std::path::PathBuf {
    std::env::var("LPR_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}
