//! # lpr — Latent Prototype Routing, reproduced as a three-layer stack
//!
//! Reproduction of *"Latent Prototype Routing: Achieving Near-Perfect
//! Load Balancing in Mixture-of-Experts"* (Yang, 2025) as a
//! Rust + JAX + Pallas system:
//!
//! - **L1/L2 (build time, python)** — Pallas MoE kernels + JAX model,
//!   AOT-lowered to HLO text under `artifacts/` (`make artifacts`).
//! - **L3 (this crate)** — the runtime coordinator: PJRT execution with
//!   device-resident training state, data pipeline, load-balance
//!   metrics, an expert-parallel dispatch simulator, a compiled
//!   pure-Rust serving router, and the experiment harness reproducing
//!   every table/figure of the paper.
//!
//! The serving hot path is a compile-then-route-then-dispatch design:
//!
//! 1. **route** — [`router::RouterPlan`] precompiles parameters
//!    (projected prototypes, fused score kernel, prototype-side
//!    constants) and routes batches into flat `[N*k]` buffers with zero
//!    steady-state allocation; [`router::ServingEngine`] shards batches
//!    across scoped worker threads with bit-identical outputs for every
//!    thread count (the thread-determinism contract is documented in
//!    `router::engine`).
//! 2. **plan** — the routed batch compiles into a
//!    [`dispatch::DispatchPlan`]: capacity-binned per-expert buckets in
//!    the grouped-GEMM scatter/gather layout, with a pluggable
//!    [`dispatch::OverflowPolicy`] (greedy drop / next-choice
//!    fall-through / least-loaded reroute) applied at plan build.
//! 3. **compute** — [`experts::ExpertBank`] runs real dense FFN expert
//!    shards over the plan's contiguous buckets (sharded across the
//!    engine's threads, still bit-identical).
//! 4. **combine** — gate-weighted accumulation back into token order
//!    (`router::FullForward::combined`); dropped slots fall through to
//!    the residual stream (or, with `--renormalize`, a token's
//!    surviving gate weights are rescaled to its pre-drop mass).
//!
//! The [`model`] layer stacks `L` of those per-layer pipelines into a
//! served **model**: [`model::StackedModel`] holds one compiled
//! `RouterPlan` + `ExpertBank` per layer, [`model::ModelEngine`] /
//! [`serve::PoolEngine::forward_model`] run them in order with layer
//! ℓ's residual output feeding layer ℓ+1 (bit-identical for every
//! thread/worker count, stack-wide), and [`model::bridge`] builds the
//! stack from real training output — `coordinator::checkpoint` +
//! `runtime::ArtifactMeta` → per-layer `RouterParams`/`ExpertBank`,
//! pure Rust, no PJRT. Per-layer balance lands in
//! [`metrics::LayerLoadTracker`] (`[L, E]` rolling windows), exactly
//! the per-layer Gini/min-max resolution the paper reports.
//!
//! The [`serve`] module turns that per-batch pipeline into a
//! **serving runtime**: [`serve::BatchQueue`] micro-batches a bounded
//! stream of requests (flush on `max_batch` tokens or `max_wait`
//! virtual-clock ticks), [`serve::PoolEngine`] runs the full path —
//! single layer or whole stack — on a *persistent* channel-fed worker
//! pool (no per-batch thread spawns; bit-identical to the scoped
//! engine for every worker count), and [`serve::ServeRuntime`] records
//! per-request latency percentiles plus windowed per-layer balance
//! stats.
//!
//! [`dispatch::DispatchSim`] consumes the *same* plans for its latency
//! model, so simulated accounting and real compute agree by
//! construction; [`metrics::LoadTracker`] gives both a rolling
//! balance window.
//!
//! Since PR 5 the whole forward surface sits behind **one facade**:
//! [`engine::MoeEngine`], implemented by the scoped and pool backends
//! for single layers and stacks alike, constructed only through
//! [`engine::Engine::builder`] (typed [`engine::EngineBuildError`]s
//! instead of panics, every knob — backend, overflow policy, capacity
//! factor, renormalization, GEMM kernel and weight dtype — in one
//! place). The FFN matmuls themselves live in [`kernels`]: naive /
//! cache-blocked / `simd`-feature AVX2 micro-kernels plus bf16 and
//! int8 quantized weight storage, selected per engine via
//! `Engine::builder().kernel(...)` / `.weight_dtype(...)`. [`serve::Server`] makes
//! the virtual-clock runtime deployable: real `Instant`-stamped
//! arrivals, a background flusher thread, blocking
//! `enqueue`/`await_completion`. Typed errors share one conversion
//! point, [`Error`].
//!
//! In front of the server sits a **network + admission layer**:
//! [`serve::AdmissionConfig`] declares per-tenant/path/priority lanes
//! as data (each lane with its own token quota, flush weight, and
//! shed/spill back-pressure), validates into typed
//! [`serve::AdmissionError`]s like the builder, and compiles once into
//! a matcher tree ([`serve::Admission`]) evaluated per request with
//! zero steady-state allocation — property-tested bit-equal to its
//! naive first-match reference and pinned by a fixture-driven
//! conformance suite (`rust/tests/fixtures/admission/`).
//! [`serve::NetServer`] is the dependency-free TCP front-end feeding
//! [`serve::Server`] over a [`serve::Wire`] — native length-prefixed
//! framing or HTTP/1.1-shaped request lines — answering admission
//! refusals with explicit 503-style statuses while priority lanes
//! keep bounded latency under overload (`lpr listen`).
//!
//! Start with [`runtime::Runtime`] + [`coordinator::Trainer`] for
//! training, [`engine::Engine::builder`] + [`serve::ServeRuntime`] /
//! [`serve::Server`] + [`dispatch::DispatchSim`] for serving-path
//! studies (the pre-facade entry points — `Router::forward`,
//! `ServingEngine::forward_full`, `PoolEngine::forward_full`,
//! `ServeRuntime::new` — remain as deprecated shims), and
//! [`report::Reporter`] for the paper's experiments. See `examples/`
//! for end-to-end drivers.
//!
//! A layered map of the whole crate — module dependencies, the
//! grouped-GEMM layout with a worked example, the thread-determinism
//! contract, and where every `BENCH_*.json` / `repro` artifact comes
//! from — lives in
//! [docs/ARCHITECTURE.md](../../docs/ARCHITECTURE.md) at the repo
//! root.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod dispatch;
pub mod engine;
pub mod error;
pub mod experts;
pub mod kernels;
pub mod metrics;
pub mod model;
pub mod report;
pub mod router;
pub mod runtime;
pub mod serve;
pub mod util;

pub use error::Error;

/// Default artifacts directory (relative to the repo root); override
/// with env `LPR_ARTIFACTS`.
pub fn default_art_dir() -> std::path::PathBuf {
    std::env::var("LPR_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Default results directory for experiment reports; override with env
/// `LPR_RESULTS`.
pub fn default_out_dir() -> std::path::PathBuf {
    std::env::var("LPR_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}
