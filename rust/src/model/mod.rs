//! Multi-layer model serving: a stack of per-layer MoE blocks behind
//! one forward pipeline.
//!
//! PRs 1–3 built the full per-batch data path (route → [`DispatchPlan`]
//! → expert FFN → combine) and a serving runtime around it — but always
//! for exactly **one** router layer and one expert bank, while the
//! trainer's artifacts carry `[L, E]` load shapes and per-layer router
//! leaves. This module serves the model the trainer actually trains:
//!
//! - [`MoeLayer`] — one layer's compiled [`RouterPlan`] plus its
//!   [`ExpertBank`];
//! - [`StackedModel`] — `L` layers with a uniform `d_model`, validated
//!   at construction;
//! - [`ModelForward`] — reusable output/scratch of a stacked forward:
//!   one [`FullForward`] per layer plus the final `[N, d]` residual
//!   stream;
//! - [`ModelEngine`] — the scoped-thread execution path: one
//!   [`ServingEngine`] per layer, layer ℓ's residual output feeding
//!   layer ℓ+1 ([`residual_add`]); per-layer balance lands in a
//!   [`LayerLoadTracker`].
//!
//! The persistent-pool twin is [`crate::serve::PoolEngine::forward_model`],
//! which runs the same stack on long-lived workers and is bit-identical
//! to [`ModelEngine::forward`] for every worker count (pinned by
//! `pool_forward_model_matches_scoped` in `serve::pool` and the bridge
//! acceptance test in [`bridge`]).
//!
//! # Residual semantics
//!
//! Layer ℓ's output is `h_{ℓ+1} = h_ℓ + combined_ℓ` — the gate-weighted
//! MoE output added back onto the residual stream, elementwise in token
//! order. Dropped slots contribute nothing to `combined`, so a dropped
//! token's row passes through unchanged — exactly the capacity-factor
//! training semantics (`python/compile/moe.py`). A layer may also carry
//! a pre-norm causal attention sublayer ([`attention::AttnBlock`]) that
//! runs *before* its MoE block — `h += attn(norm(h))`, then
//! `h += moe(h)` — reading and appending per-request keys/values in a
//! [`cache::KvCache`] slot ([`ModelEngine::forward_seqs`]); `combined`
//! per layer stays observable in [`ModelForward::layers`] for the
//! telemetry either way.
//!
//! # Determinism
//!
//! Each layer's forward is the PR 2/3 pipeline, bit-identical across
//! thread counts; the residual add is a fixed elementwise walk on the
//! caller's thread. A stack of deterministic layers composed through a
//! deterministic add is deterministic, so the **whole-stack** output is
//! bit-identical for every thread/worker count and equals hand-composing
//! `L` single-layer `forward_full` calls (pinned by
//! `model_forward_matches_hand_composed_layers` and
//! `model_forward_bit_identical_across_thread_counts` below).
//!
//! The checkpoint → model bridge (`coordinator::checkpoint` +
//! `runtime::ArtifactMeta` → [`StackedModel`], no PJRT needed) lives in
//! [`bridge`].

pub mod attention;
pub mod bridge;
pub mod cache;

use attention::{synthetic_attn, AttnBlock, AttnScratch};
use cache::{KvCache, SeqSpan};

use crate::data::MixtureStream;
use crate::dispatch::plan::OverflowPolicy;
use crate::dispatch::{DispatchPlan, DispatchSim};
use crate::experts::ExpertBank;
use crate::metrics::{LayerLoadTracker, DEFAULT_LOAD_WINDOW};
use crate::router::linalg::rms_norm_rows_into;
use crate::router::{
    synthetic_lpr_router, FullForward, RouterPlan, ServingEngine,
};
use crate::util::rng::Rng;

/// One layer of a served model: its compiled router plan, its expert
/// bank, and (for decoder stacks) the causal attention sublayer that
/// precedes the MoE block. Construction validates that the pieces agree
/// on `d_model` and expert count.
#[derive(Debug, Clone)]
pub struct MoeLayer {
    pub plan: RouterPlan,
    pub bank: ExpertBank,
    /// Pre-norm causal self-attention, run before the MoE block.
    /// `None` for the MoE-only stacks of PRs 1–9, which serve
    /// bit-identically to before.
    pub attn: Option<AttnBlock>,
}

impl MoeLayer {
    pub fn new(plan: RouterPlan, bank: ExpertBank) -> MoeLayer {
        assert_eq!(
            plan.cfg.d_model, bank.d_model,
            "layer plan/bank d_model mismatch"
        );
        assert_eq!(
            plan.cfg.n_experts, bank.n_experts,
            "layer plan/bank expert count mismatch"
        );
        MoeLayer { plan, bank, attn: None }
    }

    /// A layer with an optional attention sublayer in front of the MoE
    /// block.
    pub fn with_attn(
        plan: RouterPlan,
        bank: ExpertBank,
        attn: Option<AttnBlock>,
    ) -> MoeLayer {
        let mut layer = MoeLayer::new(plan, bank);
        if let Some(a) = &attn {
            assert_eq!(
                a.d_model(),
                layer.plan.cfg.d_model,
                "layer attn d_model mismatch"
            );
        }
        layer.attn = attn;
        layer
    }
}

/// `L` MoE layers with a uniform `d_model` (the residual stream ties
/// them together). Expert count / top-k / metric may vary per layer —
/// the bridge builds whatever the checkpoint holds.
#[derive(Debug, Clone)]
pub struct StackedModel {
    layers: Vec<MoeLayer>,
}

impl StackedModel {
    pub fn new(layers: Vec<MoeLayer>) -> StackedModel {
        assert!(!layers.is_empty(), "a model needs at least one layer");
        let d = layers[0].plan.cfg.d_model;
        for (l, layer) in layers.iter().enumerate() {
            assert_eq!(
                layer.plan.cfg.d_model, d,
                "layer {l} d_model differs from layer 0 — the residual \
                 stream needs one width"
            );
        }
        StackedModel { layers }
    }

    /// The single-layer model behind the PR 1–3 serving paths — the
    /// compatibility constructor `PoolEngine::new` / `ServeRuntime::new`
    /// still build through.
    pub fn single(plan: RouterPlan, bank: ExpertBank) -> StackedModel {
        StackedModel::new(vec![MoeLayer::new(plan, bank)])
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn d_model(&self) -> usize {
        self.layers[0].plan.cfg.d_model
    }

    pub fn layer(&self, l: usize) -> &MoeLayer {
        &self.layers[l]
    }

    pub fn layers(&self) -> &[MoeLayer] {
        &self.layers
    }

    pub fn into_layers(self) -> Vec<MoeLayer> {
        self.layers
    }

    /// True when any layer carries an attention sublayer (i.e. the
    /// stack is a decoder and plain forwards run through the internal
    /// prefill cache).
    pub fn has_attn(&self) -> bool {
        self.layers.iter().any(|l| l.attn.is_some())
    }
}

/// Deterministic synthetic `L`-layer model: one [`synthetic_lpr_router`]
/// and one [`ExpertBank`] per layer, each layer drawing from its own
/// `rng.fold(layer)` child stream so layer `l`'s parameters depend only
/// on `(seed, l)`. The shared builder behind `lpr serve synthetic`,
/// `model-sim`, `repro model-serve`, the model benches, and the tests.
#[allow(clippy::too_many_arguments)]
pub fn synthetic_stacked_model(
    metric: &str,
    rng: &Rng,
    n_layers: usize,
    d: usize,
    dz: usize,
    e: usize,
    k: usize,
    d_ff: usize,
) -> StackedModel {
    let layers = (0..n_layers)
        .map(|l| {
            let mut lr = rng.fold(l as u64);
            let router = synthetic_lpr_router(metric, &mut lr, d, dz, e, k);
            let bank = ExpertBank::new(&lr.fold(u64::MAX), e, d, d_ff);
            MoeLayer::new(router.plan().clone(), bank)
        })
        .collect();
    StackedModel::new(layers)
}

/// The decoder's token head: tied input/output embedding (`[vocab, d]`
/// row-major) and the final RMSNorm scale (`[d]`). Logits are
/// `rms_norm(h_last, final_norm) · embed[v]`; greedy decode takes the
/// argmax with ties broken toward the **lowest** token id, so the next
/// token is a pure function of the hidden row.
#[derive(Debug, Clone)]
pub struct DecodeHead {
    embed: Vec<f32>,
    final_norm: Vec<f32>,
    d_model: usize,
}

impl DecodeHead {
    pub fn new(embed: Vec<f32>, final_norm: Vec<f32>) -> DecodeHead {
        let d = final_norm.len();
        assert!(d >= 1, "final_norm must be [d]");
        assert!(
            !embed.is_empty() && embed.len() % d == 0,
            "embed must be [vocab, d]"
        );
        DecodeHead { embed, final_norm, d_model: d }
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn vocab(&self) -> usize {
        self.embed.len() / self.d_model
    }

    /// Token `tok`'s embedding row — the model input for that token.
    pub fn embedding(&self, tok: usize) -> &[f32] {
        let d = self.d_model;
        &self.embed[tok * d..(tok + 1) * d]
    }

    /// Fill `out` with the `[len, d]` embedding rows of `toks`.
    pub fn embed_tokens(&self, toks: &[usize], out: &mut Vec<f32>) {
        out.clear();
        for &t in toks {
            out.extend_from_slice(self.embedding(t));
        }
    }

    /// Greedy next token for a final hidden row (`[d]`): argmax over
    /// the tied-embedding logits, ties → lowest id. `scratch` holds the
    /// normed row between calls so steady-state decode does not
    /// allocate.
    pub fn greedy_next(
        &self,
        h_last: &[f32],
        scratch: &mut Vec<f32>,
    ) -> usize {
        let d = self.d_model;
        assert_eq!(h_last.len(), d, "h_last must be [d]");
        scratch.resize(d, 0.0);
        rms_norm_rows_into(h_last, &self.final_norm, scratch, 1, d);
        let mut best = 0usize;
        let mut best_score = f32::NEG_INFINITY;
        for v in 0..self.vocab() {
            let row = &self.embed[v * d..(v + 1) * d];
            let mut s = 0.0f32;
            for (a, b) in scratch.iter().zip(row) {
                s += a * b;
            }
            if s > best_score {
                best_score = s;
                best = v;
            }
        }
        best
    }
}

/// A decoder: an attention-carrying [`StackedModel`] plus the token
/// head that turns hidden rows into greedy next tokens. The generation
/// loop lives in [`crate::engine::decode::DecodeSession`]; this type
/// just pairs the parts the bridge / synthetic builders produce.
#[derive(Debug, Clone)]
pub struct DecoderModel {
    model: StackedModel,
    head: DecodeHead,
}

impl DecoderModel {
    pub fn new(
        model: StackedModel,
        embed: Vec<f32>,
        final_norm: Vec<f32>,
    ) -> DecoderModel {
        assert_eq!(
            final_norm.len(),
            model.d_model(),
            "final_norm width must match the stack"
        );
        DecoderModel { model, head: DecodeHead::new(embed, final_norm) }
    }

    pub fn model(&self) -> &StackedModel {
        &self.model
    }

    pub fn head(&self) -> &DecodeHead {
        &self.head
    }

    pub fn vocab(&self) -> usize {
        self.head.vocab()
    }

    /// Split into the stack (for the engine builder) and the head (for
    /// the decode session).
    pub fn into_parts(self) -> (StackedModel, DecodeHead) {
        (self.model, self.head)
    }
}

/// Deterministic synthetic decoder: [`synthetic_stacked_model`]'s
/// per-layer init plus an attention sublayer per layer (drawn from the
/// layer's own child stream), a `[vocab, d]` embedding at scale `0.02`,
/// and a unit final norm. The builder behind `lpr generate` without
/// `--ckpt`, the decode benches, and the parity tests.
#[allow(clippy::too_many_arguments)]
pub fn synthetic_decoder_model(
    metric: &str,
    rng: &Rng,
    n_layers: usize,
    d: usize,
    dz: usize,
    e: usize,
    k: usize,
    d_ff: usize,
    n_heads: usize,
    vocab: usize,
) -> DecoderModel {
    let layers = (0..n_layers)
        .map(|l| {
            let mut lr = rng.fold(l as u64);
            let router = synthetic_lpr_router(metric, &mut lr, d, dz, e, k);
            let bank = ExpertBank::new(&lr.fold(u64::MAX), e, d, d_ff);
            let attn =
                synthetic_attn(&mut lr.fold(u64::MAX - 1), d, n_heads);
            MoeLayer::with_attn(router.plan().clone(), bank, Some(attn))
        })
        .collect();
    let mut er = rng.fold(u64::MAX);
    let embed =
        (0..vocab * d).map(|_| er.normal() as f32 * 0.02).collect();
    DecoderModel::new(StackedModel::new(layers), embed, vec![1.0; d])
}

/// Residual-stream update shared by every stack executor: `out[i] =
/// h[i] + moe[i]`, elementwise in token order. One fixed walk on the
/// caller's thread, so composing bit-identical layer forwards through
/// it keeps the whole stack bit-identical.
pub fn residual_add(h: &[f32], moe: &[f32], out: &mut Vec<f32>) {
    assert_eq!(h.len(), moe.len(), "residual shapes");
    out.clear();
    out.extend(h.iter().zip(moe).map(|(a, b)| a + b));
}

/// Reusable output + scratch of a stacked forward: layer ℓ's full
/// per-batch pipeline state in `layers[ℓ]` (routed batch, dispatch
/// plan, combined MoE output) and the final residual stream in
/// `hidden`. All buffers reuse capacity across calls.
#[derive(Debug, Clone, Default)]
pub struct ModelForward {
    /// Per-layer pipeline state, layer order.
    pub layers: Vec<FullForward>,
    /// `[N, d]` residual stream after the last layer.
    pub hidden: Vec<f32>,
    /// Current layer's `[N, d]` input (ping-pongs with `hidden`).
    pub(crate) h_cur: Vec<f32>,
    /// Attention scratch shared by both backends' stack executors.
    pub(crate) attn_scratch: AttnScratch,
}

impl ModelForward {
    pub fn new() -> ModelForward {
        ModelForward::default()
    }

    /// Resize the per-layer slots for an `L`-layer stack.
    pub(crate) fn ensure_layers(&mut self, n_layers: usize) {
        self.layers.resize_with(n_layers, FullForward::new);
    }

    /// Tokens in the last forward.
    pub fn n_tokens(&self) -> usize {
        self.layers.first().map(|f| f.plan.n).unwrap_or(0)
    }

    /// Final residual-stream row of token `r`.
    pub fn token_row(&self, r: usize) -> &[f32] {
        let d = self.hidden.len() / self.n_tokens().max(1);
        &self.hidden[r * d..(r + 1) * d]
    }

    /// Per-layer dispatch plans of the last forward (for the layered
    /// simulator: [`DispatchSim::step_model`]).
    pub fn plans(&self) -> impl Iterator<Item = &DispatchPlan> {
        self.layers.iter().map(|f| &f.plan)
    }
}

/// Scoped-thread execution of a [`StackedModel`]: one [`ServingEngine`]
/// per layer (each reusing the PR 1 shard/merge primitives and the PR 2
/// expert-compute sharding), composed through [`residual_add`].
/// Bit-identical for every thread count; the persistent-pool twin is
/// `serve::PoolEngine::forward_model`.
#[derive(Debug)]
pub struct ModelEngine {
    engines: Vec<ServingEngine>,
    banks: Vec<ExpertBank>,
    /// Per-layer attention sublayers (`None` on MoE-only stacks), run
    /// on the caller's thread before each layer's MoE block.
    attn: Vec<Option<AttnBlock>>,
    d_model: usize,
    /// Rolling `[L, E]` routed-load balance over this engine's batches.
    tracker: LayerLoadTracker,
    /// One-slot scratch cache backing plain [`Self::forward`] on
    /// attention stacks (the batch is treated as one full-sequence
    /// prefill, reset every call). `None` on MoE-only stacks, whose
    /// forward path is byte-for-byte the PR 9 loop. Kept in an `Option`
    /// so `forward` can temporarily take it while borrowing `self`.
    prefill: Option<KvCache>,
}

impl ModelEngine {
    pub fn new(model: StackedModel, n_threads: usize) -> ModelEngine {
        let d_model = model.d_model();
        let experts: Vec<usize> = model
            .layers()
            .iter()
            .map(|l| l.plan.cfg.n_experts)
            .collect();
        let mut engines = Vec::with_capacity(experts.len());
        let mut banks = Vec::with_capacity(experts.len());
        let mut attn = Vec::with_capacity(experts.len());
        for layer in model.into_layers() {
            engines.push(ServingEngine::new(layer.plan, n_threads));
            banks.push(layer.bank);
            attn.push(layer.attn);
        }
        let prefill = if attn.iter().any(Option::is_some) {
            let mut c = KvCache::new(
                1,
                engines.len(),
                d_model,
                usize::MAX / 2,
            );
            let _ = c.alloc();
            Some(c)
        } else {
            None
        };
        ModelEngine {
            engines,
            banks,
            attn,
            d_model,
            tracker: LayerLoadTracker::with_experts(
                DEFAULT_LOAD_WINDOW,
                &experts,
            ),
            prefill,
        }
    }

    /// True when any layer carries an attention sublayer.
    pub fn has_attn(&self) -> bool {
        self.attn.iter().any(Option::is_some)
    }

    pub fn n_layers(&self) -> usize {
        self.engines.len()
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn layer_plan(&self, l: usize) -> &RouterPlan {
        self.engines[l].plan()
    }

    /// Rolling per-layer balance of the batches this engine has served.
    pub fn tracker(&self) -> &LayerLoadTracker {
        &self.tracker
    }

    /// Route `h` through **layer 0**'s router only (no dispatch/FFN),
    /// accounting the batch in the layer-0 balance window — the
    /// routing-study entry point the engine facade
    /// ([`crate::engine::MoeEngine::route_into`]) delegates to; the
    /// pool twin is `serve::PoolEngine::route_into`.
    pub fn route_into(
        &mut self,
        h: &[f32],
        out: &mut crate::router::RouterBatch,
    ) {
        self.engines[0].route_into(h, out);
        self.tracker.push(0, &out.load);
    }

    /// Gate-weight renormalization for partially-dropped tokens, applied
    /// in every layer's combine (see `experts::combine_rows_opts`).
    pub fn set_renormalize(&mut self, on: bool) {
        for e in &mut self.engines {
            e.set_renormalize(on);
        }
    }

    /// GEMM micro-kernel for every layer's expert FFN stage (the
    /// `Engine::builder().kernel(..)` knob; see `crate::kernels`).
    pub fn set_kernel(&mut self, kernel: crate::kernels::Kernel) {
        for e in &mut self.engines {
            e.set_kernel(kernel);
        }
    }

    /// MC×KC×NC cache tiles for every layer's FFN GEMMs (the
    /// `Engine::builder().gemm_tiles(..)` knob; see `crate::kernels`).
    pub fn set_gemm_tiles(&mut self, tiles: crate::kernels::GemmTiles) {
        for e in &mut self.engines {
            e.set_gemm_tiles(tiles);
        }
    }

    /// Run the full stack over `h` (`[N, d]` row-major): per layer,
    /// (attention sublayer, if present) → route → plan → expert FFN →
    /// combine, then the residual add; the final stream lands in
    /// `out.hidden`. Bit-identical for every thread count (module
    /// docs). On an attention stack the batch is treated as **one
    /// sequence** prefilled from position 0 through the internal
    /// one-slot cache — bitwise equal to decoding the same rows
    /// token-at-a-time through [`Self::forward_seqs`].
    #[allow(deprecated)] // backend internals compose the legacy layer path
    pub fn forward(
        &mut self,
        h: &[f32],
        capacity_factor: f64,
        policy: OverflowPolicy,
        out: &mut ModelForward,
    ) {
        assert_eq!(h.len() % self.d_model, 0, "h must be [N, d]");
        if let Some(mut cache) = self.prefill.take() {
            cache.reset(0);
            let n = h.len() / self.d_model;
            let spans = [SeqSpan { slot: 0, n_tokens: n }];
            let spans = if n == 0 { &[][..] } else { &spans[..] };
            self.forward_seqs(
                h,
                spans,
                capacity_factor,
                policy,
                &mut cache,
                out,
            );
            self.prefill = Some(cache);
            return;
        }
        let n_layers = self.engines.len();
        out.ensure_layers(n_layers);
        let ModelForward { layers, hidden, h_cur, .. } = out;
        h_cur.clear();
        h_cur.extend_from_slice(h);
        for l in 0..n_layers {
            self.engines[l].forward_full(
                &h_cur[..],
                &self.banks[l],
                capacity_factor,
                policy,
                &mut layers[l],
            );
            self.tracker.push(l, &layers[l].batch.load);
            residual_add(&h_cur[..], &layers[l].combined, hidden);
            if l + 1 < n_layers {
                std::mem::swap(&mut *h_cur, &mut *hidden);
            }
        }
    }

    /// Run the stack over a **ragged step batch**: `h` is `[N, d]`
    /// whose rows are the concatenation of `spans` in span order — each
    /// span extends one cached sequence by `n_tokens` new positions
    /// (1 for a decode step, the prompt length for a prefill).
    /// Attention sublayers read each span's past keys/values from (and
    /// append the new ones to) the span's cache slot, span by span on
    /// the caller's thread; MoE stages see the whole coalesced batch at
    /// once. The per-span result is bit-identical however the
    /// sequence's rows are split across calls (decode ≡ prefill; see
    /// [`attention`]) and across thread counts — provided the
    /// capacity factor admits every token, since dispatch bins scale
    /// with batch size (see `engine::decode`).
    ///
    /// Slots must be allocated with room for their spans — sessions
    /// pre-check with [`KvCache::check_capacity`]; violations panic
    /// here.
    #[allow(deprecated)] // backend internals compose the legacy layer path
    pub fn forward_seqs(
        &mut self,
        h: &[f32],
        spans: &[SeqSpan],
        capacity_factor: f64,
        policy: OverflowPolicy,
        cache: &mut KvCache,
        out: &mut ModelForward,
    ) {
        let d = self.d_model;
        assert_eq!(h.len() % d, 0, "h must be [N, d]");
        let n = h.len() / d;
        let spanned: usize = spans.iter().map(|s| s.n_tokens).sum();
        assert_eq!(spanned, n, "spans must cover the batch exactly");
        let n_layers = self.engines.len();
        assert_eq!(cache.n_layers(), n_layers, "cache depth mismatch");
        assert_eq!(cache.d_model(), d, "cache width mismatch");
        for s in spans {
            assert!(s.n_tokens >= 1, "spans must carry tokens");
            cache
                .check_capacity(s.slot, s.n_tokens)
                .expect("kv capacity must be pre-checked by the caller");
        }
        out.ensure_layers(n_layers);
        let ModelForward { layers, hidden, h_cur, attn_scratch } = out;
        h_cur.clear();
        h_cur.extend_from_slice(h);
        for l in 0..n_layers {
            if let Some(attn) = &self.attn[l] {
                let mut off = 0usize;
                for s in spans {
                    let rows =
                        &mut h_cur[off * d..(off + s.n_tokens) * d];
                    let (k, v) = cache.layer_mut(s.slot, l);
                    attn.forward(rows, s.n_tokens, k, v, attn_scratch);
                    off += s.n_tokens;
                }
            }
            self.engines[l].forward_full(
                &h_cur[..],
                &self.banks[l],
                capacity_factor,
                policy,
                &mut layers[l],
            );
            self.tracker.push(l, &layers[l].batch.load);
            residual_add(&h_cur[..], &layers[l].combined, hidden);
            if l + 1 < n_layers {
                std::mem::swap(&mut *h_cur, &mut *hidden);
            }
        }
        for s in spans {
            cache.advance(s.slot, s.n_tokens);
        }
    }
}

/// Drive `steps` stacked serving steps end-to-end: sample a mixture
/// batch, run the full `L`-layer forward through the engine facade,
/// account every layer's plan in the layered simulator
/// ([`DispatchSim::step_model`]). Returns total forward nanoseconds.
/// The single protocol behind `lpr model-sim`, `repro model-serve`'s
/// sim column, and `examples/serving_sim.rs` part 5 — the stacked
/// sibling of `dispatch::run_full_steps`.
///
/// The engine's builder-time capacity factor / overflow policy govern
/// the forward; build the engine from `sim.cfg.capacity_factor` —
/// asserted here, so simulator accounting and real compute cannot
/// silently use different bin sizes.
pub fn run_model_steps(
    engine: &mut dyn crate::engine::MoeEngine,
    mix: &MixtureStream,
    rng: &mut Rng,
    sim: &mut DispatchSim,
    steps: usize,
    tokens_per_step: usize,
) -> u128 {
    assert!(
        (engine.capacity_factor() - sim.cfg.capacity_factor).abs() < 1e-12,
        "engine capacity factor {} != sim capacity factor {} — build \
         the engine from sim.cfg.capacity_factor so accounting matches \
         compute",
        engine.capacity_factor(),
        sim.cfg.capacity_factor
    );
    let mut h = Vec::new();
    let mut fwd_ns = 0u128;
    for _ in 0..steps {
        mix.fill(rng, tokens_per_step, &mut h);
        let t0 = std::time::Instant::now();
        engine.forward(&h, tokens_per_step);
        fwd_ns += t0.elapsed().as_nanos();
        sim.step_model(&engine.last().layers);
    }
    fwd_ns
}

#[cfg(test)]
#[allow(deprecated)] // hand-composed legacy paths are the parity oracle
mod tests {
    use super::*;
    use crate::dispatch::SimConfig;
    use crate::router::FullForward;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    const D: usize = 16;
    const DZ: usize = 8;
    const E: usize = 6;
    const K: usize = 2;
    const FF: usize = 10;

    fn tiny_model(n_layers: usize) -> StackedModel {
        synthetic_stacked_model(
            "cosine",
            &Rng::new(5),
            n_layers,
            D,
            DZ,
            E,
            K,
            FF,
        )
    }

    #[test]
    fn synthetic_layers_are_distinct_and_deterministic() {
        let a = tiny_model(3);
        let b = tiny_model(3);
        // deterministic in the seed
        let ha = rand_vec(&mut Rng::new(1), 8 * D);
        let mut ea = ModelEngine::new(a, 1);
        let mut eb = ModelEngine::new(b, 1);
        let (mut fa, mut fb) = (ModelForward::new(), ModelForward::new());
        ea.forward(&ha, 2.0, OverflowPolicy::Drop, &mut fa);
        eb.forward(&ha, 2.0, OverflowPolicy::Drop, &mut fb);
        assert_eq!(fa.hidden, fb.hidden);
        // layers route differently (independent parameters — identical
        // continuous combine weights across layers would require
        // identical score geometry)
        assert_ne!(fa.layers[0].batch.weights, fa.layers[1].batch.weights);
    }

    /// Satellite: the stack contract. An L-layer `ModelForward` is
    /// bit-identical for thread counts {1, 2, 3, 8} and equals
    /// hand-composing L single-layer `forward_full` calls through the
    /// residual add.
    #[test]
    fn model_forward_matches_hand_composed_layers() {
        let model = tiny_model(4);
        let mut rng = Rng::new(31);
        for n in [5usize, 37] {
            let h = rand_vec(&mut rng, n * D);
            for policy in OverflowPolicy::ALL {
                let mut eng = ModelEngine::new(model.clone(), 3);
                let mut out = ModelForward::new();
                eng.forward(&h, 1.0, policy, &mut out);

                // hand-compose: L separate single-layer engines
                let mut h_cur = h.clone();
                for (l, layer) in model.layers().iter().enumerate() {
                    let mut single =
                        ServingEngine::new(layer.plan.clone(), 1);
                    let mut ff = FullForward::new();
                    single.forward_full(
                        &h_cur,
                        &layer.bank,
                        1.0,
                        policy,
                        &mut ff,
                    );
                    assert_eq!(
                        out.layers[l].combined, ff.combined,
                        "layer {l} combined diverged ({})",
                        policy.name()
                    );
                    assert_eq!(out.layers[l].batch, ff.batch);
                    assert_eq!(out.layers[l].plan, ff.plan);
                    let mut next = Vec::new();
                    residual_add(&h_cur, &ff.combined, &mut next);
                    h_cur = next;
                }
                assert_eq!(out.hidden, h_cur, "{}", policy.name());
            }
        }
    }

    #[test]
    fn model_forward_bit_identical_across_thread_counts() {
        let model = tiny_model(4);
        let mut rng = Rng::new(77);
        for n in [7usize, 53] {
            let h = rand_vec(&mut rng, n * D);
            let mut single = ModelEngine::new(model.clone(), 1);
            let mut want = ModelForward::new();
            single.forward(&h, 1.0, OverflowPolicy::NextChoice, &mut want);
            for threads in [2usize, 3, 8] {
                let mut eng = ModelEngine::new(model.clone(), threads);
                let mut got = ModelForward::new();
                eng.forward(&h, 1.0, OverflowPolicy::NextChoice, &mut got);
                assert_eq!(got.hidden, want.hidden, "t={threads} n={n}");
                for l in 0..model.n_layers() {
                    assert_eq!(
                        got.layers[l].combined, want.layers[l].combined,
                        "layer {l} t={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_reuses_buffers_across_batch_sizes() {
        let model = tiny_model(2);
        let mut eng = ModelEngine::new(model, 2);
        let mut rng = Rng::new(3);
        let mut out = ModelForward::new();
        let h1 = rand_vec(&mut rng, 24 * D);
        eng.forward(&h1, 1.25, OverflowPolicy::Drop, &mut out);
        let first = out.hidden.clone();
        let h2 = rand_vec(&mut rng, 4 * D);
        eng.forward(&h2, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.hidden.len(), 4 * D);
        assert_eq!(out.n_tokens(), 4);
        eng.forward(&h1, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.hidden, first);
        assert_eq!(out.token_row(0).len(), D);
    }

    #[test]
    fn dropped_token_rows_pass_through_residual() {
        // capacity 0 is impossible (min 1), so force heavy drops with a
        // single-expert-bin squeeze and check a fully-dropped token's
        // row equals its input row exactly.
        let model = tiny_model(1);
        let mut eng = ModelEngine::new(model, 1);
        let mut rng = Rng::new(9);
        let h = rand_vec(&mut rng, 40 * D);
        let mut out = ModelForward::new();
        // tiny capacity factor: bins hold ~1 slot each
        eng.forward(&h, 0.05, OverflowPolicy::Drop, &mut out);
        let plan = &out.layers[0].plan;
        assert!(plan.n_dropped > 0);
        let mut saw_full_drop = false;
        for t in 0..40 {
            let all_dropped = (0..K).all(|j| {
                plan.pos_of[t * K + j] == crate::dispatch::DROPPED
            });
            if all_dropped {
                saw_full_drop = true;
                assert_eq!(
                    &out.hidden[t * D..(t + 1) * D],
                    &h[t * D..(t + 1) * D],
                    "dropped token {t} must pass through unchanged"
                );
            }
        }
        assert!(saw_full_drop, "squeeze should fully drop some token");
    }

    #[test]
    fn tracker_resolves_layers() {
        let model = tiny_model(3);
        let mut eng = ModelEngine::new(model, 1);
        let mut rng = Rng::new(13);
        let h = rand_vec(&mut rng, 32 * D);
        let mut out = ModelForward::new();
        eng.forward(&h, 1.25, OverflowPolicy::Drop, &mut out);
        let t = eng.tracker();
        assert_eq!(t.n_layers(), 3);
        for l in 0..3 {
            assert_eq!(t.layer(l).total_steps(), 1);
            assert_eq!(t.layer(l).windowed(), out.layers[l].batch.load);
        }
        assert_eq!(t.per_layer().len(), 3);
    }

    #[test]
    fn run_model_steps_accounts_every_layer() {
        use crate::engine::{Backend, Engine, MoeEngine};
        let model = tiny_model(3);
        // the facade engine is built from the sim's capacity factor so
        // simulated bins and real compute agree
        let mut eng = Engine::builder()
            .model(model)
            .backend(Backend::Scoped { threads: 2 })
            .policy(OverflowPolicy::Drop)
            .capacity_factor(1.0)
            .build()
            .unwrap();
        let mut rng = Rng::new(21);
        let mix = MixtureStream::standard(&mut rng, D);
        let mut sim = DispatchSim::new_layered(
            SimConfig {
                n_experts: E,
                n_devices: 2,
                top_k: K,
                capacity_factor: 1.0,
                ..SimConfig::default()
            },
            3,
        )
        .unwrap();
        run_model_steps(&mut eng, &mix, &mut rng, &mut sim, 4, 32);
        let rep = sim.report();
        assert_eq!(rep.steps, 4);
        // every (token, slot) of every layer is accounted
        assert_eq!(rep.tokens_routed, 4 * 32 * K * 3);
        assert_eq!(rep.layers.len(), 3);
        for lb in &rep.layers {
            assert!(lb.gini >= 0.0 && lb.gini <= 1.0);
        }
        assert_eq!(eng.last().n_tokens(), 32);
    }

    const H: usize = 4;
    const V: usize = 32;

    fn tiny_decoder(n_layers: usize) -> DecoderModel {
        synthetic_decoder_model(
            "cosine",
            &Rng::new(5),
            n_layers,
            D,
            DZ,
            E,
            K,
            FF,
            H,
            V,
        )
    }

    /// Tentpole contract at the engine level: a full-sequence prefill
    /// through plain `forward` equals token-at-a-time decode through an
    /// external cache, bitwise, and a ragged prompt+decode split lands
    /// on the same rows. Capacity factor E admits every token — the
    /// contract's precondition, since bins scale with batch size.
    #[test]
    fn attn_stack_decode_matches_prefill() {
        let (model, _head) = tiny_decoder(3).into_parts();
        assert!(model.has_attn());
        let cf = E as f64; // cannot drop
        let t = 6;
        let h = rand_vec(&mut Rng::new(1), t * D);
        let mut eng = ModelEngine::new(model.clone(), 2);
        let mut pre = ModelForward::new();
        eng.forward(&h, cf, OverflowPolicy::Drop, &mut pre);
        let want = pre.hidden.clone();
        // plain forward resets its internal prefill slot per call
        eng.forward(&h, cf, OverflowPolicy::Drop, &mut pre);
        assert_eq!(pre.hidden, want);

        // token-at-a-time through an external cache
        let mut dec = ModelEngine::new(model.clone(), 2);
        let mut cache = KvCache::new(1, 3, D, t);
        let slot = cache.alloc().unwrap();
        let mut out = ModelForward::new();
        let mut got = Vec::new();
        for i in 0..t {
            let spans = [SeqSpan { slot, n_tokens: 1 }];
            dec.forward_seqs(
                &h[i * D..(i + 1) * D],
                &spans,
                cf,
                OverflowPolicy::Drop,
                &mut cache,
                &mut out,
            );
            got.extend_from_slice(&out.hidden);
        }
        assert_eq!(got, want, "decode diverged from prefill");
        assert_eq!(cache.len(slot), t);

        // ragged: 4-token prompt prefill, then single-token steps
        let mut rag = ModelEngine::new(model, 2);
        cache.reset(slot);
        let mut rows = Vec::new();
        rag.forward_seqs(
            &h[..4 * D],
            &[SeqSpan { slot, n_tokens: 4 }],
            cf,
            OverflowPolicy::Drop,
            &mut cache,
            &mut out,
        );
        rows.extend_from_slice(&out.hidden);
        for i in 4..t {
            rag.forward_seqs(
                &h[i * D..(i + 1) * D],
                &[SeqSpan { slot, n_tokens: 1 }],
                cf,
                OverflowPolicy::Drop,
                &mut cache,
                &mut out,
            );
            rows.extend_from_slice(&out.hidden);
        }
        assert_eq!(rows, want, "ragged prefill+decode diverged");
    }

    /// Two sequences interleaved in one ragged step batch produce the
    /// same rows as each sequence decoded alone — span order feeds the
    /// cache per slot, and with no drops the MoE stage is row-
    /// independent.
    #[test]
    fn coalesced_spans_match_isolated_sequences() {
        let (model, _head) = tiny_decoder(2).into_parts();
        let cf = E as f64;
        let t = 4;
        let ha = rand_vec(&mut Rng::new(2), t * D);
        let hb = rand_vec(&mut Rng::new(3), t * D);
        // isolated references
        let mut solo = Vec::new();
        for h in [&ha, &hb] {
            let mut eng = ModelEngine::new(model.clone(), 1);
            let mut out = ModelForward::new();
            eng.forward(h, cf, OverflowPolicy::Drop, &mut out);
            solo.push(out.hidden.clone());
        }
        // coalesced: both sequences advance one token per step
        let mut eng = ModelEngine::new(model, 1);
        let mut cache = KvCache::new(2, 2, D, t);
        let (sa, sb) = (cache.alloc().unwrap(), cache.alloc().unwrap());
        let mut out = ModelForward::new();
        let (mut got_a, mut got_b) = (Vec::new(), Vec::new());
        let mut step = Vec::new();
        for i in 0..t {
            step.clear();
            step.extend_from_slice(&ha[i * D..(i + 1) * D]);
            step.extend_from_slice(&hb[i * D..(i + 1) * D]);
            let spans = [
                SeqSpan { slot: sa, n_tokens: 1 },
                SeqSpan { slot: sb, n_tokens: 1 },
            ];
            eng.forward_seqs(
                &step,
                &spans,
                cf,
                OverflowPolicy::Drop,
                &mut cache,
                &mut out,
            );
            got_a.extend_from_slice(&out.hidden[..D]);
            got_b.extend_from_slice(&out.hidden[D..]);
        }
        assert_eq!(got_a, solo[0], "sequence A moved by its batchmate");
        assert_eq!(got_b, solo[1], "sequence B moved by its batchmate");
    }

    #[test]
    fn greedy_head_is_argmax_with_low_tie() {
        #[rustfmt::skip]
        let head = DecodeHead::new(
            vec![1.0, 0.0,
                 0.0, 1.0,
                 1.0, 0.0],
            vec![1.0, 1.0],
        );
        assert_eq!(head.vocab(), 3);
        assert_eq!(head.d_model(), 2);
        let mut scratch = Vec::new();
        // rows 0 and 2 tie on a dim-0 hidden → lowest id wins
        assert_eq!(head.greedy_next(&[2.0, 0.0], &mut scratch), 0);
        assert_eq!(head.greedy_next(&[0.0, 2.0], &mut scratch), 1);
        let mut h = Vec::new();
        head.embed_tokens(&[2, 1], &mut h);
        assert_eq!(h, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(head.embedding(1), &[0.0, 1.0]);
    }

    #[test]
    fn moe_only_forward_seqs_matches_forward() {
        // an attention-less stack through the seqs path: cache is a
        // pass-through and rows equal the plain forward
        let model = tiny_model(2);
        let cf = E as f64;
        let h = rand_vec(&mut Rng::new(4), 5 * D);
        let mut eng = ModelEngine::new(model.clone(), 1);
        assert!(!eng.has_attn());
        let mut want = ModelForward::new();
        eng.forward(&h, cf, OverflowPolicy::Drop, &mut want);
        let mut cache = KvCache::new(1, 2, D, 8);
        let slot = cache.alloc().unwrap();
        let mut out = ModelForward::new();
        let mut eng2 = ModelEngine::new(model, 1);
        eng2.forward_seqs(
            &h,
            &[SeqSpan { slot, n_tokens: 5 }],
            cf,
            OverflowPolicy::Drop,
            &mut cache,
            &mut out,
        );
        assert_eq!(out.hidden, want.hidden);
        assert_eq!(cache.len(slot), 5);
    }

    #[test]
    #[should_panic(expected = "d_model differs")]
    fn mixed_width_stack_is_rejected() {
        let a = synthetic_stacked_model(
            "dot",
            &Rng::new(1),
            1,
            16,
            8,
            4,
            2,
            8,
        );
        let b = synthetic_stacked_model(
            "dot",
            &Rng::new(2),
            1,
            32,
            8,
            4,
            2,
            8,
        );
        let mut layers = a.into_layers();
        layers.extend(b.into_layers());
        let _ = StackedModel::new(layers);
    }
}
