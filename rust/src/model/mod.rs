//! Multi-layer model serving: a stack of per-layer MoE blocks behind
//! one forward pipeline.
//!
//! PRs 1–3 built the full per-batch data path (route → [`DispatchPlan`]
//! → expert FFN → combine) and a serving runtime around it — but always
//! for exactly **one** router layer and one expert bank, while the
//! trainer's artifacts carry `[L, E]` load shapes and per-layer router
//! leaves. This module serves the model the trainer actually trains:
//!
//! - [`MoeLayer`] — one layer's compiled [`RouterPlan`] plus its
//!   [`ExpertBank`];
//! - [`StackedModel`] — `L` layers with a uniform `d_model`, validated
//!   at construction;
//! - [`ModelForward`] — reusable output/scratch of a stacked forward:
//!   one [`FullForward`] per layer plus the final `[N, d]` residual
//!   stream;
//! - [`ModelEngine`] — the scoped-thread execution path: one
//!   [`ServingEngine`] per layer, layer ℓ's residual output feeding
//!   layer ℓ+1 ([`residual_add`]); per-layer balance lands in a
//!   [`LayerLoadTracker`].
//!
//! The persistent-pool twin is [`crate::serve::PoolEngine::forward_model`],
//! which runs the same stack on long-lived workers and is bit-identical
//! to [`ModelEngine::forward`] for every worker count (pinned by
//! `pool_forward_model_matches_scoped` in `serve::pool` and the bridge
//! acceptance test in [`bridge`]).
//!
//! # Residual semantics
//!
//! Layer ℓ's output is `h_{ℓ+1} = h_ℓ + combined_ℓ` — the gate-weighted
//! MoE output added back onto the residual stream, elementwise in token
//! order. Dropped slots contribute nothing to `combined`, so a dropped
//! token's row passes through unchanged — exactly the capacity-factor
//! training semantics (`python/compile/moe.py`). Attention sublayers are
//! out of scope: this is the *MoE serving* stack, the part whose balance
//! the paper measures; `combined` per layer stays observable in
//! [`ModelForward::layers`] for the telemetry.
//!
//! # Determinism
//!
//! Each layer's forward is the PR 2/3 pipeline, bit-identical across
//! thread counts; the residual add is a fixed elementwise walk on the
//! caller's thread. A stack of deterministic layers composed through a
//! deterministic add is deterministic, so the **whole-stack** output is
//! bit-identical for every thread/worker count and equals hand-composing
//! `L` single-layer `forward_full` calls (pinned by
//! `model_forward_matches_hand_composed_layers` and
//! `model_forward_bit_identical_across_thread_counts` below).
//!
//! The checkpoint → model bridge (`coordinator::checkpoint` +
//! `runtime::ArtifactMeta` → [`StackedModel`], no PJRT needed) lives in
//! [`bridge`].

pub mod bridge;

use crate::data::MixtureStream;
use crate::dispatch::plan::OverflowPolicy;
use crate::dispatch::{DispatchPlan, DispatchSim};
use crate::experts::ExpertBank;
use crate::metrics::{LayerLoadTracker, DEFAULT_LOAD_WINDOW};
use crate::router::{
    synthetic_lpr_router, FullForward, RouterPlan, ServingEngine,
};
use crate::util::rng::Rng;

/// One MoE layer of a served model: its compiled router plan and its
/// expert bank. Construction validates that the two agree on `d_model`
/// and expert count.
#[derive(Debug, Clone)]
pub struct MoeLayer {
    pub plan: RouterPlan,
    pub bank: ExpertBank,
}

impl MoeLayer {
    pub fn new(plan: RouterPlan, bank: ExpertBank) -> MoeLayer {
        assert_eq!(
            plan.cfg.d_model, bank.d_model,
            "layer plan/bank d_model mismatch"
        );
        assert_eq!(
            plan.cfg.n_experts, bank.n_experts,
            "layer plan/bank expert count mismatch"
        );
        MoeLayer { plan, bank }
    }
}

/// `L` MoE layers with a uniform `d_model` (the residual stream ties
/// them together). Expert count / top-k / metric may vary per layer —
/// the bridge builds whatever the checkpoint holds.
#[derive(Debug, Clone)]
pub struct StackedModel {
    layers: Vec<MoeLayer>,
}

impl StackedModel {
    pub fn new(layers: Vec<MoeLayer>) -> StackedModel {
        assert!(!layers.is_empty(), "a model needs at least one layer");
        let d = layers[0].plan.cfg.d_model;
        for (l, layer) in layers.iter().enumerate() {
            assert_eq!(
                layer.plan.cfg.d_model, d,
                "layer {l} d_model differs from layer 0 — the residual \
                 stream needs one width"
            );
        }
        StackedModel { layers }
    }

    /// The single-layer model behind the PR 1–3 serving paths — the
    /// compatibility constructor `PoolEngine::new` / `ServeRuntime::new`
    /// still build through.
    pub fn single(plan: RouterPlan, bank: ExpertBank) -> StackedModel {
        StackedModel::new(vec![MoeLayer::new(plan, bank)])
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn d_model(&self) -> usize {
        self.layers[0].plan.cfg.d_model
    }

    pub fn layer(&self, l: usize) -> &MoeLayer {
        &self.layers[l]
    }

    pub fn layers(&self) -> &[MoeLayer] {
        &self.layers
    }

    pub fn into_layers(self) -> Vec<MoeLayer> {
        self.layers
    }
}

/// Deterministic synthetic `L`-layer model: one [`synthetic_lpr_router`]
/// and one [`ExpertBank`] per layer, each layer drawing from its own
/// `rng.fold(layer)` child stream so layer `l`'s parameters depend only
/// on `(seed, l)`. The shared builder behind `lpr serve synthetic`,
/// `model-sim`, `repro model-serve`, the model benches, and the tests.
#[allow(clippy::too_many_arguments)]
pub fn synthetic_stacked_model(
    metric: &str,
    rng: &Rng,
    n_layers: usize,
    d: usize,
    dz: usize,
    e: usize,
    k: usize,
    d_ff: usize,
) -> StackedModel {
    let layers = (0..n_layers)
        .map(|l| {
            let mut lr = rng.fold(l as u64);
            let router = synthetic_lpr_router(metric, &mut lr, d, dz, e, k);
            let bank = ExpertBank::new(&lr.fold(u64::MAX), e, d, d_ff);
            MoeLayer::new(router.plan().clone(), bank)
        })
        .collect();
    StackedModel::new(layers)
}

/// Residual-stream update shared by every stack executor: `out[i] =
/// h[i] + moe[i]`, elementwise in token order. One fixed walk on the
/// caller's thread, so composing bit-identical layer forwards through
/// it keeps the whole stack bit-identical.
pub fn residual_add(h: &[f32], moe: &[f32], out: &mut Vec<f32>) {
    assert_eq!(h.len(), moe.len(), "residual shapes");
    out.clear();
    out.extend(h.iter().zip(moe).map(|(a, b)| a + b));
}

/// Reusable output + scratch of a stacked forward: layer ℓ's full
/// per-batch pipeline state in `layers[ℓ]` (routed batch, dispatch
/// plan, combined MoE output) and the final residual stream in
/// `hidden`. All buffers reuse capacity across calls.
#[derive(Debug, Clone, Default)]
pub struct ModelForward {
    /// Per-layer pipeline state, layer order.
    pub layers: Vec<FullForward>,
    /// `[N, d]` residual stream after the last layer.
    pub hidden: Vec<f32>,
    /// Current layer's `[N, d]` input (ping-pongs with `hidden`).
    pub(crate) h_cur: Vec<f32>,
}

impl ModelForward {
    pub fn new() -> ModelForward {
        ModelForward::default()
    }

    /// Resize the per-layer slots for an `L`-layer stack.
    pub(crate) fn ensure_layers(&mut self, n_layers: usize) {
        self.layers.resize_with(n_layers, FullForward::new);
    }

    /// Tokens in the last forward.
    pub fn n_tokens(&self) -> usize {
        self.layers.first().map(|f| f.plan.n).unwrap_or(0)
    }

    /// Final residual-stream row of token `r`.
    pub fn token_row(&self, r: usize) -> &[f32] {
        let d = self.hidden.len() / self.n_tokens().max(1);
        &self.hidden[r * d..(r + 1) * d]
    }

    /// Per-layer dispatch plans of the last forward (for the layered
    /// simulator: [`DispatchSim::step_model`]).
    pub fn plans(&self) -> impl Iterator<Item = &DispatchPlan> {
        self.layers.iter().map(|f| &f.plan)
    }
}

/// Scoped-thread execution of a [`StackedModel`]: one [`ServingEngine`]
/// per layer (each reusing the PR 1 shard/merge primitives and the PR 2
/// expert-compute sharding), composed through [`residual_add`].
/// Bit-identical for every thread count; the persistent-pool twin is
/// `serve::PoolEngine::forward_model`.
#[derive(Debug)]
pub struct ModelEngine {
    engines: Vec<ServingEngine>,
    banks: Vec<ExpertBank>,
    d_model: usize,
    /// Rolling `[L, E]` routed-load balance over this engine's batches.
    tracker: LayerLoadTracker,
}

impl ModelEngine {
    pub fn new(model: StackedModel, n_threads: usize) -> ModelEngine {
        let d_model = model.d_model();
        let experts: Vec<usize> = model
            .layers()
            .iter()
            .map(|l| l.plan.cfg.n_experts)
            .collect();
        let mut engines = Vec::with_capacity(experts.len());
        let mut banks = Vec::with_capacity(experts.len());
        for layer in model.into_layers() {
            engines.push(ServingEngine::new(layer.plan, n_threads));
            banks.push(layer.bank);
        }
        ModelEngine {
            engines,
            banks,
            d_model,
            tracker: LayerLoadTracker::with_experts(
                DEFAULT_LOAD_WINDOW,
                &experts,
            ),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.engines.len()
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn layer_plan(&self, l: usize) -> &RouterPlan {
        self.engines[l].plan()
    }

    /// Rolling per-layer balance of the batches this engine has served.
    pub fn tracker(&self) -> &LayerLoadTracker {
        &self.tracker
    }

    /// Route `h` through **layer 0**'s router only (no dispatch/FFN),
    /// accounting the batch in the layer-0 balance window — the
    /// routing-study entry point the engine facade
    /// ([`crate::engine::MoeEngine::route_into`]) delegates to; the
    /// pool twin is `serve::PoolEngine::route_into`.
    pub fn route_into(
        &mut self,
        h: &[f32],
        out: &mut crate::router::RouterBatch,
    ) {
        self.engines[0].route_into(h, out);
        self.tracker.push(0, &out.load);
    }

    /// Gate-weight renormalization for partially-dropped tokens, applied
    /// in every layer's combine (see `experts::combine_rows_opts`).
    pub fn set_renormalize(&mut self, on: bool) {
        for e in &mut self.engines {
            e.set_renormalize(on);
        }
    }

    /// GEMM micro-kernel for every layer's expert FFN stage (the
    /// `Engine::builder().kernel(..)` knob; see `crate::kernels`).
    pub fn set_kernel(&mut self, kernel: crate::kernels::Kernel) {
        for e in &mut self.engines {
            e.set_kernel(kernel);
        }
    }

    /// MC×KC×NC cache tiles for every layer's FFN GEMMs (the
    /// `Engine::builder().gemm_tiles(..)` knob; see `crate::kernels`).
    pub fn set_gemm_tiles(&mut self, tiles: crate::kernels::GemmTiles) {
        for e in &mut self.engines {
            e.set_gemm_tiles(tiles);
        }
    }

    /// Run the full stack over `h` (`[N, d]` row-major): per layer,
    /// route → plan → expert FFN → combine, then the residual add; the
    /// final stream lands in `out.hidden`. Bit-identical for every
    /// thread count (module docs).
    #[allow(deprecated)] // backend internals compose the legacy layer path
    pub fn forward(
        &mut self,
        h: &[f32],
        capacity_factor: f64,
        policy: OverflowPolicy,
        out: &mut ModelForward,
    ) {
        assert_eq!(h.len() % self.d_model, 0, "h must be [N, d]");
        let n_layers = self.engines.len();
        out.ensure_layers(n_layers);
        let ModelForward { layers, hidden, h_cur } = out;
        h_cur.clear();
        h_cur.extend_from_slice(h);
        for l in 0..n_layers {
            self.engines[l].forward_full(
                &h_cur[..],
                &self.banks[l],
                capacity_factor,
                policy,
                &mut layers[l],
            );
            self.tracker.push(l, &layers[l].batch.load);
            residual_add(&h_cur[..], &layers[l].combined, hidden);
            if l + 1 < n_layers {
                std::mem::swap(&mut *h_cur, &mut *hidden);
            }
        }
    }
}

/// Drive `steps` stacked serving steps end-to-end: sample a mixture
/// batch, run the full `L`-layer forward through the engine facade,
/// account every layer's plan in the layered simulator
/// ([`DispatchSim::step_model`]). Returns total forward nanoseconds.
/// The single protocol behind `lpr model-sim`, `repro model-serve`'s
/// sim column, and `examples/serving_sim.rs` part 5 — the stacked
/// sibling of `dispatch::run_full_steps`.
///
/// The engine's builder-time capacity factor / overflow policy govern
/// the forward; build the engine from `sim.cfg.capacity_factor` —
/// asserted here, so simulator accounting and real compute cannot
/// silently use different bin sizes.
pub fn run_model_steps(
    engine: &mut dyn crate::engine::MoeEngine,
    mix: &MixtureStream,
    rng: &mut Rng,
    sim: &mut DispatchSim,
    steps: usize,
    tokens_per_step: usize,
) -> u128 {
    assert!(
        (engine.capacity_factor() - sim.cfg.capacity_factor).abs() < 1e-12,
        "engine capacity factor {} != sim capacity factor {} — build \
         the engine from sim.cfg.capacity_factor so accounting matches \
         compute",
        engine.capacity_factor(),
        sim.cfg.capacity_factor
    );
    let mut h = Vec::new();
    let mut fwd_ns = 0u128;
    for _ in 0..steps {
        mix.fill(rng, tokens_per_step, &mut h);
        let t0 = std::time::Instant::now();
        engine.forward(&h, tokens_per_step);
        fwd_ns += t0.elapsed().as_nanos();
        sim.step_model(&engine.last().layers);
    }
    fwd_ns
}

#[cfg(test)]
#[allow(deprecated)] // hand-composed legacy paths are the parity oracle
mod tests {
    use super::*;
    use crate::dispatch::SimConfig;
    use crate::router::FullForward;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    const D: usize = 16;
    const DZ: usize = 8;
    const E: usize = 6;
    const K: usize = 2;
    const FF: usize = 10;

    fn tiny_model(n_layers: usize) -> StackedModel {
        synthetic_stacked_model(
            "cosine",
            &Rng::new(5),
            n_layers,
            D,
            DZ,
            E,
            K,
            FF,
        )
    }

    #[test]
    fn synthetic_layers_are_distinct_and_deterministic() {
        let a = tiny_model(3);
        let b = tiny_model(3);
        // deterministic in the seed
        let ha = rand_vec(&mut Rng::new(1), 8 * D);
        let mut ea = ModelEngine::new(a, 1);
        let mut eb = ModelEngine::new(b, 1);
        let (mut fa, mut fb) = (ModelForward::new(), ModelForward::new());
        ea.forward(&ha, 2.0, OverflowPolicy::Drop, &mut fa);
        eb.forward(&ha, 2.0, OverflowPolicy::Drop, &mut fb);
        assert_eq!(fa.hidden, fb.hidden);
        // layers route differently (independent parameters — identical
        // continuous combine weights across layers would require
        // identical score geometry)
        assert_ne!(fa.layers[0].batch.weights, fa.layers[1].batch.weights);
    }

    /// Satellite: the stack contract. An L-layer `ModelForward` is
    /// bit-identical for thread counts {1, 2, 3, 8} and equals
    /// hand-composing L single-layer `forward_full` calls through the
    /// residual add.
    #[test]
    fn model_forward_matches_hand_composed_layers() {
        let model = tiny_model(4);
        let mut rng = Rng::new(31);
        for n in [5usize, 37] {
            let h = rand_vec(&mut rng, n * D);
            for policy in OverflowPolicy::ALL {
                let mut eng = ModelEngine::new(model.clone(), 3);
                let mut out = ModelForward::new();
                eng.forward(&h, 1.0, policy, &mut out);

                // hand-compose: L separate single-layer engines
                let mut h_cur = h.clone();
                for (l, layer) in model.layers().iter().enumerate() {
                    let mut single =
                        ServingEngine::new(layer.plan.clone(), 1);
                    let mut ff = FullForward::new();
                    single.forward_full(
                        &h_cur,
                        &layer.bank,
                        1.0,
                        policy,
                        &mut ff,
                    );
                    assert_eq!(
                        out.layers[l].combined, ff.combined,
                        "layer {l} combined diverged ({})",
                        policy.name()
                    );
                    assert_eq!(out.layers[l].batch, ff.batch);
                    assert_eq!(out.layers[l].plan, ff.plan);
                    let mut next = Vec::new();
                    residual_add(&h_cur, &ff.combined, &mut next);
                    h_cur = next;
                }
                assert_eq!(out.hidden, h_cur, "{}", policy.name());
            }
        }
    }

    #[test]
    fn model_forward_bit_identical_across_thread_counts() {
        let model = tiny_model(4);
        let mut rng = Rng::new(77);
        for n in [7usize, 53] {
            let h = rand_vec(&mut rng, n * D);
            let mut single = ModelEngine::new(model.clone(), 1);
            let mut want = ModelForward::new();
            single.forward(&h, 1.0, OverflowPolicy::NextChoice, &mut want);
            for threads in [2usize, 3, 8] {
                let mut eng = ModelEngine::new(model.clone(), threads);
                let mut got = ModelForward::new();
                eng.forward(&h, 1.0, OverflowPolicy::NextChoice, &mut got);
                assert_eq!(got.hidden, want.hidden, "t={threads} n={n}");
                for l in 0..model.n_layers() {
                    assert_eq!(
                        got.layers[l].combined, want.layers[l].combined,
                        "layer {l} t={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_reuses_buffers_across_batch_sizes() {
        let model = tiny_model(2);
        let mut eng = ModelEngine::new(model, 2);
        let mut rng = Rng::new(3);
        let mut out = ModelForward::new();
        let h1 = rand_vec(&mut rng, 24 * D);
        eng.forward(&h1, 1.25, OverflowPolicy::Drop, &mut out);
        let first = out.hidden.clone();
        let h2 = rand_vec(&mut rng, 4 * D);
        eng.forward(&h2, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.hidden.len(), 4 * D);
        assert_eq!(out.n_tokens(), 4);
        eng.forward(&h1, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.hidden, first);
        assert_eq!(out.token_row(0).len(), D);
    }

    #[test]
    fn dropped_token_rows_pass_through_residual() {
        // capacity 0 is impossible (min 1), so force heavy drops with a
        // single-expert-bin squeeze and check a fully-dropped token's
        // row equals its input row exactly.
        let model = tiny_model(1);
        let mut eng = ModelEngine::new(model, 1);
        let mut rng = Rng::new(9);
        let h = rand_vec(&mut rng, 40 * D);
        let mut out = ModelForward::new();
        // tiny capacity factor: bins hold ~1 slot each
        eng.forward(&h, 0.05, OverflowPolicy::Drop, &mut out);
        let plan = &out.layers[0].plan;
        assert!(plan.n_dropped > 0);
        let mut saw_full_drop = false;
        for t in 0..40 {
            let all_dropped = (0..K).all(|j| {
                plan.pos_of[t * K + j] == crate::dispatch::DROPPED
            });
            if all_dropped {
                saw_full_drop = true;
                assert_eq!(
                    &out.hidden[t * D..(t + 1) * D],
                    &h[t * D..(t + 1) * D],
                    "dropped token {t} must pass through unchanged"
                );
            }
        }
        assert!(saw_full_drop, "squeeze should fully drop some token");
    }

    #[test]
    fn tracker_resolves_layers() {
        let model = tiny_model(3);
        let mut eng = ModelEngine::new(model, 1);
        let mut rng = Rng::new(13);
        let h = rand_vec(&mut rng, 32 * D);
        let mut out = ModelForward::new();
        eng.forward(&h, 1.25, OverflowPolicy::Drop, &mut out);
        let t = eng.tracker();
        assert_eq!(t.n_layers(), 3);
        for l in 0..3 {
            assert_eq!(t.layer(l).total_steps(), 1);
            assert_eq!(t.layer(l).windowed(), out.layers[l].batch.load);
        }
        assert_eq!(t.per_layer().len(), 3);
    }

    #[test]
    fn run_model_steps_accounts_every_layer() {
        use crate::engine::{Backend, Engine, MoeEngine};
        let model = tiny_model(3);
        // the facade engine is built from the sim's capacity factor so
        // simulated bins and real compute agree
        let mut eng = Engine::builder()
            .model(model)
            .backend(Backend::Scoped { threads: 2 })
            .policy(OverflowPolicy::Drop)
            .capacity_factor(1.0)
            .build()
            .unwrap();
        let mut rng = Rng::new(21);
        let mix = MixtureStream::standard(&mut rng, D);
        let mut sim = DispatchSim::new_layered(
            SimConfig {
                n_experts: E,
                n_devices: 2,
                top_k: K,
                capacity_factor: 1.0,
                ..SimConfig::default()
            },
            3,
        )
        .unwrap();
        run_model_steps(&mut eng, &mix, &mut rng, &mut sim, 4, 32);
        let rep = sim.report();
        assert_eq!(rep.steps, 4);
        // every (token, slot) of every layer is accounted
        assert_eq!(rep.tokens_routed, 4 * 32 * K * 3);
        assert_eq!(rep.layers.len(), 3);
        for lb in &rep.layers {
            assert!(lb.gini >= 0.0 && lb.gini <= 1.0);
        }
        assert_eq!(eng.last().n_tokens(), 32);
    }

    #[test]
    #[should_panic(expected = "d_model differs")]
    fn mixed_width_stack_is_rejected() {
        let a = synthetic_stacked_model(
            "dot",
            &Rng::new(1),
            1,
            16,
            8,
            4,
            2,
            8,
        );
        let b = synthetic_stacked_model(
            "dot",
            &Rng::new(2),
            1,
            32,
            8,
            4,
            2,
            8,
        );
        let mut layers = a.into_layers();
        layers.extend(b.into_layers());
        let _ = StackedModel::new(layers);
    }
}
