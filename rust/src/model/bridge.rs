//! Checkpoint → [`StackedModel`] bridge: build the served model from
//! real training output, pure Rust, no PJRT.
//!
//! The trainer's flat-buffer contract (`runtime::ArtifactMeta`) lists
//! every parameter leaf with its pytree key string, e.g.
//! `['layers'][2]['moe']['router']['proto_mu']`, and
//! `coordinator::checkpoint` files carry the host buffers in the same
//! order. `meta.router_params` names the leaves **one** router owns
//! (paths like `['proto_mu']` — the layer-0 router template the AOT
//! pipeline emits); this bridge matches that template against
//! `['layers'][ℓ]['moe']['router'][…]` for every layer ℓ, pulls the
//! matching buffers into per-layer [`RouterParams`], pairs them with the
//! layer's stacked expert weights (`['layers'][ℓ]['moe']['w1'/'w2']`),
//! and compiles the lot into a [`StackedModel`] of `RouterPlan` +
//! `ExpertBank` layers.
//!
//! Works against the offline `vendor/xla` stub: only `meta.json` and
//! the checkpoint file are read — closing ROADMAP's "trained-router
//! serving" follow-up (serving-time balance measured on the routers the
//! trainer trained, not on `synthetic_lpr_router`).
//!
//! The python training FFN is SwiGLU (`w1`/`w3`/`w2`), and the bridge
//! now consumes all three: when a layer carries a
//! `['layers'][ℓ]['moe']['w3']` leaf the bank is built gated
//! ([`ExpertBank::from_weights_gated`]) and serves
//! `SiLU(x·W1) ⊙ (x·W3) · W2` through the fused
//! `kernels::gemm_bias_act_gated` epilogue — the checkpointed FFN,
//! exactly. Checkpoints without `w3` leaves (the pre-gate artifact
//! layout) still load as ungated SiLU banks, so old files keep
//! serving. The synthesized checkpoints (`synth_checkpoint_artifact`)
//! emit `w3`, so every pinned bit-identity claim covers the gated
//! path end-to-end.

use anyhow::{bail, ensure, Context, Result};
use std::collections::HashSet;
use std::fmt;
use std::path::Path;

use crate::coordinator::checkpoint::{self, Checkpoint};
use crate::experts::ExpertBank;
use crate::router::{
    RouterConfig, RouterKind, RouterParams, RouterPlan, ScoreKernel,
};
use crate::runtime::ArtifactMeta;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::attention::AttnBlock;
use super::{DecoderModel, MoeLayer, StackedModel};

/// Last `['name']` segment of a pytree key string
/// (`"['layers'][0]['moe']['router']['proto_mu']"` → `proto_mu`).
fn leaf_name(path: &str) -> Result<&str> {
    let start = path
        .rfind("['")
        .with_context(|| format!("pytree path without key segment: {path}"))?
        + 2;
    let end = path[start..]
        .find("']")
        .with_context(|| format!("unterminated pytree key: {path}"))?
        + start;
    Ok(&path[start..end])
}

/// Full pytree path of layer `l`'s MoE leaf `name`.
fn moe_leaf_path(l: usize, name: &str) -> String {
    format!("['layers'][{l}]['moe']['{name}']")
}

fn router_leaf_path(l: usize, name: &str) -> String {
    format!("['layers'][{l}]['moe']['router']['{name}']")
}

/// Full pytree path of layer `l`'s attention-sublayer leaf `name`.
fn attn_leaf_path(l: usize, name: &str) -> String {
    format!("['layers'][{l}]['attn']['{name}']")
}

/// Index of the param leaf at exactly `path`.
fn find_leaf(meta: &ArtifactMeta, path: &str) -> Result<usize> {
    meta.params.iter().position(|s| s.path == path).with_context(|| {
        format!(
            "meta '{}' has no param leaf '{path}' — checkpoint does not \
             describe an L={} MoE stack",
            meta.name, meta.config.n_layers
        )
    })
}

/// The leaf buffer at `path`, shape-checked against its spec.
fn leaf_buf<'a>(
    meta: &ArtifactMeta,
    buffers: &'a [Vec<f32>],
    path: &str,
) -> Result<&'a Vec<f32>> {
    let idx = find_leaf(meta, path)?;
    let spec = &meta.params[idx];
    let buf = buffers
        .get(idx)
        .with_context(|| format!("checkpoint has no buffer {idx} ({path})"))?;
    ensure!(
        buf.len() == spec.numel(),
        "checkpoint buffer {idx} ({path}) has {} elems, meta says {:?}",
        buf.len(),
        spec.shape
    );
    Ok(buf)
}

/// The shared [`RouterConfig`] of every layer, from the artifact's
/// model config. `n_score_heads` is recovered from the `wq` leaf shape
/// (`[H, dz, dh]`) when the metric uses it.
pub fn router_config_from_meta(meta: &ArtifactMeta) -> Result<RouterConfig> {
    let c = &meta.config;
    let kind = match c.router.as_str() {
        "vanilla" => RouterKind::Vanilla,
        "deepseek" => RouterKind::DeepSeek,
        "lpr" => RouterKind::Lpr,
        other => bail!("unknown router kind '{other}' in meta '{}'", meta.name),
    };
    if kind == RouterKind::Lpr {
        ensure!(
            ScoreKernel::parse(&c.metric).is_some(),
            "unknown routing metric '{}' in meta '{}'",
            c.metric,
            meta.name
        );
    }
    ensure!(
        c.top_k <= c.n_experts && c.top_k >= 1,
        "meta '{}': top_k {} vs {} experts",
        meta.name,
        c.top_k,
        c.n_experts
    );
    let n_score_heads = meta
        .router_params
        .iter()
        .find(|s| leaf_name(&s.path).map(|n| n == "wq").unwrap_or(false))
        .map(|s| s.shape.first().copied().unwrap_or(1))
        .unwrap_or(1)
        .max(1);
    Ok(RouterConfig {
        kind,
        d_model: c.d_model,
        n_experts: c.n_experts,
        top_k: c.top_k,
        latent_dim: c.latent_dim,
        metric: c.metric.clone(),
        unit_ball: c.unit_ball,
        gaussian_sigma: c.gaussian_sigma as f32,
        n_score_heads,
    })
}

/// Layer `ℓ`'s raw (unprojected) [`RouterParams`], matched leaf-by-leaf
/// against the `meta.router_params` template.
pub fn router_params_for_layer(
    meta: &ArtifactMeta,
    buffers: &[Vec<f32>],
    layer: usize,
) -> Result<RouterParams> {
    let mut p = RouterParams::default();
    for spec in &meta.router_params {
        let name = leaf_name(&spec.path)?;
        let path = router_leaf_path(layer, name);
        let buf = leaf_buf(meta, buffers, &path)?.clone();
        match name {
            "wg" => p.wg = buf,
            "bias" => p.bias = buf,
            "norm" => p.norm = buf,
            "w_mu" => p.w_mu = buf,
            "b_mu" => p.b_mu = buf,
            "w_lv" => p.w_lv = buf,
            "b_lv" => p.b_lv = buf,
            "proto_mu" => p.proto_mu = buf,
            "proto_lv" => p.proto_lv = buf,
            "wq" => p.wq = buf,
            "wk" => p.wk = buf,
            other => bail!(
                "meta '{}' router leaf '{other}' is not a RouterParams \
                 field",
                meta.name
            ),
        }
    }
    Ok(p)
}

/// Layer `ℓ`'s [`ExpertBank`] from the stacked expert weights: `w1`
/// (`[E, d, ff]`), `w2` (`[E, ff, d]`), and — when the checkpoint
/// carries one — the SwiGLU gate `w3` (`[E, d, ff]`), which makes the
/// bank **gated** ([`ExpertBank::from_weights_gated`]). Checkpoints
/// without a `w3` leaf load as ungated SiLU banks (module docs).
pub fn expert_bank_for_layer(
    meta: &ArtifactMeta,
    buffers: &[Vec<f32>],
    layer: usize,
) -> Result<ExpertBank> {
    let (e, d) = (meta.config.n_experts, meta.config.d_model);
    let w1_path = moe_leaf_path(layer, "w1");
    let w1_spec = &meta.params[find_leaf(meta, &w1_path)?];
    ensure!(
        w1_spec.shape.len() == 3
            && w1_spec.shape[0] == e
            && w1_spec.shape[1] == d,
        "w1 leaf {w1_path} has shape {:?}, want [{e}, {d}, ff]",
        w1_spec.shape
    );
    let d_ff = w1_spec.shape[2];
    let w2_path = moe_leaf_path(layer, "w2");
    let w2_spec = &meta.params[find_leaf(meta, &w2_path)?];
    ensure!(
        w2_spec.shape == vec![e, d_ff, d],
        "w2 leaf {w2_path} has shape {:?}, want [{e}, {d_ff}, {d}]",
        w2_spec.shape
    );
    let w1 = leaf_buf(meta, buffers, &w1_path)?.clone();
    let w2 = leaf_buf(meta, buffers, &w2_path)?.clone();
    // optional gate leaf: present -> gated SwiGLU bank
    let w3_path = moe_leaf_path(layer, "w3");
    if let Some(idx) = meta.params.iter().position(|s| s.path == w3_path) {
        let w3_spec = &meta.params[idx];
        ensure!(
            w3_spec.shape == vec![e, d, d_ff],
            "w3 leaf {w3_path} has shape {:?}, want [{e}, {d}, {d_ff}]",
            w3_spec.shape
        );
        let w3 = leaf_buf(meta, buffers, &w3_path)?.clone();
        return Ok(ExpertBank::from_weights_gated(e, d, d_ff, w1, w3, w2));
    }
    Ok(ExpertBank::from_weights(e, d, d_ff, w1, w2))
}

/// Build the `L`-layer served model from host state buffers (either the
/// parameter prefix or a full `3·P` params+Adam checkpoint — the bridge
/// reads the first `n_params` buffers either way).
pub fn model_from_state(
    meta: &ArtifactMeta,
    buffers: &[Vec<f32>],
) -> Result<StackedModel> {
    ensure!(
        buffers.len() == meta.n_params || buffers.len() == meta.n_state,
        "state has {} buffers; meta '{}' wants {} (params) or {} \
         (params + Adam moments)",
        buffers.len(),
        meta.name,
        meta.n_params,
        meta.n_state
    );
    let params = &buffers[..meta.n_params];
    let cfg = router_config_from_meta(meta)?;
    let mut layers = Vec::with_capacity(meta.config.n_layers);
    for l in 0..meta.config.n_layers {
        let rp = router_params_for_layer(meta, params, l)
            .with_context(|| format!("layer {l} router"))?;
        let bank = expert_bank_for_layer(meta, params, l)
            .with_context(|| format!("layer {l} experts"))?;
        // RouterPlan::new applies the unit-ball projection the training
        // forward applies on the fly — checkpoints carry raw prototypes.
        layers.push(MoeLayer::new(RouterPlan::new(cfg.clone(), &rp), bank));
    }
    Ok(StackedModel::new(layers))
}

/// [`model_from_state`] for a loaded checkpoint; rejects checkpoints
/// saved for a different artifact.
pub fn model_from_checkpoint(
    meta: &ArtifactMeta,
    ck: &Checkpoint,
) -> Result<StackedModel> {
    ck.expect_artifact(&meta.name)?;
    model_from_state(meta, &ck.buffers)
}

/// One-call CLI path: `artifacts/<preset>.meta.json` + a checkpoint
/// file → the served model (no PJRT; works against the vendor stub).
pub fn model_from_files(
    art_dir: &Path,
    preset: &str,
    ckpt: &Path,
) -> Result<(ArtifactMeta, StackedModel)> {
    let meta = ArtifactMeta::load(art_dir, preset)?;
    let ck = checkpoint::load(ckpt)
        .with_context(|| format!("load checkpoint {}", ckpt.display()))?;
    let model = model_from_checkpoint(&meta, &ck)?;
    Ok((meta, model))
}

// ---------------------------------------------------------------------
// Load accounting + the decode-capable (attention / embed / norm) bridge
// ---------------------------------------------------------------------

/// What a bridge load actually read from the checkpoint: every param
/// leaf is either consumed into the built model or listed in
/// `skipped` — nothing is silently ignored. A decoder load of a
/// decoder checkpoint skips nothing; an MoE-only load of the same file
/// reports the attention / embed / norm leaves it left behind, and a
/// leaf no loader recognizes (junk, renamed, future format) always
/// surfaces here instead of vanishing.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LoadSummary {
    /// Param leaves consumed into the model.
    pub consumed: usize,
    /// Pytree paths of the leaves this load did not read, in
    /// checkpoint order.
    pub skipped: Vec<String>,
}

impl fmt::Display for LoadSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.skipped.is_empty() {
            write!(f, "consumed all {} param leaves", self.consumed)
        } else {
            write!(
                f,
                "consumed {}/{} param leaves; skipped: {}",
                self.consumed,
                self.consumed + self.skipped.len(),
                self.skipped.join(", ")
            )
        }
    }
}

/// Diff `meta.params` against the paths a load consumed.
fn summarize(meta: &ArtifactMeta, consumed: &[String]) -> LoadSummary {
    let set: HashSet<&str> = consumed.iter().map(|s| s.as_str()).collect();
    let skipped: Vec<String> = meta
        .params
        .iter()
        .filter(|s| !set.contains(s.path.as_str()))
        .map(|s| s.path.clone())
        .collect();
    LoadSummary { consumed: meta.params.len() - skipped.len(), skipped }
}

/// Every path [`model_from_state`] reads (MoE-only: per-layer router
/// leaves, `w1`/`w2`, and `w3` where present).
fn moe_consumed_paths(meta: &ArtifactMeta) -> Result<Vec<String>> {
    let mut v = Vec::new();
    for l in 0..meta.config.n_layers {
        for spec in &meta.router_params {
            v.push(router_leaf_path(l, leaf_name(&spec.path)?));
        }
        v.push(moe_leaf_path(l, "w1"));
        v.push(moe_leaf_path(l, "w2"));
        let w3 = moe_leaf_path(l, "w3");
        if meta.params.iter().any(|s| s.path == w3) {
            v.push(w3);
        }
    }
    Ok(v)
}

/// [`model_from_state`] plus the [`LoadSummary`] accounting of what the
/// MoE-only load left behind.
pub fn model_from_state_summary(
    meta: &ArtifactMeta,
    buffers: &[Vec<f32>],
) -> Result<(StackedModel, LoadSummary)> {
    let model = model_from_state(meta, buffers)?;
    Ok((model, summarize(meta, &moe_consumed_paths(meta)?)))
}

/// Layer `ℓ`'s attention sublayer, when the checkpoint carries one.
/// Attention leaves are **all-or-nothing per layer**: a
/// `['layers'][ℓ]['attn']['norm']` leaf commits the layer to `wq`,
/// `wk`, `wv`, `wo` too (a partial sublayer is a corrupt checkpoint,
/// not a loadable one); no `norm` leaf means the layer has no
/// attention sublayer and loads exactly as the MoE-only bridge does.
///
/// The `wq` leaf is `[H, d, d/H]` — `n_heads` is recovered from its
/// leading dim, the same shape-borne convention as the router's
/// cross-attention `wq` — stored as `H` head-major `[d, dh]` blocks
/// and repacked here into the row-major `[d, d]` (head-split along
/// columns) layout [`AttnBlock`] multiplies with. The repack is a pure
/// permutation, so it preserves bits. `wk`/`wv`/`wo` are plain
/// `[d, d]`.
pub fn attn_for_layer(
    meta: &ArtifactMeta,
    buffers: &[Vec<f32>],
    layer: usize,
) -> Result<Option<AttnBlock>> {
    let d = meta.config.d_model;
    let norm_path = attn_leaf_path(layer, "norm");
    if !meta.params.iter().any(|s| s.path == norm_path) {
        return Ok(None);
    }
    let norm_spec = &meta.params[find_leaf(meta, &norm_path)?];
    ensure!(
        norm_spec.shape == vec![d],
        "attn norm leaf {norm_path} has shape {:?}, want [{d}]",
        norm_spec.shape
    );
    let wq_path = attn_leaf_path(layer, "wq");
    let wq_spec = &meta.params[find_leaf(meta, &wq_path)?];
    ensure!(
        wq_spec.shape.len() == 3
            && wq_spec.shape[1] == d
            && wq_spec.shape[0] * wq_spec.shape[2] == d,
        "attn wq leaf {wq_path} has shape {:?}, want [H, {d}, {d}/H]",
        wq_spec.shape
    );
    let (heads, dh) = (wq_spec.shape[0], wq_spec.shape[2]);
    let wq_raw = leaf_buf(meta, buffers, &wq_path)?;
    let mut wq = vec![0.0f32; d * d];
    for h in 0..heads {
        for r in 0..d {
            wq[r * d + h * dh..r * d + (h + 1) * dh]
                .copy_from_slice(&wq_raw[(h * d + r) * dh..(h * d + r + 1) * dh]);
        }
    }
    let square = |name: &str| -> Result<Vec<f32>> {
        let path = attn_leaf_path(layer, name);
        let spec = &meta.params[find_leaf(meta, &path)?];
        ensure!(
            spec.shape == vec![d, d],
            "attn {name} leaf {path} has shape {:?}, want [{d}, {d}]",
            spec.shape
        );
        Ok(leaf_buf(meta, buffers, &path)?.clone())
    };
    let (wk, wv, wo) = (square("wk")?, square("wv")?, square("wo")?);
    let norm = leaf_buf(meta, buffers, &norm_path)?.clone();
    Ok(Some(AttnBlock::new(heads, norm, wq, wk, wv, wo)))
}

/// Build the decode-capable model from host state buffers: the MoE
/// stack of [`model_from_state`], plus per-layer attention sublayers
/// ([`attn_for_layer`]) and the `['embed']` / `['final_norm']` leaves
/// that make up the greedy [`DecodeHead`](super::DecodeHead).
/// Checkpoints without attention leaves load as attention-less stacks
/// that serve bit-identically to the MoE-only bridge.
pub fn decoder_from_state(
    meta: &ArtifactMeta,
    buffers: &[Vec<f32>],
) -> Result<(DecoderModel, LoadSummary)> {
    ensure!(
        buffers.len() == meta.n_params || buffers.len() == meta.n_state,
        "state has {} buffers; meta '{}' wants {} (params) or {} \
         (params + Adam moments)",
        buffers.len(),
        meta.name,
        meta.n_params,
        meta.n_state
    );
    let params = &buffers[..meta.n_params];
    let cfg = router_config_from_meta(meta)?;
    let d = meta.config.d_model;
    let mut consumed = moe_consumed_paths(meta)?;
    let mut layers = Vec::with_capacity(meta.config.n_layers);
    for l in 0..meta.config.n_layers {
        let rp = router_params_for_layer(meta, params, l)
            .with_context(|| format!("layer {l} router"))?;
        let bank = expert_bank_for_layer(meta, params, l)
            .with_context(|| format!("layer {l} experts"))?;
        let attn = attn_for_layer(meta, params, l)
            .with_context(|| format!("layer {l} attention"))?;
        if attn.is_some() {
            for name in ["norm", "wq", "wk", "wv", "wo"] {
                consumed.push(attn_leaf_path(l, name));
            }
        }
        layers.push(MoeLayer::with_attn(
            RouterPlan::new(cfg.clone(), &rp),
            bank,
            attn,
        ));
    }
    let embed_path = "['embed']";
    let embed_spec = &meta.params[find_leaf(meta, embed_path)?];
    ensure!(
        embed_spec.shape.len() == 2 && embed_spec.shape[1] == d,
        "embed leaf has shape {:?}, want [vocab, {d}]",
        embed_spec.shape
    );
    let embed = leaf_buf(meta, params, embed_path)?.clone();
    let norm_path = "['final_norm']";
    let norm_spec = &meta.params[find_leaf(meta, norm_path)?];
    ensure!(
        norm_spec.shape == vec![d],
        "final_norm leaf has shape {:?}, want [{d}]",
        norm_spec.shape
    );
    let final_norm = leaf_buf(meta, params, norm_path)?.clone();
    consumed.push(embed_path.to_string());
    consumed.push(norm_path.to_string());
    let model =
        DecoderModel::new(StackedModel::new(layers), embed, final_norm);
    let summary = summarize(meta, &consumed);
    Ok((model, summary))
}

/// [`decoder_from_state`] for a loaded checkpoint; rejects checkpoints
/// saved for a different artifact.
pub fn decoder_from_checkpoint(
    meta: &ArtifactMeta,
    ck: &Checkpoint,
) -> Result<(DecoderModel, LoadSummary)> {
    ck.expect_artifact(&meta.name)?;
    decoder_from_state(meta, &ck.buffers)
}

/// One-call CLI path for `lpr generate --ckpt`: meta + checkpoint file
/// → the decode-capable model and its load accounting.
pub fn decoder_from_files(
    art_dir: &Path,
    preset: &str,
    ckpt: &Path,
) -> Result<(ArtifactMeta, DecoderModel, LoadSummary)> {
    let meta = ArtifactMeta::load(art_dir, preset)?;
    let ck = checkpoint::load(ckpt)
        .with_context(|| format!("load checkpoint {}", ckpt.display()))?;
    let (model, summary) = decoder_from_checkpoint(&meta, &ck)?;
    Ok((meta, model, summary))
}

// ---------------------------------------------------------------------
// Synthesized checkpoint artifacts (tests + offline demos)
// ---------------------------------------------------------------------

fn leaf_json(path: &str, shape: &[usize]) -> Json {
    obj(vec![
        ("path", Json::Str(path.to_string())),
        (
            "shape",
            Json::Arr(shape.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
        ("dtype", Json::Str("float32".to_string())),
    ])
}

/// Synthesize a self-consistent `(ArtifactMeta, full 3·P host state)`
/// for an `L`-layer LPR model — the same flat-buffer contract `aot.py`
/// emits, built without python or PJRT. Params use the §2.4 synthetic
/// init (hypersphere prototypes, small log-variances); Adam moments are
/// zeros, as after step 0. Used by the bridge acceptance tests and any
/// offline `train → ckpt → serve` demo.
#[allow(clippy::too_many_arguments)]
pub fn synth_checkpoint_artifact(
    name: &str,
    metric: &str,
    n_layers: usize,
    d: usize,
    dz: usize,
    e: usize,
    k: usize,
    d_ff: usize,
    seed: u64,
) -> Result<(ArtifactMeta, Vec<Vec<f32>>)> {
    synth_artifact_impl(name, metric, n_layers, d, dz, e, k, d_ff, seed, None)
}

/// [`synth_checkpoint_artifact`] plus per-layer attention sublayers:
/// each layer additionally carries `['attn']['norm'|'wq'|'wk'|'wv'|'wo']`
/// leaves (`wq` in the `[H, d, d/H]` head-major layout
/// [`attn_for_layer`] repacks), making the artifact loadable through
/// [`decoder_from_state`] as a full decode stack. `d` must split
/// evenly into `n_heads`. The attention-less
/// [`synth_checkpoint_artifact`] is byte-for-byte what it always was —
/// the two share one generator, and the attention draws only happen
/// when requested.
#[allow(clippy::too_many_arguments)]
pub fn synth_decoder_artifact(
    name: &str,
    metric: &str,
    n_layers: usize,
    d: usize,
    dz: usize,
    e: usize,
    k: usize,
    d_ff: usize,
    n_heads: usize,
    seed: u64,
) -> Result<(ArtifactMeta, Vec<Vec<f32>>)> {
    assert!(
        n_heads >= 1 && d % n_heads == 0,
        "d_model {d} must split evenly into {n_heads} heads"
    );
    synth_artifact_impl(
        name,
        metric,
        n_layers,
        d,
        dz,
        e,
        k,
        d_ff,
        seed,
        Some(n_heads),
    )
}

#[allow(clippy::too_many_arguments)]
fn synth_artifact_impl(
    name: &str,
    metric: &str,
    n_layers: usize,
    d: usize,
    dz: usize,
    e: usize,
    k: usize,
    d_ff: usize,
    seed: u64,
    attn_heads: Option<usize>,
) -> Result<(ArtifactMeta, Vec<Vec<f32>>)> {
    assert!(n_layers >= 1 && d >= 1 && dz >= 1 && e >= 1 && d_ff >= 1);
    let heads = 4usize;
    let dh = dz.div_euclid(heads).max(1);
    let vocab = 32usize;
    let xattn = metric == "xattn";

    let mut rng = Rng::new(seed);
    let mut normal = |n: usize, scale: f32| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    };

    // (path, shape, buffer) triples in flatten order: embed, per-layer
    // router + expert leaves, final_norm. The embed/final_norm leaves
    // exist to prove the bridge skips non-MoE parameters.
    let mut leaves: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
    leaves.push((
        "['embed']".to_string(),
        vec![vocab, d],
        normal(vocab * d, 0.02),
    ));
    let mut router_template: Vec<(&str, Vec<usize>)> = vec![
        ("norm", vec![d]),
        ("w_mu", vec![d, dz]),
        ("b_mu", vec![dz]),
        ("w_lv", vec![d, dz]),
        ("b_lv", vec![dz]),
        ("proto_mu", vec![e, dz]),
        ("proto_lv", vec![e, dz]),
    ];
    if xattn {
        router_template.push(("wq", vec![heads, dz, dh]));
        router_template.push(("wk", vec![heads, dz, dh]));
    }
    for l in 0..n_layers {
        if let Some(h) = attn_heads {
            let adh = d / h;
            let scale = 1.0 / (d as f32).sqrt();
            leaves.push((attn_leaf_path(l, "norm"), vec![d], vec![1.0; d]));
            leaves.push((
                attn_leaf_path(l, "wq"),
                vec![h, d, adh],
                normal(d * d, scale),
            ));
            for nm in ["wk", "wv", "wo"] {
                leaves.push((
                    attn_leaf_path(l, nm),
                    vec![d, d],
                    normal(d * d, scale),
                ));
            }
        }
        for (rname, shape) in &router_template {
            let numel: usize = shape.iter().product();
            let buf = match *rname {
                "norm" => vec![1.0f32; numel],
                "w_mu" => normal(numel, 1.0 / (d as f32).sqrt()),
                "b_mu" => vec![0.0; numel],
                "w_lv" => normal(numel, 0.01),
                "b_lv" => vec![-4.0; numel],
                "proto_mu" => {
                    let mut p = normal(numel, 1.0);
                    for row in p.chunks_mut(dz) {
                        let norm: f32 =
                            row.iter().map(|x| x * x).sum::<f32>().sqrt();
                        if norm > 0.0 {
                            row.iter_mut().for_each(|x| *x /= norm);
                        }
                    }
                    p
                }
                "proto_lv" => vec![-2.0; numel],
                _ => normal(numel, 0.3), // wq / wk
            };
            leaves.push((router_leaf_path(l, rname), shape.clone(), buf));
        }
        leaves.push((
            moe_leaf_path(l, "w1"),
            vec![e, d, d_ff],
            normal(e * d * d_ff, 1.0 / (d as f32).sqrt()),
        ));
        leaves.push((
            moe_leaf_path(l, "w3"),
            vec![e, d, d_ff],
            normal(e * d * d_ff, 1.0 / (d as f32).sqrt()),
        ));
        leaves.push((
            moe_leaf_path(l, "w2"),
            vec![e, d_ff, d],
            normal(e * d_ff * d, 1.0 / (d_ff as f32).sqrt()),
        ));
    }
    leaves.push(("['final_norm']".to_string(), vec![d], vec![1.0; d]));

    let n_params = leaves.len();
    let param_count: usize =
        leaves.iter().map(|(_, s, _)| s.iter().product::<usize>()).sum();
    let params_json = Json::Arr(
        leaves.iter().map(|(p, s, _)| leaf_json(p, s)).collect(),
    );
    let router_params_json = Json::Arr(
        router_template
            .iter()
            .map(|(rname, shape)| leaf_json(&format!("['{rname}']"), shape))
            .collect(),
    );
    let config = obj(vec![
        ("name", Json::Str(name.to_string())),
        ("arch", Json::Str("qwen3".to_string())),
        ("router", Json::Str("lpr".to_string())),
        ("metric", Json::Str(metric.to_string())),
        ("vocab", Json::Num(vocab as f64)),
        ("d_model", Json::Num(d as f64)),
        ("n_layers", Json::Num(n_layers as f64)),
        ("n_experts", Json::Num(e as f64)),
        ("top_k", Json::Num(k as f64)),
        ("latent_dim", Json::Num(dz as f64)),
        ("total_steps", Json::Num(10.0)),
        ("batch_size", Json::Num(2.0)),
        ("seq_len", Json::Num(8.0)),
        ("capacity_factor", Json::Num(1.25)),
        ("unit_ball", Json::Bool(true)),
        ("hypersphere_init", Json::Bool(true)),
        ("gaussian_sigma", Json::Num(1.0)),
    ]);
    let meta_json = obj(vec![
        ("name", Json::Str(name.to_string())),
        ("config", config),
        ("n_params", Json::Num(n_params as f64)),
        ("n_state", Json::Num(3.0 * n_params as f64)),
        ("params", params_json),
        ("router_params", router_params_json),
        (
            "metric_names",
            Json::Arr(vec![
                Json::Str("loss".to_string()),
                Json::Str("lr".to_string()),
            ]),
        ),
        (
            "eval_metric_names",
            Json::Arr(vec![
                Json::Str("loss".to_string()),
                Json::Str("drop_frac".to_string()),
            ]),
        ),
        (
            "load_shape",
            Json::Arr(vec![
                Json::Num(n_layers as f64),
                Json::Num(e as f64),
            ]),
        ),
        (
            "batch_shape",
            Json::Arr(vec![Json::Num(2.0), Json::Num(8.0)]),
        ),
        (
            "default_loss_weights",
            Json::Arr(vec![Json::Num(0.0); 8]),
        ),
        ("param_count", Json::Num(param_count as f64)),
    ]);
    let meta = ArtifactMeta::from_json(&meta_json)?;

    // full 3·P state: params, then zeroed Adam m/v (step-0 moments)
    let mut state: Vec<Vec<f32>> =
        leaves.into_iter().map(|(_, _, b)| b).collect();
    for _ in 0..2 {
        for i in 0..n_params {
            state.push(vec![0.0f32; state[i].len()]);
        }
    }
    Ok((meta, state))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::plan::OverflowPolicy;
    use crate::model::{ModelEngine, ModelForward};
    use crate::serve::PoolEngine;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("lpr-bridge-{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn leaf_name_parses_pytree_paths() {
        assert_eq!(leaf_name("['proto_mu']").unwrap(), "proto_mu");
        assert_eq!(
            leaf_name("['layers'][3]['moe']['router']['w_mu']").unwrap(),
            "w_mu"
        );
        assert!(leaf_name("no-brackets").is_err());
    }

    #[test]
    fn bridge_builds_the_described_stack() {
        let (meta, state) = synth_checkpoint_artifact(
            "m", "cosine", 3, 16, 8, 6, 2, 10, 7,
        )
        .unwrap();
        assert_eq!(state.len(), meta.n_state);
        let model = model_from_state(&meta, &state).unwrap();
        assert_eq!(model.n_layers(), 3);
        assert_eq!(model.d_model(), 16);
        assert_eq!(model.layer(0).plan.cfg.n_experts, 6);
        assert_eq!(model.layer(0).bank.d_ff, 10);
        // synthesized checkpoints carry w3, so every bank is gated
        for l in 0..3 {
            assert!(model.layer(l).bank.is_gated(), "layer {l}");
        }
        // params-only prefix builds the same model
        let model2 =
            model_from_state(&meta, &state[..meta.n_params]).unwrap();
        let h = rand_vec(&mut Rng::new(3), 12 * 16);
        let mut a = ModelEngine::new(model, 1);
        let mut b = ModelEngine::new(model2, 1);
        let (mut fa, mut fb) = (ModelForward::new(), ModelForward::new());
        a.forward(&h, 1.25, OverflowPolicy::Drop, &mut fa);
        b.forward(&h, 1.25, OverflowPolicy::Drop, &mut fb);
        assert_eq!(fa.hidden, fb.hidden);
    }

    /// The `w3` gate leaves are **consumed**: perturbing only a `w3`
    /// buffer changes the served outputs (the old ignore-`w3` bridge
    /// would have produced identical hidden states).
    #[test]
    fn w3_leaves_are_consumed_and_change_served_outputs() {
        let (meta, state) = synth_checkpoint_artifact(
            "m", "cosine", 1, 16, 8, 4, 2, 8, 13,
        )
        .unwrap();
        let params = &state[..meta.n_params];
        let base = model_from_state(&meta, params).unwrap();
        assert!(base.layer(0).bank.is_gated());
        let w3_path = moe_leaf_path(0, "w3");
        let w3_idx = meta
            .params
            .iter()
            .position(|s| s.path == w3_path)
            .unwrap();
        let mut bent = params.to_vec();
        for v in &mut bent[w3_idx] {
            *v += 0.5;
        }
        let bent_model = model_from_state(&meta, &bent).unwrap();

        let h = rand_vec(&mut Rng::new(17), 10 * 16);
        let mut a = ModelEngine::new(base, 1);
        let mut b = ModelEngine::new(bent_model, 1);
        let (mut fa, mut fb) = (ModelForward::new(), ModelForward::new());
        a.forward(&h, 1.25, OverflowPolicy::Drop, &mut fa);
        b.forward(&h, 1.25, OverflowPolicy::Drop, &mut fb);
        assert_ne!(
            fa.hidden, fb.hidden,
            "w3 must be consumed by the serving path"
        );
        // routing is upstream of the FFN and must not move
        assert_eq!(fa.layers[0].plan, fb.layers[0].plan);
    }

    /// Checkpoints in the pre-gate layout (no `w3` leaves) still load,
    /// as ungated SiLU banks.
    #[test]
    fn checkpoints_without_w3_load_as_ungated_banks() {
        let (mut meta, state) = synth_checkpoint_artifact(
            "m", "cosine", 2, 16, 8, 4, 2, 8, 9,
        )
        .unwrap();
        let keep: Vec<usize> = meta
            .params
            .iter()
            .enumerate()
            .filter(|(_, s)| leaf_name(&s.path).unwrap() != "w3")
            .map(|(i, _)| i)
            .collect();
        let stripped: Vec<Vec<f32>> =
            keep.iter().map(|&i| state[i].clone()).collect();
        meta.params =
            keep.iter().map(|&i| meta.params[i].clone()).collect();
        meta.n_params = meta.params.len();
        meta.n_state = 3 * meta.n_params;
        let model = model_from_state(&meta, &stripped).unwrap();
        for l in 0..2 {
            assert!(!model.layer(l).bank.is_gated(), "layer {l}");
        }
        // and it still serves
        let h = rand_vec(&mut Rng::new(29), 6 * 16);
        let mut eng = ModelEngine::new(model, 1);
        let mut out = ModelForward::new();
        eng.forward(&h, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.hidden.len(), 6 * 16);
    }

    #[test]
    fn bridge_rejects_truncated_and_mismatched_state() {
        let (meta, state) = synth_checkpoint_artifact(
            "m", "cosine", 2, 16, 8, 4, 2, 8, 1,
        )
        .unwrap();
        // wrong buffer count
        assert!(model_from_state(&meta, &state[..3]).is_err());
        // right count, wrong leaf size
        let mut bad = state[..meta.n_params].to_vec();
        bad[1] = vec![0.0; 1];
        let err = model_from_state(&meta, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("elems"), "{err:#}");
    }

    #[test]
    fn bridge_handles_xattn_heads() {
        let (meta, state) = synth_checkpoint_artifact(
            "x", "xattn", 2, 16, 8, 4, 2, 8, 5,
        )
        .unwrap();
        let cfg = router_config_from_meta(&meta).unwrap();
        assert_eq!(cfg.n_score_heads, 4);
        let model = model_from_state(&meta, &state).unwrap();
        let h = rand_vec(&mut Rng::new(11), 9 * 16);
        let mut eng = ModelEngine::new(model, 2);
        let mut out = ModelForward::new();
        eng.forward(&h, 1.25, OverflowPolicy::Drop, &mut out);
        assert_eq!(out.hidden.len(), 9 * 16);
    }

    /// Acceptance: an L=4 model built from a **synthesized checkpoint
    /// file** (saved + loaded through `coordinator::checkpoint`, no
    /// PJRT) runs `ModelForward` through `serve::PoolEngine`
    /// bit-identically for every tested worker count, and equals the
    /// scoped `ModelEngine`.
    #[test]
    fn l4_checkpoint_model_serves_bit_identically_across_workers() {
        let (meta, state) = synth_checkpoint_artifact(
            "l4-serve", "cosine", 4, 16, 8, 6, 2, 10, 23,
        )
        .unwrap();
        let dir = temp_dir("l4");
        let path = dir.join("l4.ckpt");
        checkpoint::save(&path, "l4-serve", 10, &state).unwrap();
        let ck = checkpoint::load(&path).unwrap();
        let model = model_from_checkpoint(&meta, &ck).unwrap();

        let h = rand_vec(&mut Rng::new(41), 61 * 16);
        let mut scoped = ModelEngine::new(model.clone(), 1);
        let mut want = ModelForward::new();
        scoped.forward(&h, 1.0, OverflowPolicy::LeastLoaded, &mut want);
        for workers in [1usize, 2, 3, 8] {
            let mut pool = PoolEngine::from_model(model.clone(), workers);
            let mut got = ModelForward::new();
            pool.forward_model(
                &h,
                1.0,
                OverflowPolicy::LeastLoaded,
                &mut got,
            );
            assert_eq!(got.hidden, want.hidden, "workers={workers}");
            for l in 0..4 {
                assert_eq!(
                    got.layers[l].combined, want.layers[l].combined,
                    "layer {l} workers={workers}"
                );
                assert_eq!(got.layers[l].batch, want.layers[l].batch);
                assert_eq!(got.layers[l].plan, want.layers[l].plan);
            }
        }
    }

    /// Satellite: nothing is silently ignored. An MoE-only load
    /// reports the embed / final-norm leaves it leaves behind, and a
    /// junk leaf no loader recognizes surfaces in the summary instead
    /// of vanishing.
    #[test]
    fn load_summary_reports_skipped_and_junk_leaves() {
        use crate::runtime::LeafSpec;
        let (mut meta, state) = synth_checkpoint_artifact(
            "m", "cosine", 2, 16, 8, 4, 2, 8, 3,
        )
        .unwrap();
        let mut bufs = state[..meta.n_params].to_vec();
        meta.params.push(LeafSpec {
            path: "['junk']".to_string(),
            shape: vec![5],
            dtype: "float32".to_string(),
        });
        meta.n_params += 1;
        meta.n_state = 3 * meta.n_params;
        bufs.push(vec![0.5; 5]);

        let (model, summary) =
            model_from_state_summary(&meta, &bufs).unwrap();
        assert_eq!(model.n_layers(), 2);
        assert_eq!(
            summary.skipped,
            vec![
                "['embed']".to_string(),
                "['final_norm']".to_string(),
                "['junk']".to_string(),
            ]
        );
        assert_eq!(summary.consumed, meta.params.len() - 3);
        let line = summary.to_string();
        assert!(line.contains("['junk']"), "{line}");

        // the decoder load consumes embed/final_norm but still flags
        // the junk leaf
        let (_, dsum) = decoder_from_state(&meta, &bufs).unwrap();
        assert_eq!(dsum.skipped, vec!["['junk']".to_string()]);
    }

    /// A decoder artifact (attention + embed + final-norm leaves)
    /// round-trips through a checkpoint file into a decode-capable
    /// model with nothing skipped, and the head-count survives via the
    /// `wq` leaf shape.
    #[test]
    fn decoder_artifact_loads_with_attention_and_head() {
        let (meta, state) = synth_decoder_artifact(
            "dec", "cosine", 2, 16, 8, 4, 2, 8, 4, 31,
        )
        .unwrap();
        let dir = temp_dir("dec");
        let path = dir.join("dec.ckpt");
        checkpoint::save(&path, "dec", 5, &state).unwrap();
        let ck = checkpoint::load(&path).unwrap();
        let (dec, summary) = decoder_from_checkpoint(&meta, &ck).unwrap();
        assert!(summary.skipped.is_empty(), "{summary}");
        assert_eq!(summary.consumed, meta.params.len());
        assert!(dec.model().has_attn());
        assert_eq!(dec.model().layer(0).attn.as_ref().unwrap().n_heads(), 4);
        assert_eq!(dec.head().vocab(), 32);
        assert_eq!(dec.head().d_model(), 16);
    }

    /// Checkpoints without attention leaves load through the decoder
    /// bridge as attention-less stacks that serve **bit-identically**
    /// to the MoE-only bridge — the backward-compatibility half of the
    /// tentpole contract.
    #[test]
    fn attention_less_decoder_load_matches_moe_only_bridge() {
        let (meta, state) = synth_checkpoint_artifact(
            "m", "cosine", 2, 16, 8, 4, 2, 8, 19,
        )
        .unwrap();
        let moe_model = model_from_state(&meta, &state).unwrap();
        let (dec, _) = decoder_from_state(&meta, &state).unwrap();
        assert!(!dec.model().has_attn());
        let h = rand_vec(&mut Rng::new(5), 7 * 16);
        let mut a = ModelEngine::new(moe_model, 2);
        let mut b = ModelEngine::new(dec.into_parts().0, 2);
        let (mut fa, mut fb) = (ModelForward::new(), ModelForward::new());
        a.forward(&h, 1.25, OverflowPolicy::Drop, &mut fa);
        b.forward(&h, 1.25, OverflowPolicy::Drop, &mut fb);
        assert_eq!(fa.hidden, fb.hidden);
    }

    /// A partial attention sublayer (norm present, projections missing)
    /// is a load error, not a silently attention-less layer.
    #[test]
    fn partial_attention_sublayer_is_rejected() {
        let (mut meta, state) = synth_decoder_artifact(
            "dec", "cosine", 1, 16, 8, 4, 2, 8, 4, 2,
        )
        .unwrap();
        let wq_path = attn_leaf_path(0, "wq");
        let keep: Vec<usize> = meta
            .params
            .iter()
            .enumerate()
            .filter(|(_, s)| s.path != wq_path)
            .map(|(i, _)| i)
            .collect();
        let bufs: Vec<Vec<f32>> =
            keep.iter().map(|&i| state[i].clone()).collect();
        meta.params =
            keep.iter().map(|&i| meta.params[i].clone()).collect();
        meta.n_params = meta.params.len();
        meta.n_state = 3 * meta.n_params;
        let err = decoder_from_state(&meta, &bufs).unwrap_err();
        assert!(format!("{err:#}").contains("attn"), "{err:#}");
    }

    #[test]
    fn checkpoint_artifact_name_is_enforced() {
        let (meta, state) = synth_checkpoint_artifact(
            "right", "cosine", 1, 8, 4, 4, 2, 6, 2,
        )
        .unwrap();
        let dir = temp_dir("name");
        let path = dir.join("wrong.ckpt");
        checkpoint::save(&path, "some-other-artifact", 3, &state).unwrap();
        let ck = checkpoint::load(&path).unwrap();
        let err = model_from_checkpoint(&meta, &ck).unwrap_err();
        assert!(
            format!("{err:#}").contains("some-other-artifact"),
            "{err:#}"
        );
    }
}
