//! Multi-head causal self-attention sublayer for the decode subsystem.
//!
//! [`AttnBlock`] is the pre-norm attention half of a transformer block:
//! `h += W_o · attend(RMSNorm(h) · W_q, K, V)` with keys/values appended
//! to a per-request [`KvCache`](super::cache::KvCache) slot, followed by
//! the existing MoE block (`h += moe(h)`). No positional encoding is
//! applied (RoPE is a noted follow-up); position enters only through
//! the causal mask, which is enough to make decode-time routing
//! measurable.
//!
//! # The decode ≡ prefill bitwise contract
//!
//! Decoding token-at-a-time through the cache must produce *bitwise*
//! the same hidden states as one full-sequence prefill. That holds by
//! construction because every stage is **row-independent with a fixed
//! reduction order**:
//!
//! - RMSNorm and the Q/K/V/O projections use
//!   [`rms_norm_rows_into`] / [`matmul_into`], whose per-row
//!   accumulation order (`k` ascending) does not depend on how many
//!   rows are in the call;
//! - the attention scores for the query at absolute position `p` are
//!   computed over keys `0..=p` in ascending key order, max-folded and
//!   normalized in that same order, and the value reduction walks keys
//!   ascending — identical float operations whether the call carries
//!   one new row (decode) or the whole sequence (prefill).
//!
//! So a stacked forward over `[prompt]` followed by `T` single-token
//! forwards equals one forward over `[prompt + T tokens]`, bit for bit,
//! per layer — which composes with the MoE stage's own per-token
//! determinism as long as no token is dropped (capacity bins scale with
//! batch size, so a dropping configuration is *not* batch-invariant;
//! see `engine::decode`). Attention always runs on the **caller's
//! thread**, sequentially, in both backends, so thread-count and
//! backend invariance are inherited rather than re-proven.

use crate::router::linalg::{matmul_into, rms_norm_rows_into, softmax_rows};
use crate::util::rng::Rng;

/// Reusable buffers of one attention forward (normed input, Q rows,
/// per-head scores, context rows, output rows). Lives in
/// [`ModelForward`](super::ModelForward) so both backends share one
/// steady-state-allocation-free scratch across layers and calls.
#[derive(Debug, Clone, Default)]
pub struct AttnScratch {
    x: Vec<f32>,
    q: Vec<f32>,
    scores: Vec<f32>,
    ctx: Vec<f32>,
    out: Vec<f32>,
}

/// One layer's multi-head causal self-attention parameters: RMSNorm
/// scale `norm` (`[d]`) and square projections `wq`/`wk`/`wv`/`wo`
/// (`[d, d]` row-major), split into `n_heads` heads of `d / n_heads`
/// lanes each.
#[derive(Debug, Clone)]
pub struct AttnBlock {
    n_heads: usize,
    norm: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
}

impl AttnBlock {
    pub fn new(
        n_heads: usize,
        norm: Vec<f32>,
        wq: Vec<f32>,
        wk: Vec<f32>,
        wv: Vec<f32>,
        wo: Vec<f32>,
    ) -> AttnBlock {
        let d = norm.len();
        assert!(n_heads >= 1, "attention needs at least one head");
        assert!(d >= 1, "norm must be [d]");
        assert_eq!(
            d % n_heads,
            0,
            "d_model {d} must split evenly into {n_heads} heads"
        );
        for (name, w) in
            [("wq", &wq), ("wk", &wk), ("wv", &wv), ("wo", &wo)]
        {
            assert_eq!(w.len(), d * d, "{name} must be [{d}, {d}]");
        }
        AttnBlock { n_heads, norm, wq, wk, wv, wo }
    }

    pub fn d_model(&self) -> usize {
        self.norm.len()
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    /// Run the sublayer over `n` new rows of `h` (`[n, d]`, updated in
    /// place: `h += attn(norm(h))`), appending the rows' keys/values to
    /// `k_cache`/`v_cache` — one (slot, layer) pair of buffers already
    /// holding the sequence's past positions. The caller commits the
    /// new positions via [`KvCache::advance`](super::cache::KvCache::advance)
    /// once every layer has appended.
    pub fn forward(
        &self,
        h: &mut [f32],
        n: usize,
        k_cache: &mut Vec<f32>,
        v_cache: &mut Vec<f32>,
        scratch: &mut AttnScratch,
    ) {
        let d = self.d_model();
        assert_eq!(h.len(), n * d, "h must be [n, d]");
        assert_eq!(k_cache.len() % d, 0, "k cache must be [past, d]");
        assert_eq!(k_cache.len(), v_cache.len(), "k/v cache shapes");
        let past = k_cache.len() / d;
        let dh = d / self.n_heads;
        let scale = 1.0 / (dh as f32).sqrt();

        // pre-norm + projections (row-independent: module docs)
        scratch.x.resize(n * d, 0.0);
        rms_norm_rows_into(h, &self.norm, &mut scratch.x, n, d);
        scratch.q.resize(n * d, 0.0);
        matmul_into(&scratch.x, &self.wq, &mut scratch.q, n, d, d);
        let off = past * d;
        k_cache.resize(off + n * d, 0.0);
        matmul_into(&scratch.x, &self.wk, &mut k_cache[off..], n, d, d);
        v_cache.resize(off + n * d, 0.0);
        matmul_into(&scratch.x, &self.wv, &mut v_cache[off..], n, d, d);

        // causal attention: query i (absolute position past + i) over
        // keys 0..=past+i, ascending — the fixed reduction order the
        // decode ≡ prefill contract depends on
        scratch.ctx.resize(n * d, 0.0);
        for i in 0..n {
            let p = past + i;
            for head in 0..self.n_heads {
                let hs = head * dh;
                let qv = &scratch.q[i * d + hs..i * d + hs + dh];
                scratch.scores.clear();
                for j in 0..=p {
                    let kv = &k_cache[j * d + hs..j * d + hs + dh];
                    let mut s = 0.0f32;
                    for (a, b) in qv.iter().zip(kv) {
                        s += a * b;
                    }
                    scratch.scores.push(s * scale);
                }
                softmax_rows(&mut scratch.scores, 1, p + 1);
                let ctx = &mut scratch.ctx[i * d + hs..i * d + hs + dh];
                ctx.fill(0.0);
                for (j, &w) in scratch.scores.iter().enumerate() {
                    let vv = &v_cache[j * d + hs..j * d + hs + dh];
                    for (c, &vx) in ctx.iter_mut().zip(vv) {
                        *c += w * vx;
                    }
                }
            }
        }

        // output projection, then the residual add in place
        scratch.out.resize(n * d, 0.0);
        matmul_into(&scratch.ctx, &self.wo, &mut scratch.out, n, d, d);
        for (hv, &o) in h.iter_mut().zip(&scratch.out) {
            *hv += o;
        }
    }
}

/// Deterministic synthetic attention block: unit norm scales and
/// `1/sqrt(d)`-scaled normal projections, drawn from `rng` in a fixed
/// field order — the attention sibling of
/// [`synthetic_stacked_model`](super::synthetic_stacked_model)'s
/// per-layer init.
pub fn synthetic_attn(rng: &mut Rng, d: usize, n_heads: usize) -> AttnBlock {
    let scale = 1.0 / (d as f32).sqrt();
    let mut normal =
        |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * scale).collect()
        };
    let wq = normal(d * d);
    let wk = normal(d * d);
    let wv = normal(d * d);
    let wo = normal(d * d);
    AttnBlock::new(n_heads, vec![1.0; d], wq, wk, wv, wo)
}

#[cfg(test)]
mod tests {
    use super::*;

    const D: usize = 16;
    const H: usize = 4;

    fn rand_rows(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * D).map(|_| rng.normal() as f32).collect()
    }

    fn block(seed: u64) -> AttnBlock {
        synthetic_attn(&mut Rng::new(seed), D, H)
    }

    #[test]
    fn forward_is_causal() {
        // perturbing the last token must not move any earlier row
        let attn = block(3);
        let t = 6;
        let h0 = rand_rows(11, t);
        let mut h1 = h0.clone();
        for v in &mut h1[(t - 1) * D..] {
            *v += 1.0;
        }
        let (mut a, mut b) = (h0.clone(), h1.clone());
        let mut s = AttnScratch::default();
        let (mut k0, mut v0) = (Vec::new(), Vec::new());
        attn.forward(&mut a, t, &mut k0, &mut v0, &mut s);
        let (mut k1, mut v1) = (Vec::new(), Vec::new());
        attn.forward(&mut b, t, &mut k1, &mut v1, &mut s);
        assert_eq!(
            &a[..(t - 1) * D],
            &b[..(t - 1) * D],
            "future tokens leaked into the past"
        );
        assert_ne!(&a[(t - 1) * D..], &b[(t - 1) * D..]);
        // and the sublayer actually did something
        assert_ne!(a, h0);
    }

    #[test]
    fn cached_decode_is_bitwise_prefill() {
        let attn = block(7);
        let t = 9;
        let h = rand_rows(13, t);
        // prefill: all rows in one call
        let mut pre = h.clone();
        let mut s = AttnScratch::default();
        let (mut kp, mut vp) = (Vec::new(), Vec::new());
        attn.forward(&mut pre, t, &mut kp, &mut vp, &mut s);
        // decode: one row at a time through a growing cache
        let (mut kd, mut vd) = (Vec::new(), Vec::new());
        let mut dec = Vec::new();
        for i in 0..t {
            let mut row = h[i * D..(i + 1) * D].to_vec();
            attn.forward(&mut row, 1, &mut kd, &mut vd, &mut s);
            dec.extend_from_slice(&row);
        }
        assert_eq!(dec, pre, "decode-with-cache diverged from prefill");
        assert_eq!(kd, kp);
        assert_eq!(vd, vp);
        // ragged splits too: [0..4) then [4..t)
        let (mut kr, mut vr) = (Vec::new(), Vec::new());
        let mut rag = h.clone();
        let (head, tail) = rag.split_at_mut(4 * D);
        attn.forward(head, 4, &mut kr, &mut vr, &mut s);
        attn.forward(tail, t - 4, &mut kr, &mut vr, &mut s);
        assert_eq!(rag, pre);
    }

    #[test]
    fn synthetic_is_deterministic_in_the_seed() {
        let a = block(5);
        let b = block(5);
        let c = block(6);
        let mut s = AttnScratch::default();
        let h = rand_rows(1, 3);
        let (mut ha, mut hb, mut hc) = (h.clone(), h.clone(), h);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        a.forward(&mut ha, 3, &mut k, &mut v, &mut s);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        b.forward(&mut hb, 3, &mut k, &mut v, &mut s);
        let (mut k, mut v) = (Vec::new(), Vec::new());
        c.forward(&mut hc, 3, &mut k, &mut v, &mut s);
        assert_eq!(ha, hb);
        assert_ne!(ha, hc);
        assert_eq!(a.d_model(), D);
        assert_eq!(a.n_heads(), H);
    }

    #[test]
    #[should_panic(expected = "split evenly")]
    fn ragged_heads_are_rejected() {
        AttnBlock::new(
            3,
            vec![1.0; D],
            vec![0.0; D * D],
            vec![0.0; D * D],
            vec![0.0; D * D],
            vec![0.0; D * D],
        );
    }
}
