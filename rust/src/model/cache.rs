//! Per-request KV cache for the autoregressive decode path.
//!
//! A [`KvCache`] owns a fixed number of **slots**, one per in-flight
//! request. Each slot holds, per model layer, the key and value rows of
//! every token the request has pushed through the stack so far — the
//! state that makes token-at-a-time decode O(T) per step instead of
//! O(T²) re-prefill. Slots are recycled through a free list:
//! [`KvCache::alloc`] hands out the lowest free slot, [`KvCache::free`]
//! resets it and returns it to the pool, so a long-running
//! [`DecodeSession`](crate::engine::decode::DecodeSession) serves an
//! unbounded request stream with bounded memory.
//!
//! # Capacity bound
//!
//! Every slot is bounded by `max_seq` positions. The bound is enforced
//! *before* a forward touches the cache — [`KvCache::check_capacity`]
//! returns the typed [`CacheError::Overflow`] — so an over-long request
//! is refused at submission instead of corrupting a mid-stack append.
//!
//! # Layout
//!
//! Slot `s`, layer `l` keeps two row-major `[t, d_model]` buffers
//! (`t` = tokens cached so far). Appends happen inside
//! [`AttnBlock::forward`](super::attention::AttnBlock::forward), one
//! layer at a time during a stacked forward; the per-slot length is
//! advanced once per forward by [`KvCache::advance`] after every layer
//! has appended. Buffers keep their allocation across [`KvCache::reset`]
//! so steady-state decode does not allocate.

use std::fmt;

/// One contiguous run of rows in a ragged step batch: `n_tokens` new
/// positions for the request holding cache slot `slot`. The rows of a
/// `[N, d]` batch are consumed span by span, in span order — span `i`'s
/// rows start where span `i-1`'s ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqSpan {
    /// Cache slot of the sequence these rows extend.
    pub slot: usize,
    /// New positions in this forward (1 for a decode step, the prompt
    /// length for a prefill).
    pub n_tokens: usize,
}

/// Typed cache failures. `Overflow` is the per-slot `max_seq` bound;
/// `NoFreeSlot` means every slot is held by an in-flight request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheError {
    /// `len + add` would exceed the slot's `max_seq` bound.
    Overflow { slot: usize, len: usize, add: usize, max_seq: usize },
    /// All slots are allocated.
    NoFreeSlot { n_slots: usize },
}

impl fmt::Display for CacheError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            CacheError::Overflow { slot, len, add, max_seq } => write!(
                f,
                "kv cache slot {slot} holds {len} positions; appending \
                 {add} exceeds the max_seq bound of {max_seq}"
            ),
            CacheError::NoFreeSlot { n_slots } => write!(
                f,
                "all {n_slots} kv cache slots are held by in-flight \
                 requests"
            ),
        }
    }
}

impl std::error::Error for CacheError {}

/// Slot-pooled per-layer key/value cache (module docs).
#[derive(Debug, Clone)]
pub struct KvCache {
    n_slots: usize,
    n_layers: usize,
    d_model: usize,
    max_seq: usize,
    /// `[n_slots * n_layers]` key buffers, each row-major `[t, d]`.
    k: Vec<Vec<f32>>,
    /// `[n_slots * n_layers]` value buffers, same layout.
    v: Vec<Vec<f32>>,
    /// Cached positions per slot (committed by [`Self::advance`]).
    lens: Vec<usize>,
    /// Allocation state per slot.
    live: Vec<bool>,
}

impl KvCache {
    /// A cache with `n_slots` request slots for an `n_layers` stack of
    /// width `d_model`, each slot bounded to `max_seq` positions.
    pub fn new(
        n_slots: usize,
        n_layers: usize,
        d_model: usize,
        max_seq: usize,
    ) -> KvCache {
        assert!(n_slots >= 1, "a cache needs at least one slot");
        assert!(n_layers >= 1 && d_model >= 1, "cache shape");
        assert!(max_seq >= 1, "max_seq must be >= 1");
        KvCache {
            n_slots,
            n_layers,
            d_model,
            max_seq,
            k: vec![Vec::new(); n_slots * n_layers],
            v: vec![Vec::new(); n_slots * n_layers],
            lens: vec![0; n_slots],
            live: vec![false; n_slots],
        }
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// Per-slot position bound.
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Slots currently allocated.
    pub fn n_live(&self) -> usize {
        self.live.iter().filter(|&&b| b).count()
    }

    /// Claim the lowest free slot (reset to length 0).
    pub fn alloc(&mut self) -> Result<usize, CacheError> {
        match self.live.iter().position(|&b| !b) {
            Some(slot) => {
                self.live[slot] = true;
                self.reset(slot);
                Ok(slot)
            }
            None => Err(CacheError::NoFreeSlot { n_slots: self.n_slots }),
        }
    }

    /// Drop a slot's cached positions, keeping its buffer allocations.
    pub fn reset(&mut self, slot: usize) {
        self.lens[slot] = 0;
        for l in 0..self.n_layers {
            self.k[slot * self.n_layers + l].clear();
            self.v[slot * self.n_layers + l].clear();
        }
    }

    /// Release a slot back to the free pool (resetting it).
    pub fn free(&mut self, slot: usize) {
        assert!(self.live[slot], "freeing a slot that was never allocated");
        self.reset(slot);
        self.live[slot] = false;
    }

    /// Committed positions in `slot`.
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// True when `slot` holds no positions.
    pub fn is_empty(&self, slot: usize) -> bool {
        self.lens[slot] == 0
    }

    /// Refuse an append that would blow the `max_seq` bound — call
    /// before a forward touches the cache.
    pub fn check_capacity(
        &self,
        slot: usize,
        add: usize,
    ) -> Result<(), CacheError> {
        let len = self.lens[slot];
        if len + add > self.max_seq {
            return Err(CacheError::Overflow {
                slot,
                len,
                add,
                max_seq: self.max_seq,
            });
        }
        Ok(())
    }

    /// Layer `l`'s key/value buffers of `slot`, for the attention
    /// forward to read and append to.
    pub fn layer_mut(
        &mut self,
        slot: usize,
        l: usize,
    ) -> (&mut Vec<f32>, &mut Vec<f32>) {
        assert!(l < self.n_layers, "layer {l} out of range");
        let idx = slot * self.n_layers + l;
        (&mut self.k[idx], &mut self.v[idx])
    }

    /// Commit `add` new positions to `slot` after every layer has
    /// appended its k/v rows for them (debug-checked against the
    /// per-layer buffer lengths; a layer without an attention sublayer
    /// never appends and keeps an empty buffer, which is also in sync).
    pub fn advance(&mut self, slot: usize, add: usize) {
        self.lens[slot] += add;
        debug_assert!(
            (0..self.n_layers).all(|l| {
                let len = self.k[slot * self.n_layers + l].len();
                len == self.lens[slot] * self.d_model || len == 0
            }),
            "cache advance out of sync with per-layer appends"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_recycle_through_the_free_list() {
        let mut c = KvCache::new(2, 3, 4, 16);
        let a = c.alloc().unwrap();
        let b = c.alloc().unwrap();
        assert_eq!((a, b), (0, 1));
        assert_eq!(c.n_live(), 2);
        assert_eq!(
            c.alloc().unwrap_err(),
            CacheError::NoFreeSlot { n_slots: 2 }
        );
        // freeing the lower slot makes it the next allocation
        c.free(a);
        assert_eq!(c.n_live(), 1);
        assert_eq!(c.alloc().unwrap(), 0);
    }

    #[test]
    fn reuse_resets_lengths_and_buffers() {
        let mut c = KvCache::new(1, 2, 4, 16);
        let s = c.alloc().unwrap();
        for l in 0..2 {
            let (k, v) = c.layer_mut(s, l);
            k.extend_from_slice(&[1.0; 8]);
            v.extend_from_slice(&[2.0; 8]);
        }
        c.advance(s, 2);
        assert_eq!(c.len(s), 2);
        assert!(!c.is_empty(s));
        c.free(s);
        let s2 = c.alloc().unwrap();
        assert_eq!(s2, s);
        assert_eq!(c.len(s2), 0);
        assert!(c.is_empty(s2));
        let (k, v) = c.layer_mut(s2, 0);
        assert!(k.is_empty() && v.is_empty());
    }

    #[test]
    fn capacity_bound_is_a_typed_error() {
        let mut c = KvCache::new(1, 1, 4, 3);
        let s = c.alloc().unwrap();
        assert!(c.check_capacity(s, 3).is_ok());
        assert_eq!(
            c.check_capacity(s, 4).unwrap_err(),
            CacheError::Overflow { slot: 0, len: 0, add: 4, max_seq: 3 }
        );
        let (k, v) = c.layer_mut(s, 0);
        k.extend_from_slice(&[0.0; 8]);
        v.extend_from_slice(&[0.0; 8]);
        c.advance(s, 2);
        assert!(c.check_capacity(s, 1).is_ok());
        let err = c.check_capacity(s, 2).unwrap_err();
        assert_eq!(
            err,
            CacheError::Overflow { slot: 0, len: 2, add: 2, max_seq: 3 }
        );
        assert!(err.to_string().contains("max_seq"), "{err}");
    }

    #[test]
    #[should_panic(expected = "never allocated")]
    fn double_free_panics() {
        let mut c = KvCache::new(1, 1, 2, 4);
        let s = c.alloc().unwrap();
        c.free(s);
        c.free(s);
    }
}
