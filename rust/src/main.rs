//! `lpr` — CLI launcher for the LPR reproduction.
//!
//! Subcommands:
//!   train <preset>        train one artifact, log metrics + heatmap
//!   eval <preset> --ckpt  evaluate a checkpoint
//!   repro <exp>           reproduce a paper table/figure
//!                         (t1..t7, fig1, fig3, fig4, dispatch,
//!                          dispatch-routed, dispatch-policies,
//!                          placement, serve, dispatch-replay, all)
//!   dispatch-sim          run the expert-parallel dispatch simulator;
//!                         --routed drives it from the compiled routing
//!                         engine (--threads shards the batch)
//!   serve <preset|synthetic>  serve a whole L-layer model stack on the
//!                         persistent pool: `--ckpt FILE` bridges a
//!                         training checkpoint (pure Rust, no PJRT),
//!                         `synthetic` builds an L-layer LPR stack;
//!                         prints the per-layer Gini/min-max table
//!   generate <preset|synthetic>  autoregressive greedy decode on the
//!                         KV-cached continuous-batching session:
//!                         `--ckpt FILE` bridges a training checkpoint
//!                         (attention + MoE leaves, prints the leaf
//!                         load summary), `synthetic` builds a decoder
//!                         stack; emits per-step balance telemetry
//!   model-sim             run the stacked model through the layered
//!                         dispatch simulator (per-layer balance +
//!                         sequential straggler latency model)
//!   serve-bench           drive open-loop MixtureStream traffic
//!                         through the persistent-pool serving runtime
//!                         (policy x workers x arrival-rate sweep,
//!                         emits BENCH_serve.json)
//!   listen                bind the TCP serving front-end (native
//!                         length-prefixed framing, or --http) over a
//!                         synthetic engine, optionally behind a
//!                         multi-lane --lanes admission config
//!   route <preset>        run the standalone router artifact and print
//!                         the specialization proxy; `route synthetic`
//!                         runs the pure-Rust serving engine instead
//!   bench-tables          render BENCH_*.json perf artifacts into the
//!                         ROADMAP perf-trajectory markdown tables
//!   list                  list artifacts present in the artifacts dir
//!
//! Global options: --artifacts DIR, --out DIR, --steps N, --seed N.

use anyhow::{bail, Context, Result};
use std::path::PathBuf;

use lpr::coordinator::{checkpoint, Trainer};
use lpr::data::{MixtureStream, ZipfMarkovCorpus};
use lpr::dispatch::{
    run_full_steps, run_routed_steps, synthetic_assignments,
    DispatchPlan, DispatchSim, OverflowPolicy, PlacementConfig,
    PlacementPolicy, SimConfig,
};
use lpr::engine::{
    Backend, DecodeSession, Engine, GenRequest, MoeEngine,
};
use lpr::experts::ExpertBank;
use lpr::metrics::{ascii_heatmap, entropy_frac, gini, min_max_ratio};
use lpr::model::{
    bridge, run_model_steps, synthetic_decoder_model,
    synthetic_stacked_model, DecoderModel, StackedModel,
};
use lpr::report::Reporter;
use lpr::router::{synthetic_lpr_router, RouterBatch};
use lpr::runtime::{CompiledArtifacts, Runtime};
use lpr::serve::{
    measure_engine_rate, run_admitted_open_loop, run_open_loop,
    AdmissionConfig, AdmittedRuntime, HttpWire, LengthPrefixed, NetServer,
    RequestMeta, Server, ServeConfig, ServeRuntime,
};
use lpr::util::bench::write_json_rows;
use lpr::util::cli::Args;
use lpr::util::rng::Rng;
use lpr::util::table::fmt_sci;

const USAGE: &str = "\
lpr — Latent Prototype Routing reproduction (rust + jax + pallas)

USAGE:
  lpr train <preset> [--steps N] [--seed N] [--ckpt-out FILE]
  lpr eval <preset> --ckpt FILE [--batches N]
  lpr route <preset> [--ckpt FILE]
  lpr route synthetic [--metric M] [--threads N] [--tokens N]
            [--experts N] [--topk K]
  lpr serve <preset> --ckpt FILE [--workers N] [--policy P] [--rate R]
            [--requests N] [--req-tokens N] [--cf F] [--renormalize]
  lpr serve synthetic [--layers L] [--metric M] [--experts N] [--topk K]
            [--dmodel D] [--latent Z] [--dff F] [...same options]
  lpr generate <preset> --ckpt FILE [--prompt TOKS] [--max-new N]
               [--slots N] [--max-seq N] [--threads N] [--cf F]
  lpr generate synthetic [--layers L] [--metric M] [--experts N]
               [--topk K] [--dmodel D] [--latent Z] [--dff F]
               [--heads H] [--vocab V] [...same decode options]
  lpr model-sim [--layers L] [--metric M] [--experts N] [--topk K]
                [--dmodel D] [--dff F] [--threads N] [--policy P]
                [--steps N] [--tokens N] [--cf F] [--devices N]
  lpr repro <t1|t2|t3|t4|t5|t6|t7|fig1|fig3|fig4|dispatch
            |dispatch-routed|dispatch-policies|placement|serve
            |model-serve|admission|decode|dispatch-replay|all>
            [--steps N]
  lpr dispatch-sim [--experts N] [--devices N] [--topk K] [--skew S]
                   [--cf F] [--steps N] [--threads N] [--metric M]
                   [--policy P] [--routed] [--full] [--renormalize]
                   [--placement P] [--replan N] [--hot N] [--replicas N]
  lpr bench-tables [--dir DIR] [--out FILE]
  lpr serve-bench [--metric M] [--experts N] [--topk K] [--dmodel D]
                  [--dff F] [--workers N] [--policy P] [--rate TOK/S]
                  [--requests N] [--req-tokens N] [--max-batch N]
                  [--max-wait TICKS] [--cf F] [--renormalize]
                  [--lanes FILE]
  lpr listen [--addr HOST:PORT] [--http] [--lanes FILE] [--metric M]
             [--experts N] [--topk K] [--dmodel D] [--dff F]
             [--workers N] [--max-batch N] [--max-wait TICKS]
  lpr list
Options:
  --artifacts DIR   artifact directory (default: artifacts/)
  --out DIR         results directory (default: results/)
  --threads N       routing threads for the serving engine (default 1)
  --policy P        overflow policy for over-capacity tokens:
                    drop | next-choice | least-loaded (default drop;
                    serve-bench sweeps all three when omitted)
  --routed          dispatch-sim: drive the simulator from the compiled
                    routing engine on clustered tokens instead of
                    synthetic Zipf assignments
  --placement P     dispatch-sim: expert-placement planner:
                    roundrobin | loadaware | replicated (default
                    roundrobin = standard expert parallelism)
  --replan N        dispatch-sim: steps between placement re-plans
                    (default 16); --hot/--replicas size the replicated
                    planner's hot set
  --full            dispatch-sim: with --routed, run the real expert
                    FFN path (route -> plan -> compute -> combine)
                    instead of the latency model alone
  --renormalize     rescale a token's surviving gate weights to its
                    pre-drop mass when the overflow policy drops slots
                    (off by default)
  --workers N       serve-bench: pool workers (sweeps 1,2,4 if omitted)
  --rate R          serve-bench: absolute arrival rate in tokens/s
                    (sweeps 0.5x/1x/2x of measured capacity if omitted);
                    serve: one absolute rate (default 0.8x measured)
  --layers L        serve synthetic / model-sim: MoE layers in the
                    served stack (default 4)
  --ckpt FILE       serve/eval/route: training checkpoint; serve builds
                    the whole L-layer model from it (pure Rust, no PJRT)
  --lanes FILE      listen / serve-bench: multi-lane admission config
                    (lane / path / tenant / quota / weight / overflow
                    directives — see docs/ARCHITECTURE.md); default is
                    one catch-all lane
  --prompt TOKS     generate: comma-separated token ids; `;` separates
                    sequences batched together (default \"3,1,4\")
  --max-new N       generate: new tokens per sequence (default 16)
  --slots N         generate: KV-cache slots, the max concurrently
                    decoding sequences (default 4)
  --max-seq N       generate: per-slot KV capacity in tokens (default
                    longest prompt + max-new)
  --addr HOST:PORT  listen: bind address (default 127.0.0.1:7077)
  --http            listen: speak the HTTP/1.1-shaped wire instead of
                    the native length-prefixed framing
  --tiles MCxKCxNC  cache-tile override for the expert-FFN GEMM
                    kernels, e.g. 64x256x128 (serve / model-sim /
                    dispatch-sim --routed; default comes from the
                    LPR_GEMM_TILES env var, else the built-in tiles)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    let args = Args::parse(&argv);
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn art_dir(args: &Args) -> PathBuf {
    args.opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(lpr::default_art_dir)
}

fn out_dir(args: &Args) -> PathBuf {
    args.opt("out")
        .map(PathBuf::from)
        .unwrap_or_else(lpr::default_out_dir)
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "route" => cmd_route(args),
        "repro" => cmd_repro(args),
        "serve" => cmd_serve(args),
        "generate" => cmd_generate(args),
        "model-sim" => cmd_model_sim(args),
        "dispatch-sim" => cmd_dispatch_sim(args),
        "serve-bench" => cmd_serve_bench(args),
        "listen" => cmd_listen(args),
        "bench-tables" => cmd_bench_tables(args),
        "list" => cmd_list(args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn preset_arg(args: &Args) -> Result<&str> {
    args.positional
        .first()
        .map(|s| s.as_str())
        .context("missing <preset> argument")
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = preset_arg(args)?;
    let rt = Runtime::cpu()?;
    let arts = CompiledArtifacts::load(&rt, &art_dir(args), preset)?;
    let steps = args.opt_usize("steps", arts.meta.config.total_steps);
    let seed = args.opt_usize("seed", 0) as i32;

    eprintln!(
        "training {preset}: {} params, {} experts x top-{}, {} steps",
        arts.meta.param_count,
        arts.meta.config.n_experts,
        arts.meta.config.top_k,
        steps
    );
    let mut trainer = Trainer::new(&rt, &arts, seed, None)?;
    let mut corpus =
        ZipfMarkovCorpus::standard(arts.meta.config.vocab, 1000 + seed as u64);
    let loss_idx = arts.meta.metric_idx("loss")?;
    let lr_idx = arts.meta.metric_idx("lr")?;
    let t0 = std::time::Instant::now();
    trainer.train_synthetic(&mut corpus, steps, |m| {
        if m.step % 20 == 0 || m.step + 1 == steps {
            eprintln!(
                "step {:>5}/{steps}  loss {:.4}  lr {:.2e}",
                m.step, m.values[loss_idx], m.values[lr_idx]
            );
        }
    })?;
    let dt = t0.elapsed().as_secs_f64();
    eprintln!(
        "trained {steps} steps in {dt:.1}s ({:.2} steps/s)",
        steps as f64 / dt
    );

    let mut eval_corpus = ZipfMarkovCorpus::held_out(
        arts.meta.config.vocab, 1000 + seed as u64, 990_000);
    let eval =
        trainer.evaluate(&mut eval_corpus, args.opt_usize("batches", 8))?;
    println!(
        "test loss {:.4}  GINI {:.4}  min-max {:.4}  drop {:.4}",
        eval.loss,
        eval.load.mean_gini(),
        eval.load.mean_min_max(),
        eval.drop_frac
    );
    println!("{}", ascii_heatmap(&eval.load));

    let out = out_dir(args);
    std::fs::create_dir_all(&out)?;
    std::fs::write(
        out.join(format!("{preset}.train.csv")),
        trainer.history_csv(),
    )?;
    if let Some(ckpt) = args.opt("ckpt-out") {
        let state = trainer.state_to_host()?;
        checkpoint::save(
            std::path::Path::new(ckpt),
            preset,
            trainer.step,
            &state,
        )?;
        eprintln!("checkpoint written to {ckpt}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let preset = preset_arg(args)?;
    let ckpt_path = args.opt("ckpt").context("--ckpt FILE required")?;
    let rt = Runtime::cpu()?;
    let arts = CompiledArtifacts::load(&rt, &art_dir(args), preset)?;
    let ck = checkpoint::load(std::path::Path::new(ckpt_path))?;
    ck.expect_artifact(preset)?;
    let mut trainer = Trainer::new(&rt, &arts, 0, None)?;
    trainer.state_from_host(&ck.buffers)?;
    let mut corpus = ZipfMarkovCorpus::held_out(
        arts.meta.config.vocab, 1000, 990_000);
    let eval =
        trainer.evaluate(&mut corpus, args.opt_usize("batches", 8))?;
    println!(
        "step {}  test loss {:.4}  GINI {:.4}  min-max {:.4}",
        ck.step,
        eval.loss,
        eval.load.mean_gini(),
        eval.load.mean_min_max()
    );
    println!("{}", ascii_heatmap(&eval.load));
    Ok(())
}

/// Pure-Rust serving path: no artifacts / PJRT needed. Routes a
/// clustered token stream through the engine facade (scoped backend)
/// and reports balance + throughput.
fn cmd_route_synthetic(args: &Args) -> Result<()> {
    let threads = args.opt_usize("threads", 1);
    let metric = args.opt_or("metric", "cosine");
    let n_tokens = args.opt_usize("tokens", 4096);
    let d = args.opt_usize("dmodel", 64);
    let dz = args.opt_usize("latent", 16);
    let e = args.opt_usize("experts", 32);
    let k = args.opt_usize("topk", 4);
    let mut rng = Rng::new(args.opt_usize("seed", 2025) as u64);
    let router = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
    // routing-only study: the FFN stage never runs, so a 1-wide
    // placeholder bank satisfies the facade's stack shape
    let bank = ExpertBank::new(&Rng::new(0), e, d, 1);
    let mut engine = Engine::builder()
        .layer(router.plan().clone(), bank)
        .backend(Backend::Scoped { threads })
        .build()?;
    let mix = MixtureStream::standard(&mut rng, d);
    let mut h = Vec::new();
    mix.fill(&mut rng, n_tokens, &mut h);
    let mut out = RouterBatch::new();
    engine.route_into(&h, &mut out); // warm buffers
    let t0 = std::time::Instant::now();
    engine.route_into(&h, &mut out);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "route synthetic: {n_tokens} tokens -> {e} experts top-{k} \
         ({metric}, {threads} threads)"
    );
    println!(
        "  GINI {:.3}  min-max {:.4}  entropy {:.3}  \
         win-GINI {:.3} ({} batches)",
        gini(&out.load),
        min_max_ratio(&out.load),
        entropy_frac(&out.load),
        engine.balance().layer(0).gini(),
        engine.balance().layer(0).len()
    );
    println!(
        "  {:.0} tok/s  ({:.0} ns/token)",
        n_tokens as f64 / dt,
        dt * 1e9 / n_tokens as f64
    );
    Ok(())
}

fn cmd_route(args: &Args) -> Result<()> {
    // Standalone router pass over cluster-structured inputs; uses the
    // checkpointed trained params when given, otherwise fresh init.
    let preset = preset_arg(args)?;
    if preset == "synthetic" || args.has_flag("synthetic") {
        return cmd_route_synthetic(args);
    }
    let rt = Runtime::cpu()?;
    let arts = CompiledArtifacts::load(&rt, &art_dir(args), preset)?;
    let mut trainer = Trainer::new(&rt, &arts, 0, None)?;
    if let Some(ckpt_path) = args.opt("ckpt") {
        let ck = checkpoint::load(std::path::Path::new(ckpt_path))?;
        ck.expect_artifact(preset)?;
        trainer.state_from_host(&ck.buffers)?;
    }
    let conf = lpr::config::router_top1_confidence(&rt, &arts, &trainer)?;
    println!(
        "router {preset}: mean top-1 combine weight {conf:.4} \
         (1/k = {:.4} means undecided)",
        1.0 / arts.meta.config.top_k as f64
    );
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let exp = preset_arg(args)?;
    let art = art_dir(args);
    let out = out_dir(args);
    // The dispatch/serve reports are pure Rust: only build the PJRT
    // runtime for experiments that execute AOT artifacts, so the
    // serving reports work against the offline vendor/xla stub.
    let pure_rust = matches!(
        exp,
        "dispatch"
            | "dispatch-routed"
            | "dispatch-policies"
            | "placement"
            | "serve"
            | "model-serve"
            | "admission"
            | "decode"
    );
    let rt = if pure_rust { None } else { Some(Runtime::cpu()?) };
    let mut rep = Reporter::new(rt.as_ref(), &art, &out);
    if let Some(steps) = args.opt("steps") {
        rep.steps_override = Some(steps.parse().context("--steps")?);
    }
    rep.verbose = !args.has_flag("quiet");
    match exp {
        "t1" => rep.table1().map(|_| ())?,
        "t2" => rep.table2().map(|_| ())?,
        "t3" => rep.table3().map(|_| ())?,
        "t4" => rep.table4().map(|_| ())?,
        "t5" => rep.table5().map(|_| ())?,
        "t6" => rep.table6().map(|_| ())?,
        "t7" => rep.table7().map(|_| ())?,
        "fig1" => rep.fig1()?,
        "fig3" => rep.fig3()?,
        "fig4" => rep.fig4()?,
        "dispatch" => rep.dispatch_report()?,
        "dispatch-routed" => rep.dispatch_routed()?,
        "dispatch-policies" => rep.dispatch_policies()?,
        "placement" => rep.placement()?,
        "serve" => rep.serve_table()?,
        "model-serve" => rep.model_serve_table()?,
        "admission" => rep.admission_table()?,
        "decode" => rep.decode_table()?,
        "dispatch-replay" => rep.dispatch_replay()?,
        "all" => rep.all()?,
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn parse_policy(args: &Args, default: &str) -> Result<OverflowPolicy> {
    // ParsePolicyError renders the accepted set itself — no
    // hand-assembled message here
    Ok(args.opt_or("policy", default).parse::<OverflowPolicy>()?)
}

/// `--tiles MCxKCxNC` into a [`lpr::kernels::GemmTiles`] override for
/// the expert-FFN GEMM kernels; `None` lets the engine builder fall
/// back to `LPR_GEMM_TILES` / the built-in defaults.
fn parse_tiles(args: &Args) -> Result<Option<lpr::kernels::GemmTiles>> {
    args.opt("tiles")
        .map(|s| {
            lpr::kernels::GemmTiles::parse(s)
                .map_err(|detail| anyhow::anyhow!("--tiles: {detail}"))
        })
        .transpose()
}

/// `--placement/--replan/--hot/--replicas` into a [`PlacementConfig`];
/// a bad `--placement` surfaces the typed [`lpr::Error`] (which renders
/// the accepted planner set itself).
fn parse_placement(args: &Args) -> Result<PlacementConfig> {
    let policy = args
        .opt_or("placement", "roundrobin")
        .parse::<PlacementPolicy>()
        .map_err(lpr::Error::from)?;
    let mut cfg = PlacementConfig::with_policy(policy);
    cfg.replan_every = args.opt_usize("replan", cfg.replan_every);
    cfg.hot_experts = args.opt_usize("hot", cfg.hot_experts);
    cfg.replicas = args.opt_usize("replicas", cfg.replicas);
    Ok(cfg)
}

/// Build the model stack `serve`/`model-sim` operate on: a training
/// checkpoint through the pure-Rust bridge when `--ckpt` is given,
/// otherwise a synthetic L-layer LPR stack.
fn stacked_model_arg(args: &Args, preset: &str) -> Result<(StackedModel, String)> {
    if preset == "synthetic" {
        let n_layers = args.opt_usize("layers", 4);
        let metric = args.opt_or("metric", "cosine");
        let d = args.opt_usize("dmodel", 32);
        let dz = args.opt_usize("latent", 16);
        let e = args.opt_usize("experts", 32);
        let k = args.opt_usize("topk", 4);
        let d_ff = args.opt_usize("dff", 2 * d);
        let seed = args.opt_usize("seed", 2025) as u64;
        let model = synthetic_stacked_model(
            metric,
            &Rng::new(seed),
            n_layers,
            d,
            dz,
            e,
            k,
            d_ff,
        );
        let desc = format!(
            "synthetic {n_layers}-layer {metric} stack, {e} experts \
             top-{k}, d={d} d_ff={d_ff}"
        );
        Ok((model, desc))
    } else {
        let ckpt = args.opt("ckpt").context(
            "--ckpt FILE required for a checkpointed model (or use \
             `serve synthetic`)",
        )?;
        let (meta, model) = bridge::model_from_files(
            &art_dir(args),
            preset,
            std::path::Path::new(ckpt),
        )?;
        let desc = format!(
            "checkpoint {ckpt} ({preset}: {} layers, {} experts top-{}, \
             {} router/{})",
            meta.config.n_layers,
            meta.config.n_experts,
            meta.config.top_k,
            meta.config.router,
            meta.config.metric
        );
        Ok((model, desc))
    }
}

fn print_layer_table(layers: &[lpr::metrics::LayerBalance]) {
    println!(
        "  {:<6} {:>9} {:>9} {:>9}",
        "layer", "win-GINI", "min-max", "cv"
    );
    for lb in layers {
        println!(
            "  L{:<5} {:>9.4} {:>9.4} {:>9.3}",
            lb.layer, lb.gini, lb.min_max, lb.cv
        );
    }
}

/// Serve a whole model stack on the persistent pool: bounded queue,
/// micro-batching, open-loop Poisson arrivals — the `train → ckpt →
/// serve` endpoint. Pure Rust: the checkpoint bridge reads only
/// `meta.json` + the checkpoint file, so this works against the
/// offline vendor/xla stub.
fn cmd_serve(args: &Args) -> Result<()> {
    let preset = preset_arg(args)?;
    let (model, desc) = stacked_model_arg(args, preset)?;
    let d = model.d_model();
    let workers = args.opt_usize("workers", 2);
    let policy = parse_policy(args, "drop")?;
    let cf = args.opt_f64("cf", 1.25);
    let req_tokens = args.opt_usize("req-tokens", 32);
    let n_requests = args.opt_usize("requests", 256);
    let max_batch = args.opt_usize("max-batch", 256);
    let max_wait = args.opt_usize("max-wait", 2000) as u64;
    let seed = args.opt_usize("seed", 23) as u64;
    anyhow::ensure!(
        req_tokens <= max_batch,
        "--req-tokens {req_tokens} exceeds --max-batch {max_batch}"
    );

    // the one construction path for the serving engine — calibration
    // and the runtime share it, so the measured capacity is honest for
    // exactly the backend that will serve
    let renormalize = args.has_flag("renormalize");
    let tiles = parse_tiles(args)?;
    let build_engine = |model: StackedModel| -> Result<Engine> {
        let mut b = Engine::builder()
            .model(model)
            .backend(Backend::Pool { workers })
            .policy(policy)
            .capacity_factor(cf)
            .renormalize(renormalize);
        if let Some(t) = tiles {
            b = b.gemm_tiles(t);
        }
        Ok(b.build()?)
    };

    // calibrate this machine's stacked-forward capacity, then default
    // the arrival rate to 0.8x of it (below saturation)
    let mut rng = Rng::new(seed);
    let mix = MixtureStream::skewed(&mut rng, d, 1.6);
    let mut cal = build_engine(model.clone())?;
    let cap_tok_s =
        measure_engine_rate(&mut cal, &mix, &mut rng, max_batch, 3);
    drop(cal);
    let rate = match args.opt("rate") {
        Some(r) => r.parse::<f64>().context("--rate")?,
        None => 0.8 * cap_tok_s,
    };

    let cfg = ServeConfig {
        max_batch,
        max_wait,
        queue_tokens: 8 * max_batch,
        service_ticks: None,
        ..ServeConfig::default()
    };
    let mut rt =
        ServeRuntime::with_engine(build_engine(model)?.into_inner(), cfg);
    run_open_loop(&mut rt, &mix, &mut rng, n_requests, req_tokens, rate);
    let r = rt.report();
    println!("serve: {desc}");
    println!(
        "  {workers} workers, policy {}, cf {cf}; measured capacity \
         {cap_tok_s:.0} tok/s, arrival {rate:.0} tok/s",
        policy.name()
    );
    println!(
        "  {} requests ({} rejected), {} batches, p50/p99 {:.0}/{:.0} us, \
         {:.0} tok/s served",
        r.requests,
        r.rejected,
        r.batches,
        r.latency_p50_us,
        r.latency_p99_us,
        r.throughput_tok_per_s
    );
    println!(
        "  per-layer rolling balance (mean GINI {:.4}, min-max {:.4}):",
        r.window_gini, r.window_min_max
    );
    print_layer_table(&r.layers);
    Ok(())
}

/// `--prompt "3,1,4;2,7"`: comma-separated token ids, `;` between
/// sequences that join the same continuous-batching session.
fn parse_prompts(s: &str) -> Result<Vec<Vec<usize>>> {
    s.split(';')
        .map(|seq| {
            let toks = seq
                .split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.parse::<usize>().with_context(|| {
                        format!("--prompt: bad token id '{t}'")
                    })
                })
                .collect::<Result<Vec<usize>>>()?;
            anyhow::ensure!(
                !toks.is_empty(),
                "--prompt: empty sequence (check the ';' splits)"
            );
            Ok(toks)
        })
        .collect()
}

/// The decoder `generate` operates on: a training checkpoint through
/// the attention-aware bridge when `--ckpt` is given (printing which
/// leaves were consumed vs skipped), otherwise a synthetic decoder
/// stack. Also returns a description line and the expert count (the
/// no-drop capacity-factor default).
fn decoder_model_arg(
    args: &Args,
    preset: &str,
) -> Result<(DecoderModel, String, usize)> {
    if preset == "synthetic" {
        let n_layers = args.opt_usize("layers", 2);
        let metric = args.opt_or("metric", "cosine");
        let d = args.opt_usize("dmodel", 32);
        let dz = args.opt_usize("latent", 16);
        let e = args.opt_usize("experts", 16);
        let k = args.opt_usize("topk", 2);
        let d_ff = args.opt_usize("dff", 2 * d);
        let heads = args.opt_usize("heads", 4);
        let vocab = args.opt_usize("vocab", 64);
        anyhow::ensure!(
            heads > 0 && d % heads == 0,
            "--dmodel {d} must split evenly into --heads {heads}"
        );
        let seed = args.opt_usize("seed", 2025) as u64;
        let dec = synthetic_decoder_model(
            metric,
            &Rng::new(seed),
            n_layers,
            d,
            dz,
            e,
            k,
            d_ff,
            heads,
            vocab,
        );
        let desc = format!(
            "synthetic {n_layers}-layer {metric} decoder, {e} experts \
             top-{k}, d={d} heads={heads} vocab={vocab}"
        );
        Ok((dec, desc, e))
    } else {
        let ckpt = args.opt("ckpt").context(
            "--ckpt FILE required for a checkpointed decoder (or use \
             `generate synthetic`)",
        )?;
        let (meta, dec, summary) = bridge::decoder_from_files(
            &art_dir(args),
            preset,
            std::path::Path::new(ckpt),
        )?;
        println!("checkpoint leaves: {summary}");
        let attn = if dec.model().has_attn() {
            "attention"
        } else {
            "MoE-only (no attention leaves)"
        };
        let desc = format!(
            "checkpoint {ckpt} ({preset}: {} layers, {} experts \
             top-{}, {attn}, vocab {})",
            meta.config.n_layers,
            meta.config.n_experts,
            meta.config.top_k,
            dec.vocab()
        );
        Ok((dec, desc, meta.config.n_experts))
    }
}

/// Greedy autoregressive generation on the KV-cached decode session:
/// submit every `--prompt` sequence, run continuous-batching steps to
/// idle, and print the generated tokens plus the per-step per-layer
/// routed-load balance (the paper's Gini / min-max lens at decode's
/// n=1 regime). Defaults to the no-drop capacity factor (`cf =
/// n_experts`) so cached decode is bitwise the prefill forward.
fn cmd_generate(args: &Args) -> Result<()> {
    let preset = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("synthetic");
    let (dec, desc, n_experts) = decoder_model_arg(args, preset)?;
    let prompts = parse_prompts(args.opt_or("prompt", "3,1,4"))?;
    let max_new = args.opt_usize("max-new", 16);
    let slots = args.opt_usize("slots", 4);
    let longest = prompts.iter().map(Vec::len).max().unwrap_or(1);
    let max_seq = args.opt_usize("max-seq", longest + max_new);
    let threads = args.opt_usize("threads", 1);
    let cf = args.opt_f64("cf", n_experts as f64);
    if cf < n_experts as f64 {
        eprintln!(
            "note: --cf {cf} can drop tokens; decode is only \
             batch-invariant at the no-drop cf {n_experts}"
        );
    }

    let (model, head) = dec.into_parts();
    let mut builder = Engine::builder()
        .model(model)
        .backend(Backend::Scoped { threads })
        .capacity_factor(cf);
    if let Some(t) = parse_tiles(args)? {
        builder = builder.gemm_tiles(t);
    }
    let engine = builder.build()?;
    let mut sess = DecodeSession::new(engine, head, slots, max_seq);
    for prompt in &prompts {
        sess.submit(GenRequest { prompt: prompt.clone(), max_new })?;
    }

    println!("generate: {desc}");
    println!(
        "  {} sequence(s), {max_new} new tokens each, {slots} KV \
         slots x {max_seq} tokens, cf {cf}, {threads} threads",
        prompts.len()
    );
    let t0 = std::time::Instant::now();
    let stats = sess.run_to_idle();
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "  {:<5} {:>5} {:>5} {:>5} {:>5} {:>10} {:>9} {:>9}",
        "step", "seqs", "join", "toks", "drop", "mean-GINI", "min-max",
        "us"
    );
    for s in &stats {
        let nl = s.layers.len().max(1) as f64;
        let mean_gini =
            s.layers.iter().map(|l| l.gini).sum::<f64>() / nl;
        let mean_mm =
            s.layers.iter().map(|l| l.min_max).sum::<f64>() / nl;
        println!(
            "  {:<5} {:>5} {:>5} {:>5} {:>5} {:>10.4} {:>9.4} {:>9.1}",
            s.step,
            s.n_seqs,
            s.n_joined,
            s.n_tokens,
            s.n_dropped,
            mean_gini,
            mean_mm,
            s.latency_ns as f64 / 1e3
        );
    }
    if let Some(last) = stats.last() {
        println!("  final-step per-layer balance:");
        print_layer_table(&last.layers);
    }

    let fin = sess.take_finished();
    let new_tokens: usize = fin.iter().map(|f| f.tokens.len()).sum();
    for f in &fin {
        let toks: Vec<String> =
            f.tokens.iter().map(usize::to_string).collect();
        println!(
            "  seq {} ({}-token prompt) -> {}",
            f.id,
            f.prompt_len,
            toks.join(",")
        );
    }
    println!(
        "  {} new tokens in {} steps, {:.1} ms ({:.0} ns/token)",
        new_tokens,
        stats.len(),
        dt * 1e3,
        dt * 1e9 / new_tokens.max(1) as f64
    );
    Ok(())
}

/// Stacked-model dispatch study: run the L-layer facade engine through
/// the layered simulator — per-layer `[L, E]` balance plus the
/// sequential straggler latency model (layer l+1 waits for layer l's
/// slowest device).
fn cmd_model_sim(args: &Args) -> Result<()> {
    let n_layers = args.opt_usize("layers", 4);
    let metric = args.opt_or("metric", "cosine");
    let d = args.opt_usize("dmodel", 64);
    let dz = args.opt_usize("latent", 16);
    let e = args.opt_usize("experts", 32);
    let k = args.opt_usize("topk", 4);
    let d_ff = args.opt_usize("dff", 2 * d);
    let threads = args.opt_usize("threads", 1);
    let steps = args.opt_usize("steps", 50);
    let tokens = args.opt_usize("tokens", 1024);
    let policy = parse_policy(args, "drop")?;
    let cfg = SimConfig {
        n_experts: e,
        n_devices: args.opt_usize("devices", 8),
        top_k: k,
        capacity_factor: args.opt_f64("cf", 1.25),
        alpha_us: args.opt_f64("alpha", 50.0),
        beta_us: args.opt_f64("beta", 0.5),
    };
    let seed = args.opt_usize("seed", 2025) as u64;
    let model = synthetic_stacked_model(
        metric,
        &Rng::new(seed),
        n_layers,
        d,
        dz,
        e,
        k,
        d_ff,
    );
    // the facade engine carries cf/policy; built from the sim's cf so
    // simulated bins and real compute agree
    let mut builder = Engine::builder()
        .model(model)
        .backend(Backend::Scoped { threads })
        .policy(policy)
        .capacity_factor(cfg.capacity_factor)
        .renormalize(args.has_flag("renormalize"));
    if let Some(t) = parse_tiles(args)? {
        builder = builder.gemm_tiles(t);
    }
    let mut engine = builder.build()?;
    let mut sim = DispatchSim::new_layered(cfg, n_layers)?;
    let mut rng = Rng::new(seed);
    let mix = MixtureStream::skewed(&mut rng, d, 1.6);
    let fwd_ns =
        run_model_steps(&mut engine, &mix, &mut rng, &mut sim, steps, tokens);
    let r = sim.report();
    println!(
        "model-sim: {n_layers}-layer {metric} stack, {e} experts top-{k}, \
         policy {}, {threads} threads",
        policy.name()
    );
    println!(
        "  {} steps x {tokens} tokens, stacked forward {:.0} ns/token",
        r.steps,
        fwd_ns as f64 / (steps * tokens).max(1) as f64
    );
    println!(
        "  throughput {:.0} tok/s  latency p50/p99 {:.0}/{:.0} us  \
         drop {:.2}%  reroute {:.2}%  utilization {:.3}",
        r.throughput_tok_per_s,
        r.latency_p50_us,
        r.latency_p99_us,
        100.0 * r.drop_frac,
        100.0 * r.reroute_frac,
        r.utilization
    );
    print_layer_table(&r.layers);
    Ok(())
}

fn cmd_dispatch_sim(args: &Args) -> Result<()> {
    let cfg = SimConfig {
        n_experts: args.opt_usize("experts", 64),
        n_devices: args.opt_usize("devices", 8),
        top_k: args.opt_usize("topk", 8),
        capacity_factor: args.opt_f64("cf", 1.25),
        alpha_us: args.opt_f64("alpha", 50.0),
        beta_us: args.opt_f64("beta", 0.5),
    };
    let skew = args.opt_f64("skew", 0.0);
    let steps = args.opt_usize("steps", 200);
    let tokens = args.opt_usize("tokens", 1024);
    let threads = args.opt_usize("threads", 1);
    let routed = args.has_flag("routed") || args.opt("routed").is_some();
    let full = args.has_flag("full") || args.opt("full").is_some();
    let policy = parse_policy(args, "drop")?;
    let placement = parse_placement(args)?;
    let (e, k, cf) = (cfg.n_experts, cfg.top_k, cfg.capacity_factor);
    let mut sim = DispatchSim::new(cfg)?;
    sim.set_placement(placement);
    let mut rng = Rng::new(args.opt_usize("seed", 7) as u64);
    let t0 = std::time::Instant::now();
    if routed {
        // serving path: the engine facade over clustered tokens
        let metric = args.opt_or("metric", "cosine");
        let d = args.opt_usize("dmodel", 64);
        let dz = args.opt_usize("latent", 16);
        let d_ff = args.opt_usize("dff", 4 * d);
        let router = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
        // route-only runs never touch the FFN stage: a 1-wide
        // placeholder bank keeps the facade's stack shape cheap
        let bank = if full {
            ExpertBank::new(&Rng::new(42), e, d, d_ff)
        } else {
            ExpertBank::new(&Rng::new(0), e, d, 1)
        };
        let mut builder = Engine::builder()
            .layer(router.plan().clone(), bank)
            .backend(Backend::Scoped { threads })
            .policy(policy)
            .capacity_factor(cf)
            .renormalize(args.has_flag("renormalize"));
        if let Some(t) = parse_tiles(args)? {
            builder = builder.gemm_tiles(t);
        }
        let mut engine = builder.build()?;
        let mix = MixtureStream::standard(&mut rng, d);
        if full {
            // real expert compute: route -> plan -> FFN -> combine
            let fwd_ns = run_full_steps(
                &mut engine, &mix, &mut rng, &mut sim, steps, tokens,
            );
            println!(
                "dispatch-sim --routed --full: metric {metric}, \
                 policy {}, d_ff {d_ff}, {threads} threads, \
                 full forward {:.0} ns/token",
                policy.name(),
                fwd_ns as f64 / (steps * tokens) as f64
            );
        } else {
            let route_ns = run_routed_steps(
                &mut engine, &mix, &mut rng, &mut sim, steps, tokens,
                policy,
            );
            println!(
                "dispatch-sim --routed: metric {metric}, policy {}, \
                 {threads} threads, routing {:.0} ns/token",
                policy.name(),
                route_ns as f64 / (steps * tokens) as f64
            );
        }
    } else {
        let mut plan = DispatchPlan::new();
        for _ in 0..steps {
            let a = synthetic_assignments(&mut rng, tokens, k, e, skew);
            sim.step_assignments(&a, k, policy, &mut plan);
        }
    }
    let r = sim.report();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "dispatch-sim: {} steps x {tokens} tokens (skew {skew}, \
         policy {}) in {dt:.2}s ({:.0} tok/s simulated)",
        r.steps,
        policy.name(),
        (r.tokens_routed as f64 / k as f64) / dt
    );
    println!(
        "  GINI {}  win-GINI {}  min-max {}  throughput {:.0} tok/s  \
         latency mean/p50/p99 {:.0}/{:.0}/{:.0} us",
        fmt_sci(r.load_gini),
        fmt_sci(r.window_gini),
        fmt_sci(r.load_min_max),
        r.throughput_tok_per_s,
        r.latency_mean_us,
        r.latency_p50_us,
        r.latency_p99_us
    );
    println!(
        "  drop {:.2}%  reroute {:.2}%  utilization {:.3}  stall {:.3}",
        100.0 * r.drop_frac,
        100.0 * r.reroute_frac,
        r.utilization,
        r.stall_frac
    );
    if r.placement != "roundrobin" {
        println!(
            "  placement {}: {} replans, {:.0} KiB migrated \
             ({:.1} us charged to step latency)",
            r.placement,
            r.replans,
            r.migrated_bytes as f64 / 1024.0,
            r.migration_us
        );
    }
    Ok(())
}

/// Render downloaded `BENCH_*.json` perf artifacts (the bench-smoke CI
/// uploads) into the markdown tables the ROADMAP perf-trajectory
/// section tracks across PRs. Missing files are skipped with a note so
/// one command works on any subset of artifacts.
fn cmd_bench_tables(args: &Args) -> Result<()> {
    const BENCH_FILES: &[&str] = &[
        "BENCH_router.json",
        "BENCH_dispatch.json",
        "BENCH_serve.json",
        "BENCH_model.json",
        "BENCH_engine.json",
        "BENCH_gemm.json",
        "BENCH_placement.json",
        "BENCH_admission.json",
        "BENCH_decode.json",
    ];
    let dir = PathBuf::from(args.opt_or("dir", "."));
    let mut md = String::new();
    let mut rendered = 0usize;
    for file in BENCH_FILES {
        let path = dir.join(file);
        let Ok(text) = std::fs::read_to_string(&path) else {
            eprintln!("note: {} not found, skipped", path.display());
            continue;
        };
        let json = lpr::util::json::Json::parse(&text)
            .with_context(|| format!("parse {}", path.display()))?;
        let lpr::util::json::Json::Arr(rows) = &json else {
            bail!("{}: expected a top-level array", path.display());
        };
        // column set = union of keys over all rows ("name" first,
        // the rest in BTreeMap order — stable across runs)
        let mut cols: Vec<String> = Vec::new();
        for row in rows {
            if let lpr::util::json::Json::Obj(m) = row {
                for key in m.keys() {
                    if !cols.contains(key) {
                        cols.push(key.clone());
                    }
                }
            }
        }
        cols.sort();
        if let Some(i) = cols.iter().position(|c| c == "name") {
            let name = cols.remove(i);
            cols.insert(0, name);
        }
        let headers: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
        let mut t = lpr::util::table::Table::new(file, &headers);
        for row in rows {
            let cells = cols
                .iter()
                .map(|c| match row.get(c) {
                    Some(lpr::util::json::Json::Str(s)) => s.clone(),
                    Some(lpr::util::json::Json::Num(x)) => {
                        if x.fract() == 0.0 && x.abs() < 1e15 {
                            format!("{}", *x as i64)
                        } else {
                            format!("{x}")
                        }
                    }
                    Some(lpr::util::json::Json::Bool(b)) => b.to_string(),
                    Some(other) => format!("{other:?}"),
                    None => "-".to_string(),
                })
                .collect();
            t.row(cells);
        }
        md.push_str(&t.to_markdown());
        md.push('\n');
        rendered += 1;
    }
    if rendered == 0 {
        bail!(
            "no BENCH_*.json artifacts in {} — run `cargo bench --bench \
             micro` or download the bench-smoke CI artifacts first",
            dir.display()
        );
    }
    match args.opt("out") {
        Some(out) => {
            std::fs::write(out, &md)
                .with_context(|| format!("write {out}"))?;
            eprintln!("wrote {rendered} tables to {out}");
        }
        None => print!("{md}"),
    }
    Ok(())
}

/// Open-loop serving benchmark on the persistent-pool runtime: sweep
/// overflow policy × worker count × arrival rate over a skewed
/// clustered token stream, print the latency/throughput table, and
/// emit the rows as `BENCH_serve.json` (next to `BENCH_router.json` /
/// `BENCH_dispatch.json` in the cross-PR perf trajectory).
///
/// Arrival rates default to 0.5×/1×/2× of this machine's *measured*
/// full-forward capacity per worker count (so the sweep brackets
/// saturation everywhere); `--rate` pins one absolute rate instead.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    if let Some(file) = args.opt("lanes") {
        return serve_bench_lanes(args, file);
    }
    let metric = args.opt_or("metric", "cosine");
    let d = args.opt_usize("dmodel", 32);
    let dz = args.opt_usize("latent", 16);
    let e = args.opt_usize("experts", 64);
    let k = args.opt_usize("topk", 4);
    let d_ff = args.opt_usize("dff", 2 * d);
    let req_tokens = args.opt_usize("req-tokens", 32);
    let n_requests = args.opt_usize("requests", 256);
    let max_batch = args.opt_usize("max-batch", 256);
    let max_wait = args.opt_usize("max-wait", 2000) as u64;
    let cf = args.opt_f64("cf", 1.25);
    let renormalize = args.has_flag("renormalize");
    let seed = args.opt_usize("seed", 23) as u64;
    anyhow::ensure!(
        req_tokens <= max_batch,
        "--req-tokens {req_tokens} exceeds --max-batch {max_batch}"
    );

    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let workers_list: Vec<usize> = match args.opt("workers") {
        Some(s) => vec![s.parse().context("--workers")?],
        None => [1usize, 2, 4].iter().cloned().filter(|&w| w <= cores.max(1)).collect(),
    };
    let workers_list = if workers_list.is_empty() {
        vec![1]
    } else {
        workers_list
    };
    let policies: Vec<OverflowPolicy> = match args.opt("policy") {
        Some(p) => vec![p.parse::<OverflowPolicy>()?],
        None => OverflowPolicy::ALL.to_vec(),
    };
    let fixed_rate = args.opt("rate").map(|r| r.parse::<f64>()).transpose()
        .context("--rate")?;

    println!(
        "serve-bench: {metric} router, {e} experts top-{k}, d={d} \
         d_ff={d_ff}, {req_tokens}-token requests x {n_requests}, \
         max_batch {max_batch}, max_wait {max_wait} us, cf {cf}{}",
        if renormalize { ", renormalize" } else { "" }
    );
    println!(
        "{:<14} {:>7} {:>6} {:>12} {:>9} {:>9} {:>14} {:>9} {:>9}",
        "policy", "workers", "load", "rate tok/s", "p50 us", "p99 us",
        "tok/s served", "win-GINI", "rejected"
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &workers in &workers_list {
        // measured capacity of this worker count anchors the load
        // sweep — calibrated through the same builder-constructed
        // backend the cells use
        let mut rng = Rng::new(seed);
        let router = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
        let mix = MixtureStream::skewed(&mut rng, d, 1.6);
        let mut cal = Engine::builder()
            .layer(router.plan().clone(), bank)
            .backend(Backend::Pool { workers })
            .policy(OverflowPolicy::Drop)
            .capacity_factor(cf)
            .build()?;
        let cap_tok_s =
            measure_engine_rate(&mut cal, &mix, &mut rng, max_batch, 3);
        drop(cal);
        let rates: Vec<(f64, f64)> = match fixed_rate {
            Some(r) => vec![(r / cap_tok_s, r)],
            None => [0.5f64, 1.0, 2.0]
                .iter()
                .map(|&l| (l, l * cap_tok_s))
                .collect(),
        };
        for &policy in &policies {
            for &(load, rate) in &rates {
                // identical seeds per cell: same router, same stream
                let mut rng = Rng::new(seed);
                let router =
                    synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
                let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
                let mix = MixtureStream::skewed(&mut rng, d, 1.6);
                let engine = Engine::builder()
                    .layer(router.plan().clone(), bank)
                    .backend(Backend::Pool { workers })
                    .policy(policy)
                    .capacity_factor(cf)
                    .renormalize(renormalize)
                    .build()?;
                let cfg = ServeConfig {
                    max_batch,
                    max_wait,
                    queue_tokens: 8 * max_batch,
                    service_ticks: None,
                    ..ServeConfig::default()
                };
                let mut srv =
                    ServeRuntime::with_engine(engine.into_inner(), cfg);
                run_open_loop(
                    &mut srv, &mix, &mut rng, n_requests, req_tokens,
                    rate,
                );
                let r = srv.report();
                println!(
                    "{:<14} {:>7} {:>6.2} {:>12.0} {:>9.0} {:>9.0} \
                     {:>14.0} {:>9.3} {:>9}",
                    policy.name(),
                    workers,
                    load,
                    rate,
                    r.latency_p50_us,
                    r.latency_p99_us,
                    r.throughput_tok_per_s,
                    r.window_gini,
                    r.rejected
                );
                json_rows.push(r.bench_json_row(
                    policy, workers, rate, load, req_tokens,
                ));
            }
        }
    }
    if let Err(e) = write_json_rows("BENCH_serve.json", &json_rows) {
        eprintln!("warn: could not write BENCH_serve.json: {e}");
    } else {
        eprintln!("wrote BENCH_serve.json ({} rows)", json_rows.len());
    }
    Ok(())
}

/// `serve-bench --lanes FILE`: drive the compiled admission front at
/// 0.5x/1x/2x of measured capacity with traffic aimed at every lane's
/// canonical meta, print the per-lane shed/latency table, and emit the
/// rows as `BENCH_admission.json` (rendered by `lpr bench-tables` and
/// uploaded by the bench-smoke CI job).
fn serve_bench_lanes(args: &Args, file: &str) -> Result<()> {
    let metric = args.opt_or("metric", "cosine");
    let d = args.opt_usize("dmodel", 32);
    let dz = args.opt_usize("latent", 16);
    let e = args.opt_usize("experts", 64);
    let k = args.opt_usize("topk", 4);
    let d_ff = args.opt_usize("dff", 2 * d);
    let req_tokens = args.opt_usize("req-tokens", 32);
    let n_requests = args.opt_usize("requests", 256);
    let max_batch = args.opt_usize("max-batch", 256);
    let max_wait = args.opt_usize("max-wait", 2000) as u64;
    let workers = args.opt_usize("workers", 2);
    let cf = args.opt_f64("cf", 1.25);
    let seed = args.opt_usize("seed", 23) as u64;
    anyhow::ensure!(
        req_tokens <= max_batch,
        "--req-tokens {req_tokens} exceeds --max-batch {max_batch}"
    );
    let text = std::fs::read_to_string(file)
        .with_context(|| format!("read lane config {file}"))?;
    let config = AdmissionConfig::parse(&text)?;
    config.validate(max_batch)?;
    let metas: Vec<RequestMeta> =
        config.lanes.iter().map(|l| l.example_meta()).collect();

    // capacity calibration through the same builder-constructed
    // backend the cells use, exactly like the policy sweep
    let mut rng = Rng::new(seed);
    let router = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
    let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
    let mix = MixtureStream::skewed(&mut rng, d, 1.6);
    let mut cal = Engine::builder()
        .layer(router.plan().clone(), bank)
        .backend(Backend::Pool { workers })
        .capacity_factor(cf)
        .build()?;
    let cap_tok_s =
        measure_engine_rate(&mut cal, &mix, &mut rng, max_batch, 3);
    drop(cal);

    println!(
        "serve-bench --lanes {file}: {} lanes, {metric} router, \
         {e} experts top-{k}, d={d}, capacity {cap_tok_s:.0} tok/s, \
         {req_tokens}-token requests x {n_requests}",
        config.lanes.len()
    );
    println!(
        "{:<14} {:>6} {:>7} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "lane", "load", "weight", "admitted", "shed", "p50 us",
        "p99 us", "mean us"
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &load in &[0.5f64, 1.0, 2.0] {
        let rate = load * cap_tok_s;
        // identical seeds per cell: same router, same stream
        let mut rng = Rng::new(seed);
        let router = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
        let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
        let mix = MixtureStream::skewed(&mut rng, d, 1.6);
        let engine = Engine::builder()
            .layer(router.plan().clone(), bank)
            .backend(Backend::Pool { workers })
            .capacity_factor(cf)
            .build()?;
        let cfg = ServeConfig {
            max_batch,
            max_wait,
            queue_tokens: 8 * max_batch,
            ..ServeConfig::default()
        };
        let adm = config.compile(d, max_batch)?;
        let mut rt =
            AdmittedRuntime::new(engine.into_inner(), cfg, adm);
        run_admitted_open_loop(
            &mut rt, &mix, &mut rng, &metas, n_requests, req_tokens,
            rate,
        );
        let rep = rt.report();
        for l in &rep.lanes {
            println!(
                "{:<14} {:>6.2} {:>7} {:>9} {:>9} {:>9.0} {:>9.0} \
                 {:>9.0}",
                l.name,
                load,
                l.weight,
                l.admitted,
                l.rejected,
                l.latency_p50_us,
                l.latency_p99_us,
                l.latency_mean_us
            );
            json_rows.push(format!(
                "{{\"name\": \"admission/{}\", \"load\": {:.2}, \
                 \"rate_tok_s\": {:.0}, \"weight\": {}, \
                 \"admitted\": {}, \"rejected\": {}, \
                 \"spilled_in\": {}, \"p50_us\": {:.1}, \
                 \"p99_us\": {:.1}, \"mean_us\": {:.1}}}",
                l.name,
                load,
                rate,
                l.weight,
                l.admitted,
                l.rejected,
                l.spilled_in,
                l.latency_p50_us,
                l.latency_p99_us,
                l.latency_mean_us
            ));
        }
    }
    if let Err(e) = write_json_rows("BENCH_admission.json", &json_rows) {
        eprintln!("warn: could not write BENCH_admission.json: {e}");
    } else {
        eprintln!(
            "wrote BENCH_admission.json ({} rows)",
            json_rows.len()
        );
    }
    Ok(())
}

/// `lpr listen`: bind the TCP front-end over a synthetic single-layer
/// engine and serve until interrupted, printing per-lane admission
/// stats every few seconds. `--lanes FILE` compiles a multi-lane
/// admission config; the default is one catch-all lane sized from the
/// serve config.
fn cmd_listen(args: &Args) -> Result<()> {
    let metric = args.opt_or("metric", "cosine");
    let d = args.opt_usize("dmodel", 32);
    let dz = args.opt_usize("latent", 16);
    let e = args.opt_usize("experts", 64);
    let k = args.opt_usize("topk", 4);
    let d_ff = args.opt_usize("dff", 2 * d);
    let workers = args.opt_usize("workers", 2);
    let max_batch = args.opt_usize("max-batch", 256);
    let max_wait = args.opt_usize("max-wait", 2000) as u64;
    let addr = args.opt_or("addr", "127.0.0.1:7077");
    let http = args.has_flag("http");
    let seed = args.opt_usize("seed", 23) as u64;

    let mut rng = Rng::new(seed);
    let router = synthetic_lpr_router(metric, &mut rng, d, dz, e, k);
    let bank = ExpertBank::new(&Rng::new(42), e, d, d_ff);
    let engine = Engine::builder()
        .layer(router.plan().clone(), bank)
        .backend(Backend::Pool { workers })
        .build()?;
    let cfg = ServeConfig {
        max_batch,
        max_wait,
        queue_tokens: 8 * max_batch,
        ..ServeConfig::default()
    };
    let rt = ServeRuntime::with_engine(engine.into_inner(), cfg);
    let server = match args.opt("lanes") {
        Some(file) => {
            let text = std::fs::read_to_string(file)
                .with_context(|| format!("read lane config {file}"))?;
            let adm = AdmissionConfig::parse(&text)?
                .compile(d, max_batch)?;
            println!("admission lanes ({file}):");
            for s in adm.specs() {
                println!(
                    "  {:<14} quota {} tokens, weight {}",
                    s.name, s.quota, s.weight
                );
            }
            Server::with_admission(
                rt,
                adm,
                std::time::Duration::from_micros(200),
            )
        }
        None => Server::start(rt),
    };
    let server = std::sync::Arc::new(server);
    let net = if http {
        NetServer::start(server.clone(), addr, HttpWire::default())?
    } else {
        NetServer::start(
            server.clone(),
            addr,
            LengthPrefixed::default(),
        )?
    };
    println!(
        "listening on {} ({} wire, d_model {d}, max_batch {max_batch}) \
         — ctrl-c to stop",
        net.addr(),
        if http { "http" } else { "length-prefixed" }
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        let rep = server.report();
        let lanes: Vec<String> = rep
            .lanes
            .iter()
            .map(|l| {
                format!(
                    "{}: {} ok / {} shed / {} queued",
                    l.name, l.admitted, l.rejected, l.queue_depth_tokens
                )
            })
            .collect();
        println!(
            "served {} requests ({} tokens)  |  {}",
            rep.requests,
            rep.tokens,
            lanes.join("  |  ")
        );
    }
}

fn cmd_list(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let manifest = dir.join("manifest.json");
    if !manifest.exists() {
        bail!(
            "no manifest at {} — run `make artifacts` first",
            manifest.display()
        );
    }
    let j =
        lpr::util::json::Json::parse(&std::fs::read_to_string(&manifest)?)
            .context("manifest.json")?;
    if let lpr::util::json::Json::Obj(arts) = j.at("artifacts") {
        println!("{} artifacts in {}:", arts.len(), dir.display());
        for name in arts.keys() {
            println!("  {name}");
        }
    }
    Ok(())
}
