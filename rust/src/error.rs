//! One error type for the crate's public surface.
//!
//! Before this module the crate's failure modes were a mix of ad-hoc
//! enums without `Display` (`serve::SubmitError`), panics (engine
//! misconfiguration), hand-assembled strings (`--policy` parsing), and
//! `anyhow` chains (checkpoint / bridge / artifact IO). Every typed
//! error now implements `Display` + `std::error::Error` and converts
//! into the shared [`Error`], so `main.rs` — and any embedder — can
//! print one error chain instead of formatting each family by hand:
//!
//! ```
//! use lpr::engine::Engine;
//!
//! fn build() -> Result<(), lpr::Error> {
//!     let _e = Engine::builder().build()?; // EngineBuildError -> lpr::Error
//!     Ok(())
//! }
//! let err = build().unwrap_err();
//! assert!(err.to_string().contains("model"));
//! assert!(std::error::Error::source(&err).is_some());
//! ```

use crate::dispatch::placement::ParsePlacementError;
use crate::dispatch::plan::ParsePolicyError;
use crate::engine::EngineBuildError;
use crate::serve::{AdmissionError, AdmitError, SubmitError};

/// The crate-wide error: every typed failure family converts into it
/// (`?` works across layers), and `source()` exposes the underlying
/// typed error for callers that match on it.
#[derive(Debug)]
pub enum Error {
    /// Engine/builder configuration rejected
    /// ([`crate::engine::EngineBuildError`]).
    Build(EngineBuildError),
    /// Submission refused by the serving queue
    /// ([`crate::serve::SubmitError`]).
    Submit(SubmitError),
    /// Admission config rejected at parse/validate/compile
    /// ([`crate::serve::AdmissionError`]).
    Admission(AdmissionError),
    /// Request refused by the compiled admission layer
    /// ([`crate::serve::AdmitError`]).
    Admit(AdmitError),
    /// Unrecognized overflow-policy name
    /// ([`crate::dispatch::ParsePolicyError`]).
    Policy(ParsePolicyError),
    /// Unrecognized placement-policy name
    /// ([`crate::dispatch::ParsePlacementError`]).
    Placement(ParsePlacementError),
    /// Checkpoint / bridge / artifact IO or format failure (the
    /// `anyhow` chains of `coordinator::checkpoint`, `model::bridge`,
    /// and `runtime`).
    Artifact(anyhow::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Build(e) => write!(f, "engine configuration: {e}"),
            Error::Submit(e) => write!(f, "request submission: {e}"),
            Error::Admission(e) => {
                write!(f, "admission configuration: {e}")
            }
            Error::Admit(e) => write!(f, "request admission: {e}"),
            Error::Policy(e) => write!(f, "{e}"),
            Error::Placement(e) => write!(f, "{e}"),
            Error::Artifact(e) => write!(f, "{e:#}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Build(e) => Some(e),
            Error::Submit(e) => Some(e),
            Error::Admission(e) => Some(e),
            Error::Admit(e) => Some(e),
            Error::Policy(e) => Some(e),
            Error::Placement(e) => Some(e),
            Error::Artifact(e) => Some(e.as_ref()),
        }
    }
}

impl From<EngineBuildError> for Error {
    fn from(e: EngineBuildError) -> Error {
        Error::Build(e)
    }
}

impl From<SubmitError> for Error {
    fn from(e: SubmitError) -> Error {
        Error::Submit(e)
    }
}

impl From<AdmissionError> for Error {
    fn from(e: AdmissionError) -> Error {
        Error::Admission(e)
    }
}

impl From<AdmitError> for Error {
    fn from(e: AdmitError) -> Error {
        Error::Admit(e)
    }
}

impl From<ParsePolicyError> for Error {
    fn from(e: ParsePolicyError) -> Error {
        Error::Policy(e)
    }
}

impl From<ParsePlacementError> for Error {
    fn from(e: ParsePlacementError) -> Error {
        Error::Placement(e)
    }
}

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Error {
        Error::Artifact(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_family_converts_and_displays() {
        let cases: Vec<Error> = vec![
            EngineBuildError::MissingModel.into(),
            SubmitError::Full.into(),
            SubmitError::TooLarge.into(),
            AdmissionError::NoLanes.into(),
            AdmitError::NoRoute { path: "/x".into() }.into(),
            ParsePolicyError("bogus".into()).into(),
            ParsePlacementError("nowhere".into()).into(),
            anyhow::anyhow!("artifact exploded").into(),
        ];
        for e in &cases {
            let msg = e.to_string();
            assert!(!msg.is_empty());
            // the chain is inspectable for typed handling
            assert!(
                std::error::Error::source(e).is_some(),
                "{msg} lost its source"
            );
        }
        assert!(cases[5].to_string().contains("bogus"));
        assert!(cases[5].to_string().contains("least-loaded"));
        assert!(cases[6].to_string().contains("nowhere"));
        assert!(cases[6].to_string().contains("loadaware"));
        assert!(cases[3].to_string().contains("admission"));
        assert!(cases[4].to_string().contains("/x"));
    }

    #[test]
    fn submit_errors_render_their_cause() {
        assert!(SubmitError::Full.to_string().contains("full"));
        assert!(SubmitError::TooLarge.to_string().contains("max_batch"));
    }
}
