//! Cache-blocked GEMM engine: operand packing and the MR×NR
//! register-tiled inner kernel behind `Kernel::Blocked` and both SIMD
//! kernels.
//!
//! Loop nest (BLIS-style, tile sizes from [`GemmTiles`]):
//!
//! ```text
//! jc strip (nc ≤ tiles.nc columns)
//!   pc block (kc ≤ tiles.kc of the reduction)
//!     pack B[pc.., jc..] -> [nc/NR][kc][NR] micro-panels  (dequantized)
//!     ic block (mc ≤ tiles.mc rows)
//!       pack A[ic.., pc..] -> [mc/MR][kc][MR] strips
//!       for each (MR×NR) register tile: load C, kc rank-1 updates, store C
//!   fused bias (+SiLU) epilogue over the finished strip
//! ```
//!
//! Bit-identity: every output element accumulates its `k` products in
//! ascending order (pc blocks ascend, `p` ascends inside a tile) with
//! a plain multiply-then-add in the scalar tile, so f32 results equal
//! `Kernel::Naive` bit-for-bit for any tile sizes. Packing is pure
//! data movement; ragged edges are zero-padded in the packs and the
//! padded accumulator lanes are simply never stored back. The SIMD
//! tiles keep the same loop structure but use FMA, trading the
//! bit-identity for one fewer rounding per product.

use std::cell::RefCell;

use super::{bf16_to_f32, silu_one, GemmTiles, WeightsView};

/// Register-tile rows (of A) per inner micro-kernel call.
pub(crate) const MR: usize = 4;
/// Register-tile columns (of B) per inner micro-kernel call — one
/// `__m256` / two `float32x4` per tile row.
pub(crate) const NR: usize = 8;

/// Which inner register tile the blocked engine runs. Resolved once
/// per GEMM by `Kernel::micro` (runtime ISA detection happens there,
/// not in the hot loop).
#[derive(Debug, Clone, Copy)]
pub(crate) enum Micro {
    /// Portable scalar tile — plain mul-then-add, the bit-exact path.
    Scalar,
    /// AVX2+FMA tile (`simd` feature, x86_64, runtime-detected).
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    Avx2,
    /// NEON FMA tile (`simd` feature, aarch64, runtime-detected).
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    Neon,
}

thread_local! {
    /// Packed A strips (`[mc/MR][kc][MR]`). Thread-local and fully
    /// overwritten per `(ic, pc)` block, so sharing across calls never
    /// leaks state between batches or experts.
    static PACK_A: RefCell<Vec<f32>> = RefCell::new(Vec::new());
    /// Packed, dequantized B micro-panels (`[nc/NR][kc][NR]`); same
    /// overwrite discipline per `(pc, jc)` block.
    static PACK_B: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Run `f` with the two thread-local pack buffers borrowed — the one
/// scratch entry point shared by the plain and gated drivers.
pub(crate) fn with_packs<R>(
    f: impl FnOnce(&mut Vec<f32>, &mut Vec<f32>) -> R,
) -> R {
    PACK_A.with(|ca| {
        PACK_B.with(|cb| {
            let mut ga = ca.borrow_mut();
            let mut gb = cb.borrow_mut();
            f(&mut ga, &mut gb)
        })
    })
}

/// Full blocked GEMM with the fused bias(+SiLU) epilogue per strip:
/// the body behind `gemm_bias_act_tiled` for every non-Naive kernel.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm(
    a: &[f32],
    b: WeightsView<'_>,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    silu: bool,
    tiles: GemmTiles,
    micro: Micro,
) {
    c.fill(0.0);
    with_packs(|pack_a, pack_b| {
        let mut jc = 0;
        while jc < n {
            let nc = tiles.nc.min(n - jc);
            accumulate_strip(
                a, k, b, n, m, jc, nc, c, n, jc, tiles, micro, pack_a,
                pack_b,
            );
            epilogue_strip(c, n, jc, nc, m, bias, silu);
            jc += tiles.nc;
        }
    });
}

/// Accumulate `A[m,k] · B[k, jc..jc+nc]` into `dst` (row-major with
/// row stride `dst_stride`, columns starting at `dst_col0`), walking
/// the full reduction in ascending `pc` blocks. `dst` carries the
/// partial sums between calls, so a caller may split one logical GEMM
/// across two accumulation targets (the gated driver does).
#[allow(clippy::too_many_arguments)]
pub(crate) fn accumulate_strip(
    a: &[f32],
    k: usize,
    b: WeightsView<'_>,
    n: usize,
    m: usize,
    jc: usize,
    nc: usize,
    dst: &mut [f32],
    dst_stride: usize,
    dst_col0: usize,
    tiles: GemmTiles,
    micro: Micro,
    pack_a: &mut Vec<f32>,
    pack_b: &mut Vec<f32>,
) {
    let mut pc = 0;
    while pc < k {
        let kc = tiles.kc.min(k - pc);
        pack_b_micropanels(b, pack_b, n, pc, kc, jc, nc);
        let mut ic = 0;
        while ic < m {
            let mc = tiles.mc.min(m - ic);
            pack_a_strip(a, pack_a, k, ic, mc, pc, kc);
            run_block_tiles(
                pack_a, pack_b, dst, dst_stride, dst_col0, ic, mc, nc,
                kc, micro,
            );
            ic += tiles.mc;
        }
        pc += tiles.kc;
    }
}

/// Fused bias + optional SiLU over the finished `jc` strip — every
/// output element is touched exactly twice per GEMM (accumulate,
/// epilogue).
fn epilogue_strip(
    c: &mut [f32],
    n: usize,
    jc: usize,
    nc: usize,
    m: usize,
    bias: &[f32],
    silu: bool,
) {
    for i in 0..m {
        let c_row = &mut c[i * n + jc..i * n + jc + nc];
        let b_row = &bias[jc..jc + nc];
        for (cj, &bj) in c_row.iter_mut().zip(b_row) {
            *cj += bj;
        }
        if silu {
            for cj in c_row.iter_mut() {
                *cj = silu_one(*cj);
            }
        }
    }
}

/// Pack `A[ic..ic+mc, pc..pc+kc]` into `[mc/MR]` strips of `[kc, MR]`
/// (k-major within a strip, so the micro-kernel streams both packs
/// linearly). Ragged row tails are zero-padded.
fn pack_a_strip(
    a: &[f32],
    pack: &mut Vec<f32>,
    k: usize,
    ic: usize,
    mc: usize,
    pc: usize,
    kc: usize,
) {
    let strips = mc.div_ceil(MR);
    pack.clear();
    pack.resize(strips * kc * MR, 0.0);
    for t in 0..strips {
        let i0 = ic + t * MR;
        let mr = MR.min(ic + mc - i0);
        let dst = &mut pack[t * kc * MR..(t + 1) * kc * MR];
        for (r, dcol) in dst.chunks_exact_mut(MR).enumerate().take(kc) {
            // r walks the kc reduction; dcol holds MR row values
            let p = pc + r;
            for (rr, d) in dcol.iter_mut().enumerate().take(mr) {
                *d = a[(i0 + rr) * k + p];
            }
        }
    }
}

/// Pack (and dequantize) `B[pc..pc+kc, jc..jc+nc]` into `[nc/NR]`
/// micro-panels of `[kc, NR]`. Quantized stores dequantize here,
/// panel-at-a-time, directly into the layout the register tile
/// consumes — no row-scratch round trip. Ragged column tails are
/// zero-padded.
fn pack_b_micropanels(
    b: WeightsView<'_>,
    pack: &mut Vec<f32>,
    n: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
) {
    let panels = nc.div_ceil(NR);
    pack.clear();
    pack.resize(panels * kc * NR, 0.0);
    for t in 0..panels {
        let j0 = jc + t * NR;
        let nr = NR.min(jc + nc - j0);
        let dst = &mut pack[t * kc * NR..(t + 1) * kc * NR];
        match b {
            WeightsView::F32(w) => {
                for (r, drow) in
                    dst.chunks_exact_mut(NR).enumerate().take(kc)
                {
                    let src = &w[(pc + r) * n + j0..][..nr];
                    drow[..nr].copy_from_slice(src);
                }
            }
            WeightsView::Bf16(w) => {
                for (r, drow) in
                    dst.chunks_exact_mut(NR).enumerate().take(kc)
                {
                    let src = &w[(pc + r) * n + j0..][..nr];
                    for (d, &h) in drow.iter_mut().zip(src) {
                        *d = bf16_to_f32(h);
                    }
                }
            }
            WeightsView::Int8 { q, scales } => {
                for (r, drow) in
                    dst.chunks_exact_mut(NR).enumerate().take(kc)
                {
                    let s = scales[pc + r];
                    let src = &q[(pc + r) * n + j0..][..nr];
                    for (d, &v) in drow.iter_mut().zip(src) {
                        *d = v as f32 * s;
                    }
                }
            }
        }
    }
}

/// Sweep the packed block with MR×NR register tiles: per tile, load
/// the live C sub-block into the accumulator, run the `kc` rank-1
/// updates, store the valid lanes back. Padded lanes never reach
/// `dst`.
#[allow(clippy::too_many_arguments)]
fn run_block_tiles(
    pack_a: &[f32],
    pack_b: &[f32],
    dst: &mut [f32],
    dst_stride: usize,
    dst_col0: usize,
    ic: usize,
    mc: usize,
    nc: usize,
    kc: usize,
    micro: Micro,
) {
    for jt in 0..nc.div_ceil(NR) {
        let j0 = jt * NR;
        let nr = NR.min(nc - j0);
        let bp = &pack_b[jt * kc * NR..(jt + 1) * kc * NR];
        for it in 0..mc.div_ceil(MR) {
            let i0 = it * MR;
            let mr = MR.min(mc - i0);
            let ap = &pack_a[it * kc * MR..(it + 1) * kc * MR];
            let mut acc = [[0.0f32; NR]; MR];
            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                let row =
                    (ic + i0 + r) * dst_stride + dst_col0 + j0;
                accr[..nr].copy_from_slice(&dst[row..row + nr]);
            }
            micro_tile(micro, ap, bp, kc, &mut acc);
            for (r, accr) in acc.iter().enumerate().take(mr) {
                let row =
                    (ic + i0 + r) * dst_stride + dst_col0 + j0;
                dst[row..row + nr].copy_from_slice(&accr[..nr]);
            }
        }
    }
}

/// One MR×NR register tile over packed `[kc, MR]` / `[kc, NR]`
/// operands — the only place the three engines differ.
fn micro_tile(
    micro: Micro,
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    acc: &mut [[f32; NR]; MR],
) {
    match micro {
        Micro::Scalar => scalar_tile(ap, bp, kc, acc),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Micro::Avx2 => {
            // SAFETY: Micro::Avx2 is only constructed after runtime
            // AVX2+FMA detection (`Kernel::micro` / `simd_available`).
            unsafe { super::simd_x86::tile_avx2(ap, bp, kc, acc) }
        }
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Micro::Neon => {
            // SAFETY: Micro::Neon is only constructed after runtime
            // NEON detection (`Kernel::micro` / `neon_available`).
            unsafe { super::simd_neon::tile_neon(ap, bp, kc, acc) }
        }
    }
}

/// Portable scalar tile: `kc` rank-1 updates with plain
/// multiply-then-add in ascending `p` order — the op sequence that
/// keeps Blocked bit-identical to Naive on f32.
#[inline]
fn scalar_tile(
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    acc: &mut [[f32; NR]; MR],
) {
    for p in 0..kc {
        let av = &ap[p * MR..(p + 1) * MR];
        let bv = &bp[p * NR..(p + 1) * NR];
        for (accr, &a) in acc.iter_mut().zip(av) {
            for (cell, &b) in accr.iter_mut().zip(bv) {
                *cell += a * b;
            }
        }
    }
}
