//! AVX2+FMA register tile (x86_64, `simd` feature).
//!
//! Same packing and loop structure as the scalar tile in
//! `blocked.rs`; each of the MR accumulator rows is one `__m256`
//! (NR = 8 f32 lanes) updated with `_mm256_fmadd_ps` per reduction
//! step. FMA fuses the multiply-add rounding, so results differ from
//! the scalar kernels in the last ulp — deterministic in itself
//! (fixed tile sizes, fixed lane order), just not bit-equal to
//! Blocked.

use super::blocked::{MR, NR};

// the whole-register loads below assume one __m256 per tile row
const _: () = assert!(NR == 8);

/// One MR×NR register tile over packed `[kc, MR]` A and `[kc, NR]` B.
///
/// # Safety
///
/// Caller must have verified AVX2+FMA at runtime (`simd_available`);
/// `ap`/`bp` must hold at least `kc*MR` / `kc*NR` elements (the packed
/// layouts `blocked.rs` builds).
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn tile_avx2(
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    acc: &mut [[f32; NR]; MR],
) {
    use std::arch::x86_64::*;
    let mut vacc = [_mm256_setzero_ps(); MR];
    for (v, row) in vacc.iter_mut().zip(acc.iter()) {
        *v = _mm256_loadu_ps(row.as_ptr());
    }
    for p in 0..kc {
        let vb = _mm256_loadu_ps(bp.as_ptr().add(p * NR));
        let av = &ap[p * MR..(p + 1) * MR];
        for (v, &a) in vacc.iter_mut().zip(av) {
            let va = _mm256_set1_ps(a);
            *v = _mm256_fmadd_ps(va, vb, *v);
        }
    }
    for (row, &v) in acc.iter_mut().zip(vacc.iter()) {
        _mm256_storeu_ps(row.as_mut_ptr(), v);
    }
}
