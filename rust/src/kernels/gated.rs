//! Fused SwiGLU first stage:
//! `C[m,n] = silu(A·B1 + bias1) ⊙ (A·B3 + bias3)`.
//!
//! A gated expert's up-projection needs two GEMMs over the *same*
//! activations. Instead of materializing both `[m, d_ff]` products
//! and multiplying in a third pass, [`gemm_bias_act_gated`] walks each
//! `jc` strip once: accumulate the `B1` panels into `c`, the `B3`
//! panels into a thread-local gate scratch (`[m, nc]`, re-zeroed per
//! strip), then run one fused epilogue
//! `c = silu(c + bias1) ⊙ (gate + bias3)` while the strip is still
//! cache-hot.
//!
//! Op-order contract: the epilogue applies exactly the expression a
//! hand-composed `silu(x·w1 + b1)` (via `gemm_bias_act`, silu on)
//! times `(x·w3 + b3)` (silu off) would, and the accumulation order
//! per element is the same ascending-`k` walk as the plain kernels.
//! So for f32 weights, Naive-gated and Blocked-gated are
//! **bit-identical** to that hand-composed reference (pinned below);
//! Simd/Neon match it within the usual FMA tolerance.

use std::cell::RefCell;

use super::blocked::{self, Micro};
use super::{silu_one, GemmTiles, Kernel, WeightsView};

thread_local! {
    /// Gate accumulator: `[m, nc]` per `jc` strip for the blocked
    /// drivers, `[n]` per row for the naive driver. Fully re-zeroed
    /// before each use, so sharing across calls never leaks state.
    static GATE: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Fused gated GEMM: `C[m,n] = silu(A·B1 + bias1) ⊙ (A·B3 + bias3)`,
/// f32 accumulation, overwriting `c`. `b1`/`b3` must share the
/// `[k, n]` shape (any [`WeightsView`] dtype, independently). The
/// gated counterpart of `gemm_bias_act_tiled` — same kernel dispatch,
/// same tile semantics (results are tile-invariant per kernel).
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_act_gated(
    kernel: Kernel,
    tiles: GemmTiles,
    a: &[f32],
    b1: WeightsView<'_>,
    bias1: &[f32],
    b3: WeightsView<'_>,
    bias3: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    b1.check_shape(k, n);
    b3.check_shape(k, n);
    assert_eq!(bias1.len(), n, "bias1 shape");
    assert_eq!(bias3.len(), n, "bias3 shape");
    assert_eq!(c.len(), m * n, "C shape");
    tiles.check();
    match kernel {
        Kernel::Naive => {
            naive_gated(a, b1, bias1, b3, bias3, c, m, k, n)
        }
        other => blocked_gated(
            a,
            b1,
            bias1,
            b3,
            bias3,
            c,
            m,
            k,
            n,
            tiles,
            other.micro(),
        ),
    }
}

/// Row-at-a-time gated reference path: per row, accumulate `x·w1`
/// into `c` and `x·w3` into the gate scratch (both ascending `k`),
/// then apply the fused epilogue. Bit-identical to hand-composing two
/// naive `gemm_bias_act` calls and an elementwise product.
#[allow(clippy::too_many_arguments)]
fn naive_gated(
    a: &[f32],
    b1: WeightsView<'_>,
    bias1: &[f32],
    b3: WeightsView<'_>,
    bias3: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    c.fill(0.0);
    GATE.with(|cell| {
        let mut guard = cell.borrow_mut();
        let gate: &mut Vec<f32> = &mut guard;
        gate.clear();
        gate.resize(n, 0.0);
        for i in 0..m {
            let a_row = &a[i * k..(i + 1) * k];
            let c_row = &mut c[i * n..(i + 1) * n];
            gate.fill(0.0);
            super::accumulate_row_naive(a_row, b1, c_row, n);
            super::accumulate_row_naive(a_row, b3, gate, n);
            for (((cj, &g), &bj1), &bj3) in
                c_row.iter_mut().zip(gate.iter()).zip(bias1).zip(bias3)
            {
                *cj = silu_one(*cj + bj1) * (g + bj3);
            }
        }
    });
}

/// Blocked gated driver: per `jc` strip, run the full reduction for
/// both operands through the shared register-tiled engine, then the
/// fused epilogue. `c` holds the `w1` partials in place; the gate
/// partials live in the `[m, nc]` thread-local scratch.
#[allow(clippy::too_many_arguments)]
fn blocked_gated(
    a: &[f32],
    b1: WeightsView<'_>,
    bias1: &[f32],
    b3: WeightsView<'_>,
    bias3: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tiles: GemmTiles,
    micro: Micro,
) {
    c.fill(0.0);
    blocked::with_packs(|pack_a, pack_b| {
        GATE.with(|cell| {
            let mut guard = cell.borrow_mut();
            let gate: &mut Vec<f32> = &mut guard;
            let mut jc = 0;
            while jc < n {
                let nc = tiles.nc.min(n - jc);
                gate.clear();
                gate.resize(m * nc, 0.0);
                blocked::accumulate_strip(
                    a, k, b1, n, m, jc, nc, c, n, jc, tiles, micro,
                    pack_a, pack_b,
                );
                blocked::accumulate_strip(
                    a, k, b3, n, m, jc, nc, gate, nc, 0, tiles, micro,
                    pack_a, pack_b,
                );
                for i in 0..m {
                    let c_row = &mut c[i * n + jc..i * n + jc + nc];
                    let g_row = &gate[i * nc..(i + 1) * nc];
                    let b1_row = &bias1[jc..jc + nc];
                    let b3_row = &bias3[jc..jc + nc];
                    for (((cj, &g), &bj1), &bj3) in c_row
                        .iter_mut()
                        .zip(g_row)
                        .zip(b1_row)
                        .zip(b3_row)
                    {
                        *cj = silu_one(*cj + bj1) * (g + bj3);
                    }
                }
                jc += tiles.nc;
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::super::{
        gemm_bias_act, GemmTiles, Kernel, WeightDtype, WeightStore,
        WeightsView, KC, MC, NC,
    };
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Hand-composed SwiGLU reference: `silu(x·w1 + b1)` and
    /// `(x·w3 + b3)` as two separate naive GEMMs, multiplied
    /// elementwise — the exact path a bank without the fused kernel
    /// would take.
    #[allow(clippy::too_many_arguments)]
    fn hand_composed(
        a: &[f32],
        w1: &[f32],
        b1: &[f32],
        w3: &[f32],
        b3: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut h1 = vec![0.0f32; m * n];
        let mut h3 = vec![0.0f32; m * n];
        gemm_bias_act(
            Kernel::Naive,
            a,
            WeightsView::F32(w1),
            b1,
            &mut h1,
            m,
            k,
            n,
            true,
        );
        gemm_bias_act(
            Kernel::Naive,
            a,
            WeightsView::F32(w3),
            b3,
            &mut h3,
            m,
            k,
            n,
            false,
        );
        h1.iter().zip(&h3).map(|(&x, &g)| x * g).collect()
    }

    /// Odd shapes straddling the default tile boundaries.
    const SHAPES: [(usize, usize, usize); 5] = [
        (1, 1, 1),
        (3, 5, 7),
        (7, 300, 19),
        (MC + 3, KC + 5, NC + 9),
        (13, 2 * KC + 3, NC + 1),
    ];

    /// Naive- and Blocked-gated are bit-identical to the
    /// hand-composed `silu(x·w1+b1) ⊙ (x·w3+b3)` on f32 — the fused
    /// epilogue changes no op order, only memory traffic. Holds for
    /// any valid tile choice, like the plain kernels.
    #[test]
    fn gated_scalar_kernels_match_hand_composed_bitwise() {
        let mut rng = Rng::new(71);
        let tile_grid = [
            GemmTiles::default(),
            GemmTiles::new(1, 1, 1),
            GemmTiles::new(8, 16, 8),
        ];
        for &(m, k, n) in &SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let w1 = rand_vec(&mut rng, k * n);
            let b1 = rand_vec(&mut rng, n);
            let w3 = rand_vec(&mut rng, k * n);
            let b3 = rand_vec(&mut rng, n);
            let want = hand_composed(&a, &w1, &b1, &w3, &b3, m, k, n);
            for kernel in [Kernel::Naive, Kernel::Blocked] {
                for tiles in tile_grid {
                    let mut c = vec![9.9f32; m * n]; // must overwrite
                    gemm_bias_act_gated(
                        kernel,
                        tiles,
                        &a,
                        WeightsView::F32(&w1),
                        &b1,
                        WeightsView::F32(&w3),
                        &b3,
                        &mut c,
                        m,
                        k,
                        n,
                    );
                    assert_eq!(
                        c,
                        want,
                        "{} shape ({m},{k},{n}) tiles {tiles}",
                        kernel.name()
                    );
                }
            }
        }
    }

    /// Simd/Neon gated stay within the documented FMA tolerance of
    /// the hand-composed reference (bit-equal when falling back to
    /// Blocked). The product of two ~k-sum terms squares the relative
    /// scale, hence the scale factor below.
    #[test]
    fn gated_simd_kernels_match_within_tolerance() {
        let mut rng = Rng::new(73);
        for &(m, k, n) in &SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let w1 = rand_vec(&mut rng, k * n);
            let b1 = rand_vec(&mut rng, n);
            let w3 = rand_vec(&mut rng, k * n);
            let b3 = rand_vec(&mut rng, n);
            let want = hand_composed(&a, &w1, &b1, &w3, &b3, m, k, n);
            let tol = 2e-5 * (k as f32).sqrt().max(1.0);
            for kernel in [Kernel::Simd, Kernel::Neon] {
                let mut c = vec![0.0f32; m * n];
                gemm_bias_act_gated(
                    kernel,
                    GemmTiles::default(),
                    &a,
                    WeightsView::F32(&w1),
                    &b1,
                    WeightsView::F32(&w3),
                    &b3,
                    &mut c,
                    m,
                    k,
                    n,
                );
                for (i, (&got, &w)) in c.iter().zip(&want).enumerate()
                {
                    // silu is bounded by |x|, the gate by the raw sum,
                    // so scale by the larger of the two magnitudes
                    let scale =
                        w.abs().max((k as f32).sqrt()).max(1.0);
                    assert!(
                        (got - w).abs() <= tol * scale,
                        "{} shape ({m},{k},{n}) elem {i}: {got} vs {w}",
                        kernel.name()
                    );
                }
            }
        }
    }

    /// Quantized gated stores: Naive and Blocked agree bit-for-bit on
    /// the same store (dequantize-before-accumulate either way), and
    /// mixing dtypes between w1 and w3 is supported.
    #[test]
    fn gated_quantized_stores_agree_across_scalar_kernels() {
        let mut rng = Rng::new(79);
        let (m, k, n) = (5usize, 130, 21);
        let a = rand_vec(&mut rng, m * k);
        let w1 = rand_vec(&mut rng, k * n);
        let w3 = rand_vec(&mut rng, k * n);
        let b1 = rand_vec(&mut rng, n);
        let b3 = rand_vec(&mut rng, n);
        for dtype in WeightDtype::ALL {
            let s1 = WeightStore::quantize(&w1, k, n, dtype);
            // mixed dtypes: w3 one notch away from w1's
            let s3 = WeightStore::quantize(&w3, k, n, WeightDtype::Bf16);
            let mut naive = vec![0.0f32; m * n];
            let mut blocked = vec![0.0f32; m * n];
            for (kern, out) in [
                (Kernel::Naive, &mut naive),
                (Kernel::Blocked, &mut blocked),
            ] {
                gemm_bias_act_gated(
                    kern,
                    GemmTiles::default(),
                    &a,
                    s1.view(0, k, n),
                    &b1,
                    s3.view(0, k, n),
                    &b3,
                    out,
                    m,
                    k,
                    n,
                );
            }
            assert_eq!(naive, blocked, "{}", dtype.name());
        }
    }

    #[test]
    fn gated_is_deterministic_across_calls_for_every_kernel() {
        let mut rng = Rng::new(83);
        let (m, k, n) = (MC + 1, KC + 3, NC + 5);
        let a = rand_vec(&mut rng, m * k);
        let w1 = rand_vec(&mut rng, k * n);
        let w3 = rand_vec(&mut rng, k * n);
        let b1 = rand_vec(&mut rng, n);
        let b3 = rand_vec(&mut rng, n);
        for kernel in Kernel::ALL {
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![5.0f32; m * n];
            for c in [&mut c1, &mut c2] {
                gemm_bias_act_gated(
                    kernel,
                    GemmTiles::default(),
                    &a,
                    WeightsView::F32(&w1),
                    &b1,
                    WeightsView::F32(&w3),
                    &b3,
                    c,
                    m,
                    k,
                    n,
                );
            }
            assert_eq!(c1, c2, "{} not deterministic", kernel.name());
        }
    }
}
