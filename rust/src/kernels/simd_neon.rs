//! NEON register tile (aarch64, `simd` feature).
//!
//! Same packing and loop structure as the scalar tile in
//! `blocked.rs`; each of the MR accumulator rows is two `float32x4`
//! halves (NR = 8 f32 lanes) updated with `vfmaq_f32` per reduction
//! step. Like the AVX2 tile, the fused multiply-add drops one
//! rounding per product, so results are deterministic in themselves
//! but not bit-equal to Blocked/Naive.

use super::blocked::{MR, NR};

// the paired-quad loads below assume two float32x4 per tile row
const _: () = assert!(NR == 8);

/// One MR×NR register tile over packed `[kc, MR]` A and `[kc, NR]` B.
///
/// # Safety
///
/// Caller must have verified NEON at runtime (`neon_available`);
/// `ap`/`bp` must hold at least `kc*MR` / `kc*NR` elements (the packed
/// layouts `blocked.rs` builds).
#[target_feature(enable = "neon")]
#[allow(clippy::needless_range_loop)]
pub(crate) unsafe fn tile_neon(
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    acc: &mut [[f32; NR]; MR],
) {
    use std::arch::aarch64::*;
    let mut lo = [vdupq_n_f32(0.0); MR];
    let mut hi = [vdupq_n_f32(0.0); MR];
    for r in 0..MR {
        lo[r] = vld1q_f32(acc[r].as_ptr());
        hi[r] = vld1q_f32(acc[r].as_ptr().add(4));
    }
    for p in 0..kc {
        let b_lo = vld1q_f32(bp.as_ptr().add(p * NR));
        let b_hi = vld1q_f32(bp.as_ptr().add(p * NR + 4));
        let av = &ap[p * MR..(p + 1) * MR];
        for r in 0..MR {
            let va = vdupq_n_f32(av[r]);
            lo[r] = vfmaq_f32(lo[r], va, b_lo);
            hi[r] = vfmaq_f32(hi[r], va, b_hi);
        }
    }
    for r in 0..MR {
        vst1q_f32(acc[r].as_mut_ptr(), lo[r]);
        vst1q_f32(acc[r].as_mut_ptr().add(4), hi[r]);
    }
}
