//! GEMM micro-kernels and quantized weight storage for the FFN hot
//! loop.
//!
//! Every served token ends in [`crate::experts::ExpertBank`]'s two
//! matmuls; this module owns that compute. Three kernels share one
//! fused entry point, [`gemm_bias_act`] (`C = act(A·B + bias)`), and
//! three weight storage dtypes share one container, [`WeightStore`]:
//!
//! - [`Kernel::Naive`] — the original i-k-j loop from
//!   `router::linalg::matmul_into` with the bias add and SiLU applied
//!   per output row. Per-element op order is identical to the
//!   pre-kernel-layer path (accumulate over `k` in order, add bias,
//!   apply SiLU), so f32 results are **bit-identical** to the historic
//!   goldens. The default everywhere.
//! - [`Kernel::Blocked`] — cache-blocked (BLIS-style `jc → pc → ic`
//!   loop nest, fixed [`MC`]/[`KC`]/[`NC`] tiles) with the `B` panel
//!   packed contiguously per `(pc, jc)` block and the bias+activation
//!   epilogue fused over each `jc` strip after the full `k`
//!   accumulation. Accumulation still walks `k` in ascending order
//!   (`pc` blocks in order, rows in order within a block), so for f32
//!   weights Blocked is bit-identical to Naive too — the win is cache
//!   locality, not reassociation.
//! - [`Kernel::Simd`] — the Blocked loop nest with an explicit
//!   `std::arch` AVX2+FMA inner kernel, compiled behind the `simd`
//!   cargo feature and selected at runtime via
//!   `is_x86_feature_detected!`. FMA contracts the multiply-add
//!   rounding step, so Simd is *not* bit-identical to Naive/Blocked —
//!   but it is deterministic in itself (fixed tile sizes, fixed lane
//!   order). Without the feature (or on non-x86_64, or when the CPU
//!   lacks AVX2/FMA) `Kernel::Simd` transparently falls back to
//!   Blocked.
//!
//! # Determinism contract (per kernel)
//!
//! Tile sizes are compile-time constants and the packed-panel scratch
//! is thread-local and fully overwritten per block, so a kernel's
//! output depends only on its inputs — never on thread count or which
//! thread runs the call. The serving engines parallelize at expert-
//! bucket granularity (see `router::engine`), so every kernel
//! individually satisfies the crate's bit-identical-across-threads
//! contract. Cross-*kernel* equality is only promised between Naive
//! and Blocked on f32 weights.
//!
//! # Quantized storage and error bounds
//!
//! [`WeightStore`] keeps FFN weights in f32, bf16, or int8 (per-row
//! absmax scaling). All kernels **accumulate in f32**; quantized
//! weights are dequantized on the fly (Naive) or at panel-pack time
//! (Blocked/Simd), so the only error is the weight round-trip:
//!
//! - **bf16** (round-to-nearest-even, 8 mantissa bits):
//!   `|ŵ − w| ≤ 2⁻⁸·|w|` per element (half the ulp at 7 explicit
//!   mantissa bits, i.e. relative error ≤ 2⁻⁸).
//! - **int8 per-row absmax** (`scale_r = absmax_r / 127`,
//!   `q = round(w/scale_r)` clamped to ±127):
//!   `|ŵ − w| ≤ scale_r/2 = absmax_r/254` per element of row `r`.
//!
//! A GEMM output element sums `k` products, so the worst-case output
//! error is bounded by `k · ε_w · max|a|` with `ε_w` the per-element
//! bound above — the tolerance the parity tests and
//! `docs/ARCHITECTURE.md` state.

use std::cell::RefCell;

/// Which GEMM micro-kernel the FFN hot loop runs. Builder knob:
/// `Engine::builder().kernel(...)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Kernel {
    /// Original i-k-j loop; bit-identical to the historic goldens.
    #[default]
    Naive,
    /// Cache-blocked with a packed B panel and fused epilogue.
    Blocked,
    /// Blocked + `std::arch` AVX2/FMA inner loop (`simd` feature);
    /// falls back to Blocked when unavailable.
    Simd,
}

impl Kernel {
    pub const ALL: [Kernel; 3] =
        [Kernel::Naive, Kernel::Blocked, Kernel::Simd];

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Naive => "naive",
            Kernel::Blocked => "blocked",
            Kernel::Simd => "simd",
        }
    }
}

/// Storage dtype of an expert bank's FFN weights. Builder knob:
/// `Engine::builder().weight_dtype(...)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightDtype {
    /// Full precision — exact, the default.
    #[default]
    F32,
    /// Truncated-mantissa bfloat16: half the weight bytes, relative
    /// error ≤ 2⁻⁸ per element.
    Bf16,
    /// Int8 with one f32 absmax scale per matrix row: a quarter of the
    /// weight bytes, absolute error ≤ absmax_row/254 per element.
    Int8,
}

impl WeightDtype {
    pub const ALL: [WeightDtype; 3] =
        [WeightDtype::F32, WeightDtype::Bf16, WeightDtype::Int8];

    pub fn name(&self) -> &'static str {
        match self {
            WeightDtype::F32 => "f32",
            WeightDtype::Bf16 => "bf16",
            WeightDtype::Int8 => "int8",
        }
    }
}

/// f32 → bf16 with round-to-nearest-even (the standard
/// `(bits + 0x7FFF + lsb) >> 16` trick); NaN payloads are quieted so
/// they stay NaN after truncation.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x7FFF + lsb) >> 16) as u16
}

/// bf16 → f32 (exact: bf16 is a prefix of the f32 bit pattern).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// A `[rows, cols]` row-major weight matrix in one of the
/// [`WeightDtype`] storages. Int8 keeps one f32 scale per row
/// (`scale_r = absmax_r / 127`), chosen so dequantization is a single
/// multiply in the pack/dequant loop.
#[derive(Debug, Clone)]
pub enum WeightStore {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

impl WeightStore {
    /// Quantize a row-major `[rows, cols]` f32 matrix into `dtype`
    /// storage.
    pub fn quantize(
        w: &[f32],
        rows: usize,
        cols: usize,
        dtype: WeightDtype,
    ) -> WeightStore {
        assert_eq!(w.len(), rows * cols, "weight shape");
        match dtype {
            WeightDtype::F32 => WeightStore::F32(w.to_vec()),
            WeightDtype::Bf16 => WeightStore::Bf16(
                w.iter().map(|&v| f32_to_bf16(v)).collect(),
            ),
            WeightDtype::Int8 => {
                let mut q = Vec::with_capacity(rows * cols);
                let mut scales = Vec::with_capacity(rows);
                for row in w.chunks(cols) {
                    let absmax =
                        row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let scale = absmax / 127.0;
                    scales.push(scale);
                    if scale == 0.0 {
                        q.extend(std::iter::repeat(0i8).take(cols));
                    } else {
                        q.extend(row.iter().map(|&v| {
                            (v / scale).round().clamp(-127.0, 127.0) as i8
                        }));
                    }
                }
                WeightStore::Int8 { q, scales }
            }
        }
    }

    pub fn dtype(&self) -> WeightDtype {
        match self {
            WeightStore::F32(_) => WeightDtype::F32,
            WeightStore::Bf16(_) => WeightDtype::Bf16,
            WeightStore::Int8 { .. } => WeightDtype::Int8,
        }
    }

    /// Borrow rows `[row0, row0 + n_rows)` of a `[*, cols]` matrix as
    /// a kernel operand.
    pub fn view(
        &self,
        row0: usize,
        n_rows: usize,
        cols: usize,
    ) -> WeightsView<'_> {
        let (a, b) = (row0 * cols, (row0 + n_rows) * cols);
        match self {
            WeightStore::F32(w) => WeightsView::F32(&w[a..b]),
            WeightStore::Bf16(w) => WeightsView::Bf16(&w[a..b]),
            WeightStore::Int8 { q, scales } => WeightsView::Int8 {
                q: &q[a..b],
                scales: &scales[row0..row0 + n_rows],
            },
        }
    }

    /// The full-precision buffer, when stored as f32 (tests and the
    /// checkpoint bridge use this; quantized stores return `None`).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            WeightStore::F32(w) => Some(w),
            _ => None,
        }
    }

    /// Dequantize row `r` of a `[*, cols]` matrix into `out[..cols]`
    /// (identity copy for f32).
    pub fn dequant_row(&self, r: usize, cols: usize, out: &mut [f32]) {
        match self.view(r, 1, cols) {
            WeightsView::F32(w) => out[..cols].copy_from_slice(w),
            WeightsView::Bf16(w) => {
                for (o, &h) in out[..cols].iter_mut().zip(w) {
                    *o = bf16_to_f32(h);
                }
            }
            WeightsView::Int8 { q, scales } => {
                let s = scales[0];
                for (o, &v) in out[..cols].iter_mut().zip(q) {
                    *o = v as f32 * s;
                }
            }
        }
    }
}

/// A borrowed `[k, n]` row-major B operand for [`gemm_bias_act`].
#[derive(Debug, Clone, Copy)]
pub enum WeightsView<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    Int8 { q: &'a [i8], scales: &'a [f32] },
}

impl WeightsView<'_> {
    fn check_shape(&self, k: usize, n: usize) {
        let len = match self {
            WeightsView::F32(w) => w.len(),
            WeightsView::Bf16(w) => w.len(),
            WeightsView::Int8 { q, scales } => {
                assert_eq!(scales.len(), k, "int8 scales shape");
                q.len()
            }
        };
        assert_eq!(len, k * n, "B shape");
    }
}

/// Row-panel cache blocking constants (BLIS-style). `KC·NC` f32 panel
/// ≈ 128 KiB — sized for L2; `MC` rows of A per inner block stay
/// L1-resident. Compile-time constants: blocking never depends on
/// runtime state, which is what keeps each kernel deterministic.
pub const MC: usize = 64;
pub const KC: usize = 256;
pub const NC: usize = 128;

thread_local! {
    /// Packed B panel (`[kc, nc]`, kc ≤ KC, nc ≤ NC). Thread-local and
    /// fully overwritten per `(pc, jc)` block, so sharing it across
    /// calls never leaks state between batches or experts.
    static PANEL: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Fused GEMM + bias + optional SiLU: `C[m,n] = act(A[m,k] · B[k,n] +
/// bias[n])`, f32 accumulation, overwriting `c`. The single entry
/// point of the kernel layer — `kernel` selects the implementation,
/// `b` selects the weight dtype; every combination is supported.
pub fn gemm_bias_act(
    kernel: Kernel,
    a: &[f32],
    b: WeightsView<'_>,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    silu: bool,
) {
    assert_eq!(a.len(), m * k, "A shape");
    b.check_shape(k, n);
    assert_eq!(bias.len(), n, "bias shape");
    assert_eq!(c.len(), m * n, "C shape");
    match kernel {
        Kernel::Naive => naive_gemm(a, b, bias, c, m, k, n, silu),
        Kernel::Blocked => {
            blocked_gemm(a, b, bias, c, m, k, n, silu, false)
        }
        Kernel::Simd => {
            blocked_gemm(a, b, bias, c, m, k, n, silu, simd_available())
        }
    }
}

/// SiLU of one value — the exact expression `router::linalg::silu`
/// applies, kept in sync so fused epilogues stay bit-identical to the
/// separate-pass path.
#[inline]
fn silu_one(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// The original serving kernel: i-k-j accumulation (ascending `k`),
/// then bias, then SiLU, per output row. For f32 weights this is
/// element-for-element the op sequence of the historic
/// `matmul_into` → bias loop → `silu` path, hence bit-identical.
#[allow(clippy::too_many_arguments)]
fn naive_gemm(
    a: &[f32],
    b: WeightsView<'_>,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    silu: bool,
) {
    c.fill(0.0);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        match b {
            WeightsView::F32(w) => {
                for (p, &aik) in a_row.iter().enumerate() {
                    let b_row = &w[p * n..(p + 1) * n];
                    for (cj, &bv) in c_row.iter_mut().zip(b_row) {
                        *cj += aik * bv;
                    }
                }
            }
            WeightsView::Bf16(w) => {
                for (p, &aik) in a_row.iter().enumerate() {
                    let b_row = &w[p * n..(p + 1) * n];
                    for (cj, &bv) in c_row.iter_mut().zip(b_row) {
                        *cj += aik * bf16_to_f32(bv);
                    }
                }
            }
            WeightsView::Int8 { q, scales } => {
                for (p, &aik) in a_row.iter().enumerate() {
                    let b_row = &q[p * n..(p + 1) * n];
                    let s = scales[p];
                    for (cj, &bv) in c_row.iter_mut().zip(b_row) {
                        *cj += aik * (bv as f32 * s);
                    }
                }
            }
        }
        for (cj, &bj) in c_row.iter_mut().zip(bias) {
            *cj += bj;
        }
        if silu {
            for cj in c_row.iter_mut() {
                *cj = silu_one(*cj);
            }
        }
    }
}

/// Pack (and dequantize) `B[pc..pc+kc, jc..jc+nc]` into the
/// thread-local panel as a contiguous `[kc, nc]` block.
fn pack_panel(
    b: WeightsView<'_>,
    panel: &mut Vec<f32>,
    n: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
) {
    panel.clear();
    panel.reserve(kc * nc);
    match b {
        WeightsView::F32(w) => {
            for p in pc..pc + kc {
                panel.extend_from_slice(&w[p * n + jc..p * n + jc + nc]);
            }
        }
        WeightsView::Bf16(w) => {
            for p in pc..pc + kc {
                panel.extend(
                    w[p * n + jc..p * n + jc + nc]
                        .iter()
                        .map(|&h| bf16_to_f32(h)),
                );
            }
        }
        WeightsView::Int8 { q, scales } => {
            for p in pc..pc + kc {
                let s = scales[p];
                panel.extend(
                    q[p * n + jc..p * n + jc + nc]
                        .iter()
                        .map(|&v| v as f32 * s),
                );
            }
        }
    }
}

/// Cache-blocked GEMM: `jc` (NC columns) → `pc` (KC of the reduction,
/// B panel packed once per block) → `ic` (MC rows of A). Bias +
/// activation run as a fused epilogue over each `jc` strip after the
/// whole reduction, so every output element is touched exactly twice
/// (accumulate, epilogue). `k` is walked in ascending order across
/// `pc` blocks, keeping f32 results bit-identical to [`Kernel::Naive`].
#[allow(clippy::too_many_arguments)]
fn blocked_gemm(
    a: &[f32],
    b: WeightsView<'_>,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    silu: bool,
    use_simd: bool,
) {
    c.fill(0.0);
    PANEL.with(|cell| {
        let mut guard = cell.borrow_mut();
        let panel: &mut Vec<f32> = &mut guard;
        let mut jc = 0;
        while jc < n {
            let nc = NC.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kc = KC.min(k - pc);
                pack_panel(b, panel, n, pc, kc, jc, nc);
                let mut ic = 0;
                while ic < m {
                    let mc = MC.min(m - ic);
                    accumulate_block(
                        a, panel, c, k, n, ic, mc, pc, kc, jc, nc,
                        use_simd,
                    );
                    ic += MC;
                }
                pc += KC;
            }
            // epilogue: bias + activation over the finished strip
            for i in 0..m {
                let c_row = &mut c[i * n + jc..i * n + jc + nc];
                let b_row = &bias[jc..jc + nc];
                for (cj, &bj) in c_row.iter_mut().zip(b_row) {
                    *cj += bj;
                }
                if silu {
                    for cj in c_row.iter_mut() {
                        *cj = silu_one(*cj);
                    }
                }
            }
            jc += NC;
        }
    });
}

/// One `[mc, nc] += A[mc, kc] · panel[kc, nc]` inner block.
#[allow(clippy::too_many_arguments)]
fn accumulate_block(
    a: &[f32],
    panel: &[f32],
    c: &mut [f32],
    k: usize,
    n: usize,
    ic: usize,
    mc: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    use_simd: bool,
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if use_simd {
        // SAFETY: gated on runtime AVX2+FMA detection (simd_available).
        unsafe {
            simd::accumulate_block_avx2(
                a, panel, c, k, n, ic, mc, pc, kc, jc, nc,
            );
        }
        return;
    }
    let _ = use_simd;
    for i in ic..ic + mc {
        let a_row = &a[i * k + pc..i * k + pc + kc];
        let c_row = &mut c[i * n + jc..i * n + jc + nc];
        for (p, &aik) in a_row.iter().enumerate() {
            let b_row = &panel[p * nc..(p + 1) * nc];
            for (cj, &bv) in c_row.iter_mut().zip(b_row) {
                *cj += aik * bv;
            }
        }
    }
}

/// Whether the explicit-SIMD inner kernel can run here: the `simd`
/// feature compiled in, x86_64, and the CPU reporting AVX2 + FMA.
pub fn simd_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd {
    //! AVX2+FMA inner block. Same blocking as the scalar path; the
    //! inner j loop runs 8 f32 lanes per `_mm256_fmadd_ps` with a
    //! scalar tail. FMA fuses the multiply-add rounding, so results
    //! differ from the scalar kernels in the last ulp — deterministic
    //! in itself (fixed lane order), just not bit-equal to Blocked.

    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn accumulate_block_avx2(
        a: &[f32],
        panel: &[f32],
        c: &mut [f32],
        k: usize,
        n: usize,
        ic: usize,
        mc: usize,
        pc: usize,
        kc: usize,
        jc: usize,
        nc: usize,
    ) {
        use std::arch::x86_64::*;
        let lanes = nc / 8 * 8;
        for i in ic..ic + mc {
            let a_row = &a[i * k + pc..i * k + pc + kc];
            let c_row = &mut c[i * n + jc..i * n + jc + nc];
            for (p, &aik) in a_row.iter().enumerate() {
                let b_row = &panel[p * nc..(p + 1) * nc];
                let va = _mm256_set1_ps(aik);
                let mut j = 0;
                while j < lanes {
                    let vb = _mm256_loadu_ps(b_row.as_ptr().add(j));
                    let vc = _mm256_loadu_ps(c_row.as_ptr().add(j));
                    let r = _mm256_fmadd_ps(va, vb, vc);
                    _mm256_storeu_ps(c_row.as_mut_ptr().add(j), r);
                    j += 8;
                }
                for j in lanes..nc {
                    c_row[j] = aik.mul_add(b_row[j], c_row[j]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Reference: the historic separate-pass path (matmul_into → bias
    /// → silu) the Naive kernel must reproduce bit-for-bit.
    fn reference(
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        m: usize,
        k: usize,
        n: usize,
        silu: bool,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        crate::router::linalg::matmul_into(a, b, &mut c, m, k, n);
        for row in c.chunks_mut(n) {
            for (v, &bj) in row.iter_mut().zip(bias) {
                *v += bj;
            }
        }
        if silu {
            crate::router::linalg::silu(&mut c);
        }
        c
    }

    /// Odd shapes straddling every block boundary: smaller than one
    /// tile, exact tiles, and tiles + ragged remainders in m, k and n.
    const SHAPES: [(usize, usize, usize); 6] = [
        (1, 1, 1),
        (3, 5, 7),
        (MC, KC, NC),
        (MC + 3, KC + 5, NC + 9),
        (2 * MC + 1, 2 * KC + 3, 2 * NC + 5),
        (7, 300, 19),
    ];

    #[test]
    fn naive_kernel_is_bit_identical_to_legacy_path() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            for silu in [false, true] {
                let want = reference(&a, &b, &bias, m, k, n, silu);
                let mut c = vec![9.9f32; m * n]; // must overwrite
                gemm_bias_act(
                    Kernel::Naive,
                    &a,
                    WeightsView::F32(&b),
                    &bias,
                    &mut c,
                    m,
                    k,
                    n,
                    silu,
                );
                assert_eq!(c, want, "shape ({m},{k},{n}) silu={silu}");
            }
        }
    }

    #[test]
    fn blocked_kernel_matches_naive_bitwise_on_f32() {
        // same ascending-k accumulation order ⇒ exact equality
        let mut rng = Rng::new(23);
        for &(m, k, n) in &SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            let want = reference(&a, &b, &bias, m, k, n, true);
            let mut c = vec![0.0f32; m * n];
            gemm_bias_act(
                Kernel::Blocked,
                &a,
                WeightsView::F32(&b),
                &bias,
                &mut c,
                m,
                k,
                n,
                true,
            );
            assert_eq!(c, want, "shape ({m},{k},{n})");
        }
    }

    /// Simd must match Naive within an FMA-reassociation tolerance on
    /// every odd shape (bit-equal when the feature is off, since it
    /// falls back to Blocked).
    #[test]
    fn simd_kernel_matches_naive_within_tolerance() {
        let mut rng = Rng::new(37);
        for &(m, k, n) in &SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            let want = reference(&a, &b, &bias, m, k, n, true);
            let mut c = vec![0.0f32; m * n];
            gemm_bias_act(
                Kernel::Simd,
                &a,
                WeightsView::F32(&b),
                &bias,
                &mut c,
                m,
                k,
                n,
                true,
            );
            // |Σ k products| error scales with k; 1e-5 relative covers
            // the single FMA rounding per product at these magnitudes.
            let tol = 1e-5 * (k as f32).sqrt().max(1.0);
            for (i, (&got, &w)) in c.iter().zip(&want).enumerate() {
                let scale = w.abs().max(1.0);
                assert!(
                    (got - w).abs() <= tol * scale,
                    "shape ({m},{k},{n}) elem {i}: {got} vs {w}"
                );
            }
        }
    }

    #[test]
    fn each_kernel_is_deterministic_across_calls() {
        let mut rng = Rng::new(41);
        let (m, k, n) = (MC + 5, KC + 7, NC + 3);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);
        for kernel in Kernel::ALL {
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![1.0f32; m * n];
            for c in [&mut c1, &mut c2] {
                gemm_bias_act(
                    kernel,
                    &a,
                    WeightsView::F32(&b),
                    &bias,
                    c,
                    m,
                    k,
                    n,
                    true,
                );
            }
            assert_eq!(c1, c2, "{} not deterministic", kernel.name());
        }
    }

    #[test]
    fn bf16_round_trip_stays_within_documented_bound() {
        let mut rng = Rng::new(53);
        let w = rand_vec(&mut rng, 4096);
        for &v in &w {
            let r = bf16_to_f32(f32_to_bf16(v));
            assert!(
                (r - v).abs() <= v.abs() * 2.0f32.powi(-8),
                "bf16 round-trip {v} -> {r} exceeds 2^-8 relative"
            );
        }
        // exact cases: bf16-representable values survive untouched
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.15625] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v);
        }
        // NaN stays NaN, infinities survive
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(
            bf16_to_f32(f32_to_bf16(f32::INFINITY)),
            f32::INFINITY
        );
    }

    #[test]
    fn int8_round_trip_stays_within_documented_bound() {
        let mut rng = Rng::new(59);
        let (rows, cols) = (32usize, 48usize);
        let w = rand_vec(&mut rng, rows * cols);
        let store =
            WeightStore::quantize(&w, rows, cols, WeightDtype::Int8);
        let mut deq = vec![0.0f32; cols];
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            let absmax =
                row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            store.dequant_row(r, cols, &mut deq);
            for (c, (&v, &rt)) in row.iter().zip(&deq).enumerate() {
                assert!(
                    (rt - v).abs() <= absmax / 254.0 + 1e-7,
                    "row {r} col {c}: {v} -> {rt}, absmax {absmax}"
                );
            }
        }
    }

    #[test]
    fn int8_zero_row_quantizes_to_exact_zero() {
        let w = vec![0.0f32; 8];
        let store = WeightStore::quantize(&w, 2, 4, WeightDtype::Int8);
        let mut deq = vec![1.0f32; 4];
        store.dequant_row(0, 4, &mut deq);
        assert_eq!(deq, vec![0.0; 4]);
    }

    /// Quantized weights through every kernel stay within the GEMM
    /// error bound `k · ε_w · max|a|` stated in the module docs.
    #[test]
    fn quantized_gemm_parity_within_documented_bound() {
        let mut rng = Rng::new(61);
        let (m, k, n) = (9usize, 140, 33);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);
        let amax = a.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
        let mut exact = vec![0.0f32; m * n];
        gemm_bias_act(
            Kernel::Naive,
            &a,
            WeightsView::F32(&b),
            &bias,
            &mut exact,
            m,
            k,
            n,
            false,
        );
        for dtype in [WeightDtype::Bf16, WeightDtype::Int8] {
            let store = WeightStore::quantize(&b, k, n, dtype);
            let eps = match dtype {
                WeightDtype::Bf16 => {
                    let bmax =
                        b.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
                    bmax * 2.0f32.powi(-8)
                }
                WeightDtype::Int8 => {
                    // per-row absmax ≤ global absmax
                    let bmax =
                        b.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
                    bmax / 254.0
                }
                WeightDtype::F32 => unreachable!(),
            };
            let bound = k as f32 * eps * amax;
            for kernel in Kernel::ALL {
                let mut got = vec![0.0f32; m * n];
                gemm_bias_act(
                    kernel,
                    &a,
                    store.view(0, k, n),
                    &bias,
                    &mut got,
                    m,
                    k,
                    n,
                    false,
                );
                for (i, (&g, &e)) in got.iter().zip(&exact).enumerate()
                {
                    assert!(
                        (g - e).abs() <= bound,
                        "{}/{} elem {i}: {g} vs {e} (bound {bound})",
                        kernel.name(),
                        dtype.name()
                    );
                }
            }
        }
    }

    /// All kernels agree bit-for-bit on the *same* quantized store
    /// when SIMD is unavailable, and within tolerance otherwise —
    /// dequantization happens before accumulation either way.
    #[test]
    fn kernels_agree_on_quantized_stores() {
        let mut rng = Rng::new(67);
        let (m, k, n) = (5usize, 130, 21);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = vec![0.0f32; n];
        for dtype in WeightDtype::ALL {
            let store = WeightStore::quantize(&b, k, n, dtype);
            let mut naive = vec![0.0f32; m * n];
            let mut blocked = vec![0.0f32; m * n];
            for (kern, out) in [
                (Kernel::Naive, &mut naive),
                (Kernel::Blocked, &mut blocked),
            ] {
                gemm_bias_act(
                    kern,
                    &a,
                    store.view(0, k, n),
                    &bias,
                    out,
                    m,
                    k,
                    n,
                    true,
                );
            }
            assert_eq!(naive, blocked, "{}", dtype.name());
        }
    }

    #[test]
    fn names_and_defaults_are_stable() {
        assert_eq!(Kernel::default(), Kernel::Naive);
        assert_eq!(WeightDtype::default(), WeightDtype::F32);
        assert_eq!(Kernel::Simd.name(), "simd");
        assert_eq!(WeightDtype::Int8.name(), "int8");
        // Simd silently degrades to Blocked when unsupported — the
        // knob is always safe to set.
        let _ = simd_available();
    }
}
