//! GEMM micro-kernels and quantized weight storage for the FFN hot
//! loop.
//!
//! Every served token ends in [`crate::experts::ExpertBank`]'s two
//! matmuls; this module owns that compute. Four kernels share two
//! fused entry points — [`gemm_bias_act`] (`C = act(A·B + bias)`) and
//! [`gemm_bias_act_gated`] (`C = silu(A·B1 + bias1) ⊙ (A·B3 + bias3)`,
//! the SwiGLU first stage) — and three weight storage dtypes share one
//! container, [`WeightStore`]. The implementation is split by file:
//! `mod.rs` (types, dispatch, the Naive golden), `blocked.rs` (packing
//! and the register-tiled scalar engine), `simd_x86.rs` /
//! `simd_neon.rs` (the AVX2 and NEON inner tiles), and `gated.rs` (the
//! fused SwiGLU driver).
//!
//! - [`Kernel::Naive`] — the original i-k-j loop from
//!   `router::linalg::matmul_into` with the bias add and SiLU applied
//!   per output row. Per-element op order is identical to the
//!   pre-kernel-layer path (accumulate over `k` in order, add bias,
//!   apply SiLU), so f32 results are **bit-identical** to the historic
//!   goldens. The default for f32 weights.
//! - [`Kernel::Blocked`] — cache-blocked (BLIS-style `jc → pc → ic`
//!   loop nest, [`GemmTiles`] MC/KC/NC blocking) with **both** operands
//!   packed: `B` into `[kc, NR]` column micro-panels per `(pc, jc)`
//!   block and `A` into `[kc, MR]` row strips per `(ic, pc)` block,
//!   feeding an `MR×NR = 4×8` register-tile inner kernel that holds a
//!   full accumulator tile across the `kc` reduction. Each output
//!   element still accumulates its `k` products in ascending order
//!   with a plain multiply-then-add, so for f32 weights Blocked is
//!   bit-identical to Naive **for any tile sizes** — the win is cache
//!   and register locality, not reassociation.
//! - [`Kernel::Simd`] — the Blocked loop nest with an explicit
//!   `std::arch` AVX2+FMA register tile (one `__m256` per tile row),
//!   compiled behind the `simd` cargo feature and selected at runtime
//!   via `is_x86_feature_detected!`. FMA contracts the multiply-add
//!   rounding step, so Simd is *not* bit-identical to Naive/Blocked —
//!   but it is deterministic in itself (fixed tile sizes, fixed lane
//!   order). Without the feature (or on non-x86_64, or when the CPU
//!   lacks AVX2/FMA) `Kernel::Simd` transparently falls back to
//!   Blocked.
//! - [`Kernel::Neon`] — the same contract on aarch64: `simd` feature +
//!   runtime `is_aarch64_feature_detected!("neon")`, two `float32x4`
//!   FMA lanes per tile row. Everywhere else (including x86_64) it
//!   transparently falls back to Blocked, so the knob is always safe
//!   to set and the enum round-trips through configs on any host.
//!
//! # Tile tunables
//!
//! [`GemmTiles`] carries the MC/KC/NC cache-blocking sizes at runtime.
//! Defaults are the [`MC`]/[`KC`]/[`NC`] constants (64/256/128 — a
//! `KC·NC` f32 panel ≈ 128 KiB, sized for L2). Overrides thread from
//! `Engine::builder().gemm_tiles(..)`, the `LPR_GEMM_TILES=MCxKCxNC`
//! environment variable, or the CLI `--tiles` flag (builder-explicit
//! wins over env wins over default). Tiles move cache behavior only,
//! never results: the ascending-`k` accumulation order is preserved
//! for every valid tile choice, which is pinned by the
//! any-tiles-bitwise test below.
//!
//! # Determinism contract (per kernel)
//!
//! Tile sizes are fixed per call and the packed-operand scratch
//! buffers are thread-local and fully overwritten per block, so a
//! kernel's output depends only on its inputs and tiles — never on
//! thread count or which thread runs the call. The serving engines
//! parallelize at expert-bucket granularity (see `router::engine`), so
//! every kernel individually satisfies the crate's
//! bit-identical-across-threads contract. Cross-*kernel* equality is
//! only promised between Naive and Blocked on f32 weights.
//!
//! # Quantized storage and error bounds
//!
//! [`WeightStore`] keeps FFN weights in f32, bf16, or int8 (per-row
//! absmax scaling). All kernels **accumulate in f32**; quantized
//! weights are dequantized on the fly (Naive) or at pack time straight
//! into the `[kc, NR]` micro-panels the register tile consumes
//! (Blocked/Simd/Neon), so the only error is the weight round-trip:
//!
//! - **bf16** (round-to-nearest-even, 8 mantissa bits):
//!   `|ŵ − w| ≤ 2⁻⁸·|w|` per element (half the ulp at 7 explicit
//!   mantissa bits, i.e. relative error ≤ 2⁻⁸).
//! - **int8 per-row absmax** (`scale_r = absmax_r / 127`,
//!   `q = round(w/scale_r)` clamped to ±127):
//!   `|ŵ − w| ≤ scale_r/2 = absmax_r/254` per element of row `r`.
//!
//! A GEMM output element sums `k` products, so the worst-case output
//! error is bounded by `k · ε_w · max|a|` with `ε_w` the per-element
//! bound above — the tolerance the parity tests and
//! `docs/ARCHITECTURE.md` state.

mod blocked;
mod gated;
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod simd_neon;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd_x86;

pub use gated::gemm_bias_act_gated;

/// Which GEMM micro-kernel the FFN hot loop runs. Builder knob:
/// `Engine::builder().kernel(...)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Kernel {
    /// Original i-k-j loop; bit-identical to the historic goldens.
    #[default]
    Naive,
    /// Cache-blocked with packed A/B operands and an MR×NR
    /// register-tile inner kernel; bit-identical to Naive on f32.
    Blocked,
    /// Blocked + `std::arch` AVX2/FMA register tile (`simd` feature,
    /// x86_64); falls back to Blocked when unavailable.
    Simd,
    /// Blocked + `std::arch` NEON/FMA register tile (`simd` feature,
    /// aarch64); falls back to Blocked when unavailable.
    Neon,
}

impl Kernel {
    pub const ALL: [Kernel; 4] =
        [Kernel::Naive, Kernel::Blocked, Kernel::Simd, Kernel::Neon];

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Naive => "naive",
            Kernel::Blocked => "blocked",
            Kernel::Simd => "simd",
            Kernel::Neon => "neon",
        }
    }

    /// Resolve the register-tile engine this kernel runs on this host
    /// (the runtime-dispatch point; Naive never calls it).
    fn micro(self) -> blocked::Micro {
        match self {
            Kernel::Naive => {
                unreachable!("Naive dispatches before tiling")
            }
            Kernel::Blocked => blocked::Micro::Scalar,
            Kernel::Simd => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if simd_available() {
                    return blocked::Micro::Avx2;
                }
                blocked::Micro::Scalar
            }
            Kernel::Neon => {
                #[cfg(all(feature = "simd", target_arch = "aarch64"))]
                if neon_available() {
                    return blocked::Micro::Neon;
                }
                blocked::Micro::Scalar
            }
        }
    }
}

/// Storage dtype of an expert bank's FFN weights. Builder knob:
/// `Engine::builder().weight_dtype(...)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightDtype {
    /// Full precision — exact, the default.
    #[default]
    F32,
    /// Truncated-mantissa bfloat16: half the weight bytes, relative
    /// error ≤ 2⁻⁸ per element.
    Bf16,
    /// Int8 with one f32 absmax scale per matrix row: a quarter of the
    /// weight bytes, absolute error ≤ absmax_row/254 per element.
    Int8,
}

impl WeightDtype {
    pub const ALL: [WeightDtype; 3] =
        [WeightDtype::F32, WeightDtype::Bf16, WeightDtype::Int8];

    pub fn name(&self) -> &'static str {
        match self {
            WeightDtype::F32 => "f32",
            WeightDtype::Bf16 => "bf16",
            WeightDtype::Int8 => "int8",
        }
    }
}

/// Runtime MC/KC/NC cache-blocking sizes for the blocked kernels (see
/// the module docs) — `mc` rows of A per inner block, `kc` of the
/// reduction per packed panel, `nc` columns per strip. Results are
/// tile-invariant; only cache behavior moves. Defaults to
/// [`MC`]`x`[`KC`]`x`[`NC`]; override via
/// `Engine::builder().gemm_tiles(..)`, the [`GemmTiles::ENV`]
/// environment variable, or the CLI `--tiles MCxKCxNC`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmTiles {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

impl Default for GemmTiles {
    fn default() -> GemmTiles {
        GemmTiles { mc: MC, kc: KC, nc: NC }
    }
}

impl std::fmt::Display for GemmTiles {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.mc, self.kc, self.nc)
    }
}

impl GemmTiles {
    /// Environment override read by `Engine::builder()` when no
    /// explicit `.gemm_tiles(..)` is set: `LPR_GEMM_TILES=MCxKCxNC`.
    pub const ENV: &'static str = "LPR_GEMM_TILES";

    pub fn new(mc: usize, kc: usize, nc: usize) -> GemmTiles {
        GemmTiles { mc, kc, nc }
    }

    /// Every dimension must be ≥ 1 (a zero tile would never advance
    /// the block loops).
    pub fn validate(&self) -> Result<(), String> {
        if self.mc == 0 || self.kc == 0 || self.nc == 0 {
            return Err(format!(
                "tile dims must all be >= 1, got {self}"
            ));
        }
        Ok(())
    }

    /// Parse the `MCxKCxNC` spec shared by the env var and `--tiles`.
    pub fn parse(s: &str) -> Result<GemmTiles, String> {
        let parts: Vec<&str> = s.trim().split(['x', 'X']).collect();
        if parts.len() != 3 {
            return Err(format!(
                "expected MCxKCxNC (e.g. 64x256x128), got {s:?}"
            ));
        }
        let mut dims = [0usize; 3];
        for (d, part) in dims.iter_mut().zip(&parts) {
            *d = part.trim().parse::<usize>().map_err(|_| {
                format!("bad tile dim {part:?} in {s:?}")
            })?;
        }
        let tiles = GemmTiles::new(dims[0], dims[1], dims[2]);
        tiles.validate()?;
        Ok(tiles)
    }

    /// The [`Self::ENV`] override, if set: `Ok(None)` when absent or
    /// empty, `Err` when set but unparseable (the builder surfaces
    /// that as a typed `EngineBuildError` instead of silently
    /// ignoring a typo'd sweep).
    pub fn from_env() -> Result<Option<GemmTiles>, String> {
        match std::env::var(GemmTiles::ENV) {
            Ok(s) if !s.trim().is_empty() => GemmTiles::parse(&s)
                .map(Some)
                .map_err(|e| format!("{}: {e}", GemmTiles::ENV)),
            _ => Ok(None),
        }
    }

    /// Panic with the validation message — the kernel entry points'
    /// guard for callers that bypass the builder.
    pub(crate) fn check(&self) {
        if let Err(e) = self.validate() {
            panic!("invalid GemmTiles: {e}");
        }
    }
}

/// f32 → bf16 with round-to-nearest-even (the standard
/// `(bits + 0x7FFF + lsb) >> 16` trick); NaN payloads are quieted so
/// they stay NaN after truncation.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x7FFF + lsb) >> 16) as u16
}

/// bf16 → f32 (exact: bf16 is a prefix of the f32 bit pattern).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// A `[rows, cols]` row-major weight matrix in one of the
/// [`WeightDtype`] storages. Int8 keeps one f32 scale per row
/// (`scale_r = absmax_r / 127`), chosen so dequantization is a single
/// multiply in the pack/dequant loop.
#[derive(Debug, Clone)]
pub enum WeightStore {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

impl WeightStore {
    /// Quantize a row-major `[rows, cols]` f32 matrix into `dtype`
    /// storage.
    pub fn quantize(
        w: &[f32],
        rows: usize,
        cols: usize,
        dtype: WeightDtype,
    ) -> WeightStore {
        assert_eq!(w.len(), rows * cols, "weight shape");
        match dtype {
            WeightDtype::F32 => WeightStore::F32(w.to_vec()),
            WeightDtype::Bf16 => WeightStore::Bf16(
                w.iter().map(|&v| f32_to_bf16(v)).collect(),
            ),
            WeightDtype::Int8 => {
                let mut q = Vec::with_capacity(rows * cols);
                let mut scales = Vec::with_capacity(rows);
                for row in w.chunks(cols) {
                    let absmax =
                        row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    let scale = absmax / 127.0;
                    scales.push(scale);
                    if scale == 0.0 {
                        q.extend(std::iter::repeat(0i8).take(cols));
                    } else {
                        q.extend(row.iter().map(|&v| {
                            (v / scale).round().clamp(-127.0, 127.0) as i8
                        }));
                    }
                }
                WeightStore::Int8 { q, scales }
            }
        }
    }

    pub fn dtype(&self) -> WeightDtype {
        match self {
            WeightStore::F32(_) => WeightDtype::F32,
            WeightStore::Bf16(_) => WeightDtype::Bf16,
            WeightStore::Int8 { .. } => WeightDtype::Int8,
        }
    }

    /// Borrow rows `[row0, row0 + n_rows)` of a `[*, cols]` matrix as
    /// a kernel operand.
    pub fn view(
        &self,
        row0: usize,
        n_rows: usize,
        cols: usize,
    ) -> WeightsView<'_> {
        let (a, b) = (row0 * cols, (row0 + n_rows) * cols);
        match self {
            WeightStore::F32(w) => WeightsView::F32(&w[a..b]),
            WeightStore::Bf16(w) => WeightsView::Bf16(&w[a..b]),
            WeightStore::Int8 { q, scales } => WeightsView::Int8 {
                q: &q[a..b],
                scales: &scales[row0..row0 + n_rows],
            },
        }
    }

    /// The full-precision buffer, when stored as f32 (tests and the
    /// checkpoint bridge use this; quantized stores return `None`).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            WeightStore::F32(w) => Some(w),
            _ => None,
        }
    }

    /// Dequantize row `r` of a `[*, cols]` matrix into `out[..cols]`
    /// (identity copy for f32).
    pub fn dequant_row(&self, r: usize, cols: usize, out: &mut [f32]) {
        match self.view(r, 1, cols) {
            WeightsView::F32(w) => out[..cols].copy_from_slice(w),
            WeightsView::Bf16(w) => {
                for (o, &h) in out[..cols].iter_mut().zip(w) {
                    *o = bf16_to_f32(h);
                }
            }
            WeightsView::Int8 { q, scales } => {
                let s = scales[0];
                for (o, &v) in out[..cols].iter_mut().zip(q) {
                    *o = v as f32 * s;
                }
            }
        }
    }
}

/// A borrowed `[k, n]` row-major B operand for [`gemm_bias_act`].
#[derive(Debug, Clone, Copy)]
pub enum WeightsView<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    Int8 { q: &'a [i8], scales: &'a [f32] },
}

impl WeightsView<'_> {
    fn check_shape(&self, k: usize, n: usize) {
        let len = match self {
            WeightsView::F32(w) => w.len(),
            WeightsView::Bf16(w) => w.len(),
            WeightsView::Int8 { q, scales } => {
                assert_eq!(scales.len(), k, "int8 scales shape");
                q.len()
            }
        };
        assert_eq!(len, k * n, "B shape");
    }
}

/// Default cache-blocking sizes (BLIS-style). `KC·NC` f32 panel ≈
/// 128 KiB — sized for L2; `MC` rows of A per inner block stay
/// L1-resident. [`GemmTiles`] carries runtime overrides; these
/// constants remain the defaults (and the shapes the golden tests
/// straddle).
pub const MC: usize = 64;
pub const KC: usize = 256;
pub const NC: usize = 128;

/// Fused GEMM + bias + optional SiLU: `C[m,n] = act(A[m,k] · B[k,n] +
/// bias[n])`, f32 accumulation, overwriting `c`, at the default
/// [`GemmTiles`]. `kernel` selects the implementation, `b` selects the
/// weight dtype; every combination is supported. Engines that carry a
/// tile override call [`gemm_bias_act_tiled`] instead.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_act(
    kernel: Kernel,
    a: &[f32],
    b: WeightsView<'_>,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    silu: bool,
) {
    gemm_bias_act_tiled(
        kernel,
        GemmTiles::default(),
        a,
        b,
        bias,
        c,
        m,
        k,
        n,
        silu,
    );
}

/// [`gemm_bias_act`] with explicit cache-blocking tiles. Results are
/// bit-identical across every valid `tiles` value per kernel; tiles
/// only move cache behavior.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_act_tiled(
    kernel: Kernel,
    tiles: GemmTiles,
    a: &[f32],
    b: WeightsView<'_>,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    silu: bool,
) {
    assert_eq!(a.len(), m * k, "A shape");
    b.check_shape(k, n);
    assert_eq!(bias.len(), n, "bias shape");
    assert_eq!(c.len(), m * n, "C shape");
    tiles.check();
    match kernel {
        Kernel::Naive => naive_gemm(a, b, bias, c, m, k, n, silu),
        other => blocked::gemm(
            a,
            b,
            bias,
            c,
            m,
            k,
            n,
            silu,
            tiles,
            other.micro(),
        ),
    }
}

/// SiLU of one value — the exact expression `router::linalg::silu`
/// applies, kept in sync so fused epilogues stay bit-identical to the
/// separate-pass path.
#[inline]
fn silu_one(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Accumulate `a_row[k] · B[k,n]` into `c_row[n]`, walking `k` in
/// ascending order with a plain multiply-then-add — the bit-exact
/// golden op order both the naive GEMM and the naive gated path share.
fn accumulate_row_naive(
    a_row: &[f32],
    b: WeightsView<'_>,
    c_row: &mut [f32],
    n: usize,
) {
    match b {
        WeightsView::F32(w) => {
            for (p, &aik) in a_row.iter().enumerate() {
                let b_row = &w[p * n..(p + 1) * n];
                for (cj, &bv) in c_row.iter_mut().zip(b_row) {
                    *cj += aik * bv;
                }
            }
        }
        WeightsView::Bf16(w) => {
            for (p, &aik) in a_row.iter().enumerate() {
                let b_row = &w[p * n..(p + 1) * n];
                for (cj, &bv) in c_row.iter_mut().zip(b_row) {
                    *cj += aik * bf16_to_f32(bv);
                }
            }
        }
        WeightsView::Int8 { q, scales } => {
            for (p, &aik) in a_row.iter().enumerate() {
                let b_row = &q[p * n..(p + 1) * n];
                let s = scales[p];
                for (cj, &bv) in c_row.iter_mut().zip(b_row) {
                    *cj += aik * (bv as f32 * s);
                }
            }
        }
    }
}

/// The original serving kernel: i-k-j accumulation (ascending `k`),
/// then bias, then SiLU, per output row. For f32 weights this is
/// element-for-element the op sequence of the historic
/// `matmul_into` → bias loop → `silu` path, hence bit-identical.
#[allow(clippy::too_many_arguments)]
fn naive_gemm(
    a: &[f32],
    b: WeightsView<'_>,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    silu: bool,
) {
    c.fill(0.0);
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        accumulate_row_naive(a_row, b, c_row, n);
        for (cj, &bj) in c_row.iter_mut().zip(bias) {
            *cj += bj;
        }
        if silu {
            for cj in c_row.iter_mut() {
                *cj = silu_one(*cj);
            }
        }
    }
}

/// Whether the explicit AVX2 inner kernel can run here: the `simd`
/// feature compiled in, x86_64, and the CPU reporting AVX2 + FMA.
pub fn simd_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// Whether the explicit NEON inner kernel can run here: the `simd`
/// feature compiled in, aarch64, and the CPU reporting NEON (always
/// true on AArch64 application profiles, but checked anyway so the
/// dispatch rule matches AVX2's).
pub fn neon_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        std::arch::is_aarch64_feature_detected!("neon")
    }
    #[cfg(not(all(feature = "simd", target_arch = "aarch64")))]
    {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Reference: the historic separate-pass path (matmul_into → bias
    /// → silu) the Naive kernel must reproduce bit-for-bit.
    fn reference(
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        m: usize,
        k: usize,
        n: usize,
        silu: bool,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        crate::router::linalg::matmul_into(a, b, &mut c, m, k, n);
        for row in c.chunks_mut(n) {
            for (v, &bj) in row.iter_mut().zip(bias) {
                *v += bj;
            }
        }
        if silu {
            crate::router::linalg::silu(&mut c);
        }
        c
    }

    /// Odd shapes straddling every block boundary: smaller than one
    /// tile, exact tiles, and tiles + ragged remainders in m, k and n.
    const SHAPES: [(usize, usize, usize); 6] = [
        (1, 1, 1),
        (3, 5, 7),
        (MC, KC, NC),
        (MC + 3, KC + 5, NC + 9),
        (2 * MC + 1, 2 * KC + 3, 2 * NC + 5),
        (7, 300, 19),
    ];

    #[test]
    fn naive_kernel_is_bit_identical_to_legacy_path() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            for silu in [false, true] {
                let want = reference(&a, &b, &bias, m, k, n, silu);
                let mut c = vec![9.9f32; m * n]; // must overwrite
                gemm_bias_act(
                    Kernel::Naive,
                    &a,
                    WeightsView::F32(&b),
                    &bias,
                    &mut c,
                    m,
                    k,
                    n,
                    silu,
                );
                assert_eq!(c, want, "shape ({m},{k},{n}) silu={silu}");
            }
        }
    }

    #[test]
    fn blocked_kernel_matches_naive_bitwise_on_f32() {
        // same ascending-k accumulation order ⇒ exact equality
        let mut rng = Rng::new(23);
        for &(m, k, n) in &SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            let want = reference(&a, &b, &bias, m, k, n, true);
            let mut c = vec![0.0f32; m * n];
            gemm_bias_act(
                Kernel::Blocked,
                &a,
                WeightsView::F32(&b),
                &bias,
                &mut c,
                m,
                k,
                n,
                true,
            );
            assert_eq!(c, want, "shape ({m},{k},{n})");
        }
    }

    /// The register-tiled Blocked kernel stays bitwise-equal to Naive
    /// for *every* valid tile choice — tiles (and the MR×NR register
    /// tiling beneath them) are pure data-layout moves, never
    /// reassociation. Deliberately extreme tiles included: 1x1x1
    /// degenerates to single-element blocks, the large one makes every
    /// dimension a single block.
    #[test]
    fn blocked_kernel_is_tile_invariant_bitwise() {
        let tile_grid = [
            GemmTiles::new(1, 1, 1),
            GemmTiles::new(2, 3, 5),
            GemmTiles::new(8, 16, 8),
            GemmTiles::new(16, 64, 48),
            GemmTiles::default(),
            GemmTiles::new(1000, 1000, 1000),
        ];
        let mut rng = Rng::new(29);
        for &(m, k, n) in
            &[(3usize, 5usize, 7usize), (MC + 3, KC + 5, NC + 9)]
        {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            let want = reference(&a, &b, &bias, m, k, n, true);
            for tiles in tile_grid {
                let mut c = vec![0.0f32; m * n];
                gemm_bias_act_tiled(
                    Kernel::Blocked,
                    tiles,
                    &a,
                    WeightsView::F32(&b),
                    &bias,
                    &mut c,
                    m,
                    k,
                    n,
                    true,
                );
                assert_eq!(c, want, "shape ({m},{k},{n}) tiles {tiles}");
            }
        }
    }

    #[test]
    fn gemm_tiles_parse_validate_and_env() {
        assert_eq!(GemmTiles::default(), GemmTiles::new(MC, KC, NC));
        assert_eq!(GemmTiles::default().to_string(), "64x256x128");
        assert_eq!(
            GemmTiles::parse("32x64x16").unwrap(),
            GemmTiles::new(32, 64, 16)
        );
        assert_eq!(
            GemmTiles::parse(" 8X8X8 ").unwrap(),
            GemmTiles::new(8, 8, 8)
        );
        assert!(GemmTiles::parse("64x256").is_err());
        assert!(GemmTiles::parse("axbxc").is_err());
        assert!(GemmTiles::parse("0x256x128").is_err());
        assert!(GemmTiles::new(64, 0, 128).validate().is_err());
        // env: absent -> Ok(None); set -> parsed; bad -> Err naming
        // the variable. No other test writes this variable (tests in
        // one binary share the process environment).
        std::env::remove_var(GemmTiles::ENV);
        assert_eq!(GemmTiles::from_env(), Ok(None));
        std::env::set_var(GemmTiles::ENV, "16x32x64");
        assert_eq!(
            GemmTiles::from_env(),
            Ok(Some(GemmTiles::new(16, 32, 64)))
        );
        // the builder picks the env override up when no explicit
        // .gemm_tiles(..) is given...
        let model = crate::model::synthetic_stacked_model(
            "cosine",
            &crate::util::rng::Rng::new(5),
            1,
            8,
            4,
            4,
            2,
            6,
        );
        let eng = crate::engine::Engine::builder()
            .model(model.clone())
            .build()
            .unwrap();
        assert_eq!(eng.gemm_tiles(), GemmTiles::new(16, 32, 64));
        // ...an explicit knob still wins...
        let eng = crate::engine::Engine::builder()
            .model(model)
            .gemm_tiles(GemmTiles::new(8, 8, 8))
            .build()
            .unwrap();
        assert_eq!(eng.gemm_tiles(), GemmTiles::new(8, 8, 8));
        // ...and a malformed override is an Err naming the variable.
        // Window kept minimal: while a *valid* value is set, parallel
        // tests building engines just pick it up (tiles are bit-free),
        // but a garbage value would fail their builds — so nothing
        // runs between set, read, and remove. The builder wraps this
        // Err into `EngineBuildError::BadGemmTiles` verbatim (the
        // invalid-tiles build path itself is pinned in
        // `engine::tests::gemm_tiles_knob_keeps_results_bit_identical`
        // via an explicit `.gemm_tiles(..)`).
        std::env::set_var(GemmTiles::ENV, "garbage");
        let err = GemmTiles::from_env().unwrap_err();
        std::env::remove_var(GemmTiles::ENV);
        assert!(err.contains(GemmTiles::ENV), "{err}");
        let build_err =
            crate::engine::EngineBuildError::BadGemmTiles { detail: err };
        assert!(build_err.to_string().contains(GemmTiles::ENV));
        assert_eq!(GemmTiles::from_env(), Ok(None));
    }

    /// Simd must match Naive within an FMA-reassociation tolerance on
    /// every odd shape (bit-equal when the feature is off, since it
    /// falls back to Blocked). Neon has the identical contract on
    /// aarch64 and the identical fallback elsewhere.
    #[test]
    fn simd_kernels_match_naive_within_tolerance() {
        let mut rng = Rng::new(37);
        for &(m, k, n) in &SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            let want = reference(&a, &b, &bias, m, k, n, true);
            for kernel in [Kernel::Simd, Kernel::Neon] {
                let mut c = vec![0.0f32; m * n];
                gemm_bias_act(
                    kernel,
                    &a,
                    WeightsView::F32(&b),
                    &bias,
                    &mut c,
                    m,
                    k,
                    n,
                    true,
                );
                // |Σ k products| error scales with k; 1e-5 relative
                // covers the single FMA rounding per product at these
                // magnitudes.
                let tol = 1e-5 * (k as f32).sqrt().max(1.0);
                for (i, (&got, &w)) in c.iter().zip(&want).enumerate()
                {
                    let scale = w.abs().max(1.0);
                    assert!(
                        (got - w).abs() <= tol * scale,
                        "{} shape ({m},{k},{n}) elem {i}: {got} vs {w}",
                        kernel.name()
                    );
                }
            }
        }
    }

    #[test]
    fn each_kernel_is_deterministic_across_calls() {
        let mut rng = Rng::new(41);
        let (m, k, n) = (MC + 5, KC + 7, NC + 3);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);
        for kernel in Kernel::ALL {
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![1.0f32; m * n];
            for c in [&mut c1, &mut c2] {
                gemm_bias_act(
                    kernel,
                    &a,
                    WeightsView::F32(&b),
                    &bias,
                    c,
                    m,
                    k,
                    n,
                    true,
                );
            }
            assert_eq!(c1, c2, "{} not deterministic", kernel.name());
        }
    }

    #[test]
    fn bf16_round_trip_stays_within_documented_bound() {
        let mut rng = Rng::new(53);
        let w = rand_vec(&mut rng, 4096);
        for &v in &w {
            let r = bf16_to_f32(f32_to_bf16(v));
            assert!(
                (r - v).abs() <= v.abs() * 2.0f32.powi(-8),
                "bf16 round-trip {v} -> {r} exceeds 2^-8 relative"
            );
        }
        // exact cases: bf16-representable values survive untouched
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.15625] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v);
        }
        // NaN stays NaN, infinities survive
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(
            bf16_to_f32(f32_to_bf16(f32::INFINITY)),
            f32::INFINITY
        );
    }

    #[test]
    fn int8_round_trip_stays_within_documented_bound() {
        let mut rng = Rng::new(59);
        let (rows, cols) = (32usize, 48usize);
        let w = rand_vec(&mut rng, rows * cols);
        let store =
            WeightStore::quantize(&w, rows, cols, WeightDtype::Int8);
        let mut deq = vec![0.0f32; cols];
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            let absmax =
                row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            store.dequant_row(r, cols, &mut deq);
            for (c, (&v, &rt)) in row.iter().zip(&deq).enumerate() {
                assert!(
                    (rt - v).abs() <= absmax / 254.0 + 1e-7,
                    "row {r} col {c}: {v} -> {rt}, absmax {absmax}"
                );
            }
        }
    }

    #[test]
    fn int8_zero_row_quantizes_to_exact_zero() {
        let w = vec![0.0f32; 8];
        let store = WeightStore::quantize(&w, 2, 4, WeightDtype::Int8);
        let mut deq = vec![1.0f32; 4];
        store.dequant_row(0, 4, &mut deq);
        assert_eq!(deq, vec![0.0; 4]);
    }

    /// Quantized weights through every kernel stay within the GEMM
    /// error bound `k · ε_w · max|a|` stated in the module docs.
    #[test]
    fn quantized_gemm_parity_within_documented_bound() {
        let mut rng = Rng::new(61);
        let (m, k, n) = (9usize, 140, 33);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);
        let amax = a.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
        let mut exact = vec![0.0f32; m * n];
        gemm_bias_act(
            Kernel::Naive,
            &a,
            WeightsView::F32(&b),
            &bias,
            &mut exact,
            m,
            k,
            n,
            false,
        );
        for dtype in [WeightDtype::Bf16, WeightDtype::Int8] {
            let store = WeightStore::quantize(&b, k, n, dtype);
            let eps = match dtype {
                WeightDtype::Bf16 => {
                    let bmax =
                        b.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
                    bmax * 2.0f32.powi(-8)
                }
                WeightDtype::Int8 => {
                    // per-row absmax ≤ global absmax
                    let bmax =
                        b.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
                    bmax / 254.0
                }
                WeightDtype::F32 => unreachable!(),
            };
            let bound = k as f32 * eps * amax;
            for kernel in Kernel::ALL {
                let mut got = vec![0.0f32; m * n];
                gemm_bias_act(
                    kernel,
                    &a,
                    store.view(0, k, n),
                    &bias,
                    &mut got,
                    m,
                    k,
                    n,
                    false,
                );
                for (i, (&g, &e)) in got.iter().zip(&exact).enumerate()
                {
                    assert!(
                        (g - e).abs() <= bound,
                        "{}/{} elem {i}: {g} vs {e} (bound {bound})",
                        kernel.name(),
                        dtype.name()
                    );
                }
            }
        }
    }

    /// All kernels agree bit-for-bit on the *same* quantized store
    /// when SIMD is unavailable, and within tolerance otherwise —
    /// dequantization happens before accumulation either way.
    #[test]
    fn kernels_agree_on_quantized_stores() {
        let mut rng = Rng::new(67);
        let (m, k, n) = (5usize, 130, 21);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = vec![0.0f32; n];
        for dtype in WeightDtype::ALL {
            let store = WeightStore::quantize(&b, k, n, dtype);
            let mut naive = vec![0.0f32; m * n];
            let mut blocked = vec![0.0f32; m * n];
            for (kern, out) in [
                (Kernel::Naive, &mut naive),
                (Kernel::Blocked, &mut blocked),
            ] {
                gemm_bias_act(
                    kern,
                    &a,
                    store.view(0, k, n),
                    &bias,
                    out,
                    m,
                    k,
                    n,
                    true,
                );
            }
            assert_eq!(naive, blocked, "{}", dtype.name());
        }
    }

    #[test]
    fn names_and_defaults_are_stable() {
        assert_eq!(Kernel::default(), Kernel::Naive);
        assert_eq!(WeightDtype::default(), WeightDtype::F32);
        assert_eq!(Kernel::Simd.name(), "simd");
        assert_eq!(Kernel::Neon.name(), "neon");
        assert_eq!(WeightDtype::Int8.name(), "int8");
        assert_eq!(Kernel::ALL.len(), 4);
        // Simd/Neon silently degrade to Blocked when unsupported —
        // the knob is always safe to set on any host.
        let _ = simd_available();
        let _ = neon_available();
        assert!(
            !(simd_available() && neon_available()),
            "one ISA at a time"
        );
    }
}
