//! Real expert FFN compute for the serving path — the stage PR 1's
//! analytic latency model stood in for. A [`ExpertBank`] holds `E`
//! dense FFN shards in one of two forms: the plain
//! `out = SiLU(x·W1 + b1)·W2 + b2` (matching the SiLU idiom of the
//! LPR encoder) or, when built with [`ExpertBank::from_weights_gated`],
//! the SwiGLU `out = (SiLU(x·W1 + b1) ⊙ (x·W3 + b3))·W2 + b2` — the
//! first stage runs through the fused
//! [`crate::kernels::gemm_bias_act_gated`] kernel, one pass per
//! column strip instead of two GEMMs plus a product pass. Tokens reach
//! the bank through a [`DispatchPlan`]'s grouped layout:
//!
//! 1. **gather** ([`gather_rows`]) — copy each surviving token's
//!    activation into the expert-grouped `[kept, d]` buffer (one
//!    contiguous row-block per expert: the grouped-GEMM input);
//! 2. **compute** ([`ExpertBank::forward_rows`]) — one batched matmul
//!    pair per expert over its contiguous rows (the serving engine
//!    shards these buckets across threads; per-expert compute is pure,
//!    so the grouping never changes the bits);
//! 3. **combine** ([`combine_rows`]) — gate-weighted accumulation back
//!    into token order, walked in fixed (token, slot) order so the
//!    result is independent of expert grouping and thread count.
//!
//! Dropped slots contribute nothing (the token continues through the
//! residual stream, as in capacity-factor training dispatch); rerouted
//! slots keep their original gate weight.

use crate::dispatch::plan::{DispatchPlan, DROPPED};
use crate::engine::EngineBuildError;
use crate::kernels::{
    gemm_bias_act_gated, gemm_bias_act_tiled, GemmTiles, Kernel,
    WeightDtype, WeightStore,
};
use crate::util::rng::Rng;

/// `E` dense FFN expert shards with flat row-major parameters.
///
/// Weights live in a [`WeightStore`] — f32 by default, or bf16/int8
/// after [`ExpertBank::quantized`] (the `Engine::builder()
/// .weight_dtype(...)` knob). Biases stay f32 and every kernel
/// accumulates in f32, so quantization error is exactly the weight
/// round-trip bound documented in [`crate::kernels`].
#[derive(Debug, Clone)]
pub struct ExpertBank {
    pub n_experts: usize,
    pub d_model: usize,
    pub d_ff: usize,
    /// [E, d, d_ff] — viewed as `E·d` rows of length `d_ff`.
    w1: WeightStore,
    /// [E, d_ff]
    b1: Vec<f32>,
    /// [E, d_ff, d] — viewed as `E·d_ff` rows of length `d`.
    w2: WeightStore,
    /// [E, d]
    b2: Vec<f32>,
    /// SwiGLU gate projection `[E, d, d_ff]` — present only for gated
    /// banks ([`ExpertBank::from_weights_gated`]).
    w3: Option<WeightStore>,
    /// [E, d_ff]; empty for ungated banks.
    b3: Vec<f32>,
}

impl ExpertBank {
    /// Deterministic init: every expert draws from its own `rng.fold(e)`
    /// child stream, so expert `e`'s parameters depend only on the seed
    /// and `e` — not on `E` or construction order.
    pub fn new(
        rng: &Rng,
        n_experts: usize,
        d_model: usize,
        d_ff: usize,
    ) -> ExpertBank {
        assert!(n_experts > 0 && d_model > 0 && d_ff > 0);
        let (s1, s2) = (
            1.0 / (d_model as f32).sqrt(),
            1.0 / (d_ff as f32).sqrt(),
        );
        let mut w1 = Vec::with_capacity(n_experts * d_model * d_ff);
        let mut w2 = Vec::with_capacity(n_experts * d_ff * d_model);
        for e in 0..n_experts {
            let mut r = rng.fold(e as u64);
            w1.extend(
                (0..d_model * d_ff).map(|_| r.normal() as f32 * s1),
            );
            w2.extend(
                (0..d_ff * d_model).map(|_| r.normal() as f32 * s2),
            );
        }
        ExpertBank {
            n_experts,
            d_model,
            d_ff,
            w1: WeightStore::F32(w1),
            b1: vec![0.0; n_experts * d_ff],
            w2: WeightStore::F32(w2),
            b2: vec![0.0; n_experts * d_model],
            w3: None,
            b3: Vec::new(),
        }
    }

    /// Build a bank from raw stacked weights: `w1` is `[E, d, ff]` and
    /// `w2` is `[E, ff, d]`, both flat row-major — exactly the layout
    /// of the trainer's stacked expert leaves, so the checkpoint bridge
    /// (`model::bridge`) hands buffers straight in. Biases are zero
    /// (the training FFN has none).
    pub fn from_weights(
        n_experts: usize,
        d_model: usize,
        d_ff: usize,
        w1: Vec<f32>,
        w2: Vec<f32>,
    ) -> ExpertBank {
        assert!(n_experts > 0 && d_model > 0 && d_ff > 0);
        assert_eq!(w1.len(), n_experts * d_model * d_ff, "w1 shape");
        assert_eq!(w2.len(), n_experts * d_ff * d_model, "w2 shape");
        ExpertBank {
            n_experts,
            d_model,
            d_ff,
            w1: WeightStore::F32(w1),
            b1: vec![0.0; n_experts * d_ff],
            w2: WeightStore::F32(w2),
            b2: vec![0.0; n_experts * d_model],
            w3: None,
            b3: Vec::new(),
        }
    }

    /// Build a **gated** (SwiGLU) bank: like
    /// [`ExpertBank::from_weights`] plus the gate projection `w3`
    /// (`[E, d, ff]`, the same layout as `w1`). The first FFN stage
    /// becomes `SiLU(x·W1 + b1) ⊙ (x·W3 + b3)` through the fused
    /// [`crate::kernels::gemm_bias_act_gated`] kernel. This is the
    /// layout of a checkpoint's `w3` expert leaves, which
    /// `model::bridge` now loads.
    pub fn from_weights_gated(
        n_experts: usize,
        d_model: usize,
        d_ff: usize,
        w1: Vec<f32>,
        w3: Vec<f32>,
        w2: Vec<f32>,
    ) -> ExpertBank {
        assert!(n_experts > 0 && d_model > 0 && d_ff > 0);
        assert_eq!(w1.len(), n_experts * d_model * d_ff, "w1 shape");
        assert_eq!(w3.len(), n_experts * d_model * d_ff, "w3 shape");
        assert_eq!(w2.len(), n_experts * d_ff * d_model, "w2 shape");
        ExpertBank {
            n_experts,
            d_model,
            d_ff,
            w1: WeightStore::F32(w1),
            b1: vec![0.0; n_experts * d_ff],
            w2: WeightStore::F32(w2),
            b2: vec![0.0; n_experts * d_model],
            w3: Some(WeightStore::F32(w3)),
            b3: vec![0.0; n_experts * d_ff],
        }
    }

    /// Whether this bank carries the SwiGLU gate projection.
    pub fn is_gated(&self) -> bool {
        self.w3.is_some()
    }

    /// Storage dtype of the FFN weights (both matrices share it).
    pub fn dtype(&self) -> WeightDtype {
        self.w1.dtype()
    }

    /// Quantize the bank's weights into `dtype` storage (biases stay
    /// f32; a gated bank's `w3` quantizes alongside `w1`/`w2`).
    /// Quantization always starts from full precision — calling this
    /// on an already-quantized bank with a *different* dtype would
    /// compound round-trip error, so that is rejected with the typed
    /// [`EngineBuildError::RequantizeDtype`] (it used to panic);
    /// re-quantizing to the current dtype is a no-op clone.
    pub fn quantized(
        &self,
        dtype: WeightDtype,
    ) -> Result<ExpertBank, EngineBuildError> {
        if dtype == self.dtype() {
            return Ok(self.clone());
        }
        let from = self.dtype();
        if from != WeightDtype::F32 {
            return Err(EngineBuildError::RequantizeDtype {
                from,
                to: dtype,
            });
        }
        let w1 = self.w1.as_f32().expect("f32 store has f32 buffer");
        let w2 = self.w2.as_f32().expect("f32 store has f32 buffer");
        let (e, d, ff) = (self.n_experts, self.d_model, self.d_ff);
        Ok(ExpertBank {
            n_experts: e,
            d_model: d,
            d_ff: ff,
            w1: WeightStore::quantize(w1, e * d, ff, dtype),
            b1: self.b1.clone(),
            w2: WeightStore::quantize(w2, e * ff, d, dtype),
            b2: self.b2.clone(),
            w3: self.w3.as_ref().map(|w3| {
                WeightStore::quantize(
                    w3.as_f32().expect("f32 store has f32 buffer"),
                    e * d,
                    ff,
                    dtype,
                )
            }),
            b3: self.b3.clone(),
        })
    }

    /// The f32 `w1` buffer (`None` once quantized) — tests and the
    /// checkpoint bridge read weights back through these.
    pub fn w1_f32(&self) -> Option<&[f32]> {
        self.w1.as_f32()
    }

    /// The f32 `w2` buffer (`None` once quantized).
    pub fn w2_f32(&self) -> Option<&[f32]> {
        self.w2.as_f32()
    }

    /// The f32 `w3` buffer (`None` for ungated or quantized banks).
    pub fn w3_f32(&self) -> Option<&[f32]> {
        self.w3.as_ref().and_then(|w| w.as_f32())
    }

    /// FFN of expert `e` over `m` contiguous rows: `out[m, d] =
    /// SiLU(x·W1 + b1)·W2 + b2`, with [`Kernel::Naive`] — the historic
    /// bit-exact path, kept as the parity oracle. See
    /// [`ExpertBank::forward_rows_with`].
    pub fn forward_rows(
        &self,
        e: usize,
        x: &[f32],
        m: usize,
        hid: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        self.forward_rows_with(Kernel::Naive, e, x, m, hid, out);
    }

    /// FFN of expert `e` over `m` contiguous rows with an explicit
    /// GEMM kernel at the default [`GemmTiles`] — see
    /// [`ExpertBank::forward_rows_tiled`].
    pub fn forward_rows_with(
        &self,
        kernel: Kernel,
        e: usize,
        x: &[f32],
        m: usize,
        hid: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        self.forward_rows_tiled(
            kernel,
            GemmTiles::default(),
            e,
            x,
            m,
            hid,
            out,
        );
    }

    /// FFN of expert `e` over `m` contiguous rows with an explicit
    /// GEMM kernel and cache-blocking tiles: both matmuls run through
    /// [`crate::kernels::gemm_bias_act_tiled`] with the bias add (and
    /// the SiLU, for the first matmul) fused into the kernel epilogue;
    /// a gated bank's first stage runs the fused
    /// [`crate::kernels::gemm_bias_act_gated`] SwiGLU kernel instead.
    /// `hid` is caller-owned scratch (grows once to the high-water
    /// bucket size). Pure per expert — the same rows give the same
    /// bits regardless of which thread runs them, for every kernel and
    /// every valid tile choice.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_rows_tiled(
        &self,
        kernel: Kernel,
        tiles: GemmTiles,
        e: usize,
        x: &[f32],
        m: usize,
        hid: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let (d, ff) = (self.d_model, self.d_ff);
        assert!(e < self.n_experts, "expert {e} out of range");
        assert_eq!(x.len(), m * d, "x shape");
        assert_eq!(out.len(), m * d, "out shape");
        hid.clear();
        hid.resize(m * ff, 0.0);
        match &self.w3 {
            Some(w3) => gemm_bias_act_gated(
                kernel,
                tiles,
                x,
                self.w1.view(e * d, d, ff),
                &self.b1[e * ff..(e + 1) * ff],
                w3.view(e * d, d, ff),
                &self.b3[e * ff..(e + 1) * ff],
                hid,
                m,
                d,
                ff,
            ),
            None => gemm_bias_act_tiled(
                kernel,
                tiles,
                x,
                self.w1.view(e * d, d, ff),
                &self.b1[e * ff..(e + 1) * ff],
                hid,
                m,
                d,
                ff,
                true,
            ),
        }
        gemm_bias_act_tiled(
            kernel,
            tiles,
            hid,
            self.w2.view(e * ff, ff, d),
            &self.b2[e * d..(e + 1) * d],
            out,
            m,
            ff,
            d,
            false,
        );
    }

    /// Single-threaded reference: run every expert bucket of `plan`
    /// over the gathered rows `xg` into `y` (both `[kept, d]`) with
    /// [`Kernel::Naive`]. The sharded engine path must match this
    /// bit-for-bit.
    pub fn forward_all(
        &self,
        plan: &DispatchPlan,
        xg: &[f32],
        hid: &mut Vec<f32>,
        y: &mut [f32],
    ) {
        self.forward_all_with(Kernel::Naive, plan, xg, hid, y);
    }

    /// [`ExpertBank::forward_all`] with an explicit GEMM kernel at the
    /// default [`GemmTiles`].
    pub fn forward_all_with(
        &self,
        kernel: Kernel,
        plan: &DispatchPlan,
        xg: &[f32],
        hid: &mut Vec<f32>,
        y: &mut [f32],
    ) {
        self.forward_all_tiled(
            kernel,
            GemmTiles::default(),
            plan,
            xg,
            hid,
            y,
        );
    }

    /// [`ExpertBank::forward_all`] with an explicit GEMM kernel and
    /// cache-blocking tiles.
    pub fn forward_all_tiled(
        &self,
        kernel: Kernel,
        tiles: GemmTiles,
        plan: &DispatchPlan,
        xg: &[f32],
        hid: &mut Vec<f32>,
        y: &mut [f32],
    ) {
        let d = self.d_model;
        assert_eq!(xg.len(), plan.kept() * d);
        assert_eq!(y.len(), plan.kept() * d);
        for e in 0..plan.n_experts {
            let rows = plan.expert_rows(e);
            let m = rows.len();
            if m == 0 {
                continue;
            }
            self.forward_rows_tiled(
                kernel,
                tiles,
                e,
                &xg[rows.start * d..rows.end * d],
                m,
                hid,
                &mut y[rows.start * d..rows.end * d],
            );
        }
    }
}

/// Gather surviving token activations into the expert-grouped layout:
/// `xg[pos] = h[plan.src[pos] / top_k]` for every grouped row. `h` is
/// `[N, d]` row-major; `xg` is cleared/resized to `[kept, d]`.
pub fn gather_rows(
    plan: &DispatchPlan,
    h: &[f32],
    d: usize,
    xg: &mut Vec<f32>,
) {
    assert_eq!(h.len(), plan.n * d, "h shape");
    let k = plan.top_k;
    xg.clear();
    xg.resize(plan.kept() * d, 0.0);
    for (pos, &f) in plan.src.iter().enumerate() {
        let t = f as usize / k;
        xg[pos * d..(pos + 1) * d]
            .copy_from_slice(&h[t * d..(t + 1) * d]);
    }
}

/// Gate-weighted combine back into token order: for each token, sum
/// `weight[slot] · y[row-of-slot]` over its surviving slots, in slot
/// order. `weights` is the flat `[N·k]` combine-weight buffer of the
/// routed batch; `out` is cleared/resized to `[N, d]`. Fixed iteration
/// order ⇒ bit-identical regardless of expert grouping or threading.
pub fn combine_rows(
    plan: &DispatchPlan,
    weights: &[f32],
    y: &[f32],
    d: usize,
    out: &mut Vec<f32>,
) {
    combine_rows_opts(plan, weights, y, d, false, out);
}

/// [`combine_rows`] with an optional gate-weight renormalization (the
/// `--renormalize` serving option): when `renormalize` is set and some
/// of a token's slots were dropped by the overflow policy, its
/// *surviving* weights are rescaled so their sum equals the token's
/// pre-drop mass `Σ_j w_j` — a drop then costs expert diversity rather
/// than combine magnitude. Tokens with no surviving slot stay all-zero
/// (there is nothing to renormalize onto), and tokens with no dropped
/// slot are untouched *bit-for-bit*: their surviving-mass sum is
/// computed with the identical float additions as the pre-drop mass, so
/// the scale is exactly 1 and never applied.
pub fn combine_rows_opts(
    plan: &DispatchPlan,
    weights: &[f32],
    y: &[f32],
    d: usize,
    renormalize: bool,
    out: &mut Vec<f32>,
) {
    let (n, k) = (plan.n, plan.top_k);
    assert_eq!(weights.len(), n * k, "weights shape");
    assert_eq!(y.len(), plan.kept() * d, "y shape");
    out.clear();
    out.resize(n * d, 0.0);
    for r in 0..n {
        let mut scale = 1.0f32;
        if renormalize {
            let (mut total, mut kept) = (0.0f32, 0.0f32);
            for j in 0..k {
                let f = r * k + j;
                total += weights[f];
                if plan.pos_of[f] != DROPPED {
                    kept += weights[f];
                }
            }
            if kept > 0.0 && kept != total {
                scale = total / kept;
            }
        }
        let orow = &mut out[r * d..(r + 1) * d];
        for j in 0..k {
            let f = r * k + j;
            let pos = plan.pos_of[f];
            if pos == DROPPED {
                continue;
            }
            let w = if renormalize {
                weights[f] * scale
            } else {
                weights[f]
            };
            let yrow = &y[pos as usize * d..(pos as usize + 1) * d];
            for (o, &v) in orow.iter_mut().zip(yrow) {
                *o += w * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::plan::{capacity_for, OverflowPolicy};
    use crate::router::{synthetic_lpr_router, ServingEngine};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn init_is_deterministic_and_expert_distinct() {
        let a = ExpertBank::new(&Rng::new(5), 4, 8, 16);
        let b = ExpertBank::new(&Rng::new(5), 4, 8, 16);
        let (aw1, aw2) = (a.w1_f32().unwrap(), a.w2_f32().unwrap());
        assert_eq!(aw1, b.w1_f32().unwrap());
        assert_eq!(aw2, b.w2_f32().unwrap());
        // different experts hold different weights
        assert_ne!(aw1[0..8 * 16], aw1[8 * 16..2 * 8 * 16]);
        // expert e's params depend only on (seed, e), not on E
        let wide = ExpertBank::new(&Rng::new(5), 6, 8, 16);
        assert_eq!(
            aw1[..4 * 8 * 16],
            wide.w1_f32().unwrap()[..4 * 8 * 16]
        );
    }

    #[test]
    fn forward_rows_matches_manual_ffn() {
        // d=2, ff=1: out = silu(x·w1)·w2 with zero biases
        let bank = ExpertBank::from_weights(
            1,
            2,
            1,
            vec![1.0, -1.0], // w1 [2, 1]
            vec![0.5, 2.0],  // w2 [1, 2]
        );
        let x = [3.0f32, 1.0]; // h = silu(2.0)
        let hpre = 2.0f32;
        let hval = hpre / (1.0 + (-hpre).exp());
        let mut hid = Vec::new();
        let mut out = vec![0.0f32; 2];
        bank.forward_rows(0, &x, 1, &mut hid, &mut out);
        assert!((out[0] - hval * 0.5).abs() < 1e-6);
        assert!((out[1] - hval * 2.0).abs() < 1e-6);
    }

    /// With capacity high enough that nothing drops, the full
    /// gather→compute→combine path must equal the naive per-token loop
    /// `sum_j w_j · FFN_{e_j}(h_t)` bit-for-bit.
    #[test]
    fn grouped_path_matches_naive_reference() {
        let mut rng = Rng::new(77);
        let (d, dz, e, k, n, ff) = (16usize, 8, 8, 3, 40, 12);
        let r = synthetic_lpr_router("dot", &mut rng, d, dz, e, k);
        let mut eng = ServingEngine::new(r.plan().clone(), 1);
        let h = rand_vec(&mut rng, n * d);
        let batch = eng.route(&h);
        let bank = ExpertBank::new(&Rng::new(9), e, d, ff);
        let mut plan = DispatchPlan::new();
        plan.compile_batch(&batch, n * k, OverflowPolicy::Drop);
        assert_eq!(plan.n_dropped, 0);

        let (mut xg, mut hid) = (Vec::new(), Vec::new());
        gather_rows(&plan, &h, d, &mut xg);
        let mut y = vec![0.0f32; plan.kept() * d];
        bank.forward_all(&plan, &xg, &mut hid, &mut y);
        let mut combined = Vec::new();
        combine_rows(&plan, &batch.weights, &y, d, &mut combined);

        // naive reference: route each (token, slot) through its expert
        for t in 0..n {
            let mut want = vec![0.0f32; d];
            for j in 0..k {
                let f = t * k + j;
                let ex = batch.topk_idx[f] as usize;
                let mut yrow = vec![0.0f32; d];
                bank.forward_rows(
                    ex,
                    &h[t * d..(t + 1) * d],
                    1,
                    &mut hid,
                    &mut yrow,
                );
                let w = batch.weights[f];
                for (acc, &v) in want.iter_mut().zip(&yrow) {
                    *acc += w * v;
                }
            }
            // identical op order per slot ⇒ exact equality
            assert_eq!(
                &combined[t * d..(t + 1) * d],
                &want[..],
                "token {t} diverged"
            );
        }
    }

    #[test]
    fn dropped_slots_contribute_nothing() {
        let mut rng = Rng::new(31);
        let (d, dz, e, k, n, ff) = (8usize, 4, 4, 2, 32, 8);
        let r = synthetic_lpr_router("gaussian", &mut rng, d, dz, e, k);
        let mut eng = ServingEngine::new(r.plan().clone(), 1);
        let h = rand_vec(&mut rng, n * d);
        let batch = eng.route(&h);
        let bank = ExpertBank::new(&Rng::new(2), e, d, ff);
        // capacity 1: almost everything drops
        let mut plan = DispatchPlan::new();
        plan.compile_batch(&batch, 1, OverflowPolicy::Drop);
        assert!(plan.n_dropped > 0);
        let (mut xg, mut hid, mut combined) =
            (Vec::new(), Vec::new(), Vec::new());
        gather_rows(&plan, &h, d, &mut xg);
        let mut y = vec![0.0f32; plan.kept() * d];
        bank.forward_all(&plan, &xg, &mut hid, &mut y);
        combine_rows(&plan, &batch.weights, &y, d, &mut combined);
        for t in 0..n {
            let all_dropped = (0..k)
                .all(|j| plan.pos_of[t * k + j] == DROPPED);
            let row_zero = combined[t * d..(t + 1) * d]
                .iter()
                .all(|&v| v == 0.0);
            if all_dropped {
                assert!(row_zero, "dropped token {t} must be zero");
            }
        }
        // exactly `capacity * live experts` rows computed
        assert_eq!(
            plan.kept(),
            plan.counts.iter().map(|&c| c as usize).sum::<usize>()
        );
    }

    #[test]
    fn capacity_helper_agrees_with_plan_bins() {
        let cap = capacity_for(64 * 2, 4, 1.0);
        assert_eq!(cap, 32);
    }

    /// Pinned `--renormalize` semantics: a token that lost a slot to
    /// the Drop policy has its surviving weight rescaled to the full
    /// pre-drop mass, so Drop+renormalize conserves per-token combine
    /// weight; tokens with no drops are bit-identical to the plain
    /// combine.
    #[test]
    fn renormalize_restores_dropped_mass() {
        let (d, ff, e, k) = (4usize, 6usize, 3usize, 2usize);
        let bank = ExpertBank::new(&Rng::new(15), e, d, ff);
        // tokens t0:(0,1), t1:(0,2); capacity 1, Drop: t1's slot 0
        // overflows expert 0 and drops, its slot 1 (expert 2) survives.
        let a: Vec<u32> = vec![0, 1, 0, 2];
        let mut plan = DispatchPlan::new();
        plan.compile(&a, k, e, 1, OverflowPolicy::Drop);
        assert_eq!(plan.expert_of, vec![0, 1, DROPPED, 2]);
        assert_eq!(plan.n_dropped, 1);

        let mut rng = Rng::new(7);
        let h: Vec<f32> =
            (0..2 * d).map(|_| rng.normal() as f32).collect();
        let weights: Vec<f32> = vec![0.6, 0.4, 0.7, 0.3];
        let (mut xg, mut hid) = (Vec::new(), Vec::new());
        gather_rows(&plan, &h, d, &mut xg);
        let mut y = vec![0.0f32; plan.kept() * d];
        bank.forward_all(&plan, &xg, &mut hid, &mut y);
        let (mut plain, mut renorm) = (Vec::new(), Vec::new());
        combine_rows_opts(&plan, &weights, &y, d, false, &mut plain);
        combine_rows_opts(&plan, &weights, &y, d, true, &mut renorm);

        // t0 lost nothing: bit-identical either way
        assert_eq!(&plain[..d], &renorm[..d]);
        // t1: surviving slot rescaled from 0.3 to the full 1.0 mass —
        // same op order as the implementation, so exact equality holds
        let scale = (0.7f32 + 0.3) / 0.3;
        let w = 0.3f32 * scale;
        assert!((w - 1.0).abs() < 1e-6);
        let mut f2 = vec![0.0f32; d];
        bank.forward_rows(2, &h[d..2 * d], 1, &mut hid, &mut f2);
        for c in 0..d {
            assert_eq!(renorm[d + c], w * f2[c], "dim {c}");
            // and the plain combine only kept 0.3 of it
            assert_eq!(plain[d + c], 0.3 * f2[c], "dim {c}");
        }
    }

    /// Drop+renormalize conserves per-token combine weight: with unit
    /// FFN outputs the combined row *is* the applied weight mass, which
    /// must equal the pre-drop mass for every token with a survivor.
    #[test]
    fn renormalize_conserves_per_token_mass() {
        let mut rng = Rng::new(57);
        let (d, dz, e, k, n) = (8usize, 4, 8, 3, 64);
        let r = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let mut eng = ServingEngine::new(r.plan().clone(), 1);
        let h: Vec<f32> =
            (0..n * d).map(|_| rng.normal() as f32).collect();
        let batch = eng.route(&h);
        let mut plan = DispatchPlan::new();
        plan.compile_batch(&batch, 2, OverflowPolicy::Drop);
        assert!(plan.n_dropped > 0, "capacity 2 must drop");
        // y = all-ones rows: combined[r*d] = sum of applied weights
        let y = vec![1.0f32; plan.kept() * d];
        let mut out = Vec::new();
        combine_rows_opts(&plan, &batch.weights, &y, d, true, &mut out);
        for t in 0..n {
            let survivors = (0..k)
                .filter(|&j| plan.pos_of[t * k + j] != DROPPED)
                .count();
            let total: f32 = batch.weights[t * k..(t + 1) * k].iter().sum();
            let applied = out[t * d];
            if survivors == 0 {
                assert_eq!(applied, 0.0, "token {t} has no survivors");
            } else {
                assert!(
                    (applied - total).abs() < 1e-5,
                    "token {t}: applied {applied} != pre-drop {total}"
                );
            }
        }
    }

    /// NextChoice can land a rerouted slot on an expert the token
    /// already reaches through another slot (its fallback set IS the
    /// token's later choices). The defined semantics: the token takes
    /// two rows of that expert's bucket and the combine sums both slot
    /// weights over the same FFN output — the overflowed weight
    /// transfers to the fallback expert.
    #[test]
    fn next_choice_transfers_weight_on_duplicate() {
        let (d, ff, e, k) = (4usize, 6usize, 3usize, 2usize);
        let bank = ExpertBank::new(&Rng::new(12), e, d, ff);
        // tokens (0,2), (0,2), (0,1); cap 2: token 2's slot 0
        // overflows expert 0 and falls through to its next choice,
        // expert 1 — which its own slot 1 also reaches.
        let a: Vec<u32> = vec![0, 2, 0, 2, 0, 1];
        let mut plan = DispatchPlan::new();
        plan.compile(&a, k, e, 2, OverflowPolicy::NextChoice);
        assert_eq!(plan.expert_of, vec![0, 2, 0, 2, 1, 1]);
        assert_eq!(plan.n_rerouted, 1);
        assert_eq!(plan.n_dropped, 0);

        let mut rng = Rng::new(3);
        let h: Vec<f32> =
            (0..3 * d).map(|_| rng.normal() as f32).collect();
        let weights: Vec<f32> =
            vec![0.6, 0.4, 0.7, 0.3, 0.55, 0.45];
        let (mut xg, mut hid, mut combined) =
            (Vec::new(), Vec::new(), Vec::new());
        gather_rows(&plan, &h, d, &mut xg);
        let mut y = vec![0.0f32; plan.kept() * d];
        bank.forward_all(&plan, &xg, &mut hid, &mut y);
        combine_rows(&plan, &weights, &y, d, &mut combined);

        // token 2: both slots hit expert 1 -> w0·F1(h2) + w1·F1(h2)
        let mut f1 = vec![0.0f32; d];
        bank.forward_rows(1, &h[2 * d..3 * d], 1, &mut hid, &mut f1);
        for c in 0..d {
            let want = 0.55 * f1[c] + 0.45 * f1[c];
            assert_eq!(combined[2 * d + c], want, "dim {c}");
        }
    }

    /// The Blocked kernel preserves the FFN bit-for-bit on f32 banks
    /// (same ascending-k accumulation, fused epilogue with identical
    /// per-element op order) — on odd shapes that straddle the tile
    /// boundaries.
    #[test]
    fn blocked_forward_matches_naive_bitwise_on_f32() {
        let (e, d, ff) = (3usize, 37, 2 * crate::kernels::NC + 5);
        let bank = ExpertBank::new(&Rng::new(21), e, d, ff);
        let mut rng = Rng::new(22);
        let m = crate::kernels::MC + 3;
        let x = rand_vec(&mut rng, m * d);
        let (mut hid, mut want, mut got) =
            (Vec::new(), vec![0.0f32; m * d], vec![0.0f32; m * d]);
        for ex in 0..e {
            bank.forward_rows(ex, &x, m, &mut hid, &mut want);
            bank.forward_rows_with(
                Kernel::Blocked,
                ex,
                &x,
                m,
                &mut hid,
                &mut got,
            );
            assert_eq!(got, want, "expert {ex}");
            bank.forward_rows_with(
                Kernel::Simd,
                ex,
                &x,
                m,
                &mut hid,
                &mut got,
            );
            // Simd may differ by FMA rounding only
            for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "expert {ex} elem {i}: {g} vs {w}"
                );
            }
        }
    }

    /// Quantized banks stay within the documented round-trip bound of
    /// the f32 forward: with unit-scale synthetic weights the FFN
    /// output error is small and — crucially — identical across
    /// kernels, since dequantization happens before accumulation.
    #[test]
    fn quantized_bank_parity_within_tolerance() {
        let (e, d, ff, m) = (4usize, 24, 96, 17);
        let bank = ExpertBank::new(&Rng::new(33), e, d, ff);
        let mut rng = Rng::new(34);
        let x = rand_vec(&mut rng, m * d);
        let (mut hid, mut exact) = (Vec::new(), vec![0.0f32; m * d]);
        bank.forward_rows(0, &x, m, &mut hid, &mut exact);
        for dtype in [WeightDtype::Bf16, WeightDtype::Int8] {
            let q = bank.quantized(dtype).unwrap();
            assert_eq!(q.dtype(), dtype);
            assert!(q.w1_f32().is_none());
            let mut got = vec![0.0f32; m * d];
            q.forward_rows(0, &x, m, &mut hid, &mut got);
            // loose end-to-end bound: both matmuls perturb ≤ ~k·ε_w
            // relative (see kernels module docs); at these shapes the
            // bf16 path lands well under 1e-1 absolute and int8 under
            // ~2e-1 on unit-scale activations.
            let tol = 0.2f32;
            for (i, (&g, &w)) in got.iter().zip(&exact).enumerate() {
                assert!(
                    (g - w).abs() <= tol * w.abs().max(1.0),
                    "{} elem {i}: {g} vs {w}",
                    dtype.name()
                );
            }
            // and every kernel agrees on the same quantized store
            let mut blocked = vec![0.0f32; m * d];
            q.forward_rows_with(
                Kernel::Blocked,
                0,
                &x,
                m,
                &mut hid,
                &mut blocked,
            );
            assert_eq!(blocked, got, "{}", dtype.name());
        }
    }

    #[test]
    fn requantizing_same_dtype_is_identity() {
        let bank = ExpertBank::new(&Rng::new(44), 2, 8, 16);
        let same = bank.quantized(WeightDtype::F32).unwrap();
        assert_eq!(same.w1_f32().unwrap(), bank.w1_f32().unwrap());
        let q = bank.quantized(WeightDtype::Int8).unwrap();
        let q2 = q.quantized(WeightDtype::Int8).unwrap();
        assert_eq!(q2.dtype(), WeightDtype::Int8);
    }

    /// Regression (used to panic): requantizing an already-quantized
    /// bank to a *different* dtype is a typed builder-style error
    /// naming both dtypes, never a panic.
    #[test]
    fn requantize_to_different_dtype_is_typed_error() {
        let bank = ExpertBank::new(&Rng::new(44), 2, 8, 16);
        let q = bank.quantized(WeightDtype::Int8).unwrap();
        let err = q.quantized(WeightDtype::Bf16).unwrap_err();
        assert_eq!(
            err,
            EngineBuildError::RequantizeDtype {
                from: WeightDtype::Int8,
                to: WeightDtype::Bf16,
            }
        );
        let msg = err.to_string();
        assert!(msg.contains("int8") && msg.contains("bf16"), "{msg}");
        // the bf16 -> int8 direction is equally rejected
        let q = bank.quantized(WeightDtype::Bf16).unwrap();
        assert!(q.quantized(WeightDtype::Int8).is_err());
    }

    fn gated_bank(
        seed: u64,
        e: usize,
        d: usize,
        ff: usize,
    ) -> (ExpertBank, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w1 = rand_vec(&mut rng, e * d * ff);
        let w3 = rand_vec(&mut rng, e * d * ff);
        let w2 = rand_vec(&mut rng, e * ff * d);
        let bank = ExpertBank::from_weights_gated(
            e,
            d,
            ff,
            w1.clone(),
            w3.clone(),
            w2.clone(),
        );
        (bank, w1, w3, w2)
    }

    /// Property test: the gated bank's forward equals the
    /// hand-composed `silu(x·w1) ⊙ (x·w3) · w2` reference — bitwise
    /// for the scalar kernels, within the documented FMA tolerance for
    /// Simd/Neon — across odd shapes straddling the tile boundaries.
    #[test]
    fn gated_bank_matches_hand_composed_swiglu_reference() {
        use crate::kernels::gemm_bias_act;
        for (seed, e, d, ff, m) in [
            (51u64, 2usize, 5usize, 9usize, 3usize),
            (52, 3, 37, crate::kernels::NC + 5, 7),
            (53, 1, 24, 96, crate::kernels::MC + 1),
        ] {
            let (bank, w1, w3, w2) = gated_bank(seed, e, d, ff);
            assert!(bank.is_gated());
            let mut rng = Rng::new(seed ^ 0xff);
            let x = rand_vec(&mut rng, m * d);
            let zeros_ff = vec![0.0f32; ff];
            let zeros_d = vec![0.0f32; d];
            let mut hid = Vec::new();
            for ex in 0..e {
                // hand-composed reference, all-naive
                let mut h1 = vec![0.0f32; m * ff];
                let mut h3 = vec![0.0f32; m * ff];
                gemm_bias_act(
                    Kernel::Naive,
                    &x,
                    crate::kernels::WeightsView::F32(
                        &w1[ex * d * ff..(ex + 1) * d * ff],
                    ),
                    &zeros_ff,
                    &mut h1,
                    m,
                    d,
                    ff,
                    true,
                );
                gemm_bias_act(
                    Kernel::Naive,
                    &x,
                    crate::kernels::WeightsView::F32(
                        &w3[ex * d * ff..(ex + 1) * d * ff],
                    ),
                    &zeros_ff,
                    &mut h3,
                    m,
                    d,
                    ff,
                    false,
                );
                let prod: Vec<f32> = h1
                    .iter()
                    .zip(&h3)
                    .map(|(&a, &b)| a * b)
                    .collect();
                let mut want = vec![0.0f32; m * d];
                gemm_bias_act(
                    Kernel::Naive,
                    &prod,
                    crate::kernels::WeightsView::F32(
                        &w2[ex * ff * d..(ex + 1) * ff * d],
                    ),
                    &zeros_d,
                    &mut want,
                    m,
                    ff,
                    d,
                    false,
                );
                for kernel in Kernel::ALL {
                    let mut got = vec![0.0f32; m * d];
                    bank.forward_rows_with(
                        kernel, ex, &x, m, &mut hid, &mut got,
                    );
                    match kernel {
                        Kernel::Naive | Kernel::Blocked => {
                            assert_eq!(
                                got,
                                want,
                                "{} expert {ex}",
                                kernel.name()
                            );
                        }
                        _ => {
                            let tol =
                                2e-4 * (ff as f32).sqrt().max(1.0);
                            for (i, (&g, &w)) in
                                got.iter().zip(&want).enumerate()
                            {
                                assert!(
                                    (g - w).abs()
                                        <= tol * w.abs().max(1.0),
                                    "{} expert {ex} elem {i}: \
                                     {g} vs {w}",
                                    kernel.name()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// Tiles are a pure cache knob on the bank level too: a gated and
    /// an ungated forward are bitwise tile-invariant per kernel.
    #[test]
    fn bank_forward_is_tile_invariant() {
        use crate::kernels::GemmTiles;
        let (gated, ..) = gated_bank(61, 2, 19, 33);
        let plain = ExpertBank::new(&Rng::new(62), 2, 19, 33);
        let mut rng = Rng::new(63);
        let m = 9;
        let x = rand_vec(&mut rng, m * 19);
        let mut hid = Vec::new();
        for bank in [&gated, &plain] {
            for kernel in [Kernel::Naive, Kernel::Blocked] {
                let mut want = vec![0.0f32; m * 19];
                bank.forward_rows_with(
                    kernel, 1, &x, m, &mut hid, &mut want,
                );
                for tiles in
                    [GemmTiles::new(1, 1, 1), GemmTiles::new(8, 16, 8)]
                {
                    let mut got = vec![0.0f32; m * 19];
                    bank.forward_rows_tiled(
                        kernel, tiles, 1, &x, m, &mut hid, &mut got,
                    );
                    assert_eq!(
                        got,
                        want,
                        "gated={} {} tiles {tiles}",
                        bank.is_gated(),
                        kernel.name()
                    );
                }
            }
        }
    }

    /// Quantizing a gated bank quantizes `w3` alongside `w1`/`w2` and
    /// keeps the gate within the documented round-trip tolerance.
    #[test]
    fn quantized_gated_bank_keeps_gate_within_tolerance() {
        let (bank, ..) = gated_bank(71, 2, 16, 48);
        let mut rng = Rng::new(72);
        let m = 11;
        let x = rand_vec(&mut rng, m * 16);
        let mut hid = Vec::new();
        let mut exact = vec![0.0f32; m * 16];
        bank.forward_rows(1, &x, m, &mut hid, &mut exact);
        for dtype in [WeightDtype::Bf16, WeightDtype::Int8] {
            let q = bank.quantized(dtype).unwrap();
            assert!(q.is_gated(), "{} lost the gate", dtype.name());
            assert!(q.w3_f32().is_none(), "w3 must be quantized too");
            let mut got = vec![0.0f32; m * 16];
            q.forward_rows(1, &x, m, &mut hid, &mut got);
            let mut max_rel = 0.0f32;
            for (&g, &w) in got.iter().zip(&exact) {
                max_rel = max_rel.max((g - w).abs() / w.abs().max(1.0));
            }
            assert!(
                max_rel > 0.0 && max_rel < 0.3,
                "{}: max_rel {max_rel}",
                dtype.name()
            );
            // kernels agree bit-for-bit on the same quantized store
            let mut blocked = vec![0.0f32; m * 16];
            q.forward_rows_with(
                Kernel::Blocked,
                1,
                &x,
                m,
                &mut hid,
                &mut blocked,
            );
            assert_eq!(blocked, got, "{}", dtype.name());
        }
    }
}
