//! L3 runtime: load AOT HLO-text artifacts and execute them on PJRT.
//!
//! The contract with the build-time python side (`python/compile/aot.py`)
//! is: per config, four HLO-text executables (`init`, `train`, `eval`,
//! `router`) plus `meta.json` describing the flat buffer order. This
//! module wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → compile → execute) and keeps
//! training state **device-resident**: the vendored crate is patched to
//! untuple executable outputs, so `train_step` output buffers are fed
//! straight back as next-step inputs with no host round-trip (the only
//! per-step host traffic is the metrics vector and load histogram).

pub mod artifact;

use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

pub use artifact::{ArtifactMeta, LeafSpec};

/// A PJRT CPU session owning the client and compiled executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// Load one HLO text file and compile it.
    pub fn compile_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
    }

    // ---- host -> device ------------------------------------------------
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32{dims:?}: {e:?}"))
    }

    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32{dims:?}: {e:?}"))
    }

    pub fn buf_scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.buf_i32(&[v], &[])
    }

    // ---- device -> host ------------------------------------------------
    pub fn to_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("download literal: {e:?}"))?;
        lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e:?}"))
    }

    pub fn to_i32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<i32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("download literal: {e:?}"))?;
        lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e:?}"))
    }
}

/// Run an executable whose inputs are already on device; returns the
/// untupled output buffers of replica 0.
pub fn execute_buffers(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<Vec<xla::PjRtBuffer>> {
    let mut outs = exe
        .execute_b(args)
        .map_err(|e| anyhow!("execute_b: {e:?}"))?;
    if outs.is_empty() {
        bail!("executable produced no replicas");
    }
    Ok(outs.swap_remove(0))
}

/// Compiled artifact set for one config (init/train/eval/router).
pub struct CompiledArtifacts {
    pub meta: ArtifactMeta,
    pub init: xla::PjRtLoadedExecutable,
    pub train: xla::PjRtLoadedExecutable,
    pub eval: xla::PjRtLoadedExecutable,
    pub router: xla::PjRtLoadedExecutable,
}

impl CompiledArtifacts {
    /// Load `artifacts/<name>.*` and compile all four executables.
    pub fn load(rt: &Runtime, art_dir: &Path, name: &str) -> Result<Self> {
        let meta = ArtifactMeta::load(art_dir, name)
            .with_context(|| format!("loading meta for '{name}'"))?;
        let path = |kind: &str| art_dir.join(format!("{name}.{kind}.hlo.txt"));
        Ok(CompiledArtifacts {
            init: rt.compile_hlo(&path("init"))?,
            train: rt.compile_hlo(&path("train"))?,
            eval: rt.compile_hlo(&path("eval"))?,
            router: rt.compile_hlo(&path("router"))?,
            meta,
        })
    }
}
