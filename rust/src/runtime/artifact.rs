//! Parsed `meta.json` — the flat-buffer contract emitted by aot.py.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::json::Json;

/// One leaf tensor of the flattened parameter pytree.
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub path: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl LeafSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Subset of the python `Config` the runtime needs.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub arch: String,
    pub router: String,
    pub metric: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub latent_dim: usize,
    pub total_steps: usize,
    pub batch_size: usize,
    pub seq_len: usize,
    pub capacity_factor: f64,
    pub unit_ball: bool,
    pub hypersphere_init: bool,
    pub gaussian_sigma: f64,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            Ok(j.at(k).as_str().context(k.to_string())?.to_string())
        };
        let u = |k: &str| -> Result<usize> {
            j.at(k).as_usize().with_context(|| k.to_string())
        };
        let f = |k: &str| -> Result<f64> {
            j.at(k).as_f64().with_context(|| k.to_string())
        };
        let b = |k: &str| -> Result<bool> {
            j.at(k).as_bool().with_context(|| k.to_string())
        };
        Ok(ModelConfig {
            name: s("name")?,
            arch: s("arch")?,
            router: s("router")?,
            metric: s("metric")?,
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_experts: u("n_experts")?,
            top_k: u("top_k")?,
            latent_dim: u("latent_dim")?,
            total_steps: u("total_steps")?,
            batch_size: u("batch_size")?,
            seq_len: u("seq_len")?,
            capacity_factor: f("capacity_factor")?,
            unit_ball: b("unit_ball")?,
            hypersphere_init: b("hypersphere_init")?,
            gaussian_sigma: f("gaussian_sigma")?,
        })
    }

    pub fn tokens_per_batch(&self) -> usize {
        self.batch_size * self.seq_len
    }
}

/// Full parsed meta.json.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub config: ModelConfig,
    pub n_params: usize,
    pub n_state: usize,
    pub params: Vec<LeafSpec>,
    pub router_params: Vec<LeafSpec>,
    pub metric_names: Vec<String>,
    pub eval_metric_names: Vec<String>,
    pub load_shape: (usize, usize),
    pub batch_shape: (usize, usize),
    pub default_loss_weights: Vec<f32>,
    pub param_count: usize,
}

fn leaf_specs(j: &Json) -> Result<Vec<LeafSpec>> {
    let arr = j.as_arr().context("leaf specs: expected array")?;
    arr.iter()
        .map(|x| {
            Ok(LeafSpec {
                path: x.at("path").as_str().context("path")?.to_string(),
                shape: x.at("shape").as_usize_vec(),
                dtype: x.at("dtype").as_str().context("dtype")?.to_string(),
            })
        })
        .collect()
}

fn str_vec(j: &Json) -> Result<Vec<String>> {
    j.as_arr()
        .context("expected array of strings")?
        .iter()
        .map(|x| {
            Ok(x.as_str()
                .with_context(|| format!("non-string entry {x:?}"))?
                .to_string())
        })
        .collect()
}

impl ArtifactMeta {
    pub fn load(art_dir: &Path, name: &str) -> Result<Self> {
        let path = art_dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parse {}", path.display()))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let load_shape = j.at("load_shape").as_usize_vec();
        let batch_shape = j.at("batch_shape").as_usize_vec();
        if load_shape.len() != 2 || batch_shape.len() != 2 {
            bail!("malformed shape fields in meta");
        }
        let meta = ArtifactMeta {
            name: j.at("name").as_str().context("name")?.to_string(),
            config: ModelConfig::from_json(j.at("config"))?,
            n_params: j.at("n_params").as_usize().context("n_params")?,
            n_state: j.at("n_state").as_usize().context("n_state")?,
            params: leaf_specs(j.at("params")).context("params")?,
            router_params: leaf_specs(j.at("router_params"))
                .context("router_params")?,
            metric_names: str_vec(j.at("metric_names"))
                .context("metric_names")?,
            eval_metric_names: str_vec(j.at("eval_metric_names"))
                .context("eval_metric_names")?,
            load_shape: (load_shape[0], load_shape[1]),
            batch_shape: (batch_shape[0], batch_shape[1]),
            default_loss_weights: j
                .at("default_loss_weights")
                .as_f32_flat(),
            param_count: j.at("param_count").as_usize().context("param_count")?,
        };
        if meta.n_state != 3 * meta.n_params {
            bail!("meta invariant broken: n_state != 3*n_params");
        }
        if meta.params.len() != meta.n_params {
            bail!("meta invariant broken: params list length");
        }
        Ok(meta)
    }

    /// Index of a metric in the train-step metrics vector. An unknown
    /// name is a recoverable contract mismatch (stale artifacts vs a
    /// newer binary), not a programmer error — so `Err`, not a panic,
    /// like the rest of this parser.
    pub fn metric_idx(&self, name: &str) -> Result<usize> {
        self.metric_names.iter().position(|m| m == name).with_context(
            || {
                format!(
                    "metric '{name}' not in artifact '{}' (has: {})",
                    self.name,
                    self.metric_names.join(", ")
                )
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_json() -> Json {
        Json::parse(
            r#"{
          "name": "t", "n_params": 2, "n_state": 6,
          "config": {"name":"t","arch":"qwen3","router":"lpr",
            "metric":"cosine","vocab":64,"d_model":32,"n_layers":1,
            "n_experts":8,"top_k":2,"latent_dim":8,"total_steps":10,
            "batch_size":2,"seq_len":8,"capacity_factor":1.5,
            "unit_ball":true,"hypersphere_init":true,
            "gaussian_sigma":1.0},
          "params": [
            {"path":"['embed']","shape":[64,32],"dtype":"float32"},
            {"path":"['final_norm']","shape":[32],"dtype":"float32"}],
          "router_params": [
            {"path":"['proto_mu']","shape":[8,8],"dtype":"float32"}],
          "metric_names": ["loss","lr"],
          "eval_metric_names": ["loss","drop_frac"],
          "load_shape": [1,8], "batch_shape": [2,8],
          "default_loss_weights": [0.01,1,0.1,0.01,0.001,0.001,0,0],
          "param_count": 2080
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_meta() {
        let m = ArtifactMeta::from_json(&meta_json()).unwrap();
        assert_eq!(m.n_params, 2);
        assert_eq!(m.params[0].numel(), 64 * 32);
        assert_eq!(m.load_shape, (1, 8));
        assert_eq!(m.config.n_experts, 8);
        assert_eq!(m.metric_idx("lr").unwrap(), 1);
        assert_eq!(m.default_loss_weights.len(), 8);
    }

    #[test]
    fn rejects_broken_invariants() {
        let mut j = meta_json();
        if let Json::Obj(m) = &mut j {
            m.insert("n_state".into(), Json::Num(5.0));
        }
        assert!(ArtifactMeta::from_json(&j).is_err());
    }

    /// Satellite regression: an unknown metric name and malformed
    /// metric-name arrays surface as `Err` with the offending field
    /// named — the old code panicked (`expect`/`unwrap_or_else`) on
    /// both, turning a stale-artifact mismatch into an abort.
    #[test]
    fn malformed_meta_is_an_error_not_a_panic() {
        let m = ArtifactMeta::from_json(&meta_json()).unwrap();
        let err = m.metric_idx("no-such-metric").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no-such-metric"), "{msg}");
        assert!(msg.contains("loss"), "should list known names: {msg}");

        // metric_names with a non-string entry
        let mut j = meta_json();
        if let Json::Obj(obj) = &mut j {
            obj.insert(
                "metric_names".into(),
                Json::Arr(vec![Json::Str("loss".into()), Json::Num(3.0)]),
            );
        }
        let err = ArtifactMeta::from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("metric_names"));

        // metric_names that is not an array at all
        let mut j = meta_json();
        if let Json::Obj(obj) = &mut j {
            obj.insert("eval_metric_names".into(), Json::Num(1.0));
        }
        let err = ArtifactMeta::from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("eval_metric_names"));
    }
}
