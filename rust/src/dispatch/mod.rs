//! Expert-parallel dispatch — the paper's "hardware-software mismatch"
//! claim (§1: imbalance causes "GPU memory fragmentation and pipeline
//! stalls, increasing end-to-end latency") made measurable, and (since
//! PR 2) made *runnable*: routed batches compile into capacity-binned
//! [`DispatchPlan`]s (see [`plan`]) that both the latency model here and
//! the real expert FFN compute (`experts` + `ServingEngine::
//! forward_full`) consume — so simulated accounting and actual compute
//! agree by construction.
//!
//! Model: `E` experts sharded over `G` devices — round-robin by
//! default, or planned by a [`placement`] policy (LPT bin-packing by
//! measured load, hot-expert replication, periodic live migration with
//! a transfer cost charged to step latency). Each serving
//! step, a batch of routed tokens is dispatched; every expert has a
//! capacity of `cf * fair_share` token slots per step. Over-capacity
//! tokens are handled by the step's [`OverflowPolicy`] (greedy drop,
//! next-choice fall-through, or least-loaded reroute). A device's step
//! time is `alpha + beta * tokens_on_device` (fixed kernel-launch
//! overhead + linear expert FLOPs); the *batch* completes when the
//! slowest device finishes — so imbalance translates directly into
//! pipeline stall time on every other device.
//!
//! Reported: throughput, per-step latency (mean/p50/p99, nearest-rank
//! percentiles), drop & reroute fractions, device utilization, stall
//! fraction, and both cumulative and windowed (rolling
//! [`LoadTracker`]) balance metrics.

pub mod placement;
pub mod plan;

pub use placement::{
    migration_bytes, ExpertPlacement, ParsePlacementError,
    PlacementConfig, PlacementPolicy,
};
pub use plan::{
    capacity_for, DispatchPlan, OverflowPolicy, ParsePolicyError, DROPPED,
};

use crate::data::MixtureStream;
use crate::engine::EngineBuildError;
use crate::metrics::{
    gini, min_max_ratio, percentile_nearest_rank, LayerBalance,
    LayerLoadTracker, LoadTracker,
};
use crate::router::{FullForward, RouterBatch};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub n_experts: usize,
    pub n_devices: usize,
    pub top_k: usize,
    /// Expert capacity factor per step (1.0 = exact fair share).
    pub capacity_factor: f64,
    /// Fixed per-device per-step overhead, microseconds.
    pub alpha_us: f64,
    /// Per-token expert compute cost, microseconds.
    pub beta_us: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_experts: 64,
            n_devices: 8,
            top_k: 8,
            capacity_factor: 1.25,
            alpha_us: 50.0,
            beta_us: 0.5,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub steps: usize,
    pub tokens_routed: usize,
    pub tokens_dropped: usize,
    /// Tokens kept on a different expert than routed (policy fallback).
    pub tokens_rerouted: usize,
    pub drop_frac: f64,
    pub reroute_frac: f64,
    pub throughput_tok_per_s: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    /// busy device-time / total device-time (1.0 = no stalls).
    pub utilization: f64,
    /// Mean fraction of each step the average device idles waiting for
    /// the straggler.
    pub stall_frac: f64,
    /// Cumulative (whole-run) balance of the *routed* load.
    pub load_gini: f64,
    pub load_min_max: f64,
    /// Rolling balance over the last [`DispatchSim::LOAD_WINDOW`] steps.
    pub window_gini: f64,
    pub window_min_max: f64,
    pub window_cv: f64,
    /// Active placement policy name (`roundrobin` unless
    /// [`DispatchSim::set_placement`] engaged a planner).
    pub placement: &'static str,
    /// Placement re-plans adopted during the run (live migrations).
    pub replans: usize,
    /// Total expert-weight bytes moved by adopted re-plans.
    pub migrated_bytes: u64,
    /// Total transfer time charged to step latency, microseconds.
    pub migration_us: f64,
    /// Layer-resolved rolling balance (`[L, E]` tracking) for layered
    /// sims ([`DispatchSim::new_layered`] + [`DispatchSim::step_model`]);
    /// empty for single-layer sims. The flat `window_*` fields then
    /// cover the load summed over layers.
    pub layers: Vec<LayerBalance>,
}

/// A stream of per-step routing decisions: each step is a flat `[N·k]`
/// vector of expert assignments, one entry per (token, k-slot).
pub struct DispatchSim {
    pub cfg: SimConfig,
    /// Active expert→device assignment. Starts round-robin (the
    /// oracle); a non-default [`PlacementConfig`] re-plans it between
    /// windows from measured load ([`Self::set_placement`]).
    placement: ExpertPlacement,
    placement_cfg: PlacementConfig,
    /// Rolling window of post-policy *executed* counts — the signal
    /// the placement planner bin-packs on (what devices actually ran,
    /// not what the router asked for).
    computed: LoadTracker,
    replans: usize,
    migrated_bytes: u64,
    migration_us: f64,
    /// Cumulative per-expert *routed* load (pre-policy; dropped tokens
    /// count — this is what the router asked for).
    pub expert_load: Vec<f64>,
    /// Rolling routed-load window shared with the report.
    pub tracker: LoadTracker,
    /// Layer-resolved rolling windows, present on layered sims
    /// ([`DispatchSim::new_layered`]).
    layer_tracker: Option<LayerLoadTracker>,
    latencies_us: Vec<f64>,
    busy_us: f64,
    wall_us: f64,
    tokens_routed: usize,
    tokens_dropped: usize,
    tokens_rerouted: usize,
    steps: usize,
}

impl DispatchSim {
    /// Steps covered by the rolling balance window in [`SimReport`].
    pub const LOAD_WINDOW: usize = crate::metrics::DEFAULT_LOAD_WINDOW;

    /// Errors (typed, surfaced through the builder and CLI rather than
    /// panicking) when the device count exceeds the expert count —
    /// expert-parallel placement needs at least one expert per device.
    pub fn new(cfg: SimConfig) -> Result<Self, EngineBuildError> {
        if cfg.n_experts < cfg.n_devices {
            return Err(EngineBuildError::DevicesExceedExperts {
                n_experts: cfg.n_experts,
                n_devices: cfg.n_devices,
            });
        }
        Ok(DispatchSim {
            // Round-robin expert placement (standard expert
            // parallelism) until a planner is engaged.
            placement: ExpertPlacement::round_robin(
                cfg.n_experts,
                cfg.n_devices,
            ),
            placement_cfg: PlacementConfig::default(),
            computed: LoadTracker::new(Self::LOAD_WINDOW, cfg.n_experts),
            replans: 0,
            migrated_bytes: 0,
            migration_us: 0.0,
            expert_load: vec![0.0; cfg.n_experts],
            tracker: LoadTracker::new(Self::LOAD_WINDOW, cfg.n_experts),
            layer_tracker: None,
            latencies_us: Vec::new(),
            busy_us: 0.0,
            wall_us: 0.0,
            tokens_routed: 0,
            tokens_dropped: 0,
            tokens_rerouted: 0,
            steps: 0,
            cfg,
        })
    }

    /// A sim that additionally resolves balance **per layer** of an
    /// `n_layers` model stack: [`Self::step_model`] accounts one
    /// stacked serving step (every layer's dispatch plan), the rolling
    /// `[L, E]` windows land in [`SimReport::layers`], and the flat
    /// fields cover the load summed over layers. Every layer must share
    /// this config's expert count (the bridge-built stacks do).
    pub fn new_layered(
        cfg: SimConfig,
        n_layers: usize,
    ) -> Result<Self, EngineBuildError> {
        let n_experts = cfg.n_experts;
        let mut sim = DispatchSim::new(cfg)?;
        sim.layer_tracker = Some(LayerLoadTracker::new(
            n_layers,
            Self::LOAD_WINDOW,
            n_experts,
        ));
        Ok(sim)
    }

    /// Engage a placement planner: the sim keeps serving on the
    /// round-robin oracle until the first re-plan boundary
    /// (`cfg.replan_every` steps), then periodically bin-packs experts
    /// onto devices from the measured executed-load window, charging
    /// each adopted migration's transfer time to that step's latency.
    /// A [`PlacementPolicy::RoundRobin`] config is a no-op — every
    /// pre-placement pinned number is reproduced exactly.
    pub fn set_placement(&mut self, cfg: PlacementConfig) {
        self.placement_cfg = cfg;
        self.placement = ExpertPlacement::round_robin(
            self.cfg.n_experts,
            self.cfg.n_devices,
        );
    }

    /// The currently active expert→device assignment.
    pub fn placement(&self) -> &ExpertPlacement {
        &self.placement
    }

    /// Re-plan the placement at window boundaries: plan from the
    /// per-step average of the executed-load window, then apply the
    /// **adoption guard** — the candidate is installed only when its
    /// projected straggler saving over the next re-plan interval
    /// (`beta_us · Δmakespan · replan_every`) exceeds the transfer
    /// cost (`bytes moved × us_per_byte`). Returns the microseconds of
    /// migration traffic to charge to the current step's latency.
    fn maybe_replan(&mut self) -> f64 {
        let pc = self.placement_cfg.clone();
        if pc.policy == PlacementPolicy::RoundRobin
            || pc.replan_every == 0
            || self.steps == 0
            || self.steps % pc.replan_every != 0
        {
            return 0.0;
        }
        let len = self.computed.len();
        if len == 0 {
            return 0.0;
        }
        let per_step: Vec<f64> = self
            .computed
            .windowed()
            .iter()
            .map(|&x| x as f64 / len as f64)
            .collect();
        let cand =
            ExpertPlacement::plan(&pc, &per_step, self.cfg.n_devices);
        if cand == self.placement {
            return 0.0;
        }
        let bytes =
            migration_bytes(&self.placement, &cand, pc.bytes_per_expert);
        let cost_us = bytes as f64 * pc.us_per_byte;
        let gain_us = self.cfg.beta_us
            * (self.placement.makespan_tokens(&per_step)
                - cand.makespan_tokens(&per_step));
        if gain_us * pc.replan_every as f64 <= cost_us {
            return 0.0;
        }
        self.replans += 1;
        self.migrated_bytes += bytes;
        self.migration_us += cost_us;
        self.placement = cand;
        cost_us
    }

    /// Account one **stacked** serving step from the per-layer plans of
    /// a model forward (`&model_forward.layers`). The latency model
    /// composes sequentially, matching the residual pipeline: each
    /// layer's step time is its straggler device (`alpha + beta ·
    /// tokens`), and the batch's latency is the **sum over layers** —
    /// layer ℓ+1 cannot start until ℓ's slowest device finishes, so one
    /// imbalanced layer stalls the whole stack. Requires
    /// [`Self::new_layered`] with a matching layer count.
    pub fn step_model(&mut self, layers: &[FullForward]) {
        let e = self.cfg.n_experts;
        {
            let lt = self
                .layer_tracker
                .as_ref()
                .expect("step_model needs DispatchSim::new_layered");
            assert_eq!(
                lt.n_layers(),
                layers.len(),
                "sim layer count mismatch"
            );
        }
        let mut step_latency = self.maybe_replan();
        let mut busy = 0.0f64;
        let mut routed_total = vec![0u32; e];
        let mut counts_total = vec![0u32; e];
        let (mut n_assign, mut dropped, mut rerouted) = (0usize, 0, 0);
        let mut per_device = vec![0u32; self.cfg.n_devices];
        for (l, ff) in layers.iter().enumerate() {
            let plan = &ff.plan;
            assert_eq!(
                plan.n_experts, e,
                "layer {l} expert count differs from the sim config"
            );
            let layer_assign = plan.n * plan.top_k;
            assert_eq!(
                plan.capacity,
                self.capacity(layer_assign),
                "layer {l} plan was binned with a different capacity rule"
            );
            self.placement.device_counts(
                &plan.counts,
                self.steps as u64,
                &mut per_device,
            );
            for (acc, &c) in counts_total.iter_mut().zip(&plan.counts) {
                *acc += c;
            }
            let mut layer_straggler = 0.0f64;
            for &t in &per_device {
                let time = self.cfg.alpha_us + self.cfg.beta_us * t as f64;
                layer_straggler = layer_straggler.max(time);
                busy += time;
            }
            step_latency += layer_straggler;
            self.layer_tracker
                .as_mut()
                .expect("layered")
                .push_counts(l, &plan.routed);
            for (acc, &r) in routed_total.iter_mut().zip(&plan.routed) {
                *acc += r;
            }
            n_assign += layer_assign;
            dropped += plan.n_dropped;
            rerouted += plan.n_rerouted;
        }
        for (load, &r) in self.expert_load.iter_mut().zip(&routed_total) {
            *load += r as f64;
        }
        self.tracker.push_counts(&routed_total);
        self.computed.push_counts(&counts_total);
        self.latencies_us.push(step_latency);
        self.busy_us += busy;
        self.wall_us += step_latency * self.cfg.n_devices as f64;
        self.tokens_routed += n_assign;
        self.tokens_dropped += dropped;
        self.tokens_rerouted += rerouted;
        self.steps += 1;
    }

    /// Per-expert capacity for a step routing `n_assignments` tokens
    /// (delegates to the shared [`capacity_for`], so the sim and the
    /// dispatch plans can never disagree on a bin size).
    pub fn capacity(&self, n_assignments: usize) -> usize {
        capacity_for(
            n_assignments,
            self.cfg.n_experts,
            self.cfg.capacity_factor,
        )
    }

    /// Shared accounting core: every step path (legacy greedy-drop,
    /// compiled plan, full expert-compute forward) lands here with
    /// post-policy `counts` and pre-policy `routed`, so the latency
    /// model and the drop/load bookkeeping are policy-agnostic.
    fn apply_step(
        &mut self,
        counts: &[u32],
        routed: &[u32],
        dropped: usize,
        rerouted: usize,
        n_assignments: usize,
    ) {
        // Re-plan (live migration) happens *between* steps, from the
        // window measured so far — before this step's load is pushed.
        let migration_us = self.maybe_replan();
        for (l, &r) in self.expert_load.iter_mut().zip(routed) {
            *l += r as f64;
        }
        self.tracker.push_counts(routed);
        self.computed.push_counts(counts);
        let mut per_device = vec![0u32; self.cfg.n_devices];
        self.placement.device_counts(
            counts,
            self.steps as u64,
            &mut per_device,
        );
        // Device time = alpha + beta * tokens; the step latency is the
        // straggler's time (plus any migration traffic this step
        // triggered); everyone else stalls for the difference.
        let times: Vec<f64> = per_device
            .iter()
            .map(|&t| self.cfg.alpha_us + self.cfg.beta_us * t as f64)
            .collect();
        let step_latency =
            times.iter().cloned().fold(0.0, f64::max) + migration_us;
        let busy: f64 = times.iter().sum();
        self.latencies_us.push(step_latency);
        self.busy_us += busy;
        self.wall_us += step_latency * self.cfg.n_devices as f64;
        self.tokens_routed += n_assignments;
        self.tokens_dropped += dropped;
        self.tokens_rerouted += rerouted;
        self.steps += 1;
    }

    /// Simulate one serving step given the routed expert id of every
    /// (token, slot) pair, with greedy in-order drop on overflow — the
    /// historical behavior, identical to an [`OverflowPolicy::Drop`]
    /// plan (pinned by `drop_plan_matches_sim_step_exactly`).
    pub fn step(&mut self, assignments: &[u32]) {
        let cap = self.capacity(assignments.len());
        let mut counts = vec![0u32; self.cfg.n_experts];
        let mut routed = vec![0u32; self.cfg.n_experts];
        let mut dropped = 0usize;
        for &e in assignments {
            let e = e as usize;
            routed[e] += 1;
            if (counts[e] as usize) < cap {
                counts[e] += 1;
            } else {
                dropped += 1; // over capacity: token falls to residual
            }
        }
        self.apply_step(&counts, &routed, dropped, 0, assignments.len());
    }

    /// Simulate one serving step directly from a routed batch: the flat
    /// `[N*k]` id layout of `RouterBatch` is exactly the per-(token,
    /// slot) assignment stream `step` consumes (greedy-drop policy).
    pub fn step_routed(&mut self, batch: &RouterBatch) {
        self.step(&batch.topk_idx);
    }

    /// Account one serving step from an already-compiled plan — the
    /// post-policy per-expert counts drive the latency model, so the
    /// sim agrees with whatever the plan's policy actually kept. The
    /// plan must have been binned with this sim's capacity rule.
    pub fn step_plan(&mut self, plan: &DispatchPlan) {
        assert_eq!(
            plan.n_experts, self.cfg.n_experts,
            "plan/sim expert count mismatch"
        );
        let n_assignments = plan.n * plan.top_k;
        assert_eq!(
            plan.capacity,
            self.capacity(n_assignments),
            "plan was binned with a different capacity rule"
        );
        self.apply_step(
            &plan.counts,
            &plan.routed,
            plan.n_dropped,
            plan.n_rerouted,
            n_assignments,
        );
    }

    /// Compile `batch` under `policy` (into the caller's reusable plan
    /// scratch) and account it — the one-call serving-step path.
    pub fn step_planned(
        &mut self,
        batch: &RouterBatch,
        policy: OverflowPolicy,
        plan: &mut DispatchPlan,
    ) {
        assert_eq!(batch.load.len(), self.cfg.n_experts);
        let cap = self.capacity(batch.topk_idx.len());
        plan.compile_batch(batch, cap, policy);
        self.step_plan(plan);
    }

    /// [`DispatchSim::step_planned`] for a raw assignment stream (the
    /// synthetic-skew drivers).
    pub fn step_assignments(
        &mut self,
        assignments: &[u32],
        top_k: usize,
        policy: OverflowPolicy,
        plan: &mut DispatchPlan,
    ) {
        let cap = self.capacity(assignments.len());
        plan.compile(
            assignments,
            top_k,
            self.cfg.n_experts,
            cap,
            policy,
        );
        self.step_plan(plan);
    }

    pub fn report(&self) -> SimReport {
        let mut lat = self.latencies_us.clone();
        lat.sort_by(f64::total_cmp);
        // Nearest-rank percentile (ceil) via the shared helper — the
        // previous `(len-1)·p` floor understated p99 for small step
        // counts (e.g. 10 steps gave the 9th-ranked latency, not the
        // max), and the serving runtime must report the same convention.
        let pct = |p: f64| percentile_nearest_rank(&lat, p);
        let total_lat: f64 = self.latencies_us.iter().sum();
        let load_f32: Vec<f32> =
            self.expert_load.iter().map(|&x| x as f32).collect();
        SimReport {
            steps: self.steps,
            tokens_routed: self.tokens_routed,
            tokens_dropped: self.tokens_dropped,
            tokens_rerouted: self.tokens_rerouted,
            drop_frac: self.tokens_dropped as f64
                / self.tokens_routed.max(1) as f64,
            reroute_frac: self.tokens_rerouted as f64
                / self.tokens_routed.max(1) as f64,
            throughput_tok_per_s: if total_lat > 0.0 {
                (self.tokens_routed - self.tokens_dropped) as f64
                    / (total_lat * 1e-6)
            } else {
                0.0
            },
            latency_mean_us: total_lat / self.steps.max(1) as f64,
            latency_p50_us: pct(0.5),
            latency_p99_us: pct(0.99),
            utilization: self.busy_us / self.wall_us.max(1e-9),
            stall_frac: 1.0 - self.busy_us / self.wall_us.max(1e-9),
            load_gini: gini(&load_f32),
            load_min_max: min_max_ratio(&load_f32),
            window_gini: self.tracker.gini(),
            window_min_max: self.tracker.min_max(),
            window_cv: self.tracker.cv(),
            placement: self.placement_cfg.policy.name(),
            replans: self.replans,
            migrated_bytes: self.migrated_bytes,
            migration_us: self.migration_us,
            layers: self
                .layer_tracker
                .as_ref()
                .map(|lt| lt.per_layer())
                .unwrap_or_default(),
        }
    }
}

/// Drive `steps` serving steps end-to-end with one shared protocol:
/// sample a fresh mixture batch, route it through the engine, compile
/// the routed batch into a dispatch plan under `policy`, account it in
/// the simulator. Returns total routing nanoseconds (for ns/token
/// accounting). This is the single implementation behind
/// `dispatch-sim --routed`, the `dispatch-routed` /
/// `dispatch-policies` reports, and `examples/serving_sim.rs` — change
/// the measurement protocol here, not per call site.
#[allow(clippy::too_many_arguments)]
pub fn run_routed_steps(
    engine: &mut dyn crate::engine::MoeEngine,
    mix: &MixtureStream,
    rng: &mut Rng,
    sim: &mut DispatchSim,
    steps: usize,
    tokens_per_step: usize,
    policy: OverflowPolicy,
) -> u128 {
    let mut h = Vec::new();
    let mut batch = RouterBatch::new();
    let mut plan = DispatchPlan::new();
    let mut route_ns = 0u128;
    for _ in 0..steps {
        mix.fill(rng, tokens_per_step, &mut h);
        let t0 = std::time::Instant::now();
        engine.route_into(&h, &mut batch);
        route_ns += t0.elapsed().as_nanos();
        sim.step_planned(&batch, policy, &mut plan);
    }
    route_ns
}

/// [`run_routed_steps`] with real expert compute: each step runs the
/// full route → plan → expert FFN → combine path through the engine
/// facade and accounts the resulting layer-0 plan in the simulator.
/// Returns total forward nanoseconds (routing + plan build + FFN +
/// combine). The engine's builder-time capacity factor / overflow
/// policy govern the forward; build the engine from
/// `sim.cfg.capacity_factor` — asserted here, so simulator accounting
/// and real compute cannot silently use different bin sizes.
pub fn run_full_steps(
    engine: &mut dyn crate::engine::MoeEngine,
    mix: &MixtureStream,
    rng: &mut Rng,
    sim: &mut DispatchSim,
    steps: usize,
    tokens_per_step: usize,
) -> u128 {
    assert!(
        (engine.capacity_factor() - sim.cfg.capacity_factor).abs() < 1e-12,
        "engine capacity factor {} != sim capacity factor {} — build \
         the engine from sim.cfg.capacity_factor so accounting matches \
         compute",
        engine.capacity_factor(),
        sim.cfg.capacity_factor
    );
    let mut h = Vec::new();
    let mut fwd_ns = 0u128;
    for _ in 0..steps {
        mix.fill(rng, tokens_per_step, &mut h);
        let t0 = std::time::Instant::now();
        engine.forward(&h, tokens_per_step);
        fwd_ns += t0.elapsed().as_nanos();
        sim.step_plan(&engine.last().layers[0].plan);
    }
    fwd_ns
}

/// Generate synthetic routing assignments whose expert distribution has
/// a target skew: `skew = 0` is uniform; larger skew concentrates load
/// on few experts (a convenient way to sweep Gini without training).
pub fn synthetic_assignments(
    rng: &mut Rng,
    n_tokens: usize,
    top_k: usize,
    n_experts: usize,
    skew: f64,
) -> Vec<u32> {
    // Zipf-like expert popularity with exponent `skew`.
    let weights: Vec<f64> = (1..=n_experts)
        .map(|r| 1.0 / (r as f64).powf(skew))
        .collect();
    let mut out = Vec::with_capacity(n_tokens * top_k);
    for _ in 0..n_tokens {
        // draw k distinct experts per token
        let mut chosen: Vec<usize> = Vec::with_capacity(top_k);
        let mut guard = 0;
        while chosen.len() < top_k && guard < 100 * top_k {
            let e = rng.categorical(&weights);
            if !chosen.contains(&e) {
                chosen.push(e);
            }
            guard += 1;
        }
        while chosen.len() < top_k {
            // pathological skew: fill with least-popular untaken experts
            for e in (0..n_experts).rev() {
                if !chosen.contains(&e) {
                    chosen.push(e);
                    break;
                }
            }
        }
        out.extend(chosen.iter().map(|&e| e as u32));
    }
    out
}

/// Convert a measured normalized load distribution (e.g. from a trained
/// run's LoadMatrix) into sampling weights for replayed dispatch.
pub fn assignments_from_load(
    rng: &mut Rng,
    load: &[f64],
    n_tokens: usize,
    top_k: usize,
) -> Vec<u32> {
    let mut out = Vec::with_capacity(n_tokens * top_k);
    for _ in 0..n_tokens {
        let mut chosen: Vec<usize> = Vec::with_capacity(top_k);
        let mut guard = 0;
        while chosen.len() < top_k && guard < 100 * top_k {
            let e = rng.categorical(load);
            if !chosen.contains(&e) {
                chosen.push(e);
            }
            guard += 1;
        }
        while chosen.len() < top_k {
            for e in 0..load.len() {
                if !chosen.contains(&e) {
                    chosen.push(e);
                    break;
                }
            }
        }
        out.extend(chosen.iter().map(|&e| e as u32));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(skew: f64, cf: f64) -> SimReport {
        let cfg = SimConfig {
            n_experts: 32,
            n_devices: 8,
            top_k: 4,
            capacity_factor: cf,
            alpha_us: 10.0,
            beta_us: 1.0,
        };
        let mut sim = DispatchSim::new(cfg).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let a = synthetic_assignments(&mut rng, 256, 4, 32, skew);
            sim.step(&a);
        }
        sim.report()
    }

    #[test]
    fn uniform_routing_is_efficient() {
        let r = run(0.0, 1.25);
        assert!(r.drop_frac < 0.05, "drop {}", r.drop_frac);
        assert!(r.utilization > 0.8, "util {}", r.utilization);
        assert!(r.load_gini < 0.15, "gini {}", r.load_gini);
    }

    #[test]
    fn skewed_routing_stalls_and_drops() {
        let bal = run(0.0, 1.25);
        let skew = run(1.5, 1.25);
        assert!(skew.load_gini > bal.load_gini + 0.3);
        assert!(skew.drop_frac > bal.drop_frac + 0.1);
        assert!(skew.utilization < bal.utilization);
        assert!(skew.throughput_tok_per_s < bal.throughput_tok_per_s);
        // window metrics track the cumulative story on a steady stream
        assert!(skew.window_gini > bal.window_gini + 0.3);
    }

    #[test]
    fn token_conservation() {
        let cfg = SimConfig::default();
        let mut sim = DispatchSim::new(cfg).unwrap();
        let mut rng = Rng::new(2);
        let a = synthetic_assignments(&mut rng, 100, 8, 64, 0.7);
        assert_eq!(a.len(), 800);
        sim.step(&a);
        let r = sim.report();
        assert_eq!(r.tokens_routed, 800);
        assert!(r.tokens_dropped <= 800);
        // expert_load counts every assignment exactly once
        let total: f64 = sim.expert_load.iter().sum();
        assert_eq!(total as usize, 800);
    }

    #[test]
    fn capacity_is_fair_share_times_cf() {
        let sim = DispatchSim::new(SimConfig {
            n_experts: 8,
            n_devices: 2,
            top_k: 1,
            capacity_factor: 1.5,
            alpha_us: 0.0,
            beta_us: 1.0,
        })
        .unwrap();
        assert_eq!(sim.capacity(80), 15); // 80/8 * 1.5
    }

    #[test]
    fn distinct_experts_per_token() {
        let mut rng = Rng::new(3);
        let a = synthetic_assignments(&mut rng, 50, 4, 16, 2.0);
        for chunk in a.chunks(4) {
            let mut set: Vec<u32> = chunk.to_vec();
            set.sort();
            set.dedup();
            assert_eq!(set.len(), 4, "duplicate expert in {chunk:?}");
        }
    }

    #[test]
    fn step_routed_consumes_flat_router_batches() {
        use crate::router::{synthetic_lpr_router, ServingEngine};
        let mut rng = Rng::new(5);
        let r = synthetic_lpr_router("cosine", &mut rng, 16, 8, 8, 2);
        let mut eng = ServingEngine::new(r.plan().clone(), 2);
        let h: Vec<f32> =
            (0..64 * 16).map(|_| rng.normal() as f32).collect();
        let batch = eng.route(&h);
        let cfg = SimConfig {
            n_experts: 8,
            n_devices: 2,
            top_k: 2,
            ..SimConfig::default()
        };
        let mut a = DispatchSim::new(cfg.clone()).unwrap();
        let mut b = DispatchSim::new(cfg).unwrap();
        a.step_routed(&batch);
        b.step(&batch.topk_idx);
        assert_eq!(a.report().tokens_routed, 64 * 2);
        assert_eq!(a.expert_load, b.expert_load);
    }

    /// Acceptance: an `OverflowPolicy::Drop` plan reproduces the legacy
    /// greedy-drop `step` accounting exactly — drops, routed load,
    /// latencies, the whole report.
    #[test]
    fn drop_plan_matches_sim_step_exactly() {
        let cfg = SimConfig {
            n_experts: 16,
            n_devices: 4,
            top_k: 4,
            capacity_factor: 1.0,
            alpha_us: 10.0,
            beta_us: 1.0,
        };
        let mut legacy = DispatchSim::new(cfg.clone()).unwrap();
        let mut planned = DispatchSim::new(cfg).unwrap();
        let mut rng = Rng::new(14);
        let mut plan = DispatchPlan::new();
        for _ in 0..20 {
            let a = synthetic_assignments(&mut rng, 128, 4, 16, 1.3);
            legacy.step(&a);
            planned.step_assignments(
                &a,
                4,
                OverflowPolicy::Drop,
                &mut plan,
            );
        }
        assert_eq!(legacy.expert_load, planned.expert_load);
        let (lr, pr) = (legacy.report(), planned.report());
        assert_eq!(lr.tokens_dropped, pr.tokens_dropped);
        assert_eq!(lr.tokens_routed, pr.tokens_routed);
        assert_eq!(lr.latency_p50_us, pr.latency_p50_us);
        assert_eq!(lr.latency_p99_us, pr.latency_p99_us);
        assert_eq!(lr.throughput_tok_per_s, pr.throughput_tok_per_s);
        assert_eq!(lr.utilization, pr.utilization);
        assert_eq!(lr.load_gini, pr.load_gini);
        assert_eq!(lr.window_gini, pr.window_gini);
        assert_eq!(pr.tokens_rerouted, 0);
    }

    /// A layered sim over one layer reproduces the flat `step_plan`
    /// accounting exactly, plus the per-layer window rows.
    #[test]
    fn single_layer_step_model_matches_step_plan() {
        let cfg = SimConfig {
            n_experts: 16,
            n_devices: 4,
            top_k: 4,
            capacity_factor: 1.0,
            alpha_us: 10.0,
            beta_us: 1.0,
        };
        let mut rng = Rng::new(14);
        let mut flat = DispatchSim::new(cfg.clone()).unwrap();
        let mut layered = DispatchSim::new_layered(cfg, 1).unwrap();
        let mut ff = FullForward::new();
        for _ in 0..10 {
            let a = synthetic_assignments(&mut rng, 128, 4, 16, 1.3);
            let cap = flat.capacity(a.len());
            let mut plan = DispatchPlan::new();
            plan.compile(&a, 4, 16, cap, OverflowPolicy::Drop);
            flat.step_plan(&plan);
            ff.plan.copy_from(&plan);
            layered.step_model(std::slice::from_ref(&ff));
        }
        let (fr, lr) = (flat.report(), layered.report());
        assert_eq!(fr.tokens_routed, lr.tokens_routed);
        assert_eq!(fr.tokens_dropped, lr.tokens_dropped);
        assert_eq!(fr.latency_p50_us, lr.latency_p50_us);
        assert_eq!(fr.latency_p99_us, lr.latency_p99_us);
        assert_eq!(fr.utilization, lr.utilization);
        assert_eq!(fr.load_gini, lr.load_gini);
        assert_eq!(fr.window_gini, lr.window_gini);
        assert!(fr.layers.is_empty());
        assert_eq!(lr.layers.len(), 1);
        assert_eq!(lr.layers[0].gini, fr.window_gini);
    }

    /// The stacked latency model composes sequentially: a two-layer
    /// step's latency is the sum of the layers' straggler times, and
    /// the per-layer windows keep the layers' balance separate.
    #[test]
    fn layered_step_sums_stragglers_and_splits_balance() {
        let cfg = SimConfig {
            n_experts: 4,
            n_devices: 2,
            top_k: 1,
            capacity_factor: 1e9, // never drop
            alpha_us: 0.0,
            beta_us: 1.0,
        };
        let mut sim = DispatchSim::new_layered(cfg, 2).unwrap();
        // layer 0 balanced over experts {0..3}; layer 1 collapsed on 0
        let (mut f0, mut f1) = (FullForward::new(), FullForward::new());
        let a0: Vec<u32> = vec![0, 1, 2, 3, 0, 1, 2, 3];
        let a1: Vec<u32> = vec![0; 8];
        let cap = sim.capacity(8);
        f0.plan.compile(&a0, 1, 4, cap, OverflowPolicy::Drop);
        f1.plan.compile(&a1, 1, 4, cap, OverflowPolicy::Drop);
        sim.step_model(&[f0, f1]);
        let r = sim.report();
        // layer 0: devices {0,1} get 4 tokens each -> straggler 4;
        // layer 1: device 0 gets all 8 -> straggler 8; total 12
        assert_eq!(r.latency_p50_us, 12.0);
        assert_eq!(r.tokens_routed, 16);
        assert_eq!(r.layers.len(), 2);
        assert!(r.layers[0].gini.abs() < 1e-9, "{:?}", r.layers[0]);
        assert!((r.layers[1].gini - 0.75).abs() < 1e-9);
        // flat window covers the sum over layers
        assert_eq!(sim.tracker.windowed(), vec![10.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn rerouting_policies_reduce_drops_in_sim() {
        let mut rng = Rng::new(6);
        let a = synthetic_assignments(&mut rng, 512, 4, 16, 1.4);
        let cfg = SimConfig {
            n_experts: 16,
            n_devices: 4,
            top_k: 4,
            capacity_factor: 1.0,
            ..SimConfig::default()
        };
        let mut drops = Vec::new();
        for policy in OverflowPolicy::ALL {
            let mut sim = DispatchSim::new(cfg.clone()).unwrap();
            let mut plan = DispatchPlan::new();
            sim.step_assignments(&a, 4, policy, &mut plan);
            let r = sim.report();
            assert_eq!(
                r.tokens_routed,
                r.tokens_dropped
                    + plan
                        .counts
                        .iter()
                        .map(|&c| c as usize)
                        .sum::<usize>()
            );
            drops.push(r.tokens_dropped);
        }
        assert!(drops[0] > 0, "skewed batch at cf=1.0 must drop");
        assert!(drops[1] < drops[0], "next-choice {drops:?}");
        assert!(drops[2] < drops[0], "least-loaded {drops:?}");
    }

    #[test]
    fn run_routed_steps_conserves_tokens() {
        use crate::data::MixtureStream;
        use crate::engine::{Backend, Engine};
        use crate::experts::ExpertBank;
        use crate::router::synthetic_lpr_router;
        let mut rng = Rng::new(8);
        let r = synthetic_lpr_router("dot", &mut rng, 16, 8, 8, 2);
        // routing-only study: the FFN stage never runs, so a 1-wide
        // placeholder bank satisfies the stack shape
        let bank = ExpertBank::new(&Rng::new(0), 8, 16, 1);
        let mut eng = Engine::builder()
            .layer(r.plan().clone(), bank)
            .backend(Backend::Scoped { threads: 2 })
            .build()
            .unwrap();
        let mix = MixtureStream::standard(&mut rng, 16);
        let mut sim = DispatchSim::new(SimConfig {
            n_experts: 8,
            n_devices: 2,
            top_k: 2,
            ..SimConfig::default()
        })
        .unwrap();
        run_routed_steps(
            &mut eng,
            &mix,
            &mut rng,
            &mut sim,
            3,
            32,
            OverflowPolicy::Drop,
        );
        let rep = sim.report();
        assert_eq!(rep.steps, 3);
        assert_eq!(rep.tokens_routed, 3 * 32 * 2);
    }

    #[test]
    fn run_full_steps_accounts_real_compute() {
        use crate::data::MixtureStream;
        use crate::engine::{Backend, Engine, MoeEngine};
        use crate::experts::ExpertBank;
        use crate::router::synthetic_lpr_router;
        let mut rng = Rng::new(19);
        let (d, e, k) = (16usize, 8usize, 2usize);
        let r = synthetic_lpr_router("cosine", &mut rng, d, 8, e, k);
        let bank = ExpertBank::new(&Rng::new(4), e, d, 16);
        let mix = MixtureStream::standard(&mut rng, d);
        let mut sim = DispatchSim::new(SimConfig {
            n_experts: e,
            n_devices: 2,
            top_k: k,
            capacity_factor: 1.0,
            ..SimConfig::default()
        })
        .unwrap();
        // the engine carries cf/policy; built from the sim's cf so the
        // two account the same bins
        let mut eng = Engine::builder()
            .layer(r.plan().clone(), bank)
            .backend(Backend::Pool { workers: 2 })
            .policy(OverflowPolicy::LeastLoaded)
            .capacity_factor(1.0)
            .build()
            .unwrap();
        run_full_steps(&mut eng, &mix, &mut rng, &mut sim, 4, 32);
        let rep = sim.report();
        assert_eq!(rep.steps, 4);
        assert_eq!(rep.tokens_routed, 4 * 32 * k);
        // the last step's combined output has one row per token
        assert_eq!(eng.last().layers[0].combined.len(), 32 * d);
    }

    #[test]
    fn replayed_load_matches_distribution() {
        let mut rng = Rng::new(4);
        // all mass on experts 0 and 1
        let load = vec![0.5, 0.5, 0.0, 0.0];
        let a = assignments_from_load(&mut rng, &load, 200, 1);
        assert!(a.iter().all(|&e| e < 2));
    }

    #[test]
    fn latency_percentiles_ordered() {
        let r = run(1.0, 1.25);
        assert!(r.latency_p50_us <= r.latency_p99_us + 1e-9);
        assert!(r.latency_mean_us > 0.0);
    }

    /// Satellite: more devices than experts is a typed
    /// [`EngineBuildError`], not a panic — and it threads through
    /// [`crate::Error`] with the builder-facing prefix.
    #[test]
    fn too_many_devices_is_a_typed_error() {
        let cfg = SimConfig {
            n_experts: 4,
            n_devices: 8,
            ..SimConfig::default()
        };
        let err = DispatchSim::new(cfg.clone()).unwrap_err();
        assert!(matches!(
            err,
            EngineBuildError::DevicesExceedExperts {
                n_experts: 4,
                n_devices: 8,
            }
        ));
        let msg = err.to_string();
        assert!(msg.contains("8 devices exceed 4 experts"), "{msg}");
        let top: crate::Error = err.into();
        assert!(
            top.to_string().starts_with("engine configuration:"),
            "{top}"
        );
        assert!(DispatchSim::new_layered(cfg, 2).is_err());
    }

    /// A [`PlacementPolicy::RoundRobin`] placement config is a no-op:
    /// every report field matches a sim that never touched the knob.
    #[test]
    fn round_robin_placement_config_is_a_noop() {
        let cfg = SimConfig {
            n_experts: 32,
            n_devices: 8,
            top_k: 4,
            capacity_factor: 1.25,
            alpha_us: 10.0,
            beta_us: 1.0,
        };
        let mut plain = DispatchSim::new(cfg.clone()).unwrap();
        let mut knobbed = DispatchSim::new(cfg).unwrap();
        knobbed.set_placement(PlacementConfig::default());
        let mut rng = Rng::new(11);
        for _ in 0..40 {
            let a = synthetic_assignments(&mut rng, 256, 4, 32, 1.2);
            plain.step(&a);
            knobbed.step(&a);
        }
        let (p, k) = (plain.report(), knobbed.report());
        assert_eq!(p.placement, "roundrobin");
        assert_eq!(k.placement, "roundrobin");
        assert_eq!(k.replans, 0);
        assert_eq!(k.migrated_bytes, 0);
        assert_eq!(p.latency_mean_us, k.latency_mean_us);
        assert_eq!(p.latency_p99_us, k.latency_p99_us);
        assert_eq!(p.stall_frac, k.stall_frac);
        assert_eq!(p.window_gini, k.window_gini);
        assert_eq!(p.tokens_dropped, k.tokens_dropped);
    }

    /// Live migration on a hand-computed schedule: E=4 over G=2
    /// (round-robin hosts e0,e2 on d0 and e1,e3 on d1), every step
    /// routes counts [10,1,1,1] with alpha=0, beta=1.
    ///
    /// Round-robin stragglers: d0 = 10+1 = 11 every step. At the first
    /// re-plan boundary (`replan_every = 2`, before step 3 executes)
    /// LPT plans {e0}→d0, {e1,e2,e3}→d1 — makespan 10, gain
    /// `beta·Δmakespan·replan_every` = 1·(11−10)·2 = 2 µs against a
    /// transfer of one expert (e2 to d1) = 100 bytes · 0.01 µs/B =
    /// 1 µs, so it adopts and charges 1 µs to step 3. Latencies:
    /// [11, 11, 10+1, 10, 10, 10] → mean 10.5. With `us_per_byte`
    /// raised to 10 the same move costs 1000 µs and the adoption guard
    /// keeps round-robin: nothing migrates, mean stays 11.
    #[test]
    fn migration_cost_is_charged_and_guarded() {
        let cfg = SimConfig {
            n_experts: 4,
            n_devices: 2,
            top_k: 1,
            capacity_factor: 1e9, // never drop
            alpha_us: 0.0,
            beta_us: 1.0,
        };
        let mut a: Vec<u32> = vec![0; 10];
        a.extend([1, 2, 3]);
        let run = |us_per_byte: f64| {
            let mut sim = DispatchSim::new(cfg.clone()).unwrap();
            sim.set_placement(PlacementConfig {
                policy: PlacementPolicy::LoadAware,
                replan_every: 2,
                bytes_per_expert: 100,
                us_per_byte,
                ..PlacementConfig::default()
            });
            for _ in 0..6 {
                sim.step(&a);
            }
            sim.report()
        };
        let adopted = run(0.01);
        assert_eq!(adopted.replans, 1);
        assert_eq!(adopted.migrated_bytes, 100);
        assert!((adopted.migration_us - 1.0).abs() < 1e-9);
        assert!(
            (adopted.latency_mean_us - 10.5).abs() < 1e-9,
            "{}",
            adopted.latency_mean_us
        );
        assert_eq!(adopted.placement, "loadaware");

        let guarded = run(10.0);
        assert_eq!(guarded.replans, 0);
        assert_eq!(guarded.migrated_bytes, 0);
        assert_eq!(guarded.migration_us, 0.0);
        assert!(
            (guarded.latency_mean_us - 11.0).abs() < 1e-9,
            "{}",
            guarded.latency_mean_us
        );
    }

    /// Acceptance (ISSUE): on a Zipf-skewed mixture routed end-to-end
    /// at E=64 / G=8, load-aware placement — and replication on top —
    /// strictly reduces both mean step latency and stall fraction
    /// versus round-robin, while routing/drop accounting stays
    /// identical (placement moves experts, never tokens).
    #[test]
    fn placement_beats_round_robin_on_skewed_mixture() {
        use crate::engine::{Backend, Engine};
        use crate::experts::ExpertBank;
        use crate::router::synthetic_lpr_router;
        let run = |pcfg: PlacementConfig| {
            let mut rng = Rng::new(23);
            let r =
                synthetic_lpr_router("cosine", &mut rng, 32, 16, 64, 8);
            // routing-only study: a 1-wide bank satisfies the shape
            let bank = ExpertBank::new(&Rng::new(0), 64, 32, 1);
            let mut eng = Engine::builder()
                .layer(r.plan().clone(), bank)
                .backend(Backend::Scoped { threads: 1 })
                .build()
                .unwrap();
            let mix = MixtureStream::skewed(&mut rng, 32, 1.6);
            let mut sim =
                DispatchSim::new(SimConfig::default()).unwrap();
            sim.set_placement(pcfg);
            run_routed_steps(
                &mut eng,
                &mix,
                &mut rng,
                &mut sim,
                48,
                512,
                OverflowPolicy::Drop,
            );
            sim.report()
        };
        let mk = |policy| PlacementConfig {
            policy,
            replan_every: 8,
            bytes_per_expert: 4096,
            us_per_byte: 1e-5,
            ..PlacementConfig::default()
        };
        let rr = run(mk(PlacementPolicy::RoundRobin));
        let la = run(mk(PlacementPolicy::LoadAware));
        let rep = run(mk(PlacementPolicy::Replicated));
        // identical routing: placement never changes what was routed
        for r in [&la, &rep] {
            assert_eq!(rr.tokens_routed, r.tokens_routed);
            assert_eq!(rr.tokens_dropped, r.tokens_dropped);
            assert_eq!(rr.window_gini, r.window_gini);
        }
        // live migration actually engaged for both planners
        assert!(la.replans >= 1, "{la:?}");
        assert!(rep.replans >= 1, "{rep:?}");
        assert!(la.migrated_bytes > 0);
        // the win: strictly lower straggler latency AND stall fraction
        assert!(
            la.latency_mean_us < rr.latency_mean_us,
            "loadaware {} !< roundrobin {}",
            la.latency_mean_us,
            rr.latency_mean_us
        );
        assert!(
            rep.latency_mean_us < rr.latency_mean_us,
            "replicated {} !< roundrobin {}",
            rep.latency_mean_us,
            rr.latency_mean_us
        );
        assert!(la.stall_frac < rr.stall_frac);
        assert!(rep.stall_frac < rr.stall_frac);
    }

    /// Satellite: nearest-rank percentiles on a known latency vector.
    /// The old floor-based rank gave p99 = 9 on this input.
    #[test]
    fn percentiles_are_nearest_rank() {
        let cfg = SimConfig {
            n_experts: 2,
            n_devices: 1,
            top_k: 1,
            capacity_factor: 1e9, // never drop
            alpha_us: 0.0,
            beta_us: 1.0,
        };
        let mut sim = DispatchSim::new(cfg).unwrap();
        // step i routes i+1 single-expert tokens -> latency i+1 us
        for i in 0..10usize {
            let a = vec![0u32; i + 1];
            sim.step(&a);
        }
        let r = sim.report();
        // nearest-rank over [1..10]: p50 = ceil(5)th = 5, p99 = 10
        assert_eq!(r.latency_p50_us, 5.0);
        assert_eq!(r.latency_p99_us, 10.0);
        assert_eq!(r.latency_mean_us, 5.5);
    }
}
