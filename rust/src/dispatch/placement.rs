//! Expert→device placement for the expert-parallel serving path.
//!
//! Standard expert parallelism shards experts round-robin over devices
//! (`e % G`), so any residual routing skew turns straight into
//! straggler time: the step finishes when the hottest device finishes.
//! This module makes the placement itself a planned quantity:
//!
//! - [`ExpertPlacement::round_robin`] — the historical oracle layout
//!   (and the default everywhere; every pinned number predating this
//!   module is unchanged under it).
//! - [`ExpertPlacement::load_aware`] — LPT (longest-processing-time)
//!   greedy bin-packing of experts onto devices by *measured* load:
//!   experts sorted by load descending land on the currently
//!   least-loaded device. Deterministic (ties break toward the lower
//!   expert/device id).
//! - [`ExpertPlacement::replicated`] — load-aware packing plus
//!   replication of the hottest experts: each hot expert is hosted on
//!   its primary device and the `r − 1` least-loaded other devices,
//!   with per-replica routing weights *water-filled* so the hosting
//!   devices' totals approach a common target.
//!
//! # Replica routing determinism
//!
//! When an expert has multiple replicas, the replica serving one
//! assignment is [`ExpertPlacement::replica_for`]`(token_slot, expert,
//! step)` — a pure function of those three values (a splitmix-style
//! hash mapped through the replica weights' cumulative distribution).
//! No scheduler state, queue depth, or thread timing is consulted, so
//! dispatch under replication stays deterministic, and because every
//! grouped row's FFN output depends only on its own input row and the
//! expert weights, *any* partition of rows across devices/workers
//! yields bit-identical combined outputs — the thread-count/backend
//! contract survives replication untouched.
//!
//! # Live migration cost model
//!
//! Re-planning between windows moves expert weights between devices.
//! [`migration_bytes`] charges one `bytes_per_expert` payload for every
//! (expert, device) pair that the new placement hosts and the old one
//! did not; the simulator converts bytes to microseconds
//! (`us_per_byte`) and adds the transfer to that step's latency — so a
//! placement that churns pays for it where it hurts, in step latency.
//! `DispatchSim` additionally applies an adoption guard: a candidate
//! placement is only installed when its projected straggler saving over
//! the next re-plan interval exceeds the transfer cost.

/// Which placement planner the simulator / pool should run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// `e % G` — standard expert parallelism; never re-plans.
    #[default]
    RoundRobin,
    /// LPT bin-packing by measured per-window load.
    LoadAware,
    /// LPT plus weighted replication of the hottest experts.
    Replicated,
}

/// Error of `PlacementPolicy::from_str`: carries the rejected name and
/// renders the accepted set (mirrors
/// [`crate::dispatch::ParsePolicyError`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParsePlacementError(pub String);

impl std::fmt::Display for ParsePlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown placement policy '{}' (expected ", self.0)?;
        for (i, p) in PlacementPolicy::ALL.iter().enumerate() {
            if i > 0 {
                write!(f, " | ")?;
            }
            write!(f, "{}", p.name())?;
        }
        write!(f, ")")
    }
}

impl std::error::Error for ParsePlacementError {}

impl std::str::FromStr for PlacementPolicy {
    type Err = ParsePlacementError;

    fn from_str(s: &str) -> Result<PlacementPolicy, ParsePlacementError> {
        PlacementPolicy::parse(s)
            .ok_or_else(|| ParsePlacementError(s.into()))
    }
}

impl PlacementPolicy {
    pub const ALL: [PlacementPolicy; 3] = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::LoadAware,
        PlacementPolicy::Replicated,
    ];

    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        Some(match s {
            "roundrobin" | "round-robin" | "rr" => {
                PlacementPolicy::RoundRobin
            }
            "loadaware" | "load-aware" => PlacementPolicy::LoadAware,
            "replicated" | "repl" => PlacementPolicy::Replicated,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "roundrobin",
            PlacementPolicy::LoadAware => "loadaware",
            PlacementPolicy::Replicated => "replicated",
        }
    }
}

/// Placement knob carried by `Engine::builder().placement(..)` and
/// `DispatchSim::set_placement`: the planner to run plus its re-plan
/// cadence and transfer-cost model.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementConfig {
    pub policy: PlacementPolicy,
    /// How many of the hottest experts [`PlacementPolicy::Replicated`]
    /// replicates.
    pub hot_experts: usize,
    /// Replicas per hot expert (clamped to the device count, min 2).
    pub replicas: usize,
    /// Steps between re-plans in the simulator (0 = never re-plan).
    pub replan_every: usize,
    /// Weight payload one expert moves in a migration, bytes.
    pub bytes_per_expert: usize,
    /// Transfer cost charged to step latency, microseconds per byte.
    pub us_per_byte: f64,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig {
            policy: PlacementPolicy::RoundRobin,
            hot_experts: 4,
            replicas: 2,
            replan_every: 16,
            // 64 KiB of expert weights over a ~100 GB/s interconnect.
            bytes_per_expert: 1 << 16,
            us_per_byte: 1e-5,
        }
    }
}

impl PlacementConfig {
    /// Convenience constructor: default knobs under `policy`.
    pub fn with_policy(policy: PlacementPolicy) -> Self {
        PlacementConfig { policy, ..PlacementConfig::default() }
    }
}

/// A concrete expert→device assignment: for every expert, the (sorted)
/// list of hosting devices and the normalized routing weight of each
/// replica. Unreplicated experts have exactly one host with weight 1.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertPlacement {
    n_devices: usize,
    /// `[E]` hosting-device lists, each sorted ascending, `len >= 1`.
    replicas: Vec<Vec<usize>>,
    /// `[E]` per-replica routing weights (same shape as `replicas`;
    /// each list sums to 1).
    weights: Vec<Vec<f64>>,
}

/// splitmix64-style avalanche — the deterministic replica hash.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^ (x >> 33)
}

impl ExpertPlacement {
    /// The standard expert-parallel layout: expert `e` on device
    /// `e % n_devices`.
    pub fn round_robin(n_experts: usize, n_devices: usize) -> Self {
        ExpertPlacement {
            n_devices,
            replicas: (0..n_experts).map(|e| vec![e % n_devices]).collect(),
            weights: vec![vec![1.0]; n_experts],
        }
    }

    /// LPT greedy bin-packing: experts in descending load order, each
    /// onto the currently least-loaded device (ties → lower id).
    pub fn load_aware(load: &[f64], n_devices: usize) -> Self {
        let n_experts = load.len();
        let mut order: Vec<usize> = (0..n_experts).collect();
        order.sort_by(|&a, &b| {
            load[b].total_cmp(&load[a]).then(a.cmp(&b))
        });
        let mut dev_load = vec![0.0f64; n_devices];
        let mut replicas = vec![Vec::new(); n_experts];
        for &e in &order {
            let d = (0..n_devices)
                .min_by(|&a, &b| {
                    dev_load[a].total_cmp(&dev_load[b]).then(a.cmp(&b))
                })
                .expect("n_devices >= 1");
            dev_load[d] += load[e];
            replicas[e] = vec![d];
        }
        ExpertPlacement {
            n_devices,
            replicas,
            weights: vec![vec![1.0]; n_experts],
        }
    }

    /// [`Self::load_aware`] plus replication of the `hot_experts`
    /// hottest experts across `replicas` devices each (primary host +
    /// the least-loaded others), with water-filled routing weights:
    /// each replica's share is proportional to the gap between its
    /// device's load and the hosts' common target, so the hosting
    /// devices finish together.
    pub fn replicated(
        load: &[f64],
        n_devices: usize,
        hot_experts: usize,
        replicas: usize,
    ) -> Self {
        let mut p = Self::load_aware(load, n_devices);
        if n_devices < 2 || hot_experts == 0 {
            return p;
        }
        let r = replicas.max(2).min(n_devices);
        let mut dev_load = vec![0.0f64; n_devices];
        for (e, &l) in load.iter().enumerate() {
            dev_load[p.replicas[e][0]] += l;
        }
        let mut order: Vec<usize> = (0..load.len()).collect();
        order.sort_by(|&a, &b| {
            load[b].total_cmp(&load[a]).then(a.cmp(&b))
        });
        for &e in order.iter().take(hot_experts.min(load.len())) {
            if load[e] <= 0.0 {
                break; // nothing to split
            }
            let primary = p.replicas[e][0];
            dev_load[primary] -= load[e];
            let mut others: Vec<usize> =
                (0..n_devices).filter(|&d| d != primary).collect();
            others.sort_by(|&a, &b| {
                dev_load[a].total_cmp(&dev_load[b]).then(a.cmp(&b))
            });
            let mut hosts = vec![primary];
            hosts.extend(others.into_iter().take(r - 1));
            // water-fill: weight each host by its gap to the common
            // target load, clamp negatives (hosts already past the
            // target take no share), renormalize
            let base: Vec<f64> =
                hosts.iter().map(|&d| dev_load[d]).collect();
            let target = (base.iter().sum::<f64>() + load[e])
                / hosts.len() as f64;
            let mut w: Vec<f64> =
                base.iter().map(|&b| (target - b).max(0.0)).collect();
            let total: f64 = w.iter().sum();
            if total > 0.0 {
                for x in w.iter_mut() {
                    *x /= total;
                }
            } else {
                w = vec![1.0 / hosts.len() as f64; hosts.len()];
            }
            let mut pairs: Vec<(usize, f64)> = hosts
                .into_iter()
                .zip(w)
                .filter(|&(_, wi)| wi > 1e-12)
                .collect();
            if pairs.is_empty() {
                pairs.push((primary, 1.0));
            }
            let kept: f64 = pairs.iter().map(|&(_, wi)| wi).sum();
            for (_, wi) in pairs.iter_mut() {
                *wi /= kept;
            }
            pairs.sort_by_key(|&(d, _)| d);
            for &(d, wi) in &pairs {
                dev_load[d] += wi * load[e];
            }
            p.replicas[e] = pairs.iter().map(|&(d, _)| d).collect();
            p.weights[e] = pairs.iter().map(|&(_, wi)| wi).collect();
        }
        p
    }

    /// Run the planner selected by `cfg.policy` on a measured load
    /// vector.
    pub fn plan(
        cfg: &PlacementConfig,
        load: &[f64],
        n_devices: usize,
    ) -> Self {
        match cfg.policy {
            PlacementPolicy::RoundRobin => {
                Self::round_robin(load.len(), n_devices)
            }
            PlacementPolicy::LoadAware => {
                Self::load_aware(load, n_devices)
            }
            PlacementPolicy::Replicated => Self::replicated(
                load,
                n_devices,
                cfg.hot_experts,
                cfg.replicas,
            ),
        }
    }

    pub fn n_experts(&self) -> usize {
        self.replicas.len()
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// The lowest-id device hosting expert `e`.
    pub fn device_of(&self, e: usize) -> usize {
        self.replicas[e][0]
    }

    /// All devices hosting expert `e` (sorted ascending).
    pub fn replicas_of(&self, e: usize) -> &[usize] {
        &self.replicas[e]
    }

    /// Normalized routing weights matching [`Self::replicas_of`].
    pub fn weights_of(&self, e: usize) -> &[f64] {
        &self.weights[e]
    }

    /// The device serving assignment `(token_slot, expert)` at `step` —
    /// a **pure function** of its arguments (plus this placement), so
    /// replica routing is deterministic and independent of thread
    /// count, backend, and scheduler timing. The hash value is mapped
    /// through the replica weights' cumulative distribution, so over
    /// many slots each replica serves its weight's share of the load.
    pub fn replica_for(
        &self,
        token_slot: usize,
        expert: usize,
        step: u64,
    ) -> usize {
        let reps = &self.replicas[expert];
        if reps.len() == 1 {
            return reps[0];
        }
        let h = mix64(
            (token_slot as u64)
                ^ (expert as u64).rotate_left(21)
                ^ step.rotate_left(42),
        );
        // 53 uniform mantissa bits -> u in [0, 1)
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        let ws = &self.weights[expert];
        let mut acc = 0.0f64;
        for (i, &w) in ws.iter().enumerate() {
            acc += w;
            if u < acc {
                return reps[i];
            }
        }
        reps[reps.len() - 1]
    }

    /// Split post-policy per-expert token counts over devices into
    /// `per_device` (cleared first). Single-host experts contribute
    /// their whole count to their host; replicated experts assign each
    /// of their `cnt` token slots through [`Self::replica_for`]`(slot,
    /// e, step)` — deterministic, and conserving `sum(counts)` exactly.
    pub fn device_counts(
        &self,
        counts: &[u32],
        step: u64,
        per_device: &mut [u32],
    ) {
        assert_eq!(counts.len(), self.n_experts());
        assert_eq!(per_device.len(), self.n_devices);
        per_device.fill(0);
        for (e, &cnt) in counts.iter().enumerate() {
            let reps = &self.replicas[e];
            if reps.len() == 1 {
                per_device[reps[0]] += cnt;
            } else {
                for slot in 0..cnt as usize {
                    per_device[self.replica_for(slot, e, step)] += 1;
                }
            }
        }
    }

    /// Projected straggler load: the max over devices of the weighted
    /// expert load assigned to it (in `load`'s unit — tokens per step
    /// when fed a per-step average window). The simulator's adoption
    /// guard converts this to microseconds via its `beta_us`.
    pub fn makespan_tokens(&self, load: &[f64]) -> f64 {
        let mut dev = vec![0.0f64; self.n_devices];
        for (e, &l) in load.iter().enumerate() {
            for (ri, &d) in self.replicas[e].iter().enumerate() {
                dev[d] += self.weights[e][ri] * l;
            }
        }
        dev.iter().cloned().fold(0.0, f64::max)
    }
}

/// Transfer volume of switching `old` → `new`: one `bytes_per_expert`
/// payload for every (expert, device) pair hosted by `new` but not by
/// `old`. Dropping a replica is free (no data moves); weight-only
/// changes on an existing host are free too.
pub fn migration_bytes(
    old: &ExpertPlacement,
    new: &ExpertPlacement,
    bytes_per_expert: usize,
) -> u64 {
    assert_eq!(
        old.n_experts(),
        new.n_experts(),
        "placements cover different expert counts"
    );
    let mut moved = 0u64;
    for e in 0..new.n_experts() {
        for d in new.replicas_of(e) {
            if !old.replicas_of(e).contains(d) {
                moved += 1;
            }
        }
    }
    moved * bytes_per_expert as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn policy_parse_roundtrips() {
        for p in PlacementPolicy::ALL {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(
            PlacementPolicy::parse("rr"),
            Some(PlacementPolicy::RoundRobin)
        );
        assert_eq!(PlacementPolicy::parse("nope"), None);
        let err = "nope".parse::<PlacementPolicy>().unwrap_err();
        assert!(err.to_string().contains("roundrobin"), "{err}");
        assert!(err.to_string().contains("replicated"), "{err}");
    }

    #[test]
    fn round_robin_matches_modulo_layout() {
        let p = ExpertPlacement::round_robin(10, 4);
        for e in 0..10 {
            assert_eq!(p.replicas_of(e), &[e % 4]);
            assert_eq!(p.weights_of(e), &[1.0]);
            assert_eq!(p.replica_for(7, e, 3), e % 4);
        }
    }

    /// Hand-computed LPT: loads [10, 1, 1, 1] on 2 devices isolate the
    /// hot expert; round-robin pairs it with another expert.
    #[test]
    fn lpt_isolates_the_hot_expert() {
        let load = [10.0, 1.0, 1.0, 1.0];
        let p = ExpertPlacement::load_aware(&load, 2);
        assert_eq!(p.replicas_of(0), &[0]);
        assert_eq!(p.replicas_of(1), &[1]);
        assert_eq!(p.replicas_of(2), &[1]);
        assert_eq!(p.replicas_of(3), &[1]);
        assert_eq!(p.makespan_tokens(&load), 10.0);
        let rr = ExpertPlacement::round_robin(4, 2);
        assert_eq!(rr.makespan_tokens(&load), 11.0); // e0 + e2
    }

    /// Replication splits the hot expert across both devices with
    /// water-filled weights: device 1 already carries 3.0, so device 0
    /// takes (target − 0) = 6.5 of the 10.0 and device 1 takes 3.5.
    #[test]
    fn replication_water_fills_the_hot_expert() {
        let load = [10.0, 1.0, 1.0, 1.0];
        let p = ExpertPlacement::replicated(&load, 2, 1, 2);
        assert_eq!(p.replicas_of(0), &[0, 1]);
        let w = p.weights_of(0);
        assert!((w[0] - 0.65).abs() < 1e-12, "{w:?}");
        assert!((w[1] - 0.35).abs() < 1e-12, "{w:?}");
        // both devices land exactly on the target
        assert!((p.makespan_tokens(&load) - 6.5).abs() < 1e-12);
        // the cold experts stay single-host
        for e in 1..4 {
            assert_eq!(p.replicas_of(e).len(), 1);
        }
    }

    #[test]
    fn replica_for_is_deterministic_and_weight_respecting() {
        let load = [10.0, 1.0, 1.0, 1.0];
        let p = ExpertPlacement::replicated(&load, 2, 1, 2);
        let mut on0 = 0usize;
        for slot in 0..10_000 {
            let d = p.replica_for(slot, 0, 5);
            assert_eq!(d, p.replica_for(slot, 0, 5), "pure function");
            assert!(p.replicas_of(0).contains(&d));
            if d == 0 {
                on0 += 1;
            }
        }
        // weight 0.65 ± a few percent over 10k hashed slots
        let frac = on0 as f64 / 10_000.0;
        assert!((frac - 0.65).abs() < 0.03, "replica split {frac}");
        // a different step re-shuffles at least one slot
        assert!(
            (0..64).any(|s| p.replica_for(s, 0, 5) != p.replica_for(s, 0, 6)),
            "step must enter the hash"
        );
    }

    #[test]
    fn planner_weights_always_normalize_and_conserve() {
        forall(
            24,
            4242,
            |rng| {
                let e = 2 + rng.below(62);
                let g = (1 + rng.below(8)).min(e);
                let load: Vec<f64> =
                    (0..e).map(|_| rng.range_f64(0.0, 100.0)).collect();
                let hot = rng.below(6);
                let reps = 2 + rng.below(3);
                (load, g, hot, reps)
            },
            |(load, g, hot, reps)| {
                for cfg in [
                    PlacementConfig::with_policy(PlacementPolicy::RoundRobin),
                    PlacementConfig {
                        policy: PlacementPolicy::LoadAware,
                        ..PlacementConfig::default()
                    },
                    PlacementConfig {
                        policy: PlacementPolicy::Replicated,
                        hot_experts: *hot,
                        replicas: *reps,
                        ..PlacementConfig::default()
                    },
                ] {
                    let p = ExpertPlacement::plan(&cfg, load, *g);
                    for e in 0..load.len() {
                        let reps = p.replicas_of(e);
                        if reps.is_empty() {
                            return Err(format!("expert {e} unhosted"));
                        }
                        if reps.windows(2).any(|w| w[0] >= w[1]) {
                            return Err(format!(
                                "hosts of {e} not sorted: {reps:?}"
                            ));
                        }
                        if reps.iter().any(|&d| d >= *g) {
                            return Err("device out of range".into());
                        }
                        let sum: f64 = p.weights_of(e).iter().sum();
                        if (sum - 1.0).abs() > 1e-9 {
                            return Err(format!(
                                "weights of {e} sum to {sum}"
                            ));
                        }
                    }
                    // weighted makespan never exceeds putting
                    // everything on one device
                    let total: f64 = load.iter().sum();
                    if p.makespan_tokens(load) > total + 1e-9 {
                        return Err("makespan exceeds total".into());
                    }
                }
                Ok(())
            },
        );
    }

    /// Token conservation under replication: however the hashed
    /// replica choice lands, every token slot is served on exactly one
    /// device — the per-device counts sum back to the expert counts.
    #[test]
    fn device_counts_conserve_tokens_under_replication() {
        forall(
            32,
            777,
            |rng| {
                let e = 2 + rng.below(62);
                let g = (2 + rng.below(7)).min(e);
                let load: Vec<f64> =
                    (0..e).map(|_| rng.range_f64(0.0, 50.0)).collect();
                let counts: Vec<u32> =
                    (0..e).map(|_| rng.below(200) as u32).collect();
                let step = rng.below(1000) as u64;
                (load, counts, g, step)
            },
            |(load, counts, g, step)| {
                let p = ExpertPlacement::replicated(load, *g, 6, 3);
                let mut per_device = vec![0u32; *g];
                p.device_counts(counts, *step, &mut per_device);
                let total: u64 =
                    counts.iter().map(|&c| c as u64).sum();
                let placed: u64 =
                    per_device.iter().map(|&c| c as u64).sum();
                if total != placed {
                    return Err(format!(
                        "placed {placed} of {total} tokens"
                    ));
                }
                Ok(())
            },
        );
    }

    /// Hand-computed migration: round-robin [e0,e2→d0; e1,e3→d1] to
    /// the LPT plan for loads [10,1,1,1] ([e0→d0; e1,e2,e3→d1]) moves
    /// exactly one expert (e2 gains host d1).
    #[test]
    fn migration_counts_only_new_hosts() {
        let load = [10.0, 1.0, 1.0, 1.0];
        let rr = ExpertPlacement::round_robin(4, 2);
        let lpt = ExpertPlacement::load_aware(&load, 2);
        assert_eq!(migration_bytes(&rr, &lpt, 1000), 1000);
        // identical placements move nothing; direction matters
        assert_eq!(migration_bytes(&lpt, &lpt, 1000), 0);
        assert_eq!(migration_bytes(&lpt, &rr, 1000), 1000);
        // replication adds one more host (e0 gains d1) on top of e2
        let rep = ExpertPlacement::replicated(&load, 2, 1, 2);
        assert_eq!(migration_bytes(&rr, &rep, 1000), 2000);
        // dropping a replica is free
        assert_eq!(migration_bytes(&rep, &lpt, 1000), 0);
    }
}
