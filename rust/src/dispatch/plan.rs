//! Compiled dispatch plans: a routed batch compiled into the
//! capacity-binned, expert-grouped layout that real expert-parallel
//! kernels consume (the grouped-GEMM layout), with the overflow policy
//! applied at plan-build time.
//!
//! A [`DispatchPlan`] is the single source of truth for "what actually
//! runs" after routing: the simulator's latency model, the drop
//! accounting, and the real expert FFN compute all read the *same*
//! post-policy per-expert counts, so they agree by construction.
//!
//! Layout (mirrors the scatter/gather buffers of fused MoE dispatch
//! kernels):
//!
//! - `counts[e]`   — post-policy tokens assigned to expert `e`
//!                   (every entry ≤ `capacity`);
//! - `offsets`     — exclusive prefix sum of `counts` (`[E+1]`), so
//!                   expert `e`'s rows live at `offsets[e]..offsets[e+1]`
//!                   of the grouped buffers;
//! - `src[pos]`    — flat `(token·k + slot)` source index of grouped row
//!                   `pos` (the gather permutation; stable in token
//!                   order within each expert bucket);
//! - `pos_of[f]` / `expert_of[f]` — the inverse maps per routed slot
//!                   (`DROPPED` when the slot overflowed), which the
//!                   weighted combine walks in fixed token order.
//!
//! # Overflow policies
//!
//! When an expert's capacity bin is full, the [`OverflowPolicy`]
//! decides what happens to the overflowing (token, slot) assignment:
//!
//! - [`OverflowPolicy::Drop`] — discard it (the token falls back to the
//!   residual stream). Exactly the historical `DispatchSim::step`
//!   behavior, pinned by `drop_plan_matches_sim_step_exactly`.
//! - [`OverflowPolicy::NextChoice`] — fall through to the token's next
//!   routed expert (descending score order) that still has spare
//!   capacity; drop only if all remaining choices are full. Post-hoc
//!   plug-and-play rerouting in the spirit of Shahout et al., "From
//!   Score Distributions to Balance". Because the fallback targets are
//!   the token's *own* later choices, a rerouted slot can land on an
//!   expert the token already reaches through another slot; the token
//!   then occupies two rows of that expert's bucket and the combine
//!   weights that expert's output by the summed slot weights — i.e.
//!   the overflowed weight *transfers* to the fallback expert (pinned
//!   by `next_choice_transfers_weight_on_duplicate` in `experts`).
//! - [`OverflowPolicy::LeastLoaded`] — reroute to the expert with the
//!   smallest current bin occupancy among experts with spare capacity
//!   (ties → lower id), after Nguyen et al., "Least-Loaded Expert
//!   Parallelism". Experts already receiving this token (its routed
//!   set or an earlier reroute target) are excluded — duplicating a
//!   (token, expert) row buys no information; if every feasible bin
//!   already serves the token, the slot drops.
//!
//! Both rerouting policies can only *add* tokens to experts that still
//! have spare capacity, so for every expert the post-policy count is
//! ≥ `min(routed_e, capacity)` — i.e. they never drop more than `Drop`
//! on the same batch, per expert, regardless of arrival order (the
//! property test below checks the aggregate on skewed streams).
//!
//! Rerouted slots keep their original combine weight: rerouting is a
//! capacity fallback, not a re-scoring (weights are not renormalized;
//! dropped slots simply contribute nothing to the combine).

use crate::router::RouterBatch;

/// Sentinel in `pos_of` / `expert_of` for slots dropped by the policy.
pub const DROPPED: u32 = u32::MAX;

/// Per-expert token capacity for a step routing `n_assignments`
/// (token, slot) pairs: `ceil(fair_share · cf)`, at least 1. The single
/// shared definition used by plan compilation and `DispatchSim` — the
/// two must never disagree on a bin size.
pub fn capacity_for(
    n_assignments: usize,
    n_experts: usize,
    capacity_factor: f64,
) -> usize {
    let fair = n_assignments as f64 / n_experts as f64;
    (fair * capacity_factor).ceil().max(1.0) as usize
}

/// What to do with a (token, slot) assignment whose expert bin is full.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Drop the assignment (token falls back to the residual stream).
    #[default]
    Drop,
    /// Fall through to the token's next routed expert with spare
    /// capacity (descending score order); drop if none.
    NextChoice,
    /// Reroute to the least-loaded expert with spare capacity that is
    /// not already receiving this token (ties → lower id); drop when
    /// no such expert exists.
    LeastLoaded,
}

/// Error of `OverflowPolicy::from_str`: carries the rejected name and
/// renders the accepted set, so callers print it verbatim instead of
/// hand-assembling the list (`Display` + `std::error::Error`,
/// convertible into [`crate::Error`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParsePolicyError(pub String);

impl std::fmt::Display for ParsePolicyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // render the accepted set from ALL so a new variant can never
        // be missing from the message
        write!(f, "unknown overflow policy '{}' (expected ", self.0)?;
        for (i, p) in OverflowPolicy::ALL.iter().enumerate() {
            if i > 0 {
                write!(f, " | ")?;
            }
            write!(f, "{}", p.name())?;
        }
        write!(f, ")")
    }
}

impl std::error::Error for ParsePolicyError {}

impl std::str::FromStr for OverflowPolicy {
    type Err = ParsePolicyError;

    fn from_str(s: &str) -> Result<OverflowPolicy, ParsePolicyError> {
        OverflowPolicy::parse(s).ok_or_else(|| ParsePolicyError(s.into()))
    }
}

impl OverflowPolicy {
    pub const ALL: [OverflowPolicy; 3] = [
        OverflowPolicy::Drop,
        OverflowPolicy::NextChoice,
        OverflowPolicy::LeastLoaded,
    ];

    pub fn parse(s: &str) -> Option<OverflowPolicy> {
        Some(match s {
            "drop" => OverflowPolicy::Drop,
            "next-choice" | "next" => OverflowPolicy::NextChoice,
            "least-loaded" | "least" => OverflowPolicy::LeastLoaded,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            OverflowPolicy::Drop => "drop",
            OverflowPolicy::NextChoice => "next-choice",
            OverflowPolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// A routed batch compiled into capacity-binned per-expert buckets with
/// the overflow policy already applied. All buffers reuse capacity
/// across `compile` calls (zero steady-state allocation).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DispatchPlan {
    pub n: usize,
    pub top_k: usize,
    pub n_experts: usize,
    pub capacity: usize,
    pub policy: OverflowPolicy,
    /// `[E]` pre-policy routed counts (what the router asked for; the
    /// load-accounting quantity — dropped slots still count here).
    pub routed: Vec<u32>,
    /// `[E]` post-policy computed counts (what the experts actually
    /// run; every entry ≤ `capacity`).
    pub counts: Vec<u32>,
    /// `[E+1]` exclusive prefix sum of `counts`.
    pub offsets: Vec<u32>,
    /// `[kept]` gather permutation: grouped row `pos` reads flat slot
    /// `src[pos]` (token `src[pos] / top_k`).
    pub src: Vec<u32>,
    /// `[N·k]` grouped row of each flat slot, or [`DROPPED`].
    pub pos_of: Vec<u32>,
    /// `[N·k]` final expert of each flat slot, or [`DROPPED`].
    pub expert_of: Vec<u32>,
    pub n_dropped: usize,
    /// Slots kept on a *different* expert than routed (policy fallback).
    pub n_rerouted: usize,
    /// Scatter-pass scratch (deterministic content, so derived
    /// equality is unaffected; kept to stay allocation-free).
    fill: Vec<u32>,
}

impl DispatchPlan {
    pub fn new() -> DispatchPlan {
        DispatchPlan::default()
    }

    /// Tokens that survived the capacity bins (grouped-buffer rows).
    pub fn kept(&self) -> usize {
        self.src.len()
    }

    /// Grouped-buffer row range of expert `e`.
    pub fn expert_rows(&self, e: usize) -> std::ops::Range<usize> {
        self.offsets[e] as usize..self.offsets[e + 1] as usize
    }

    /// Copy `src` into `self`, reusing this plan's existing buffer
    /// capacity — how the persistent pool (`serve::PoolEngine`) hands
    /// each batch's compiled plan back to the caller's
    /// [`crate::router::FullForward`] without fresh allocations once
    /// the buffers are warm. Equivalent to `*self = src.clone()`
    /// (pinned by `copy_from_equals_clone`).
    pub fn copy_from(&mut self, src: &DispatchPlan) {
        self.n = src.n;
        self.top_k = src.top_k;
        self.n_experts = src.n_experts;
        self.capacity = src.capacity;
        self.policy = src.policy;
        self.routed.clone_from(&src.routed);
        self.counts.clone_from(&src.counts);
        self.offsets.clone_from(&src.offsets);
        self.src.clone_from(&src.src);
        self.pos_of.clone_from(&src.pos_of);
        self.expert_of.clone_from(&src.expert_of);
        self.n_dropped = src.n_dropped;
        self.n_rerouted = src.n_rerouted;
        self.fill.clone_from(&src.fill);
    }

    /// Convenience wrapper over [`DispatchPlan::compile`] for a routed
    /// [`RouterBatch`].
    pub fn compile_batch(
        &mut self,
        batch: &RouterBatch,
        capacity: usize,
        policy: OverflowPolicy,
    ) {
        self.compile(
            &batch.topk_idx,
            batch.top_k,
            batch.load.len(),
            capacity,
            policy,
        );
    }

    /// Compile a flat `[N·k]` assignment stream (the `RouterBatch`
    /// id layout — also what `synthetic_assignments` produces) into
    /// capacity-binned buckets under `policy`.
    ///
    /// Deterministic: assignments are resolved in flat (token, slot)
    /// order, exactly the order `DispatchSim::step` historically used
    /// for its greedy drop.
    pub fn compile(
        &mut self,
        assignments: &[u32],
        top_k: usize,
        n_experts: usize,
        capacity: usize,
        policy: OverflowPolicy,
    ) {
        assert!(top_k > 0, "top_k must be >= 1");
        assert!(capacity > 0, "capacity must be >= 1");
        assert_eq!(
            assignments.len() % top_k,
            0,
            "assignments must be [N * {top_k}]"
        );
        let n = assignments.len() / top_k;
        self.n = n;
        self.top_k = top_k;
        self.n_experts = n_experts;
        self.capacity = capacity;
        self.policy = policy;
        self.routed.clear();
        self.routed.resize(n_experts, 0);
        self.counts.clear();
        self.counts.resize(n_experts, 0);
        self.pos_of.clear();
        self.pos_of.resize(assignments.len(), DROPPED);
        self.expert_of.clear();
        self.expert_of.resize(assignments.len(), DROPPED);
        self.n_dropped = 0;
        self.n_rerouted = 0;

        // capacities can exceed u32 range under huge factors; compare
        // in usize and only store the (small) per-bin counts as u32
        let cap = capacity;
        // pass 1: resolve every flat slot to a final expert (or drop)
        for (f, &eid) in assignments.iter().enumerate() {
            let e = eid as usize;
            assert!(e < n_experts, "expert id {e} out of range");
            self.routed[e] += 1;
            let final_e = if (self.counts[e] as usize) < cap {
                Some(e)
            } else {
                match policy {
                    OverflowPolicy::Drop => None,
                    OverflowPolicy::NextChoice => {
                        // the token's remaining choices, in descending
                        // score order (slots after this one)
                        let (r, j) = (f / top_k, f % top_k);
                        (j + 1..top_k)
                            .map(|jj| {
                                assignments[r * top_k + jj] as usize
                            })
                            .find(|&c| (self.counts[c] as usize) < cap)
                    }
                    OverflowPolicy::LeastLoaded => {
                        // experts already receiving this token (its
                        // routed set + earlier reroute targets) are
                        // excluded: a duplicate row would double-
                        // compute the same (token, expert) pair for
                        // zero information. O(E·k) argmin; at
                        // serving-scale E (≤ 512) this beats
                        // maintaining a heap across reroutes.
                        let r = f / top_k;
                        let row =
                            &assignments[r * top_k..(r + 1) * top_k];
                        let placed = &self.expert_of
                            [r * top_k..r * top_k + f % top_k];
                        self.counts
                            .iter()
                            .enumerate()
                            .filter(|&(i, &c)| {
                                (c as usize) < cap
                                    && !row.contains(&(i as u32))
                                    && !placed.contains(&(i as u32))
                            })
                            .min_by_key(|&(i, &c)| (c, i))
                            .map(|(i, _)| i)
                    }
                }
            };
            match final_e {
                Some(fe) => {
                    if fe != e {
                        self.n_rerouted += 1;
                    }
                    self.counts[fe] += 1;
                    self.expert_of[f] = fe as u32;
                }
                None => self.n_dropped += 1,
            }
        }

        // pass 2: exclusive prefix sum -> per-expert bucket offsets
        self.offsets.clear();
        self.offsets.reserve(n_experts + 1);
        let mut acc = 0u32;
        self.offsets.push(0);
        for &c in &self.counts {
            acc += c;
            self.offsets.push(acc);
        }

        // pass 3: stable scatter into the grouped layout
        self.src.clear();
        self.src.resize(acc as usize, 0);
        self.fill.clear();
        self.fill.extend_from_slice(&self.offsets[..n_experts]);
        for f in 0..self.pos_of.len() {
            let fe = self.expert_of[f];
            if fe == DROPPED {
                continue;
            }
            let pos = self.fill[fe as usize];
            self.src[pos as usize] = f as u32;
            self.pos_of[f] = pos;
            self.fill[fe as usize] = pos + 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureStream;
    use crate::dispatch::synthetic_assignments;
    use crate::router::{synthetic_lpr_router, ServingEngine};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn capacity_matches_fair_share() {
        assert_eq!(capacity_for(80, 8, 1.5), 15); // 80/8 * 1.5
        assert_eq!(capacity_for(0, 8, 1.0), 1); // floor of 1
        assert_eq!(capacity_for(7, 8, 1.0), 1); // ceil(0.875)
    }

    #[test]
    fn policy_parse_roundtrips() {
        for p in OverflowPolicy::ALL {
            assert_eq!(OverflowPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(
            OverflowPolicy::parse("next"),
            Some(OverflowPolicy::NextChoice)
        );
        assert_eq!(
            OverflowPolicy::parse("least"),
            Some(OverflowPolicy::LeastLoaded)
        );
        assert_eq!(OverflowPolicy::parse("nope"), None);
    }

    /// Hand-checkable example: 3 tokens, top-2, 2 experts, capacity 2.
    /// Expert 0 is every token's first choice.
    #[test]
    fn known_case_all_policies() {
        // tokens: (0,1), (0,1), (0,1)
        let a: Vec<u32> = vec![0, 1, 0, 1, 0, 1];
        let mut p = DispatchPlan::new();

        p.compile(&a, 2, 2, 2, OverflowPolicy::Drop);
        assert_eq!(p.routed, vec![3, 3]);
        assert_eq!(p.counts, vec![2, 2]);
        // token 2's slot (0) and slot (1) overflow: 2 drops
        assert_eq!(p.n_dropped, 2);
        assert_eq!(p.n_rerouted, 0);
        assert_eq!(p.offsets, vec![0, 2, 4]);
        // expert 0 bucket: flat slots 0 and 2 (tokens 0, 1 / slot 0)
        assert_eq!(&p.src[0..2], &[0, 2]);
        assert_eq!(p.pos_of[4], DROPPED);
        assert_eq!(p.expert_of[5], DROPPED);

        // NextChoice: token 2 slot-0 falls through to expert 1 — but
        // expert 1 is already full by then (slots 1 and 3), so it drops
        // too; same totals here.
        p.compile(&a, 2, 2, 2, OverflowPolicy::NextChoice);
        assert_eq!(p.counts, vec![2, 2]);
        assert_eq!(p.n_dropped, 2);

        // with capacity 3 nothing drops under any policy
        for policy in OverflowPolicy::ALL {
            p.compile(&a, 2, 2, 3, policy);
            assert_eq!(p.n_dropped, 0, "{}", policy.name());
            assert_eq!(p.counts, vec![3, 3]);
        }
    }

    #[test]
    fn next_choice_reroutes_to_spare_capacity() {
        // 3 experts, cap 1. Token 0 routed (0, 2); token 1 routed
        // (0, 1): its slot 0 overflows expert 0 and falls through to
        // its next choice, expert 1, which has a spare slot. Token 1's
        // own slot 1 then finds expert 1 full and has no later choice.
        let a: Vec<u32> = vec![0, 2, 0, 1];
        let mut p = DispatchPlan::new();
        p.compile(&a, 2, 3, 1, OverflowPolicy::NextChoice);
        assert_eq!(p.counts, vec![1, 1, 1]);
        assert_eq!(p.n_rerouted, 1);
        assert_eq!(p.expert_of, vec![0, 2, 1, DROPPED]);
        assert_eq!(p.n_dropped, 1);
    }

    #[test]
    fn least_loaded_picks_emptiest_bin() {
        // 3 experts, cap 2. Flat stream hammers expert 0; expert 2
        // starts emptier than expert 1 so reroutes go there first.
        let a: Vec<u32> = vec![0, 0, 1, 0, 0, 0];
        let mut p = DispatchPlan::new();
        p.compile(&a, 1, 3, 2, OverflowPolicy::LeastLoaded);
        // slots in order: e0 kept, e0 kept, e1 kept; then e0 is full —
        // reroute to e2 (count 0 < e1's 1); e0 full — counts tie at 1,
        // lower id wins -> e1; e0 full — only e2 has room -> e2.
        assert_eq!(p.expert_of, vec![0, 0, 1, 2, 1, 2]);
        assert_eq!(p.counts, vec![2, 2, 2]);
        assert_eq!(p.n_dropped, 0);
        assert_eq!(p.n_rerouted, 3);
    }

    #[test]
    fn least_loaded_skips_experts_already_serving_token() {
        // 3 experts, cap 2, top-2. Tokens (0,1), (0,1), (0,2): the
        // third token's slot 0 overflows expert 0 and the emptiest
        // feasible bin is expert 2 — but that token already routes to
        // expert 2 through its own slot 1, so a reroute there would
        // only duplicate the (token, expert) row. It must drop
        // instead, and slot 1 still reaches expert 2 exactly once.
        let a: Vec<u32> = vec![0, 1, 0, 1, 0, 2];
        let mut p = DispatchPlan::new();
        p.compile(&a, 2, 3, 2, OverflowPolicy::LeastLoaded);
        assert_eq!(p.expert_of, vec![0, 1, 0, 1, DROPPED, 2]);
        assert_eq!(p.counts, vec![2, 2, 1]);
        assert_eq!(p.n_dropped, 1);
        assert_eq!(p.n_rerouted, 0);
        // and in general: no token ever occupies two rows of the same
        // expert under least-loaded
        let mut rng = Rng::new(53);
        let big = synthetic_assignments(&mut rng, 256, 4, 16, 1.5);
        p.compile(&big, 4, 16, 16, OverflowPolicy::LeastLoaded);
        for t in 0..256 {
            let mut finals: Vec<u32> = p.expert_of
                [t * 4..(t + 1) * 4]
                .iter()
                .cloned()
                .filter(|&x| x != DROPPED)
                .collect();
            finals.sort();
            let before = finals.len();
            finals.dedup();
            assert_eq!(finals.len(), before, "token {t} duplicated");
        }
    }

    #[test]
    fn copy_from_equals_clone() {
        let mut rng = Rng::new(71);
        let a = synthetic_assignments(&mut rng, 64, 3, 8, 1.1);
        let mut src = DispatchPlan::new();
        src.compile(&a, 3, 8, 5, OverflowPolicy::NextChoice);
        let mut dst = DispatchPlan::new();
        // warm dst with a different shape first: copy_from must fully
        // overwrite stale state
        let b = synthetic_assignments(&mut rng, 16, 2, 4, 0.0);
        dst.compile(&b, 2, 4, 9, OverflowPolicy::Drop);
        dst.copy_from(&src);
        assert_eq!(dst, src);
        assert_eq!(dst, src.clone());
    }

    #[test]
    fn grouped_layout_is_consistent() {
        let mut rng = Rng::new(41);
        let a = synthetic_assignments(&mut rng, 128, 4, 16, 1.2);
        let mut p = DispatchPlan::new();
        for policy in OverflowPolicy::ALL {
            p.compile(&a, 4, 16, 10, policy);
            // offsets are the prefix sum of counts
            for e in 0..16 {
                assert_eq!(
                    p.offsets[e + 1] - p.offsets[e],
                    p.counts[e],
                    "{}",
                    policy.name()
                );
                assert!(p.counts[e] as usize <= p.capacity);
            }
            assert_eq!(p.kept(), p.offsets[16] as usize);
            // src/pos_of are mutually inverse permutations
            for (pos, &f) in p.src.iter().enumerate() {
                assert_eq!(p.pos_of[f as usize] as usize, pos);
                let e = p.expert_of[f as usize] as usize;
                assert!(p.expert_rows(e).contains(&pos));
            }
            // every slot is either placed or dropped, never both
            let placed =
                p.pos_of.iter().filter(|&&x| x != DROPPED).count();
            assert_eq!(placed, p.kept());
            assert_eq!(p.kept() + p.n_dropped, a.len());
            // pre-policy routed counts always conserve the stream
            assert_eq!(
                p.routed.iter().map(|&x| x as usize).sum::<usize>(),
                a.len()
            );
        }
    }

    /// Satellite: token conservation across all three policies on
    /// engine-routed mixture streams of varying skew, plus the policy
    /// ordering guarantee (rerouting never drops more than Drop).
    #[test]
    fn policies_conserve_tokens_and_order_drops() {
        forall(
            12,
            2026,
            |rng| {
                let (d, dz, e, k) = (16usize, 8usize, 16usize, 4usize);
                let r = synthetic_lpr_router("cosine", rng, d, dz, e, k);
                let mut eng = ServingEngine::new(r.plan().clone(), 1);
                // sweep the cluster skew: zipf_s in [0, 2)
                let s = rng.range_f64(0.0, 2.0);
                let mix = MixtureStream::new(rng, d, 8, s, 0.4);
                let mut h = Vec::new();
                mix.fill(rng, 96, &mut h);
                let batch = eng.route(&h);
                let cf = if rng.below(2) == 0 { 1.0 } else { 1.25 };
                (batch, cf, s)
            },
            |(batch, cf, s)| {
                let e = batch.load.len();
                let cap = capacity_for(batch.topk_idx.len(), e, *cf);
                let mut drops = Vec::new();
                for policy in OverflowPolicy::ALL {
                    let mut p = DispatchPlan::new();
                    p.compile_batch(batch, cap, policy);
                    let computed: usize =
                        p.counts.iter().map(|&c| c as usize).sum();
                    // routed = computed + dropped
                    if computed + p.n_dropped != batch.topk_idx.len() {
                        return Err(format!(
                            "{} (skew {s:.2}): {} computed + {} \
                             dropped != {} routed",
                            policy.name(),
                            computed,
                            p.n_dropped,
                            batch.topk_idx.len()
                        ));
                    }
                    if p.counts.iter().any(|&c| c as usize > cap) {
                        return Err(format!(
                            "{}: capacity violated",
                            policy.name()
                        ));
                    }
                    drops.push(p.n_dropped);
                }
                // rerouting policies drop no more than greedy Drop
                if drops[1] > drops[0] || drops[2] > drops[0] {
                    return Err(format!(
                        "skew {s:.2} cf {cf}: drops {drops:?} not \
                         ordered (Drop must be the worst)"
                    ));
                }
                Ok(())
            },
        );
    }

    /// Acceptance: at capacity factor 1.0 on a skewed stream, both
    /// rerouting policies *strictly* reduce drops vs greedy Drop.
    #[test]
    fn rerouting_strictly_beats_drop_on_skewed_stream() {
        let mut rng = Rng::new(23);
        let (d, dz, e, k) = (32usize, 16usize, 32usize, 4usize);
        let r = synthetic_lpr_router("cosine", &mut rng, d, dz, e, k);
        let mut eng = ServingEngine::new(r.plan().clone(), 1);
        let mix = MixtureStream::skewed(&mut rng, d, 1.6);
        let mut h = Vec::new();
        mix.fill(&mut rng, 1024, &mut h);
        let batch = eng.route(&h);
        let cap = capacity_for(batch.topk_idx.len(), e, 1.0);
        let drop_of = |policy| {
            let mut p = DispatchPlan::new();
            p.compile_batch(&batch, cap, policy);
            (p.n_dropped, p.n_rerouted)
        };
        let (base, _) = drop_of(OverflowPolicy::Drop);
        let (next, next_rr) = drop_of(OverflowPolicy::NextChoice);
        let (least, least_rr) = drop_of(OverflowPolicy::LeastLoaded);
        assert!(base > 0, "skewed stream at cf=1.0 must overflow");
        assert!(next < base, "next-choice {next} !< drop {base}");
        assert!(least < base, "least-loaded {least} !< drop {base}");
        assert!(next_rr > 0 && least_rr > 0);
        // least-loaded vs next-choice has no guaranteed ordering (their
        // fallback sets differ); only the beat-Drop bound is pinned.
    }
}
